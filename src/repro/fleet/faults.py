"""Deterministic fault injection for the serving/fleet stack.

Fault tolerance that cannot be rehearsed is folklore. This module makes
every failure mode the recovery layer handles *replayable*: a seeded
:class:`FaultInjector` wraps any :class:`~repro.core.engine.InferenceEngine`
in a :class:`FaultyEngine` proxy that can, on a deterministic schedule,

  * raise :class:`InjectedFault` from the engine step (``infer`` /
    ``infer_collect``) or from host packing (``prepare``) -- exercising
    the engine's bounded retry and lane-death paths,
  * poison one occupied slot's logits with NaN -- exercising the
    non-finite quarantine path,
  * stall a call for ``stall_ms`` wall milliseconds -- a straggler, not
    a failure (the engine is oblivious; only wall-clock metrics move).

Determinism contract: the injector draws from one
``np.random.default_rng(seed)`` in strict call order -- a fixed number
of draws per decision point regardless of which fault (if any) fires --
so the same seed against the same call sequence replays the same fault
schedule bit-for-bit. Scripted faults (:meth:`FaultInjector.fail_next`,
:meth:`FaultInjector.kill`) consume no randomness and take precedence
over the rates, so tests can pin "the next frame collect fails" exactly.

The proxy is transparent to the engine protocol: attribute reads and
writes delegate to the wrapped engine (``duration_us`` latching included)
and the async split (``infer_dispatch``/``infer_collect``) is exposed
only when the inner engine has it, so ``StreamEngine``'s
``getattr(engine, "infer_dispatch", None)`` capability probe is
preserved.

Typical wiring::

    inj = FaultInjector(FaultConfig(seed=7, step_error_rate=0.05))
    eng = StreamEngine(engines=[inj.wrap(event_engine)],
                       config=EngineConfig(recovery=RecoveryConfig()))
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core._api import FaultConfig

__all__ = ["FaultConfig", "FaultInjector", "FaultyEngine",
           "InjectedFault", "LaneStall"]

_KINDS = ("error", "nan", "stall")


class InjectedFault(RuntimeError):
    """Raised by a :class:`FaultyEngine` when an error fault fires."""


class LaneStall(InjectedFault):
    """An injected stall escalated to a failure (scripted use only)."""


class FaultInjector:
    """Seeded source of fault decisions shared by all wrapped engines.

    ``counters`` tracks what actually fired: ``calls`` (decision
    points), ``errors``, ``nans``, ``stalls``, ``scripted``.
    """

    def __init__(self, config: Optional[FaultConfig] = None):
        if config is None:
            config = FaultConfig()
        if not isinstance(config, FaultConfig):
            raise TypeError(
                f"config must be a FaultConfig, got "
                f"{type(config).__name__}")
        self.config = config
        self._rng = np.random.default_rng(config.seed)
        self._scripted: Deque[Tuple[Optional[str], str, str]] = deque()
        self._killed: set = set()
        self.counters: Dict[str, int] = {
            "calls": 0, "errors": 0, "nans": 0, "stalls": 0,
            "scripted": 0}

    # -- scripted faults (deterministic, no randomness consumed) ---------

    def fail_next(self, modality: Optional[str] = None, *,
                  kind: str = "error", count: int = 1,
                  site: str = "step") -> None:
        """Queue ``count`` scripted faults of ``kind`` for the next
        matching decision points (``modality=None`` matches any lane).
        ``site="step"`` fires at the engine step (``infer`` for sync
        engines, ``infer_collect`` for split engines); ``site="prepare"``
        fires at host packing (``kind`` must be ``"error"`` there)."""
        if kind not in _KINDS:
            raise ValueError(f"kind must be one of {_KINDS}, got {kind!r}")
        if site not in ("step", "prepare"):
            raise ValueError(
                f"site must be 'step' or 'prepare', got {site!r}")
        if site == "prepare" and kind != "error":
            raise ValueError("host packing faults are errors only")
        if count < 1:
            raise ValueError(f"count must be >= 1, got {count}")
        for _ in range(count):
            self._scripted.append((modality, kind, site))

    def kill(self, modality: str) -> None:
        """Every engine call on ``modality`` raises until :meth:`revive`
        -- drives the lane's fail streak past ``dead_after``."""
        self._killed.add(modality)

    def revive(self, modality: str) -> None:
        self._killed.discard(modality)

    def killed(self, modality: str) -> bool:
        return modality in self._killed

    # -- engine wiring ---------------------------------------------------

    def wrap(self, engine: Any) -> "FaultyEngine":
        """Wrap ``engine`` in a fault-injecting proxy bound to this
        injector's seed, schedule, and counters."""
        return FaultyEngine(engine, self)

    # -- decision machinery ----------------------------------------------

    def _pop_scripted(self, modality: str,
                      site: str = "step") -> Optional[str]:
        for i, (mod, kind, at) in enumerate(self._scripted):
            if at == site and (mod is None or mod == modality):
                del self._scripted[i]
                self.counters["scripted"] += 1
                return kind
        return None

    def _decide(self, modality: str) -> Optional[str]:
        """One decision point. Raises :class:`InjectedFault` for error
        faults; returns ``"nan"``/``"stall"``/``None`` otherwise. Always
        draws exactly three uniforms when rates apply, so the stream of
        randomness is a pure function of the call sequence."""
        cfg = self.config
        if cfg.modalities is not None and modality not in cfg.modalities:
            return None
        self.counters["calls"] += 1
        if modality in self._killed:
            self.counters["errors"] += 1
            raise InjectedFault(f"injected: {modality} lane killed")
        action = self._pop_scripted(modality)
        if action is None:
            draws = self._rng.random(3)
            if draws[0] < cfg.step_error_rate:
                action = "error"
            elif draws[1] < cfg.nan_rate:
                action = "nan"
            elif draws[2] < cfg.stall_rate:
                action = "stall"
        if action == "error":
            self.counters["errors"] += 1
            raise InjectedFault(f"injected: {modality} step error")
        return action

    def _apply_stall(self) -> None:
        self.counters["stalls"] += 1
        if self.config.stall_ms > 0:
            time.sleep(self.config.stall_ms / 1e3)

    def _poison(self, results: Sequence[Any]) -> List[Any]:
        """Replace one occupied slot's logits with NaN (rng-chosen among
        occupied slots; one extra draw, only when a nan fault fired)."""
        occ = [i for i, r in enumerate(results)
               if r is not None and getattr(r, "logits", None) is not None]
        if not occ:
            return list(results)
        slot = occ[int(self._rng.integers(len(occ)))]
        out = list(results)
        res = out[slot]
        logits = np.asarray(res.logits)
        out[slot] = dataclasses.replace(
            res, logits=np.full(logits.shape, np.nan, dtype=logits.dtype))
        self.counters["nans"] += 1
        return out


class FaultyEngine:
    """Transparent engine proxy that routes calls through a
    :class:`FaultInjector`. All attributes delegate to the inner engine;
    only the call sites below are intercepted."""

    def __init__(self, inner: Any, injector: FaultInjector):
        object.__setattr__(self, "_inner", inner)
        object.__setattr__(self, "_injector", injector)
        # Expose the async split only when the inner engine has it, so
        # the StreamEngine capability probe sees the true surface.
        if (getattr(inner, "infer_dispatch", None) is not None
                and getattr(inner, "infer_collect", None) is not None):
            object.__setattr__(self, "infer_dispatch", self._infer_dispatch)
            object.__setattr__(self, "infer_collect", self._infer_collect)

    # -- transparent delegation -----------------------------------------

    def __getattr__(self, name: str) -> Any:
        return getattr(object.__getattribute__(self, "_inner"), name)

    def __setattr__(self, name: str, value: Any) -> None:
        setattr(object.__getattribute__(self, "_inner"), name, value)

    @property
    def inner(self) -> Any:
        return object.__getattribute__(self, "_inner")

    # -- intercepted call sites -----------------------------------------

    def prepare(self, items, **kw):
        inj: FaultInjector = object.__getattribute__(self, "_injector")
        # Host packing only honors scripted faults: random rates target
        # the device step, keeping the per-step draw count at one
        # decision point for either execution mode (sync or split).
        if inj._pop_scripted(self.inner.modality, "prepare") is not None:
            inj.counters["errors"] += 1
            raise InjectedFault(
                f"injected: {self.inner.modality} host packing error")
        return self.inner.prepare(items, **kw)

    def infer(self, batch, state=None):
        inj: FaultInjector = object.__getattribute__(self, "_injector")
        inner = self.inner
        action = inj._decide(inner.modality)
        if action == "stall":
            inj._apply_stall()
        if state is None:
            results = inner.infer(batch)
            if action == "nan":
                results = inj._poison(results)
            return results
        results, new_state = inner.infer(batch, state)
        if action == "nan":
            results = inj._poison(results)
        return results, new_state

    def _infer_dispatch(self, batch, state=None):
        # Dispatch is fault-free by design: the decision point for a
        # split engine sits at collect, where the engine's recovery
        # layer can retry without having advanced any carry.
        inner = self.inner
        if state is None:
            return inner.infer_dispatch(batch)
        return inner.infer_dispatch(batch, state)

    def _infer_collect(self, pending):
        inj: FaultInjector = object.__getattribute__(self, "_injector")
        inner = self.inner
        action = inj._decide(inner.modality)
        if action == "stall":
            inj._apply_stall()
        results = inner.infer_collect(pending)
        if action == "nan":
            results = inj._poison(results)
        return results
