"""A checkpoint store with single-use restore semantics.

The rebalancer moves streams between engines through checkpoints; the
store is the hand-off point. Two properties matter and both are
enforced here rather than hoped for:

  * **Host-serializable or rejected at put.** ``put`` pickles the
    checkpoint to bytes immediately, so a checkpoint that secretly
    holds device buffers (or anything else unpicklable) fails at the
    source engine, not later on whatever machine tries to restore it.
    ``get`` unpickles a *fresh copy* every time -- mutating a restored
    checkpoint can never corrupt the stored blob.
  * **Single-use restore.** A stream must live on exactly one engine;
    replaying the same checkpoint into two engines would fork it (two
    streams claiming the same identity and sequence numbers). The store
    remembers consumed ids and rejects a second restore of the same
    checkpoint outright.

The store is in-process (a dict of pickled blobs). That is deliberate:
the serialization boundary is the contract, and a durable backend
(file, object store) only has to replace ``_blobs``.

``capacity`` bounds the store: auto-checkpointing
(:class:`~repro.fleet.supervisor.LaneSupervisor` puts a fresh blob per
watched stream every K ticks) must not grow it without bound, so a full
store evicts its least-recently-used blob at ``put``. Every eviction
drops an un-restored checkpoint -- consumed blobs are already gone --
and is counted in ``stats["evicted"]``; a supervisor that later needs
an evicted blob fails loudly, so size ``capacity`` to at least the
watched-stream count.
"""
from __future__ import annotations

import pickle
from collections import OrderedDict
from typing import Dict, Hashable, List, Optional

__all__ = ["CheckpointStore"]


class CheckpointStore:
    """Pickled :class:`~repro.serving.session.StreamCheckpoint` blobs
    keyed by checkpoint id, with consumed-id tracking and an optional
    LRU capacity bound."""

    def __init__(self, capacity: Optional[int] = None):
        if capacity is not None and capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._blobs: "OrderedDict[str, bytes]" = OrderedDict()
        self._consumed: set = set()
        self._count = 0
        self.stats: Dict[str, int] = {"evicted": 0}

    def __len__(self) -> int:
        return len(self._blobs)

    def __contains__(self, ckpt_id: str) -> bool:
        return ckpt_id in self._blobs

    def ids(self) -> List[str]:
        """Stored (not-yet-consumed) checkpoint ids, insertion order."""
        return list(self._blobs)

    def put(self, ckpt, ckpt_id: Optional[str] = None) -> str:
        """Serialize ``ckpt`` into the store; returns its id.

        Pickling happens here, so an unserializable checkpoint fails at
        put time. Ids are never reused: an explicit ``ckpt_id`` that was
        already stored OR already consumed is rejected (reuse would
        silently defeat the double-restore guard).
        """
        if ckpt_id is None:
            self._count += 1
            ckpt_id = f"ckpt-{self._count}"
        if ckpt_id in self._blobs or ckpt_id in self._consumed:
            raise ValueError(f"checkpoint id {ckpt_id!r} already used")
        self._blobs[ckpt_id] = pickle.dumps(ckpt)
        if self.capacity is not None:
            while len(self._blobs) > self.capacity:
                # LRU victim: least recently put/get blob. It was never
                # restored (consumed blobs are already gone), so the
                # eviction is recorded -- the signal a supervisor sizing
                # its store too small will eventually trip over.
                self._blobs.popitem(last=False)
                self.stats["evicted"] += 1
        return ckpt_id

    def get(self, ckpt_id: str):
        """A fresh deserialized copy of the stored checkpoint (the blob
        stays in the store until ``consume`` or ``delete``)."""
        if ckpt_id in self._consumed:
            raise ValueError(
                f"checkpoint {ckpt_id!r} was already restored once; "
                "checkpoints are single-use (a second restore would fork "
                "the stream)")
        if ckpt_id not in self._blobs:
            raise KeyError(f"no checkpoint {ckpt_id!r} in store")
        self._blobs.move_to_end(ckpt_id)
        return pickle.loads(self._blobs[ckpt_id])

    def delete(self, ckpt_id: str) -> bool:
        """Drop a stored blob without consuming its id (the stream was
        not migrated -- e.g. a periodic backup superseded by a newer
        one). Returns whether anything was deleted."""
        return self._blobs.pop(ckpt_id, None) is not None

    def consume(self, ckpt_id: str) -> None:
        """Mark ``ckpt_id`` restored: the blob is dropped and the id is
        permanently rejected by ``get``/``put``. Called by
        ``restore_into`` after a restore lands; call it directly when
        composing a restore by hand (e.g. through a ``FusionSession``)."""
        if ckpt_id not in self._blobs:
            raise KeyError(f"no checkpoint {ckpt_id!r} in store")
        del self._blobs[ckpt_id]
        self._consumed.add(ckpt_id)

    def restore_into(self, engine, ckpt_id: str, *,
                     stream_id: Optional[Hashable] = None):
        """Open a matching stream on ``engine`` and replay the stored
        checkpoint into it; returns the new
        :class:`~repro.serving.stream.StreamHandle`.

        The id is consumed only after the restore lands, so a failed
        restore (modality mismatch, duration conflict, rejected window)
        leaves the checkpoint in the store and the engine untouched.
        """
        ckpt = self.get(ckpt_id)
        handle = engine.open(
            ckpt.modality,
            stream_id=ckpt.stream_id if stream_id is None else stream_id,
            stateful=ckpt.stateful, deadline=ckpt.deadline)
        try:
            handle.restore(ckpt)
        except Exception:
            handle.close()
            raise
        self.consume(ckpt_id)
        return handle
