"""Load rebalancing across engine instances, with hysteresis.

A fleet serves many engines (processes, hosts, meshes); arrival skew
makes some hot -- deep queues, missed deadlines -- while others idle.
:class:`FleetRebalancer` equalizes them using the primitives the rest
of this package built: each ``observe()`` tick snapshots every engine's
:class:`~repro.serving.stream.LaneTelemetry`, scores load, and (when
the hottest-coldest gap justifies the cost) live-migrates one stream
hot-to-cold through the :class:`~repro.fleet.store.CheckpointStore`.

The load score is deliberately simple and dimensionless::

    score = queued_windows / slots + miss_weight * deadline_miss_rate
            + fault_weight * fault_rate          (+ fault_weight if dead)

Backlog per slot measures *pressure* (how far behind the lane is per
unit of capacity); the sliding-horizon miss rate measures *harm*
(deadlines actually slipping, the thing the paper's closed-loop latency
story cares about); ``miss_weight`` converts harm into pressure units.
The fault terms make unhealthy lanes score hot: ``fault_rate`` is the
lane's retries+quarantines per window attempt, and a dead lane takes a
flat ``fault_weight`` penalty on top -- so the rebalancer drains load
AWAY from a degrading engine before its streams start failing. A dead
lane is additionally never chosen as a migration *target*, and a dead
hot lane is left for the :class:`~repro.fleet.supervisor.LaneSupervisor`
(migrating off it needs recovery, not a drain).

Anti-thrash, twice over: the ``imbalance`` dead-band means small gaps
are never acted on (a migration costs a lane drain and a restore), and
after every move the rebalancer sits out ``cooldown`` ticks so the
moved load shows up in both engines' sliding-horizon telemetry before
the next decision. One migration per tick, always the hottest engine's
deepest-queued stream to the coldest engine.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, Tuple

from repro.core._api import FleetConfig
from repro.fleet.migrate import migrate_stream
from repro.fleet.store import CheckpointStore

__all__ = ["FleetRebalancer", "RebalanceReport", "load_score"]


def load_score(telemetry, config: FleetConfig) -> float:
    """One lane's scalar load: backlog pressure + weighted miss harm
    + weighted fault churn (+ a flat penalty for a dead lane)."""
    score = (telemetry.backlog_per_slot
             + config.miss_weight * telemetry.deadline_miss_rate
             + config.fault_weight * getattr(telemetry, "fault_rate", 0.0))
    if getattr(telemetry, "dead", False):
        score += config.fault_weight
    return score


@dataclasses.dataclass(frozen=True)
class RebalanceReport:
    """One ``observe()`` tick's outcome. ``displaced`` results were
    collected early by the migration's lane drain; the driver routes
    them like ``step()`` output."""

    moved: Tuple                     # MigrationRecord rows (0 or 1)
    displaced: Tuple                 # StreamResult rows from the drain
    loads: Dict[str, float]          # engine id -> score this tick
    reason: str

    @property
    def migrated(self) -> bool:
        return bool(self.moved)


class FleetRebalancer:
    """Watch a fleet of engines; migrate streams hot-to-cold.

    ``engines`` maps an engine id (any display name) to a
    ``StreamEngine``. All engines must serve the watched modality
    (``modality=None`` works for single-lane engines, like every other
    lane-addressed surface). The rebalancer owns nothing: engines keep
    serving between ticks, and every decision goes through the public
    telemetry/migration surfaces.
    """

    def __init__(self, engines: Mapping[str, object], *,
                 store: Optional[CheckpointStore] = None,
                 config: Optional[FleetConfig] = None,
                 modality: Optional[str] = None):
        if len(engines) < 2:
            raise ValueError(
                f"rebalancing needs >= 2 engines, got {len(engines)}")
        self.engines = dict(engines)
        self.store = store if store is not None else CheckpointStore()
        self.config = config if config is not None else FleetConfig()
        self.modality = modality
        self._cooldown = 0
        self.migrations = []         # every MigrationRecord, in order

    def loads(self) -> Dict[str, float]:
        """Current per-engine load scores (one telemetry snapshot each)."""
        return {eid: load_score(e.telemetry(self.modality), self.config)
                for eid, e in self.engines.items()}

    def observe(self) -> RebalanceReport:
        """One control tick: score, compare, maybe migrate one stream."""
        tels = {eid: e.telemetry(self.modality)
                for eid, e in self.engines.items()}
        scores = {eid: load_score(t, self.config)
                  for eid, t in tels.items()}
        if self._cooldown > 0:
            self._cooldown -= 1
            return RebalanceReport(
                (), (), scores,
                f"cooldown ({self._cooldown + 1} ticks left)")
        hot_id = max(scores, key=scores.__getitem__)
        # A dead lane cannot accept a restore (its engine raises), so it
        # is never a migration target -- even when it scores coldest.
        alive = [eid for eid, t in tels.items()
                 if not getattr(t, "dead", False)]
        if not alive:
            return RebalanceReport(
                (), (), scores, "every lane is dead (supervisor's job)")
        cold_id = min(alive, key=scores.__getitem__)
        gap = scores[hot_id] - scores[cold_id]
        if hot_id == cold_id or gap <= self.config.imbalance:
            return RebalanceReport(
                (), (), scores,
                f"balanced (gap {gap:.2f} <= "
                f"dead-band {self.config.imbalance})")
        if getattr(tels[hot_id], "dead", False):
            # Draining a dead lane needs recovery (abort + rebuild),
            # which is the LaneSupervisor's move, not a live migration.
            return RebalanceReport(
                (), (), scores,
                f"hot lane {hot_id} is dead (supervisor's job)")
        hot = self.engines[hot_id]
        cold = self.engines[cold_id]
        telemetry = tels[hot_id]
        # The victim: the hot engine's deepest queue moves the most
        # pressure per migration. Skip streams with nothing queued
        # (moving them changes no score) and ids already open on the
        # target (restore demands a fresh stream).
        for sid, snap in sorted(telemetry.streams.items(),
                                key=lambda kv: kv[1].queued, reverse=True):
            if snap.queued <= 0 or cold.has_stream(sid):
                continue
            record = migrate_stream(hot.handle(sid), cold,
                                    store=self.store)
            self.migrations.append(record)
            self._cooldown = self.config.cooldown
            return RebalanceReport(
                (record,), record.displaced, scores,
                f"moved {sid!r}: {hot_id} ({scores[hot_id]:.2f}) -> "
                f"{cold_id} ({scores[cold_id]:.2f})")
        return RebalanceReport(
            (), (), scores,
            f"no migratable stream on {hot_id} (gap {gap:.2f})")
