"""Supervised lane recovery: auto-checkpoint, rebuild, restore, replay.

The engine-level recovery layer (``EngineConfig.recovery``) keeps a
failing lane *contained* -- retries, quarantines, fail-fast on a dead
lane -- but a dead lane stays dead until someone installs a new engine.
:class:`LaneSupervisor` is that someone. It closes the loop the ISSUE's
flight-critical framing demands: a supervised stateful stream survives
its lane's death with every window it ever reported successful
bitwise-identical to an uninterrupted scan.

Mechanism, in order:

  * **Journal.** Every window enters through :meth:`LaneSupervisor.
    submit`, which records ``(seq, window, deadline)`` per stream before
    queueing it. The journal is the replay source; it is trimmed below
    each checkpoint's ``next_seq`` so it never outgrows one checkpoint
    interval.
  * **Auto-checkpoint.** Every ``recovery.checkpoint_every`` calls to
    :meth:`tick`, each watched stream is checkpointed live
    (:func:`~repro.fleet.migrate.checkpoint_live` -- drains only that
    stream's lane, other lanes keep their pipelined steps) into the
    :class:`~repro.fleet.store.CheckpointStore`; the superseded blob is
    deleted so a supervised stream holds exactly one stored checkpoint.
  * **Death detection + recovery.** :meth:`tick` watches
    ``engine.telemetry()`` for a lane with ``dead=True``; recovery is
    ``abort_lane`` (flush the lane's in-flight records back to queues),
    ``replace_lane_engine`` with a fresh engine from the ``rebuild``
    callback, then per watched stream: close, restore the stored
    checkpoint, and replay the journal from ``next_seq`` on -- the
    replayed submits reassign the exact original sequence numbers.
  * **Dedupe.** Replay recomputes windows that were already reported
    successful before the crash (that is what makes the carry advance
    identically); :meth:`tick` drops those duplicate rows so the caller
    sees each successful ``(stream, seq)`` exactly once.

What the supervisor does NOT hide: rows the engine failed (quarantine,
retry exhaustion) pass through ``tick`` -- after recovery the same seq
may later emit a successful row, which is the supervisor making the
failure transient rather than rewriting history.

A checkpoint evicted from a bounded store (LRU) before its stream
needed it makes that stream unrecoverable-bitwise; :meth:`recover`
raises rather than silently restarting the carry cold. Size the store
capacity to the watched-stream count.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Hashable, List, Optional, Set

from repro.core._api import RecoveryConfig
from repro.fleet.migrate import checkpoint_live
from repro.fleet.store import CheckpointStore

__all__ = ["LaneSupervisor"]


class LaneSupervisor:
    """Journal + auto-checkpoint + rebuild/restore/replay for the
    stateful streams of one :class:`~repro.serving.stream.StreamEngine`.

    ``rebuild`` is a ``modality -> InferenceEngine`` callback producing
    the replacement engine for a dead lane (same params; a fresh jit
    surface). Without it, dead lanes are reported but not recovered.
    """

    def __init__(self, engine, *, store: Optional[CheckpointStore] = None,
                 rebuild: Optional[Callable[[str], Any]] = None,
                 recovery: Optional[RecoveryConfig] = None):
        if recovery is None:
            recovery = getattr(engine, "recovery", None) or RecoveryConfig()
        if not isinstance(recovery, RecoveryConfig):
            raise TypeError(
                f"recovery must be a RecoveryConfig, got "
                f"{type(recovery).__name__}")
        self.engine = engine
        self.store = store if store is not None else CheckpointStore()
        self.rebuild = rebuild
        self.recovery = recovery
        self._handles: Dict[Hashable, Any] = {}
        self._journal: Dict[Hashable, List[tuple]] = {}
        self._ckpts: Dict[Hashable, str] = {}
        self._reported: Dict[Hashable, Set[int]] = {}
        self._ticks = 0
        self.stats: Dict[str, int] = {
            "checkpoints": 0, "restores": 0, "replayed": 0, "deduped": 0}

    # -- registration and journaled submission ---------------------------

    def watch(self, handle) -> Any:
        """Supervise ``handle``'s stream. Submit through
        :meth:`submit` from here on -- windows submitted directly on the
        handle are invisible to the journal and cannot be replayed."""
        sid = handle.stream_id
        if sid in self._handles and not self._handles[sid].closed:
            raise ValueError(f"stream {sid!r} is already supervised")
        self._handles[sid] = handle
        self._journal.setdefault(sid, [])
        self._reported.setdefault(sid, set())
        return handle

    def handle(self, sid: Hashable):
        """The stream's current handle (replaced after a recovery)."""
        return self._handles[sid]

    def watched(self) -> List[Hashable]:
        return list(self._handles)

    def submit(self, sid: Hashable, window: Any, *,
               deadline: Optional[float] = None) -> int:
        """Journal then queue one window on the supervised stream."""
        h = self._handles[sid]
        seq = h.submit(window, deadline=deadline)
        self._journal[sid].append((seq, window, deadline))
        return seq

    # -- the per-step hook ----------------------------------------------

    def tick(self, results) -> List[Any]:
        """Feed one ``step()``'s results through the supervisor.

        Returns the rows the caller should consume: duplicates of
        already-reported successful windows are dropped, and any results
        displaced by an auto-checkpoint's lane drain are appended.
        Auto-checkpoints fire every ``recovery.checkpoint_every`` ticks;
        dead lanes recover (when ``rebuild`` is set) before returning.
        """
        out = self._filter(results)
        self._ticks += 1
        # Recovery runs BEFORE the periodic checkpoint: a checkpoint
        # taken while a lane is dead would advance next_seq past the
        # windows the death quarantined and trim them from the journal
        # -- a permanent hole. Recover first requeues them, so the
        # checkpoint that follows carries them in ``queued``.
        if self.rebuild is not None:
            for modality in list(self.engine.engines):
                if (self.engine.telemetry(modality).dead
                        and self._watched_on(modality)):
                    self.recover(modality)
        if self._ticks % self.recovery.checkpoint_every == 0:
            out.extend(self._filter(self.checkpoint_now()))
        return out

    # -- checkpointing ---------------------------------------------------

    def checkpoint_now(self, sid: Optional[Hashable] = None) -> List[Any]:
        """Checkpoint one watched stream (or all) live; returns the
        results displaced by the lane drains (route them like ``step()``
        output -- :meth:`tick` already does)."""
        displaced: List[Any] = []
        sids = [sid] if sid is not None else list(self._handles)
        for s in sids:
            h = self._handles[s]
            if h.closed:
                continue
            ckpt, shed = checkpoint_live(h)
            displaced.extend(shed)
            old = self._ckpts.get(s)
            self._ckpts[s] = self.store.put(ckpt)
            if old is not None:
                self.store.delete(old)
            self.stats["checkpoints"] += 1
            # The journal only needs to cover windows the checkpoint
            # does not: trim below next_seq (ckpt.queued carries the
            # still-queued ones itself).
            cut = int(ckpt.next_seq)
            self._journal[s] = [e for e in self._journal[s]
                                if e[0] >= cut]
            # The dedupe set must survive for any seq the checkpoint
            # still carries queued: a post-restore replay re-runs those
            # windows, and ones already reported ok would re-emit. Only
            # seqs below every queued entry are settled for good.
            rcut = min([cut] + [q[1] for q in ckpt.queued])
            self._reported[s] = {q for q in self._reported[s] if q >= rcut}
        return displaced

    # -- recovery --------------------------------------------------------

    def recover(self, modality: str) -> int:
        """Rebuild a dead lane and restore+replay its watched streams.

        Returns the number of streams restored. Unwatched streams on
        the lane keep their queued windows through ``abort_lane`` but
        restart from zero carry (documented on
        ``replace_lane_engine``); watched streams resume from their
        last checkpoint with their full journal replayed, reassigning
        the original sequence numbers.
        """
        if self.rebuild is None:
            raise ValueError("no rebuild callback; cannot recover")
        eng = self.engine
        eng.abort_lane(modality)
        eng.replace_lane_engine(modality, engine=self.rebuild(modality))
        restored = 0
        for sid in self._watched_on(modality):
            old = self._handles[sid]
            stateful = old.stateful
            deadline = old.deadline
            if not old.closed:
                old.close()
            ckpt_id = self._ckpts.pop(sid, None)
            if ckpt_id is not None and ckpt_id not in self.store:
                raise RuntimeError(
                    f"checkpoint {ckpt_id!r} for supervised stream "
                    f"{sid!r} was evicted from the store; bitwise "
                    f"recovery is impossible (raise the store capacity "
                    f"above the watched-stream count)")
            if ckpt_id is not None:
                h = self.store.restore_into(eng, ckpt_id)
                replay_from = h.next_seq
            else:
                h = eng.open(modality, stream_id=sid, stateful=stateful,
                             deadline=deadline)
                replay_from = 0
            for seq, window, dl in self._journal[sid]:
                if seq < replay_from:
                    continue
                got = h.submit(window, deadline=dl)
                if got != seq:
                    raise RuntimeError(
                        f"replay of stream {sid!r} assigned seq {got}, "
                        f"journal says {seq}; the journal has a gap "
                        f"(was a window submitted around the "
                        f"supervisor?)")
                self.stats["replayed"] += 1
            self._handles[sid] = h
            restored += 1
            self.stats["restores"] += 1
            # The restore consumed the stored checkpoint; take a fresh
            # one NOW (replayed windows ride its ``queued``) so a second
            # death before the next periodic checkpoint is recoverable.
            self.checkpoint_now(sid)
        return restored

    # -- internals -------------------------------------------------------

    def _watched_on(self, modality: str) -> List[Hashable]:
        return [sid for sid, h in self._handles.items()
                if h.modality == modality]

    def _filter(self, results) -> List[Any]:
        out = []
        for r in results:
            seen = self._reported.get(r.stream_id)
            if seen is None or not getattr(r, "ok", True):
                out.append(r)
                continue
            if r.seq in seen:
                self.stats["deduped"] += 1
                continue
            seen.add(r.seq)
            out.append(r)
        return out
