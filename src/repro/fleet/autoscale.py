"""Lane autoscaling from queue-depth and deadline-miss telemetry.

A lane's slot count is its provisioned capacity: too few slots and the
waiting line grows while deadlines slip; too many and every step pays
for dead batch rows (and, sharded, reserves devices a cold lane does not
need). :class:`LaneAutoscaler` closes that loop: each ``observe()`` tick
reads one consistent :class:`~repro.serving.stream.LaneTelemetry`
snapshot and either grows the lane (sustained backlog), shrinks it
(sustained idleness), or holds.

Resizes are deliberately rare and cheap. Rare: both directions require
*patience* -- ``grow_patience`` / ``shrink_patience`` consecutive
over/under-threshold observations -- so a single bursty tick never
triggers a recompile, and shrink patience is the longer of the two
(capacity is easy to gain, slow to give back). Cheap: ``resize_lane``
pre-warms the new slot count's executables through the engines'
per-``shape_key`` AOT caches, so the first post-resize step runs a
warmed compile instead of stalling mid-serve; with ``scale_step=2`` the
slot counts visited over the whole ``[min_slots, max_slots]`` range stay
logarithmic, bounding the cache population.

With a device mesh attached, keep ``min_slots`` divisible by the mesh's
slot-axis size; doubling/halving then preserves divisibility at every
step and ``resize_lane``'s mesh validation never fires.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core._api import FleetConfig

__all__ = ["LaneAutoscaler", "ScaleDecision"]


@dataclasses.dataclass(frozen=True)
class ScaleDecision:
    """One ``observe()`` tick's outcome (also the audit-log row)."""

    modality: str
    action: str                      # "grow" | "shrink" | "hold"
    old_slots: int
    new_slots: int
    evicted: Tuple = ()              # streams bumped to the waiting line
    reason: str = ""

    @property
    def resized(self) -> bool:
        return self.action != "hold"


class LaneAutoscaler:
    """Grow/shrink one engine lane's slot count from its telemetry.

    Drives only the public lane surface -- ``engine.telemetry()`` and
    ``engine.resize_lane()`` -- so it composes with any engine the
    serving layer accepts. One autoscaler watches one lane; run one per
    lane (they share nothing).

    ``observe()`` is meant to be called on the fleet driver's tick (e.g.
    once per scheduling round); it never blocks on the device.
    """

    def __init__(self, engine, modality: Optional[str] = None,
                 config: Optional[FleetConfig] = None):
        self.engine = engine
        self.modality = modality
        self.config = config if config is not None else FleetConfig()
        self._grow_streak = 0
        self._shrink_streak = 0
        self.decisions = []          # every non-hold decision, in order

    def observe(self) -> ScaleDecision:
        """Take one telemetry reading and maybe resize; returns what
        happened (holds included, so callers can log every tick)."""
        cfg = self.config
        t = self.engine.telemetry(self.modality)
        old = t.slots
        # A lane is backlogged when queued work per slot exceeds the
        # threshold; idle when occupancy is low AND nothing is queued or
        # in flight (a drained-but-about-to-refill lane is not idle).
        backlogged = t.backlog_per_slot >= cfg.grow_backlog
        idle = (t.occupancy <= cfg.shrink_occupancy
                and t.queued == 0 and t.in_flight == 0)
        self._grow_streak = self._grow_streak + 1 if backlogged else 0
        self._shrink_streak = self._shrink_streak + 1 if idle else 0

        if (self._grow_streak >= cfg.grow_patience
                and old < cfg.max_slots):
            new = min(old * cfg.scale_step, cfg.max_slots)
            evicted = self.engine.resize_lane(self.modality, slots=new)
            self._grow_streak = self._shrink_streak = 0
            decision = ScaleDecision(
                t.modality, "grow", old, new, tuple(evicted),
                reason=(f"backlog {t.backlog_per_slot:.2f} windows/slot "
                        f">= {cfg.grow_backlog} for "
                        f"{cfg.grow_patience} ticks"))
            self.decisions.append(decision)
            return decision

        if (self._shrink_streak >= cfg.shrink_patience
                and old > cfg.min_slots):
            new = max(old // cfg.scale_step, cfg.min_slots)
            evicted = self.engine.resize_lane(self.modality, slots=new)
            self._grow_streak = self._shrink_streak = 0
            decision = ScaleDecision(
                t.modality, "shrink", old, new, tuple(evicted),
                reason=(f"occupancy {t.occupancy:.2f} <= "
                        f"{cfg.shrink_occupancy} for "
                        f"{cfg.shrink_patience} ticks"))
            self.decisions.append(decision)
            return decision

        return ScaleDecision(t.modality, "hold", old, old)
