"""Fleet control plane: the policy layer over the serving primitives.

The paper closes one loop on one Kraken SoC; the ROADMAP north-star is
millions of streams, which is a *system* problem -- admission,
autoscaling, rebalancing -- not an engine problem. PRs 1-6 built every
mechanism this needs (host-serializable ``StreamCheckpoint`` with
bitwise restore, per-stream ``StreamStats``, per-``shape_key`` AOT
warmup caches, live lane resize/drain hooks); this package is the
control plane that drives them, in three cooperating pieces:

  * :class:`~repro.fleet.autoscale.LaneAutoscaler` -- watches one
    lane's queue-depth and deadline-miss telemetry and resizes its slot
    count: grow on sustained backlog, shrink on idle, recompiles
    amortized through the engines' AOT warmup caches.
  * :mod:`~repro.fleet.migrate` -- live migration: checkpoint a stream
    *while windows are in flight* by draining only its lane
    (``drain_lane``), then replay the checkpoint into another engine,
    bitwise-identical to an uninterrupted scan.
  * :class:`~repro.fleet.store.CheckpointStore` +
    :class:`~repro.fleet.rebalance.FleetRebalancer` -- snapshot every
    engine's telemetry, score load (queue depth + deadline-miss rate),
    and migrate streams hot-to-cold through the store, with an
    imbalance dead-band and a post-move cooldown so it never thrashes.

Every knob lives in :class:`~repro.core._api.FleetConfig`; the serving
layer stays policy-free. Ev-Edge (PAPERS.md) is the reference point for
reactive scheduling on heterogeneous event platforms.
"""
from repro.core._api import FleetConfig
from repro.fleet.autoscale import LaneAutoscaler, ScaleDecision
from repro.fleet.migrate import MigrationRecord, checkpoint_live, migrate_stream
from repro.fleet.rebalance import FleetRebalancer, RebalanceReport, load_score
from repro.fleet.store import CheckpointStore

__all__ = [
    "FleetConfig",
    "LaneAutoscaler", "ScaleDecision",
    "MigrationRecord", "checkpoint_live", "migrate_stream",
    "FleetRebalancer", "RebalanceReport", "load_score",
    "CheckpointStore",
]
