"""Fleet control plane: the policy layer over the serving primitives.

The paper closes one loop on one Kraken SoC; the ROADMAP north-star is
millions of streams, which is a *system* problem -- admission,
autoscaling, rebalancing -- not an engine problem. PRs 1-6 built every
mechanism this needs (host-serializable ``StreamCheckpoint`` with
bitwise restore, per-stream ``StreamStats``, per-``shape_key`` AOT
warmup caches, live lane resize/drain hooks); this package is the
control plane that drives them, in three cooperating pieces:

  * :class:`~repro.fleet.autoscale.LaneAutoscaler` -- watches one
    lane's queue-depth and deadline-miss telemetry and resizes its slot
    count: grow on sustained backlog, shrink on idle, recompiles
    amortized through the engines' AOT warmup caches.
  * :mod:`~repro.fleet.migrate` -- live migration: checkpoint a stream
    *while windows are in flight* by draining only its lane
    (``drain_lane``), then replay the checkpoint into another engine,
    bitwise-identical to an uninterrupted scan.
  * :class:`~repro.fleet.store.CheckpointStore` +
    :class:`~repro.fleet.rebalance.FleetRebalancer` -- snapshot every
    engine's telemetry, score load (queue depth + deadline-miss rate),
    and migrate streams hot-to-cold through the store, with an
    imbalance dead-band and a post-move cooldown so it never thrashes.

Fault tolerance rides the same surfaces:

  * :class:`~repro.fleet.faults.FaultInjector` -- seeded, replayable
    fault schedules (step errors, NaN poison, stalls, lane kills)
    wrapped around any engine, so every recovery path is testable.
  * :class:`~repro.fleet.supervisor.LaneSupervisor` -- journals
    submissions, auto-checkpoints watched streams every K ticks into
    the (capacity-bounded, LRU) :class:`CheckpointStore`, and on lane
    death rebuilds the lane and restores+replays -- bitwise-identical
    for every window ever reported successful.
  * the rebalancer's load score charges ``fault_weight`` for a lane's
    retry/quarantine churn (flat penalty when dead), so unhealthy
    lanes shed load before they fail outright.

Every knob lives in :class:`~repro.core._api.FleetConfig` (injection
schedules in :class:`~repro.core._api.FaultConfig`); the serving layer
stays policy-free. Ev-Edge (PAPERS.md) is the reference point for
reactive scheduling on heterogeneous event platforms.
"""
from repro.core._api import FaultConfig, FleetConfig
from repro.fleet.autoscale import LaneAutoscaler, ScaleDecision
from repro.fleet.faults import (FaultInjector, FaultyEngine, InjectedFault,
                                LaneStall)
from repro.fleet.migrate import MigrationRecord, checkpoint_live, migrate_stream
from repro.fleet.rebalance import FleetRebalancer, RebalanceReport, load_score
from repro.fleet.store import CheckpointStore
from repro.fleet.supervisor import LaneSupervisor

__all__ = [
    "FleetConfig", "FaultConfig",
    "LaneAutoscaler", "ScaleDecision",
    "FaultInjector", "FaultyEngine", "InjectedFault", "LaneStall",
    "MigrationRecord", "checkpoint_live", "migrate_stream",
    "FleetRebalancer", "RebalanceReport", "load_score",
    "CheckpointStore",
    "LaneSupervisor",
]
