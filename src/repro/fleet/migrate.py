"""Live stream migration: checkpoint with windows in flight.

``StreamHandle.checkpoint()`` refuses while the stream has windows in
flight -- their state commits have not landed. The naive fix is
``engine.flush()``, but that stalls EVERY lane's pipeline to move one
stream. :func:`checkpoint_live` instead drains only the stream's own
lane (``drain_lane``): other lanes' dispatched steps stay in flight,
and the lane's collected results -- this stream's and its lane-mates' --
are handed back to the caller to route to their consumers as usual.

:func:`migrate_stream` is the whole move: drain, checkpoint, close the
source, replay into the target engine. Routed through a
:class:`~repro.fleet.store.CheckpointStore` it inherits the store's
guarantees (host-serializability proven at put, single-use restore);
without a store it hands the checkpoint object across directly. Either
way the restored stream's remaining windows are bitwise-identical to an
uninterrupted scan on the source engine -- that is the serving layer's
checkpoint contract, and the fleet soak test pins it under churn.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Hashable, Optional, Tuple

__all__ = ["MigrationRecord", "checkpoint_live", "migrate_stream"]


@dataclasses.dataclass(frozen=True)
class MigrationRecord:
    """What one migration did: identity, cost, and the side effects the
    caller must handle (``displaced`` results were collected early by
    the lane drain and still belong to their streams' consumers)."""

    stream_id: Hashable
    modality: str
    ckpt_id: Optional[str]           # None when no store was used
    displaced: Tuple                 # StreamResult rows from the drain
    migration_ms: float
    handle: object                   # the stream's new StreamHandle

    def __repr__(self):
        return (f"<MigrationRecord {self.stream_id!r} {self.modality} "
                f"{self.migration_ms:.2f}ms displaced={len(self.displaced)}>")


def checkpoint_live(handle):
    """Checkpoint a stream that may have windows in flight.

    Drains the stream's lane only (other lanes keep their pipelined
    steps), then captures the checkpoint. Returns ``(ckpt, displaced)``
    where ``displaced`` is every result the drain collected -- the
    caller routes them exactly like ``step()`` output.
    """
    displaced = handle.engine.drain_lane(handle.modality)
    return handle.checkpoint(), displaced


def migrate_stream(handle, target, *, store=None,
                   stream_id: Optional[Hashable] = None) -> MigrationRecord:
    """Move one stream from its engine to ``target`` live.

    Drains the source lane, checkpoints, closes the source stream, and
    replays into ``target`` (keeping the stream id unless ``stream_id``
    renames it). With a ``store``, the checkpoint crosses the pickle
    boundary and its id is consumed on restore (double-restore rejected);
    without one, the checkpoint object is handed across in-process.

    Returns a :class:`MigrationRecord`; its ``displaced`` results must
    be routed by the caller, and ``migration_ms`` is the end-to-end cost
    (drain + checkpoint + close + restore) -- the number the bench cell
    reports.
    """
    t0 = time.perf_counter()
    ckpt, displaced = checkpoint_live(handle)
    handle.close()
    new_id = ckpt.stream_id if stream_id is None else stream_id
    if store is not None:
        ckpt_id = store.put(ckpt)
        new_handle = store.restore_into(target, ckpt_id,
                                        stream_id=new_id)
    else:
        ckpt_id = None
        new_handle = target.open(
            ckpt.modality, stream_id=new_id,
            stateful=ckpt.stateful, deadline=ckpt.deadline).restore(ckpt)
    return MigrationRecord(
        stream_id=new_id, modality=ckpt.modality, ckpt_id=ckpt_id,
        displaced=tuple(displaced),
        migration_ms=(time.perf_counter() - t0) * 1e3,
        handle=new_handle)
