"""Distribution: sharding rules (FSDP/TP/EP/CP), in-model annotations."""
from repro.distributed.sharding import (batch_pspecs, cache_pspecs,
                                        opt_pspecs, param_pspecs, shardings)
from repro.distributed.annotate import constrain, current_mesh
