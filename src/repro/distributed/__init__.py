"""Distribution: mesh construction, sharding rules (FSDP/TP/EP/CP +
the serving engines' slot axis), in-model annotations."""
from repro.distributed.mesh import make_mesh, slot_axis
from repro.distributed.sharding import (batch_pspecs, cache_pspecs,
                                        opt_pspecs, param_pspecs, shardings,
                                        slot_pspec, slot_shardings,
                                        slot_state_pspecs)
from repro.distributed.annotate import constrain, current_mesh
