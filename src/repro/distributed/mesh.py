"""The one mesh constructor for the whole repo.

Two callers used to build meshes their own way: ``launch/mesh.py``
(``make_mesh_for`` over an explicit ``(shape, axes)`` for the
training/dry-run stack) and ad-hoc ``jax.sharding.Mesh(...)`` calls in
the distributed tests and examples. :func:`make_mesh` unifies them: one
function, importable without touching jax device state (a FUNCTION, not
a module constant -- dry-runs set ``XLA_FLAGS`` before any jax init),
used by the sharded serving engine, the launch stack, examples, and
benchmarks alike. The old names (``launch.mesh.make_mesh_for``) remain
as aliases.

The sharded :class:`~repro.serving.stream.StreamEngine` path wants the
simplest form: ``make_mesh()`` -- every local device on one ``("data",)``
axis, the axis the engine partitions its batch-slot dimension over (see
:func:`repro.distributed.sharding.slot_pspec`).
"""
from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh

__all__ = ["make_mesh", "slot_axis"]


def make_mesh(shape: Union[None, int, Sequence[int]] = None,
              axes: Optional[Sequence[str]] = None, *,
              devices: Optional[Sequence] = None) -> Mesh:
    """Build a device mesh; the unified entrypoint.

    Forms (all over the first ``prod(shape)`` of ``devices``, default
    ``jax.devices()``):

      * ``make_mesh()`` -- every local device on one ``("data",)`` axis:
        the sharded-serving default (slot axis == data axis).
      * ``make_mesh(4)`` / ``make_mesh((4,))`` -- the first 4 devices on
        ``("data",)``.
      * ``make_mesh((2, 16, 16), ("pod", "data", "model"))`` -- the
        explicit launch-stack form (``launch.mesh.make_mesh_for`` is an
        alias of exactly this).

    ``axes`` defaults to ``("data",)`` for 1-D shapes and is required
    otherwise.
    """
    if devices is None:
        devices = jax.devices()
    devices = list(devices)
    if shape is None:
        shape = (len(devices),)
    elif isinstance(shape, int):
        shape = (shape,)
    else:
        shape = tuple(int(s) for s in shape)
    if axes is None:
        if len(shape) != 1:
            raise ValueError(
                f"axes required for a {len(shape)}-D mesh shape {shape}; "
                f"only 1-D shapes default to ('data',)")
        axes = ("data",)
    axes = tuple(axes)
    if len(axes) != len(shape):
        raise ValueError(f"mesh shape {shape} and axes {axes} disagree")
    n = math.prod(shape)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devices)}; set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n} (or more) "
            f"before any jax import")
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:   # pre-AxisType jax: plain Mesh is equivalent
        return Mesh(np.asarray(devices[:n]).reshape(shape), axes)
    auto = (axis_type.Auto,) * len(axes)
    try:
        return jax.make_mesh(shape, axes, axis_types=auto,
                             devices=devices[:n])
    except TypeError:  # older make_mesh without devices/axis_types kwarg
        return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def slot_axis(mesh: Mesh) -> str:
    """The mesh axis the serving engines shard their slot dimension
    over: ``"data"`` when the mesh has one (the launch-stack convention
    -- batch over data), else the mesh's first axis."""
    names: Tuple[str, ...] = tuple(mesh.axis_names)
    return "data" if "data" in names else names[0]
