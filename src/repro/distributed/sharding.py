"""Sharding rules: logical parameter axes -> mesh axes (FSDP + TP + EP/SP).

Strategy (DESIGN.md):
  * ``model`` axis: tensor parallelism -- vocab, heads (or head_dim
    fallback), d_ff, experts.
  * ``data`` axis: FSDP -- the ``embed`` (d_model) dim of every matrix, and
    the optimizer moments with it. Batch is sharded over (pod, data).
  * ``pod`` axis: pure DP. Only gradient all-reduces cross pods (DCN).
  * Decode cells with global_batch < |data|: context parallelism -- the KV
    cache/state is sharded over ``data`` (sequence or state-head dim).

Every assignment is divisibility-checked with fallbacks (e.g. llama4's 40
heads % 16 != 0 -> shard head_dim instead; seamless' vocab 256206 % 16
!= 0 -> vocab unsharded). One mesh axis is used at most once per tensor.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.mesh import slot_axis
from repro.models.config import ModelConfig
from repro.models.params import ParamDef

__all__ = [
    "param_pspecs", "batch_pspecs", "cache_pspecs", "shardings",
    "batch_axes", "opt_pspecs",
    "slot_pspec", "slot_state_pspecs", "slot_shardings",
]

# Preferred mesh axis per logical axis, in priority order.
_PREFS: Dict[str, Tuple[str, ...]] = {
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),            # fallback target only
    "mlp": ("model",),
    "experts": ("model",),
    "heads_x": ("model",),     # rwkv fused-head projections (d_model-sized)
    "conv": ("model",),
    "embed": ("data",),        # FSDP
    "embed_out": ("data",),
    "lora": (),
    "state": (),
    "norm": (),
    "layers": (),
}
# If the keyed logical axis could not take 'model', try these dims instead.
_FALLBACKS = {
    "heads": ("head_dim",),
    "kv_heads": ("head_dim",),
    "vocab": (),
    "mlp": ("embed_out",),
}


def _axis_size(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape))[name]


def resolve_spec(shape: Sequence[int], axes: Sequence[Optional[str]],
                 mesh: Mesh) -> P:
    """Assign mesh axes to tensor dims honoring divisibility + uniqueness."""
    assign: list[Optional[str]] = [None] * len(shape)
    used = set()

    def try_assign(dim: int, mesh_axis: str) -> bool:
        if mesh_axis in used or mesh_axis not in mesh.axis_names:
            return False
        if shape[dim] % _axis_size(mesh, mesh_axis) != 0:
            return False
        assign[dim] = mesh_axis
        used.add(mesh_axis)
        return True

    # First pass: direct preferences.
    pending_fallback = []
    for i, name in enumerate(axes):
        if name is None:
            continue
        ok = False
        for ma in _PREFS.get(name, ()):
            if try_assign(i, ma):
                ok = True
                break
        if not ok and name in _FALLBACKS:
            pending_fallback.append(name)
    # Second pass: fallbacks (e.g. heads failed -> shard head_dim).
    for name in pending_fallback:
        for fb in _FALLBACKS[name]:
            done = False
            for i, nm in enumerate(axes):
                if nm == fb and assign[i] is None:
                    # fallback inherits the original preference list
                    for ma in _PREFS.get(name, ()):
                        if try_assign(i, ma):
                            done = True
                            break
                if done:
                    break
            if done:
                break
    return P(*assign)


def param_pspecs(defs: Any, mesh: Mesh, mode: str = "train") -> Any:
    """PartitionSpec tree matching a ParamDef tree.

    mode="serve": drop the FSDP ('data') sharding so weights are resident
    per device (TP only) -- decode must not all-gather weights every step
    (Perf cycle 5). Memory check: the biggest serve model (nemotron 340B)
    is 341e9 * 2B / 16 TP shards = 42 GB/device > HBM, so serve mode keeps
    FSDP for models over ``_SERVE_FSDP_THRESHOLD`` params and documents
    the trade (weight gathers amortized over decode batches).
    """
    def one(d: ParamDef):
        axes = d.axes
        if mode == "serve":
            axes = tuple(None if a in ("embed", "embed_out") else a
                         for a in axes)
        return resolve_spec(d.shape, axes, mesh)

    if mode == "serve":
        from repro.models.params import tree_num_params
        if tree_num_params(defs) > _SERVE_FSDP_THRESHOLD:
            mode = "train"      # fall back: weights don't fit replicated
    return jax.tree.map(one, defs, is_leaf=lambda x: isinstance(x, ParamDef))


# ~2 bytes/param over 16-way TP must fit in ~12 GB usable HBM.
_SERVE_FSDP_THRESHOLD = 96_000_000_000


def opt_pspecs(defs: Any, mesh: Mesh) -> Any:
    """Adam moment specs (same layout as params) -- see training.optimizer."""
    ps = param_pspecs(defs, mesh)
    return {"m": ps, "v": ps, "step": P()}


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Mesh axes carrying the batch dim: (pod, data) when pods exist."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _batch_dim_spec(mesh: Mesh, global_batch: int):
    """Largest prefix of (pod, data) that divides the batch."""
    axes = []
    prod = 1
    for a in batch_axes(mesh):
        if global_batch % (prod * _axis_size(mesh, a)) == 0:
            axes.append(a)
            prod *= _axis_size(mesh, a)
    return tuple(axes) if axes else None


def batch_pspecs(cfg: ModelConfig, mesh: Mesh, global_batch: int,
                 kind: str) -> Dict[str, P]:
    """Input-batch PartitionSpecs per family and step kind."""
    b = _batch_dim_spec(mesh, global_batch)
    specs: Dict[str, P] = {"tokens": P(b, None), "targets": P(b, None)}
    if cfg.family == "encdec":
        specs["frames"] = P(b, None, None)
    if cfg.family == "vlm":
        specs["patch_embeds"] = P(b, None, None)
    return specs


def cache_pspecs(cfg: ModelConfig, mesh: Mesh, cache: Any,
                 global_batch: int) -> Any:
    """Decode-cache specs. Batch-sharded when possible; context-parallel
    (sequence / state-head over 'data') when global_batch < |data|."""
    b = _batch_dim_spec(mesh, global_batch)

    def spec_for(path: str, x) -> P:
        shape = x.shape
        if path == "pos":
            return P()
        if cfg.family in ("dense", "moe", "vlm"):
            # (L, B, S, KVH, hd)
            return _kv_spec(shape, b, mesh)
        if cfg.family == "encdec":
            return _kv_spec(shape, b, mesh)
        if cfg.family == "rwkv6":
            if path == "state":        # (L, B, H, dk, dv)
                return _state_spec(shape, b, mesh)
            return P(None, b, None)     # tm_x / cm_x (L, B, D)
        if cfg.family == "zamba2":
            if path in ("attn_k", "attn_v"):
                return _kv_spec(shape, b, mesh)
            if path == "ssm":           # (L, B, H, P, N)
                return _state_spec(shape, b, mesh)
            return P(None, b, None, None)  # conv (L, B, k-1, cd)
        return P()

    flat = {}
    for k, v in cache.items():
        flat[k] = spec_for(k, v)
    return flat


def _kv_spec(shape, b, mesh) -> P:
    """(L, B, S, KVH, hd) decode cache: batch over (pod,)data when
    shardable, and the sequence dim over 'model' (flash-decoding style:
    every device holds a contiguous KV stripe, attends locally, and only
    the tiny softmax stats cross the TP axis -- Perf cycle 6; beats
    sharding kv_heads/head_dim, whose contraction forces score
    all-reduces or cache gathers). Falls back to kv-heads sharding when
    the stripe does not divide."""
    _, bsz, s, kvh, hd = shape
    dsz = _axis_size(mesh, "data")
    msz = _axis_size(mesh, "model")
    if b is not None:
        bdim, free_data = b, False
    elif s % dsz == 0 and s >= dsz:
        bdim, free_data = None, True   # context parallelism over 'data'
    else:
        bdim, free_data = None, False
    if s % msz == 0 and s >= msz:
        sdim = ("data", "model") if free_data and s % (dsz * msz) == 0 \
            else "model"
        return P(None, bdim, sdim, None, None)
    if free_data:
        return P(None, None, "data",
                 "model" if kvh % msz == 0 else None, None)
    kdim = "model" if kvh % msz == 0 else None
    hdim = "model" if (kdim is None and hd % msz == 0) else None
    return P(None, bdim, None, kdim, hdim)


def _state_spec(shape, b, mesh) -> P:
    """(L, B, H, x, y) recurrent state: heads over 'model'; if batch is not
    shardable, also spread x over 'data'."""
    _, bsz, h, x, y = shape
    msz = _axis_size(mesh, "model")
    dsz = _axis_size(mesh, "data")
    hdim = "model" if h % msz == 0 else None
    xdim = None
    if b is None and x % dsz == 0:
        xdim = "data"
    return P(None, b, hdim, xdim, None)


def shardings(mesh: Mesh, spec_tree: Any) -> Any:
    """PartitionSpec tree -> NamedSharding tree."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


# ----------------------------------------------------------------------
# Slot-axis rules: the serving engines' state/batch pytrees.
#
# The streaming engines (core/pipeline.BatchedClosedLoop, core/engine.
# FrameTCNEngine) keep everything per-stream slot-major: batch buffers
# and carried-state pytrees all lead with the batch-slot axis (PR 4's
# layout, paid exactly so it could shard). The rule is therefore one
# line -- leading axis over the mesh's data axis, everything else
# replicated -- but it lives HERE, next to the model-param rules, so
# there is a single place that says how a tensor maps onto a mesh.
# ----------------------------------------------------------------------

def slot_pspec(ndim: int, mesh: Optional[Mesh] = None,
               axis: Optional[str] = None) -> P:
    """The slot-major spec: leading (batch-slot) dim over the data axis,
    every other dim replicated. ``axis`` overrides the axis name
    (default: :func:`~repro.distributed.mesh.slot_axis` of ``mesh``,
    or ``"data"`` when neither is given)."""
    if axis is None:
        axis = slot_axis(mesh) if mesh is not None else "data"
    return P(axis, *([None] * (ndim - 1)))


def slot_state_pspecs(state: Any, mesh: Optional[Mesh] = None,
                      axis: Optional[str] = None) -> Any:
    """PartitionSpec tree for a slot-major carried-state pytree (every
    leaf is ``(B, ...)``; see ``InferenceEngine.init_state``)."""
    return jax.tree.map(
        lambda a: slot_pspec(np.ndim(a), mesh, axis), state)


def slot_shardings(mesh: Mesh, state: Any,
                   axis: Optional[str] = None) -> Any:
    """NamedSharding tree for a slot-major state pytree on ``mesh``."""
    return shardings(mesh, slot_state_pspecs(state, mesh, axis))
