"""In-model sharding annotations that degrade to no-ops off-mesh.

``constrain(x, spec)`` applies ``with_sharding_constraint`` when a mesh
context is active (pjit under ``with mesh:``), and is a no-op in plain
single-device execution (unit tests, examples). The pseudo-axis
``"batch"`` expands to every batch-carrying mesh axis present
(("pod", "data") on the multi-pod mesh, ("data",) single-pod); axis names
absent from the active mesh are dropped.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

__all__ = ["constrain", "current_mesh", "unshard_fsdp",
           "execution_mode", "get_execution_mode"]

AxisLike = Union[None, str, Tuple[str, ...]]


def current_mesh():
    """The active (context) mesh, or None."""
    try:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    return None


def _expand(axis: AxisLike, names) -> AxisLike:
    if axis is None:
        return None
    if axis == "batch":
        present = tuple(a for a in ("pod", "data") if a in names)
        return present if present else None
    if isinstance(axis, tuple):
        kept = tuple(a for a in axis if a in names)
        return kept if kept else None
    return axis if axis in names else None


def constrain(x, spec: Sequence[AxisLike]):
    """Sharding-constrain ``x`` if a mesh is active; otherwise identity."""
    mesh = current_mesh()
    if mesh is None:
        return x
    names = set(mesh.axis_names)
    resolved = P(*(_expand(a, names) for a in spec))
    try:
        return jax.lax.with_sharding_constraint(x, resolved)
    except Exception:
        return x


def _sizes(mesh):
    return dict(zip(mesh.axis_names, mesh.devices.shape))


import contextlib
import threading

_MODE = threading.local()


def get_execution_mode() -> str:
    return getattr(_MODE, "mode", "train")


@contextlib.contextmanager
def execution_mode(mode: str):
    """'train' (default): weights are gathered at use (FSDP gather-at-use,
    right for high-arithmetic-intensity steps). 'serve': weights STAY 2-D
    (data x model) sharded and the tiny decode activations are
    partial-sum all-reduced instead -- at batch<=128 decode, per-device
    weight reads are params/256 rather than params/16 (Perf cycle 7).
    Read at trace time by unshard_fsdp."""
    prev = get_execution_mode()
    _MODE.mode = mode
    try:
        yield
    finally:
        _MODE.mode = prev


def unshard_fsdp(w, *candidates: Sequence[AxisLike]):
    """FSDP gather-at-use: re-constrain a weight so only TP ('model') dims
    stay sharded, forcing GSPMD to all-gather the small FSDP ('data')
    shards instead of partial-sum all-reducing the huge activation output
    of the contraction (the 150 GB/layer failure mode -- EXPERIMENTS.md
    Perf cycle 1).

    ``candidates`` are specs tried in order; the first whose named axes
    all divide the corresponding dims wins (e.g. heads-on-model, falling
    back to head_dim-on-model for llama4's 40 heads). No candidate valid
    -> fully replicated use (still correct, still cheap vs activations).

    In 'serve' execution mode this is a NO-OP: decode keeps weights fully
    sharded and lets small activations carry the collectives.
    """
    if get_execution_mode() == "serve":
        return w
    mesh = current_mesh()
    if mesh is None or not hasattr(w, "shape"):
        return w
    names = set(mesh.axis_names)
    sizes = _sizes(mesh)
    for cand in candidates + ((None,) * w.ndim,):
        resolved = [_expand(a, names) for a in cand]
        ok = True
        for dim, axis in zip(w.shape, resolved):
            if axis is None:
                continue
            n = (np.prod([sizes[a] for a in axis])
                 if isinstance(axis, tuple) else sizes[axis])
            if dim % n:
                ok = False
                break
        if ok:
            try:
                return jax.lax.with_sharding_constraint(w, P(*resolved))
            except Exception:
                return w
    return w
