"""The ColibriES DVS-Gesture spiking CNN (paper Table II) + STBP training.

Network (input 128x128x2 voxelized spikes, T timesteps):

    0 Input  128x128x2
    1 Pool   4x4 stride 4        -> 32x32x2
    2 Conv   3x3, 16 features    -> 32x32x16   + LIF
    3 Pool   2x2 stride 2        -> 16x16x16
    4 Conv   3x3, 32 features    -> 16x16x32   + LIF
    5 Pool   2x2 stride 2        -> 8x8x32
    6 Full   2048 -> 512                        + LIF
    7 Full   512  -> 11                         + LIF (spike-count readout)

Two mathematically equivalent execution orders are provided:

  * ``time_serial``  -- scan over T, all layers advanced per step (the STBP
    training view).
  * ``layer_serial`` -- each layer consumes the full (T, ...) spike train of
    its predecessor (the SNE hardware view: SNE executes one layer tile at a
    time in time-domain-multiplexed fashion; the cluster re-assembles the
    inter-layer spike streams). Because the network is feedforward and the
    dynamics causal, both orders produce identical spike trains -- this is
    asserted by tests and lets the fused Pallas ``lif_scan`` kernel be used
    per layer.

Training follows STBP (Wu et al., 2018), the method the paper derives its
training setup from: surrogate-gradient BPTT through the unrolled dynamics,
cross-entropy on spike-count logits.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.lif import (LIFParams, lif_scan_reference, lif_step,
                            spike_surrogate)

__all__ = ["SNNConfig", "init_snn", "snn_init_state", "snn_apply",
           "snn_logits", "snn_loss", "SNN_STATE_LAYERS"]

Params = Dict[str, Any]

# The LIF layers whose membrane is carried state, in execution order. This
# names the leaves of the state pytree threaded through the serving stack
# (``snn_init_state`` / ``snn_apply(..., state=...)`` / the
# ``InferenceEngine`` state contract).
SNN_STATE_LAYERS = ("conv1", "conv2", "fc1", "fc2")


@dataclasses.dataclass(frozen=True)
class SNNConfig:
    """Configuration of the Table II SCNN (reduced variants for tests)."""

    height: int = 128
    width: int = 128
    in_channels: int = 2
    pool0: int = 4           # layer 1: 4x4 stride 4
    conv1_features: int = 16
    conv2_features: int = 32
    hidden: int = 512
    num_classes: int = 11
    time_bins: int = 16
    lif: LIFParams = LIFParams()
    readout: str = "spike_count"   # or "membrane"
    # Init gain keeps deep LIF layers out of the silent regime (synaptic
    # currents must reach v_th given sparse spike inputs); 2.0 with
    # v_th=0.5 / surrogate width 2.0 yields 10-30% firing rates at init.
    init_gain: float = 2.0

    @property
    def post_pool0(self) -> Tuple[int, int]:
        return self.height // self.pool0, self.width // self.pool0

    @property
    def flat_dim(self) -> int:
        h, w = self.post_pool0
        return (h // 4) * (w // 4) * self.conv2_features

    def spatial_sizes(self):
        """(H, W, C) after each stage, for the tiling planner / energy model."""
        h0, w0 = self.post_pool0
        return {
            "input": (self.height, self.width, self.in_channels),
            "pool0": (h0, w0, self.in_channels),
            "conv1": (h0, w0, self.conv1_features),
            "pool1": (h0 // 2, w0 // 2, self.conv1_features),
            "conv2": (h0 // 2, w0 // 2, self.conv2_features),
            "pool2": (h0 // 4, w0 // 4, self.conv2_features),
            "fc1": (1, 1, self.hidden),
            "fc2": (1, 1, self.num_classes),
        }


def init_snn(rng: jax.Array, cfg: SNNConfig, dtype=jnp.float32) -> Params:
    """He-init the SCNN parameters (conv kernels in HWIO layout)."""
    k1, k2, k3, k4 = jax.random.split(rng, 4)

    def he(key, shape, fan_in):
        return (jax.random.normal(key, shape, dtype)
                * (cfg.init_gain * jnp.sqrt(2.0 / fan_in)).astype(dtype))

    return {
        "conv1": {"w": he(k1, (3, 3, cfg.in_channels, cfg.conv1_features),
                          9 * cfg.in_channels)},
        "conv2": {"w": he(k2, (3, 3, cfg.conv1_features, cfg.conv2_features),
                          9 * cfg.conv1_features)},
        "fc1": {"w": he(k3, (cfg.flat_dim, cfg.hidden), cfg.flat_dim)},
        "fc2": {"w": he(k4, (cfg.hidden, cfg.num_classes), cfg.hidden)},
    }


def snn_init_state(cfg: SNNConfig, batch_size: int,
                   dtype=jnp.float32) -> Dict[str, jnp.ndarray]:
    """The zero carried-state pytree for a batch of ``batch_size`` streams.

    One slot-major (B, ...) membrane plane per LIF layer
    (:data:`SNN_STATE_LAYERS`). Zero membrane is exactly the network's
    cold-start condition: ``snn_apply(..., state=snn_init_state(...))``
    is bitwise identical to ``snn_apply(..., state=None)``.
    """
    h0, w0 = cfg.post_pool0
    z = lambda *shape: jnp.zeros((batch_size, *shape), dtype)
    return {
        "conv1": z(h0, w0, cfg.conv1_features),
        "conv2": z(h0 // 2, w0 // 2, cfg.conv2_features),
        "fc1": z(cfg.hidden),
        "fc2": z(cfg.num_classes),
    }


def _avg_pool(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """Average pool NHWC by k with stride k (SNN pooling on spike maps)."""
    return jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, k, k, 1), (1, k, k, 1), "VALID"
    ) / float(k * k)


def _conv(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """SAME 3x3 conv, NHWC x HWIO -> NHWC."""
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _currents_fn(params: Params, cfg: SNNConfig):
    """Per-stage synaptic-current functions (spikes -> currents)."""

    def i1(x_t):  # (B,H,W,2) input spikes -> conv1 currents
        return _conv(_avg_pool(x_t, cfg.pool0), params["conv1"]["w"])

    def i2(s1):   # conv1 spikes -> conv2 currents
        return _conv(_avg_pool(s1, 2), params["conv2"]["w"])

    def i3(s2):   # conv2 spikes -> fc1 currents
        pooled = _avg_pool(s2, 2)
        return pooled.reshape(pooled.shape[0], -1) @ params["fc1"]["w"]

    def i4(s3):   # fc1 spikes -> fc2 currents
        return s3 @ params["fc2"]["w"]

    return i1, i2, i3, i4


def snn_apply(
    params: Params,
    vox: jnp.ndarray,
    cfg: SNNConfig,
    *,
    mode: str = "time_serial",
    lif_scan_fn=None,
    fuse_fc: bool = False,
    fc_lif_scan_fn=None,
    state: Dict[str, jnp.ndarray] | None = None,
) -> Dict[str, jnp.ndarray]:
    """Run the SCNN on a voxelized spike batch.

    Args:
      params: from ``init_snn``.
      vox: (B, T, 2, H, W) float spikes (from ``events.voxelize_batch``).
      mode: ``time_serial`` (STBP view) or ``layer_serial`` (SNE view).
      lif_scan_fn: optional fused scan ``f(currents_T_first, LIFParams[,
        v0]) -> (spikes, v_final)`` used in layer_serial mode (e.g. the
        Pallas kernel); defaults to the pure-jnp reference. The ``v0``
        positional is only passed when ``state`` is given, so legacy
        two-argument callables keep working for stateless calls.
      fuse_fc: layer_serial only -- run fc1/fc2 through the fused
        synapse+LIF Pallas kernel (one launch computes ``spikes @ W`` and
        the LIF update; the (T, B, N) current tensors never reach HBM).
        Bitwise-identical to the unfused path (pinned by tests at
        B in {1, 4, 8}).
      fc_lif_scan_fn: optional override for the fused fc scan,
        ``f(spikes_T_first, W, LIFParams[, v0]) -> (spikes, v_final)``;
        defaults to :func:`repro.kernels.ops.fc_lif_scan`.
      state: optional carried state from :func:`snn_init_state` (or a
        previous call's ``out["state"]``): per-layer (B, ...) membrane
        planes. The initial spike state is the one *implied* by the
        membrane (``s0 = v0 >= v_th``), matching the kernel/oracle
        window-chaining contract: running T steps in W chained chunks is
        bitwise identical to one uninterrupted T-step run, in every mode.
        ``None`` starts from rest (zero membrane).

    Returns:
      dict with ``out_spikes`` (B, T, num_classes), ``out_membrane``
      (B, T, num_classes) in time_serial mode, per-layer mean firing
      rates, ``firing_rates_per_stream`` -- per-layer (B,) rates so
      the batched closed loop can drive the energy model per stream --
      and ``state``: the per-layer (B, ...) final membranes, feedable
      back as ``state`` to continue the stream.
    """
    if fuse_fc and mode != "layer_serial":
        raise ValueError(f"fuse_fc requires mode='layer_serial', got {mode!r}")
    b, t = vox.shape[0], vox.shape[1]
    x = jnp.transpose(vox, (1, 0, 3, 4, 2))  # (T, B, H, W, C)
    i1, i2, i3, i4 = _currents_fn(params, cfg)
    lif = cfg.lif

    # Mean firing rate per stream: reduce every axis except batch. Streams
    # are independent rows, so these values do not depend on batch size --
    # the property the batched-vs-looped parity tests pin down.
    def rate_b(s: jnp.ndarray, batch_axis: int) -> jnp.ndarray:
        axes = tuple(a for a in range(s.ndim) if a != batch_axis)
        return s.mean(axis=axes)

    if mode == "time_serial":
        if state is None:
            h0, w0 = cfg.post_pool0
            zeros = lambda shape: jnp.zeros((b, *shape), x.dtype)
            carry = {
                "v1": zeros((h0, w0, cfg.conv1_features)),
                "s1": zeros((h0, w0, cfg.conv1_features)),
                "v2": zeros((h0 // 2, w0 // 2, cfg.conv2_features)),
                "s2": zeros((h0 // 2, w0 // 2, cfg.conv2_features)),
                "v3": zeros((cfg.hidden,)), "s3": zeros((cfg.hidden,)),
                "v4": zeros((cfg.num_classes,)),
                "s4": zeros((cfg.num_classes,)),
            }
        else:
            # Window-chaining contract: the carried membrane implies the
            # spike state (s0 = v0 >= v_th), exactly as in
            # ``lif_scan_reference`` and the Pallas kernels.
            def v_s(v):
                v = v.astype(jnp.float32)
                s = spike_surrogate(v, jnp.float32(lif.v_th),
                                    lif.surrogate_width).astype(x.dtype)
                return v, s

            carry = {}
            for i, name in enumerate(SNN_STATE_LAYERS, start=1):
                carry[f"v{i}"], carry[f"s{i}"] = v_s(state[name])

        def step(c, x_t):
            v1, s1 = lif_step(c["v1"], c["s1"], i1(x_t), lif)
            v2, s2 = lif_step(c["v2"], c["s2"], i2(s1), lif)
            v3, s3 = lif_step(c["v3"], c["s3"], i3(s2), lif)
            v4, s4 = lif_step(c["v4"], c["s4"], i4(s3), lif)
            new = {"v1": v1, "s1": s1, "v2": v2, "s2": s2,
                   "v3": v3, "s3": s3, "v4": v4, "s4": s4}
            rates = (rate_b(s1, 0), rate_b(s2, 0),
                     rate_b(s3, 0), rate_b(s4, 0))        # each (B,)
            return new, (s4, v4, rates)

        fin, (out_s, out_v, rates) = jax.lax.scan(step, carry, x)
        out_spikes = jnp.transpose(out_s, (1, 0, 2))     # (B, T, classes)
        out_membrane = jnp.transpose(out_v, (1, 0, 2))
        r1, r2, r3, r4 = (r.mean(axis=0) for r in rates)  # (T, B) -> (B,)
        state_out = {name: fin[f"v{i}"]
                     for i, name in enumerate(SNN_STATE_LAYERS, start=1)}
    elif mode == "layer_serial":
        scan = lif_scan_fn or lif_scan_reference
        # v0 is only passed when carried state is given, so legacy
        # two-argument lif_scan_fn callables stay valid stateless.
        run_scan = (lambda cur, v0: scan(cur, lif) if v0 is None
                    else scan(cur, lif, v0))
        v0 = lambda name: None if state is None else state[name]
        # Layer 2: conv1 + LIF over the full train.
        c1 = jax.vmap(i1)(x)                  # (T, B, h0, w0, f1)
        s1, vf1 = run_scan(c1, v0("conv1"))
        c2 = jax.vmap(i2)(s1)
        s2, vf2 = run_scan(c2, v0("conv2"))
        if fuse_fc:
            fc_scan = fc_lif_scan_fn
            if fc_scan is None:
                # Lazy import: core -> kernels only on the fused path.
                from repro.kernels.ops import fc_lif_scan as fc_scan
            run_fc = (lambda s, w, v: fc_scan(s, w, lif) if v is None
                      else fc_scan(s, w, lif, v))
            # Pool+flatten stays outside the kernel (cheap, bandwidth-
            # bound); the matmul+LIF of fc1/fc2 fuse into one launch
            # each, so their (T, B, N) current tensors never reach HBM.
            def pool_flat(s_t):
                pooled = _avg_pool(s_t, 2)
                return pooled.reshape(pooled.shape[0], -1)

            z = jax.vmap(pool_flat)(s2)       # (T, B, flat_dim)
            s3, vf3 = run_fc(z, params["fc1"]["w"], v0("fc1"))
            s4, vf4 = run_fc(s3, params["fc2"]["w"], v0("fc2"))
        else:
            c3 = jax.vmap(i3)(s2)
            s3, vf3 = run_scan(c3, v0("fc1"))
            c4 = jax.vmap(i4)(s3)
            s4, vf4 = run_scan(c4, v0("fc2"))
        out_spikes = jnp.transpose(s4, (1, 0, 2))
        out_membrane = jnp.zeros_like(out_spikes)  # not tracked in this mode
        # Layer outputs are (T, B, ...): batch axis 1.
        r1, r2, r3, r4 = (rate_b(s, 1) for s in (s1, s2, s3, s4))
        state_out = {"conv1": vf1, "conv2": vf2, "fc1": vf3, "fc2": vf4}
    else:
        raise ValueError(f"unknown mode: {mode}")

    per_stream = {"conv1": r1, "conv2": r2, "fc1": r3, "fc2": r4}
    return {
        "out_spikes": out_spikes,
        "out_membrane": out_membrane,
        "firing_rates": {k: v.mean() for k, v in per_stream.items()},
        "firing_rates_per_stream": per_stream,
        "state": state_out,
    }


def snn_logits(outputs: Dict[str, jnp.ndarray], cfg: SNNConfig) -> jnp.ndarray:
    """Readout: spike-count (hardware-faithful) or mean-membrane logits."""
    if cfg.readout == "spike_count":
        return outputs["out_spikes"].mean(axis=1)
    return outputs["out_membrane"].mean(axis=1)


def snn_loss(
    params: Params,
    vox: jnp.ndarray,
    labels: jnp.ndarray,
    cfg: SNNConfig,
    *,
    mode: str = "time_serial",
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """STBP cross-entropy loss on readout logits. Returns (loss, aux)."""
    out = snn_apply(params, vox, cfg, mode=mode)
    # Spike-count readout gives logits in [0,1]; scale for usable softmax
    # temperature (equivalently a fixed readout gain, absorbed by training).
    logits = snn_logits(out, cfg) * 10.0
    logp = jax.nn.log_softmax(logits, axis=-1)
    loss = -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()
    acc = (jnp.argmax(logits, -1) == labels).mean()
    return loss, {"accuracy": acc, "firing_rates": out["firing_rates"],
                  "logits": logits}
