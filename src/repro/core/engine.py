"""The unified heterogeneous engine API: one protocol, two accelerators.

ColibriES is a heterogeneous platform: event streams feed the SNE (spiking
CNN) and frames feed CUTIE (ternary CNN), through one shared FC + cluster
front end. This module defines the small :class:`InferenceEngine` protocol
that lets the serving layer treat both wings uniformly:

  * ``modality``      -- which input kind the engine consumes
                         ("event" / "frame"), declared as a class attr;
  * ``duration_us``   -- the engine's latched control-tick length (the
                         one-bin-width-per-engine contract);
  * ``validate(item)``        -- reject a bad submission *before* any
                                 queue state changes;
  * ``prepare(items, batch_size)`` -- pad per-slot items into the engine's
                                 fixed batch buffer;
  * ``init_state(batch_size)`` -- the engine's zero carried-state pytree,
                                 slot-major (leading axis = batch slot).
                                 Stateless engines return an EMPTY pytree
                                 (``{}``) so the contract stays uniform;
  * ``infer(batch)``          -- one jit'd call, one result per slot.
                                 With carried state:
                                 ``infer(batch, state) -> (results,
                                 new_state)`` -- ``new_state`` is a
                                 device pytree, feedable straight back
                                 into the next call so per-stream state
                                 (e.g. the SNN's LIF membranes) chains
                                 windows into one uninterrupted scan;
  * ``shape_key(batch)``      -- the jit compilation key of a prepared
                                 batch (engines with data-dependent
                                 padding, like the event engine's
                                 power-of-two event buckets, expose how
                                 many distinct executables a workload
                                 compiles).

Optional extensions (duck-typed -- the serving layer probes with
``getattr`` so third-party engines implementing only the base protocol,
or even only its stateless pre-state subset, still plug in unchanged --
an engine without ``init_state`` is simply served stateless):

  * ``infer_dispatch(batch[, state])`` / ``infer_collect(pending)`` --
    the async split of ``infer``: dispatch launches the jit'd call and
    returns an opaque pending handle WITHOUT blocking on the device
    (with ``state``: ``(pending, new_state)``, where ``new_state`` is
    made of jax async-dispatch futures -- the pipelined serving path
    threads it into the NEXT dispatch so carried state stays
    device-resident between steps, never round-tripping the host);
    collect blocks and turns the handle into per-slot results. The
    pipelined ``StreamEngine.step`` uses these to overlap host-side
    packing of step k+1 with device compute of step k; engines without
    them are served synchronously.
  * ``warmup(shape_keys)``    -- precompile executables for a set of
    shape keys so no window pays compile time mid-stream.
  * ``export_state(state, slot)`` / ``import_state(state, slot,
    payload)`` -- the checkpoint/restore pair: export turns one slot's
    row of a slot-major carried-state pytree into a HOST-serializable
    (numpy) payload; import splices such a payload back into a row of a
    (possibly different process's) slot-major state. Together they make
    a stream's carry migratable between engine processes without the
    serving layer knowing the state's structure --
    ``StreamHandle.checkpoint()`` / ``restore()`` are built on exactly
    this pair, with a generic leading-axis-slicing fallback for engines
    that do not implement it.

Concrete engines:

  * :class:`~repro.core.pipeline.BatchedClosedLoop` -- the event->SNN wing
    (defined in ``core/pipeline.py``, conforms to this protocol);
  * :class:`FrameTCNEngine` (here) -- the frame->ternary-CNN wing: frame
    normalization (``core/frames.py``), the CUTIE TCN (``core/tcn.py``,
    2-bit packed weights through the ``ternary_matmul`` Pallas kernel),
    and per-stream CUTIE latency/energy accounting
    (:meth:`~repro.core.energy.KrakenModel.frame_loop`).

Both engines return :class:`~repro.core.pipeline.ClosedLoopResult` rows,
so per-stream stats, PWM actuation, and energy breakdowns are uniform
across modalities.
"""
from __future__ import annotations

from typing import (Any, Callable, Dict, Hashable, List, Optional, Protocol,
                    Sequence, Tuple, runtime_checkable)

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import frames as fr
from repro.core._api import (EngineConfig, suppress_api_deprecations,
                             warn_deprecated_call)
from repro.core.energy import KrakenModel
from repro.core.pipeline import (ClosedLoopResult, _check_slot_divisible,
                                 _mesh_slot_info, _replicate_to_mesh,
                                 export_state_slot, import_state_slot,
                                 pwm_from_logits)
from repro.core.tcn import TCNConfig, pack_tcn, tcn_apply, tcn_layer_macs

__all__ = ["InferenceEngine", "FrameTCNEngine"]


@runtime_checkable
class InferenceEngine(Protocol):
    """What the serving layer needs from an accelerator wing."""

    modality: str
    duration_us: Optional[int]

    def validate(self, item: Any) -> None:
        """Raise ValueError if ``item`` cannot be served by this engine.
        Must not mutate queue-visible state on failure (latching the
        engine's ``duration_us`` on first success is allowed)."""
        ...

    def prepare(self, items: Sequence[Optional[Any]], *,
                batch_size: int) -> Any:
        """Pad one item per slot (None = empty slot) into a batch."""
        ...

    def init_state(self, batch_size: int) -> Any:
        """Zero carried-state pytree, slot-major; empty if stateless."""
        ...

    def infer(self, batch: Any, state: Any = None):
        """Run one jit'd call; one result per slot, None for empty slots.

        Without ``state``: returns the result list (stateless legacy
        call). With ``state``: returns ``(results, new_state)``."""
        ...

    def shape_key(self, batch: Any) -> Hashable:
        """The jit compilation key of a prepared batch."""
        ...


class FrameTCNEngine:
    """The CUTIE wing: frame batch -> ternary CNN -> actuation.

    One jit'd call normalizes and classifies a whole
    :class:`~repro.core.frames.PaddedFrameBatch`; the Kraken model then
    accounts each slot with its own pixel count and operand activity.
    Frames are dense, so the jit shape is fixed by ``(batch_size, H, W)``
    alone -- one executable per slot count, no data-dependent bucketing.
    """

    modality = "frame"

    def __init__(
        self,
        params,
        cfg: TCNConfig,
        *,
        model: Optional[KrakenModel] = None,
        duration_us: Optional[int] = None,
        window_ms: float = 300.0,
        prepacked: bool = False,
        mesh=None,
    ):
        self.cfg = cfg
        self.packed = params if prepacked else pack_tcn(params)
        self.model = model or KrakenModel()
        self.duration_us = duration_us
        self.window_ms = window_ms
        self.layer_macs = tcn_layer_macs(cfg)
        self.total_macs = float(sum(self.layer_macs))
        self.mesh = None
        # Explicit executable cache: shape_key -> AOT-compiled callable.
        self._exe: Dict[Tuple[int, ...], Callable] = {}
        if mesh is not None:
            self.attach_mesh(mesh)

    @classmethod
    def from_config(cls, params, cfg: TCNConfig, config: EngineConfig, *,
                    model: Optional[KrakenModel] = None,
                    prepacked: bool = False):
        """Construct from the unified :class:`EngineConfig` surface.
        ``fuse_fc`` and the serving-layer fields do not apply to the
        frame wing and are ignored."""
        return cls(params, cfg, model=model, prepacked=prepacked,
                   duration_us=config.duration_us,
                   window_ms=config.window_ms, mesh=config.mesh)

    def attach_mesh(self, mesh) -> None:
        """Shard the slot axis over ``mesh``; same contract as
        :meth:`BatchedClosedLoop.attach_mesh` (idempotent for the same
        mesh, errors on a different one or after compilation). The
        packed ternary weights are pinned replicated."""
        if mesh is None or mesh == self.mesh:
            return
        if self.mesh is not None:
            raise ValueError(
                "engine is already attached to a different mesh; one "
                "engine serves one mesh for its whole lifetime")
        if self._exe:
            raise RuntimeError(
                "attach_mesh after executables were compiled: attach the "
                "mesh at construction (EngineConfig(mesh=...)) or before "
                "the first infer/warmup call")
        self.mesh = mesh
        self.packed = _replicate_to_mesh(self.packed, mesh)

    # -- protocol --------------------------------------------------------

    def validate(self, frame: fr.FrameWindow) -> None:
        if frame.shape != (self.cfg.height, self.cfg.width):
            raise ValueError(
                f"frame shape {frame.shape} != engine geometry "
                f"({self.cfg.height}, {self.cfg.width})")
        if self.duration_us is None:
            self.duration_us = frame.duration_us
        elif frame.duration_us != self.duration_us:
            raise ValueError(
                f"frame period {frame.duration_us} != engine period "
                f"{self.duration_us} (one tick length per engine)")

    def prepare(self, items: Sequence[Optional[fr.FrameWindow]], *,
                batch_size: int) -> fr.PaddedFrameBatch:
        return fr.pad_frame_windows(
            items, batch_size=batch_size, duration_us=self.duration_us,
            height=self.cfg.height, width=self.cfg.width)

    def shape_key(self, batch: fr.PaddedFrameBatch) -> Hashable:
        return (batch.batch_size, *batch.frame_shape, batch.duration_us)

    def init_state(self, batch_size: int) -> Dict:
        """The CUTIE wing is feedforward per frame: no carried state.

        Returns the empty pytree so the engine still satisfies the
        uniform state contract -- stateful serving threads ``{}`` through
        unchanged, and a ``stateful=True`` frame stream is simply a
        no-op carry."""
        return {}

    def _build_run(self) -> Callable:
        """Normalize + classify + readout for one frame batch (unjitted).
        Factored out of :meth:`_executable` so the serving layer's fused
        cross-wing megastep can lower the SAME function next to the
        event wing's -- one compiled program, bitwise-identical outputs.
        """
        cfg = self.cfg

        def run(packed, pixels):
            out = tcn_apply(packed, fr.normalize_frames(pixels), cfg)
            logits = out["logits"]
            return (jnp.argmax(logits, -1), pwm_from_logits(logits),
                    logits, out["activity_per_stream"])

        return run

    def _executable(self, key: Tuple[int, ...]) -> Callable:
        """AOT-compile (once) and return the executable for a shape key,
        ``(batch_size, height, width, duration_us)`` -- compilation is
        eager so :meth:`warmup` can pull it off the serving path."""
        exe = self._exe.get(key)
        if exe is None:
            b, h, w = int(key[0]), int(key[1]), int(key[2])
            run = self._build_run()

            px_sh = pk_sh = None
            if self.mesh is not None:
                # Dense frames shard the same way as the event wing:
                # pixels split on the slot axis, packed weights
                # replicated, each device classifying its own rows
                # (tcn_apply is row-independent, so shards are bitwise
                # equal to the full batch).
                from jax.experimental.shard_map import shard_map
                from jax.sharding import NamedSharding, PartitionSpec as P
                _check_slot_divisible(b, self.mesh, "sharded-engine")
                ax, _ = _mesh_slot_info(self.mesh)
                run = shard_map(
                    run, mesh=self.mesh,
                    in_specs=(P(), P(ax, None, None, None)),
                    out_specs=(P(ax), P(ax, None), P(ax, None),
                               {k: P(ax) for k in
                                ("conv1", "conv2", "fc1", "fc2")}),
                    check_rep=False)
                px_sh = NamedSharding(self.mesh, P(ax, None, None, None))
                pk_sh = NamedSharding(self.mesh, P())
            px_abs = jax.ShapeDtypeStruct((b, h, w, 1), jnp.float32,
                                          sharding=px_sh)
            pk_abs = jax.tree_util.tree_map(
                lambda a: jax.ShapeDtypeStruct(jnp.shape(a),
                                               jnp.asarray(a).dtype,
                                               sharding=pk_sh),
                self.packed)
            exe = jax.jit(run).lower(pk_abs, px_abs).compile()
            self._exe[key] = exe
        return exe

    def warmup(self, shape_keys) -> None:
        """Precompile executables for ``(batch_size, height, width[,
        duration_us])`` shape keys (duration is not part of the compiled
        shape for dense frames; it is accepted for symmetry with
        ``shape_key``). A 3-tuple key borrows the engine's latched
        ``duration_us`` and therefore requires one -- warming an
        unlatched engine with 3-tuples would silently cache executables
        under a ``(b, h, w, None)`` key that no served batch ever hits.
        """
        for key in shape_keys:
            key = tuple(key)
            if len(key) == 3:
                if self.duration_us is None:
                    raise ValueError(
                        "3-tuple shape key needs a pinned tick period: "
                        "latch duration_us first (pass duration_us= at "
                        "construction or validate a frame) or pass the "
                        "full (batch, height, width, duration_us) key")
                key = (*key, self.duration_us)
            if len(key) != 4:
                raise ValueError(
                    f"shape key must be (batch, height, width[, "
                    f"duration_us]), got {key}")
            if (key[1], key[2]) != (self.cfg.height, self.cfg.width):
                raise ValueError(
                    f"shape key geometry {key[1:3]} != engine geometry "
                    f"({self.cfg.height}, {self.cfg.width})")
            self._executable(key)

    def compiled_shape_keys(self) -> set:
        """Shape keys with a compiled executable (stepped or warmed)."""
        return set(self._exe)

    # -- cross-wing megastep adapters ------------------------------------
    # Counterparts of BatchedClosedLoop's: the serving layer's fused
    # megastep lowers this wing's run next to the event wing's in one
    # jit'd program (see EngineConfig.megastep).

    def _mega_parts(self, key):
        """``(run_fn, abstract_args)`` for a shape key, for fused
        cross-wing compilation (single-device only)."""
        if self.mesh is not None:
            raise ValueError(
                "the fused megastep does not compose with a mesh-attached "
                "engine")
        b, h, w = int(key[0]), int(key[1]), int(key[2])
        px_abs = jax.ShapeDtypeStruct((b, h, w, 1), jnp.float32)
        pk_abs = jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(jnp.shape(a),
                                           jnp.asarray(a).dtype),
            self.packed)
        return self._build_run(), (pk_abs, px_abs)

    def _mega_args(self, batch: fr.PaddedFrameBatch, state):
        """Concrete argument tuple matching :meth:`_mega_parts` (the
        CUTIE wing carries no state; ``state`` is ignored)."""
        return (self.packed, batch.pixels)

    def _mega_split(self, out, batch: fr.PaddedFrameBatch, state):
        """Split megastep outputs into the ``(pending, state)`` pair
        :meth:`infer_dispatch` returns (no-op carry passthrough)."""
        preds, pwm, logits, activity = out
        return (batch, preds, pwm, logits, activity), state

    def infer_dispatch(self, batch: fr.PaddedFrameBatch, state=None):
        """Launch the jit'd call without host sync; see
        :meth:`BatchedClosedLoop.infer_dispatch`. With ``state`` (the
        empty pytree) returns ``(pending, state)`` -- the uniform
        stateful dispatch shape, carrying nothing."""
        exe = self._executable(self.shape_key(batch))
        pixels = jnp.asarray(batch.pixels)
        if self.mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P
            ax, _ = _mesh_slot_info(self.mesh)
            pixels = jax.device_put(
                pixels, NamedSharding(self.mesh, P(ax, None, None, None)))
        preds, pwm, logits, activity = exe(self.packed, pixels)
        pending = (batch, preds, pwm, logits, activity)
        return pending if state is None else (pending, state)

    def infer_collect(self, pending) -> List[Optional[ClosedLoopResult]]:
        """Fetch a dispatched batch's outputs and account each slot."""
        batch, preds, pwm, logits, activity = pending
        preds = np.asarray(preds)
        pwm = np.asarray(pwm)
        logits = np.asarray(logits)
        activity = {k: np.asarray(v) for k, v in activity.items()}

        results: List[Optional[ClosedLoopResult]] = []
        for b in range(batch.batch_size):
            if not batch.occupied[b]:
                results.append(None)
                continue
            # CUTIE runs its full dense schedule regardless of content;
            # per-stream differences surface as switching activity.
            act = float(np.mean([v[b] for v in activity.values()]))
            acct = self.model.frame_loop(
                float(batch.num_pixels[b]), self.total_macs, activity=act)
            latency = float(acct["total_time_ms"])
            proc_ms = (acct["stages"]["preprocessing"]["time_ms"]
                       + acct["stages"]["tcn_inference"]["time_ms"])
            period_ms = max(self.window_ms, proc_ms)
            results.append(ClosedLoopResult(
                label_pred=preds[b:b + 1],
                pwm=pwm[b:b + 1],
                latency_ms=latency,
                energy_mj=float(acct["total_energy_mj"]),
                breakdown=acct,
                realtime=latency <= self.window_ms,
                sustained_rate_hz=1000.0 / period_ms,
                logits=logits[b:b + 1],
            ))
        return results

    def export_state(self, state, slot: int):
        """Checkpoint one slot's carry -- trivially the empty pytree for
        the feedforward CUTIE wing, through the same engine-agnostic
        contract as the event wing."""
        return export_state_slot(state, slot)

    def import_state(self, state, slot: int, payload):
        """Restore one slot's carry (a no-op splice of the empty
        pytree)."""
        return import_state_slot(state, slot, payload)

    def infer(self, batch: fr.PaddedFrameBatch, state=None):
        """Synchronous convenience: dispatch + collect back to back.
        With ``state``: returns ``(results, state)`` (no-op carry).
        The stateless direct form is deprecated -- thread the (empty)
        state or serve through ``StreamEngine.open(...)``."""
        if state is None:
            warn_deprecated_call(
                self, "stateless-infer",
                "stateless FrameTCNEngine.infer(batch) is a legacy call "
                "form; pass carried state -- infer(batch, "
                "init_state(batch_size)) -- or serve frames through the "
                "session API: StreamEngine.open(...).submit(window)")
            return self.infer_collect(self.infer_dispatch(batch))
        pending, new_state = self.infer_dispatch(batch, state)
        return self.infer_collect(pending), new_state

    def infer_frames(self, frames: Sequence[Optional[fr.FrameWindow]], *,
                     batch_size: Optional[int] = None,
                     ) -> List[Optional[ClosedLoopResult]]:
        """Convenience: pad a frame list and run it as one batch."""
        frames = list(frames)
        if not frames and not batch_size:
            return []
        for f in frames:
            if f is not None:
                self.validate(f)
        # Compat wrapper: drives the stateless form deliberately.
        with suppress_api_deprecations():
            return self.infer(self.prepare(
                frames, batch_size=batch_size or len(frames)))
