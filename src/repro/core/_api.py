"""The unified engine-construction surface + one-shot API deprecations.

:class:`EngineConfig` is the one construction surface for the serving
engines. ``StreamEngine`` construction accreted keyword arguments across
PRs 1-6 (``max_streams``, ``duration_us``, ``policy``/``fair_quantum``,
``fuse_fc``, ``pipeline_depth``, now ``mesh``); they are all fields of
this single frozen dataclass, passed as ``StreamEngine(params, cfg,
config)`` / ``StreamEngine(engines=..., config=config)`` and forwarded
to the wing engines via ``BatchedClosedLoop.from_config`` /
``FrameTCNEngine.from_config``. The legacy kwarg form still works as a
shim (bitwise-identical engines) that announces the replacement once.

Deprecation machinery: the session-handle redesign keeps every legacy
call form working -- ``StreamEngine.submit(stream_id, ...)``, the
engines' stateless ``infer(batch)``, and now kwarg construction -- but
each announces its replacement exactly once per owning instance via
:class:`DeprecationWarning`. The serving stack itself still drives the
legacy forms internally (the submit shim, the stateless lane fast path,
the B=1 ``ClosedLoopPipeline`` wrapper); those calls are wrapped in
:func:`suppress_api_deprecations` so only *user* code sees the warning.
"""
from __future__ import annotations

import contextlib
import dataclasses
import warnings
from typing import Any, Mapping, Optional, Union

__all__ = ["EngineConfig", "suppress_api_deprecations",
           "warn_deprecated_call"]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Everything that shapes a serving engine, in one frozen value.

    Fields (each previously its own ``StreamEngine`` kwarg):

      * ``max_streams`` -- batch slots per engine lane (or a
        ``{modality: count}`` mapping). With a ``mesh``, every lane's
        slot count must divide by the mesh's slot-axis size.
      * ``duration_us`` -- pin the one-bin-width-per-engine contract up
        front; ``None`` latches each engine's first submitted duration.
      * ``policy`` / ``fair_quantum`` -- slot assignment: a
        ``SlotPolicy`` instance, or just a quantum for the default
        ``FairQuantumPolicy`` (mutually exclusive, as before).
      * ``pipeline_depth`` -- ``>= 1`` dispatches steps asynchronously
        and returns results ``pipeline_depth`` steps late (bitwise
        order/value parity with the synchronous engine).
      * ``fuse_fc`` -- route the event wing's fc1/fc2 through the fused
        synapse+LIF Pallas kernel.
      * ``window_ms`` -- the control-tick window length for the
        real-time accounting.
      * ``mesh`` -- a :class:`jax.sharding.Mesh` (see
        :func:`repro.distributed.make_mesh`): the engines shard their
        slot axis over the mesh's data axis, one collective-free jit'd
        step per lane across all devices, bitwise-identical to the
        single-device engine.

    Frozen: a config is a value, shareable between engines and safe to
    put in tests' parametrize tables. ``replace`` derives variants
    (``dataclasses.replace(cfg, pipeline_depth=2)``).
    """

    max_streams: Union[int, Mapping[str, int]] = 8
    duration_us: Optional[int] = None
    policy: Optional[Any] = None           # SlotPolicy (kept Any: no
    fair_quantum: Optional[int] = None     # serving import from _api)
    pipeline_depth: int = 0
    fuse_fc: bool = False
    window_ms: float = 300.0
    mesh: Optional[Any] = None             # jax.sharding.Mesh

    def __post_init__(self):
        if self.pipeline_depth < 0:
            raise ValueError(
                f"pipeline_depth must be >= 0, got {self.pipeline_depth}")
        if self.policy is not None and self.fair_quantum is not None:
            raise ValueError(
                "fair_quantum configures the DEFAULT policy only; set "
                "the quantum on your policy instance instead")

_suppressed = 0


@contextlib.contextmanager
def suppress_api_deprecations():
    """Silence :func:`warn_deprecated_call` for the duration of the block
    (re-entrant; used by the shims' internal legacy-form calls)."""
    global _suppressed
    _suppressed += 1
    try:
        yield
    finally:
        _suppressed -= 1


def warn_deprecated_call(owner, key: str, message: str) -> None:
    """Emit ``message`` as a one-shot DeprecationWarning.

    One-shot per ``(owner instance, key)``: the first offending call on
    an object warns, repeats stay quiet -- a migration nudge, not log
    spam. No-op inside :func:`suppress_api_deprecations`.
    """
    if _suppressed:
        return
    seen = owner.__dict__.setdefault("_api_warned", set())
    if key in seen:
        return
    seen.add(key)
    warnings.warn(message, DeprecationWarning, stacklevel=3)
