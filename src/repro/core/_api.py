"""One-shot API deprecation warnings with internal suppression.

The session-handle redesign keeps every legacy call form working --
``StreamEngine.submit(stream_id, ...)`` and the engines' stateless
``infer(batch)`` -- but each now announces its replacement exactly once
per owning instance via :class:`DeprecationWarning`. The serving stack
itself still drives the legacy forms internally (the submit shim, the
stateless lane fast path, the B=1 ``ClosedLoopPipeline`` wrapper); those
calls are wrapped in :func:`suppress_api_deprecations` so only *user*
code sees the warning.
"""
from __future__ import annotations

import contextlib
import warnings

__all__ = ["suppress_api_deprecations", "warn_deprecated_call"]

_suppressed = 0


@contextlib.contextmanager
def suppress_api_deprecations():
    """Silence :func:`warn_deprecated_call` for the duration of the block
    (re-entrant; used by the shims' internal legacy-form calls)."""
    global _suppressed
    _suppressed += 1
    try:
        yield
    finally:
        _suppressed -= 1


def warn_deprecated_call(owner, key: str, message: str) -> None:
    """Emit ``message`` as a one-shot DeprecationWarning.

    One-shot per ``(owner instance, key)``: the first offending call on
    an object warns, repeats stay quiet -- a migration nudge, not log
    spam. No-op inside :func:`suppress_api_deprecations`.
    """
    if _suppressed:
        return
    seen = owner.__dict__.setdefault("_api_warned", set())
    if key in seen:
        return
    seen.add(key)
    warnings.warn(message, DeprecationWarning, stacklevel=3)
