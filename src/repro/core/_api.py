"""The unified engine-construction surface + one-shot API deprecations.

:class:`EngineConfig` is the one construction surface for the serving
engines. ``StreamEngine`` construction accreted keyword arguments across
PRs 1-6 (``max_streams``, ``duration_us``, ``policy``/``fair_quantum``,
``fuse_fc``, ``pipeline_depth``, now ``mesh``); they are all fields of
this single frozen dataclass, passed as ``StreamEngine(params, cfg,
config)`` / ``StreamEngine(engines=..., config=config)`` and forwarded
to the wing engines via ``BatchedClosedLoop.from_config`` /
``FrameTCNEngine.from_config``. The legacy kwarg form still works as a
shim (bitwise-identical engines) that announces the replacement once.

Deprecation machinery: the session-handle redesign keeps every legacy
call form working -- ``StreamEngine.submit(stream_id, ...)``, the
engines' stateless ``infer(batch)``, and now kwarg construction -- but
each announces its replacement exactly once per owning instance via
:class:`DeprecationWarning`. The serving stack itself still drives the
legacy forms internally (the submit shim, the stateless lane fast path,
the B=1 ``ClosedLoopPipeline`` wrapper); those calls are wrapped in
:func:`suppress_api_deprecations` so only *user* code sees the warning.
"""
from __future__ import annotations

import contextlib
import dataclasses
import warnings
from typing import Any, Mapping, Optional, Union

__all__ = ["EngineConfig", "FleetConfig", "FaultConfig", "RecoveryConfig",
           "suppress_api_deprecations", "warn_deprecated_call"]


@dataclasses.dataclass(frozen=True)
class RecoveryConfig:
    """Fault-recovery policy for a serving engine, in one frozen value.

    Attached as ``EngineConfig.recovery``; with the default ``None`` the
    engine keeps its pre-recovery semantics bitwise (an engine exception
    propagates, non-finite outputs are served as-is). With a config set:

      * ``max_retries`` -- how many times one window may fail an engine
        step before it is quarantined to the lane's dead-letter queue
        (its ``StreamResult`` is emitted with ``status="failed"`` and
        the stream's carry rolls back to its pre-window value).
      * ``backoff_steps`` -- engine steps a lane sits out after a failed
        step before it is dispatched again. Measured in steps, not wall
        time, so recovery schedules are deterministic and replayable.
      * ``dead_after`` -- consecutive failed lane steps after which the
        lane is declared dead: it stops calling its engine and fails
        queued windows fast (keeping paired fusion ticks completing,
        degraded) until ``replace_lane_engine`` swaps a rebuilt engine
        in.
      * ``checkpoint_every`` -- the :class:`~repro.fleet.supervisor.
        LaneSupervisor` auto-checkpoint cadence, in supervisor ticks.
      * ``quarantine_nonfinite`` -- treat non-finite logits as poison:
        the window is quarantined immediately (no retry -- NaNs are
        deterministic, a retry would just recompute them).
    """

    max_retries: int = 2
    backoff_steps: int = 1
    dead_after: int = 4
    checkpoint_every: int = 4
    quarantine_nonfinite: bool = True

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError(
                f"max_retries must be >= 0, got {self.max_retries}")
        if self.backoff_steps < 0:
            raise ValueError(
                f"backoff_steps must be >= 0, got {self.backoff_steps}")
        if self.dead_after < 1:
            raise ValueError(
                f"dead_after must be >= 1, got {self.dead_after}")
        if self.checkpoint_every < 1:
            raise ValueError(
                f"checkpoint_every must be >= 1, got "
                f"{self.checkpoint_every}")


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """A deterministic fault schedule for the
    :class:`~repro.fleet.faults.FaultInjector`.

    Rates are per *injection site visit* (one engine call), drawn from a
    ``numpy`` generator seeded with ``seed`` in call order -- the same
    seed over the same workload replays the same faults, which is what
    makes the chaos soak assertable.

      * ``step_error_rate`` -- probability an engine call raises
        :class:`~repro.fleet.faults.InjectedFault` (surfacing at
        dispatch in synchronous mode, at collect in pipelined mode).
      * ``nan_rate`` -- probability a returned batch has one slot's
        logits poisoned with NaN (the quarantine path).
      * ``stall_rate`` / ``stall_ms`` -- probability an engine call
        stalls for ``stall_ms`` wall milliseconds (a straggler, not an
        error: surfaces as deadline misses, never as an exception).
      * ``modalities`` -- restrict injection to these modalities
        (``None`` = every wrapped engine).
    """

    seed: int = 0
    step_error_rate: float = 0.0
    nan_rate: float = 0.0
    stall_rate: float = 0.0
    stall_ms: float = 1.0
    modalities: Optional[tuple] = None

    def __post_init__(self):
        for name in ("step_error_rate", "nan_rate", "stall_rate"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {v}")
        if self.stall_ms < 0.0:
            raise ValueError(
                f"stall_ms must be >= 0, got {self.stall_ms}")
        if self.modalities is not None:
            object.__setattr__(self, "modalities",
                               tuple(self.modalities))


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Everything that shapes a serving engine, in one frozen value.

    Fields (each previously its own ``StreamEngine`` kwarg):

      * ``max_streams`` -- batch slots per engine lane (or a
        ``{modality: count}`` mapping). With a ``mesh``, every lane's
        slot count must divide by the mesh's slot-axis size.
      * ``duration_us`` -- pin the one-bin-width-per-engine contract up
        front; ``None`` latches each engine's first submitted duration.
      * ``policy`` / ``fair_quantum`` -- slot assignment: a
        ``SlotPolicy`` instance, or just a quantum for the default
        ``FairQuantumPolicy`` (mutually exclusive, as before).
      * ``pipeline_depth`` -- ``>= 1`` dispatches steps asynchronously
        and returns results ``pipeline_depth`` steps late (bitwise
        order/value parity with the synchronous engine).
      * ``fuse_fc`` -- route the event wing's fc1/fc2 through the fused
        synapse+LIF Pallas kernel.
      * ``window_ms`` -- the control-tick window length for the
        real-time accounting.
      * ``mesh`` -- a :class:`jax.sharding.Mesh` (see
        :func:`repro.distributed.make_mesh`): the engines shard their
        slot axis over the mesh's data axis, one collective-free jit'd
        step per lane across all devices, bitwise-identical to the
        single-device engine.
      * ``recovery`` -- a :class:`RecoveryConfig` opting the engine
        into fault recovery (bounded retry with deterministic backoff,
        poison-window quarantine, dead-lane fail-fast). ``None`` (the
        default) keeps the pre-recovery failure semantics bitwise: an
        engine exception propagates to the caller.
      * ``coschedule`` -- fusion-aware co-scheduling (default on): after
        the slot policy assigns a lane, streams paired via
        ``StreamEngine.pair_streams`` (a :class:`~repro.serving.session.
        FusionSession` pairs its wings automatically) pull their partner
        into the partner's lane for the SAME step, so both wings of a
        tick land together instead of drifting across independently
        contended lanes. Scheduling-only: per-window results are bitwise
        unchanged.
      * ``megastep`` -- fuse the event and frame wings' kernels (the
        ``fc_lif_scan`` SNN scan and the ``ternary_matmul`` conv stack)
        into ONE jit'd dispatch per step when both lanes have work
        (default off). Requires exactly one event and one frame lane and
        is single-device only (incompatible with ``mesh``). Results stay
        bitwise-identical to the two separate per-engine calls; a lane
        without work this step (drained, dead, or backing off) falls
        back to the ordinary per-lane dispatch, so degraded single-wing
        ticks keep their semantics.

    Frozen: a config is a value, shareable between engines and safe to
    put in tests' parametrize tables. ``replace`` derives variants
    (``dataclasses.replace(cfg, pipeline_depth=2)``).
    """

    max_streams: Union[int, Mapping[str, int]] = 8
    duration_us: Optional[int] = None
    policy: Optional[Any] = None           # SlotPolicy (kept Any: no
    fair_quantum: Optional[int] = None     # serving import from _api)
    pipeline_depth: int = 0
    fuse_fc: bool = False
    window_ms: float = 300.0
    mesh: Optional[Any] = None             # jax.sharding.Mesh
    recovery: Optional["RecoveryConfig"] = None
    coschedule: bool = True
    megastep: bool = False

    def __post_init__(self):
        if self.recovery is not None and not isinstance(
                self.recovery, RecoveryConfig):
            raise TypeError(
                f"recovery must be a RecoveryConfig, got "
                f"{type(self.recovery).__name__}")
        if self.pipeline_depth < 0:
            raise ValueError(
                f"pipeline_depth must be >= 0, got {self.pipeline_depth}")
        if self.policy is not None and self.fair_quantum is not None:
            raise ValueError(
                "fair_quantum configures the DEFAULT policy only; set "
                "the quantum on your policy instance instead")
        if self.megastep and self.mesh is not None:
            raise ValueError(
                "megastep is single-device: the fused cross-wing "
                "dispatch lowers both wings into one program and does "
                "not compose with mesh slot-sharding; drop mesh= or "
                "megastep=")


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Every control-plane policy knob, in one frozen value.

    Read by ``repro.fleet``'s :class:`~repro.fleet.autoscale.LaneAutoscaler`
    and :class:`~repro.fleet.rebalance.FleetRebalancer`; the serving layer
    itself never consults it (mechanism lives in ``StreamEngine``, policy
    lives here).

    Autoscaler knobs:

      * ``grow_backlog`` -- queued windows per slot above which a lane
        counts as backlogged; ``grow_patience`` consecutive backlogged
        observations trigger a grow (sustained pressure, not a blip).
      * ``shrink_occupancy`` -- occupied-slot fraction below which a lane
        counts as idle; ``shrink_patience`` consecutive idle observations
        trigger a shrink. Shrink patience should exceed grow patience so
        capacity is easy to gain and slow to give back.
      * ``min_slots`` / ``max_slots`` -- hard slot-count bounds; with a
        mesh, ``min_slots`` must stay divisible by the slot-axis size.
      * ``scale_step`` -- multiplicative resize factor (2 doubles/halves,
        keeping the per-``shape_key`` AOT cache population logarithmic in
        the slot range).

    Rebalancer knobs:

      * ``miss_weight`` -- how many queued-windows-per-slot one unit of
        deadline-miss rate is worth in the load score
        (``queued/slots + miss_weight * miss_rate``).
      * ``imbalance`` -- minimum hottest-minus-coldest score gap before a
        migration is considered (the hysteresis dead-band; migrations
        cost a lane drain, so small gaps are left alone).
      * ``cooldown`` -- observation ticks after a migration during which
        the rebalancer holds still, letting the moved load register in
        both engines' telemetry before it re-evaluates (anti-thrash).
      * ``fault_weight`` -- how many queued-windows-per-slot one unit of
        fault rate (retries + quarantines per completed window) is worth
        in the load score; a dead lane additionally scores a flat
        ``fault_weight`` penalty, so the rebalancer evacuates it.
    """

    grow_backlog: float = 2.0
    grow_patience: int = 2
    shrink_occupancy: float = 0.25
    shrink_patience: int = 4
    min_slots: int = 1
    max_slots: int = 64
    scale_step: int = 2
    miss_weight: float = 10.0
    imbalance: float = 1.0
    cooldown: int = 4
    fault_weight: float = 5.0

    def __post_init__(self):
        if self.min_slots < 1:
            raise ValueError(f"min_slots must be >= 1, got {self.min_slots}")
        if self.max_slots < self.min_slots:
            raise ValueError(
                f"max_slots ({self.max_slots}) must be >= min_slots "
                f"({self.min_slots})")
        if self.scale_step < 2:
            raise ValueError(
                f"scale_step must be >= 2, got {self.scale_step}")
        if self.grow_patience < 1 or self.shrink_patience < 1:
            raise ValueError("patience values must be >= 1")
        if self.grow_backlog <= 0.0:
            raise ValueError(
                f"grow_backlog must be > 0, got {self.grow_backlog}")
        if not 0.0 <= self.shrink_occupancy <= 1.0:
            raise ValueError(
                "shrink_occupancy must be in [0, 1], got "
                f"{self.shrink_occupancy}")
        if self.imbalance < 0.0 or self.miss_weight < 0.0:
            raise ValueError("imbalance and miss_weight must be >= 0")
        if self.fault_weight < 0.0:
            raise ValueError(
                f"fault_weight must be >= 0, got {self.fault_weight}")
        if self.cooldown < 0:
            raise ValueError(f"cooldown must be >= 0, got {self.cooldown}")


_suppressed = 0


@contextlib.contextmanager
def suppress_api_deprecations():
    """Silence :func:`warn_deprecated_call` for the duration of the block
    (re-entrant; used by the shims' internal legacy-form calls)."""
    global _suppressed
    _suppressed += 1
    try:
        yield
    finally:
        _suppressed -= 1


def warn_deprecated_call(owner, key: str, message: str) -> None:
    """Emit ``message`` as a one-shot DeprecationWarning.

    One-shot per ``(owner instance, key)``: the first offending call on
    an object warns, repeats stay quiet -- a migration nudge, not log
    spam. No-op inside :func:`suppress_api_deprecations`.
    """
    if _suppressed:
        return
    seen = owner.__dict__.setdefault("_api_warned", set())
    if key in seen:
        return
    seen.add(key)
    warnings.warn(message, DeprecationWarning, stacklevel=3)
