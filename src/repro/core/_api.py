"""The unified engine-construction surface + one-shot API deprecations.

:class:`EngineConfig` is the one construction surface for the serving
engines. ``StreamEngine`` construction accreted keyword arguments across
PRs 1-6 (``max_streams``, ``duration_us``, ``policy``/``fair_quantum``,
``fuse_fc``, ``pipeline_depth``, now ``mesh``); they are all fields of
this single frozen dataclass, passed as ``StreamEngine(params, cfg,
config)`` / ``StreamEngine(engines=..., config=config)`` and forwarded
to the wing engines via ``BatchedClosedLoop.from_config`` /
``FrameTCNEngine.from_config``. The legacy kwarg form still works as a
shim (bitwise-identical engines) that announces the replacement once.

Deprecation machinery: the session-handle redesign keeps every legacy
call form working -- ``StreamEngine.submit(stream_id, ...)``, the
engines' stateless ``infer(batch)``, and now kwarg construction -- but
each announces its replacement exactly once per owning instance via
:class:`DeprecationWarning`. The serving stack itself still drives the
legacy forms internally (the submit shim, the stateless lane fast path,
the B=1 ``ClosedLoopPipeline`` wrapper); those calls are wrapped in
:func:`suppress_api_deprecations` so only *user* code sees the warning.
"""
from __future__ import annotations

import contextlib
import dataclasses
import warnings
from typing import Any, Mapping, Optional, Union

__all__ = ["EngineConfig", "FleetConfig", "suppress_api_deprecations",
           "warn_deprecated_call"]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Everything that shapes a serving engine, in one frozen value.

    Fields (each previously its own ``StreamEngine`` kwarg):

      * ``max_streams`` -- batch slots per engine lane (or a
        ``{modality: count}`` mapping). With a ``mesh``, every lane's
        slot count must divide by the mesh's slot-axis size.
      * ``duration_us`` -- pin the one-bin-width-per-engine contract up
        front; ``None`` latches each engine's first submitted duration.
      * ``policy`` / ``fair_quantum`` -- slot assignment: a
        ``SlotPolicy`` instance, or just a quantum for the default
        ``FairQuantumPolicy`` (mutually exclusive, as before).
      * ``pipeline_depth`` -- ``>= 1`` dispatches steps asynchronously
        and returns results ``pipeline_depth`` steps late (bitwise
        order/value parity with the synchronous engine).
      * ``fuse_fc`` -- route the event wing's fc1/fc2 through the fused
        synapse+LIF Pallas kernel.
      * ``window_ms`` -- the control-tick window length for the
        real-time accounting.
      * ``mesh`` -- a :class:`jax.sharding.Mesh` (see
        :func:`repro.distributed.make_mesh`): the engines shard their
        slot axis over the mesh's data axis, one collective-free jit'd
        step per lane across all devices, bitwise-identical to the
        single-device engine.

    Frozen: a config is a value, shareable between engines and safe to
    put in tests' parametrize tables. ``replace`` derives variants
    (``dataclasses.replace(cfg, pipeline_depth=2)``).
    """

    max_streams: Union[int, Mapping[str, int]] = 8
    duration_us: Optional[int] = None
    policy: Optional[Any] = None           # SlotPolicy (kept Any: no
    fair_quantum: Optional[int] = None     # serving import from _api)
    pipeline_depth: int = 0
    fuse_fc: bool = False
    window_ms: float = 300.0
    mesh: Optional[Any] = None             # jax.sharding.Mesh

    def __post_init__(self):
        if self.pipeline_depth < 0:
            raise ValueError(
                f"pipeline_depth must be >= 0, got {self.pipeline_depth}")
        if self.policy is not None and self.fair_quantum is not None:
            raise ValueError(
                "fair_quantum configures the DEFAULT policy only; set "
                "the quantum on your policy instance instead")

@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Every control-plane policy knob, in one frozen value.

    Read by ``repro.fleet``'s :class:`~repro.fleet.autoscale.LaneAutoscaler`
    and :class:`~repro.fleet.rebalance.FleetRebalancer`; the serving layer
    itself never consults it (mechanism lives in ``StreamEngine``, policy
    lives here).

    Autoscaler knobs:

      * ``grow_backlog`` -- queued windows per slot above which a lane
        counts as backlogged; ``grow_patience`` consecutive backlogged
        observations trigger a grow (sustained pressure, not a blip).
      * ``shrink_occupancy`` -- occupied-slot fraction below which a lane
        counts as idle; ``shrink_patience`` consecutive idle observations
        trigger a shrink. Shrink patience should exceed grow patience so
        capacity is easy to gain and slow to give back.
      * ``min_slots`` / ``max_slots`` -- hard slot-count bounds; with a
        mesh, ``min_slots`` must stay divisible by the slot-axis size.
      * ``scale_step`` -- multiplicative resize factor (2 doubles/halves,
        keeping the per-``shape_key`` AOT cache population logarithmic in
        the slot range).

    Rebalancer knobs:

      * ``miss_weight`` -- how many queued-windows-per-slot one unit of
        deadline-miss rate is worth in the load score
        (``queued/slots + miss_weight * miss_rate``).
      * ``imbalance`` -- minimum hottest-minus-coldest score gap before a
        migration is considered (the hysteresis dead-band; migrations
        cost a lane drain, so small gaps are left alone).
      * ``cooldown`` -- observation ticks after a migration during which
        the rebalancer holds still, letting the moved load register in
        both engines' telemetry before it re-evaluates (anti-thrash).
    """

    grow_backlog: float = 2.0
    grow_patience: int = 2
    shrink_occupancy: float = 0.25
    shrink_patience: int = 4
    min_slots: int = 1
    max_slots: int = 64
    scale_step: int = 2
    miss_weight: float = 10.0
    imbalance: float = 1.0
    cooldown: int = 4

    def __post_init__(self):
        if self.min_slots < 1:
            raise ValueError(f"min_slots must be >= 1, got {self.min_slots}")
        if self.max_slots < self.min_slots:
            raise ValueError(
                f"max_slots ({self.max_slots}) must be >= min_slots "
                f"({self.min_slots})")
        if self.scale_step < 2:
            raise ValueError(
                f"scale_step must be >= 2, got {self.scale_step}")
        if self.grow_patience < 1 or self.shrink_patience < 1:
            raise ValueError("patience values must be >= 1")
        if self.grow_backlog <= 0.0:
            raise ValueError(
                f"grow_backlog must be > 0, got {self.grow_backlog}")
        if not 0.0 <= self.shrink_occupancy <= 1.0:
            raise ValueError(
                "shrink_occupancy must be in [0, 1], got "
                f"{self.shrink_occupancy}")
        if self.imbalance < 0.0 or self.miss_weight < 0.0:
            raise ValueError("imbalance and miss_weight must be >= 0")
        if self.cooldown < 0:
            raise ValueError(f"cooldown must be >= 0, got {self.cooldown}")


_suppressed = 0


@contextlib.contextmanager
def suppress_api_deprecations():
    """Silence :func:`warn_deprecated_call` for the duration of the block
    (re-entrant; used by the shims' internal legacy-form calls)."""
    global _suppressed
    _suppressed += 1
    try:
        yield
    finally:
        _suppressed -= 1


def warn_deprecated_call(owner, key: str, message: str) -> None:
    """Emit ``message`` as a one-shot DeprecationWarning.

    One-shot per ``(owner instance, key)``: the first offending call on
    an object warns, repeats stay quiet -- a migration nudge, not log
    spam. No-op inside :func:`suppress_api_deprecations`.
    """
    if _suppressed:
        return
    seen = owner.__dict__.setdefault("_api_warned", set())
    if key in seen:
        return
    seen.add(key)
    warnings.warn(message, DeprecationWarning, stacklevel=3)
