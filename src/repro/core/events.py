"""Event-stream handling: the ColibriES acquisition + preprocessing stages.

ColibriES streams DVS events (x, y, t, polarity) from the camera through a
dedicated uDMA interface into L2, then the 8-core RISC-V cluster assembles
the spike streams for the SNE (and re-tiles streams between layers when the
network is executed in SNE's time-domain-multiplexed tiled mode).

TPU-native adaptation: per-event DMA has no analogue on a synchronous dense
accelerator, so acquisition becomes a host-side pipeline that delivers
fixed-duration event windows, and preprocessing becomes *event
voxelization*: sorted segment-sums binning events into a dense
(T, P, H, W) spike tensor -- the format the fused LIF scan kernel consumes.
The information content matches what SNE receives (time-binned spikes at the
training time resolution); only the us-level asynchronicity is coarsened to
the bin width, exactly as the paper's own 300 ms window batching does.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "EventWindow",
    "PaddedEventBatch",
    "pad_event_windows",
    "next_pow2",
    "voxelize",
    "voxelize_batch",
    "synthetic_gesture_events",
    "DVS_SENSOR_H",
    "DVS_SENSOR_W",
]

# DVS128 sensor geometry (IBM DVS-Gesture dataset).
DVS_SENSOR_H = 128
DVS_SENSOR_W = 128


@dataclasses.dataclass
class EventWindow:
    """A fixed-duration window of DVS events (the acquisition unit).

    Attributes:
      x, y: int32 pixel coordinates, shape (N,).
      t: int32 microsecond timestamps relative to window start, shape (N,).
      p: int32 polarity in {0, 1}, shape (N,).
      duration_us: window length in microseconds (paper: 300 ms windows).
      label: optional int class label (11 classes for DVS-Gesture).
    """

    x: np.ndarray
    y: np.ndarray
    t: np.ndarray
    p: np.ndarray
    duration_us: int
    label: int = -1

    @property
    def num_events(self) -> int:
        return int(self.x.shape[0])


def next_pow2(n: int, floor: int = 1024) -> int:
    """Round up to a power of two (>= floor): the event-count bucketing
    rule shared by the B=1 pipeline wrapper and the streaming engine, so
    both compile one executable per bucket. Padding amount never changes
    results (voxel sums are exact)."""
    n = max(int(n), floor)
    return 1 << (n - 1).bit_length()


@dataclasses.dataclass
class PaddedEventBatch:
    """A batch of event windows padded to a common event count.

    The unit the streaming engine feeds to the batched closed loop: ``B``
    fixed batch slots, each holding one window's events left-aligned in a
    ``(B, max_events)`` buffer. Empty slots (``window=None``) carry zero
    valid events and voxelize to an all-zero grid, so a partially filled
    batch runs through the same jit'd computation as a full one.

    Attributes:
      x, y, t, p: int32 arrays, shape (B, max_events); padding is zeros.
      valid: bool array (B, max_events) marking real events.
      num_events: int64 array (B,), true event count per slot.
      occupied: bool array (B,), True where the slot holds a window --
        distinct from ``num_events == 0``: a real window from a quiet
        sensor has zero events but is still occupied and gets a result.
      duration_us: shared window duration (all windows in a batch must
        agree -- they are voxelized with one bin width).
      labels: int array (B,), -1 where unknown/empty.
    """

    x: np.ndarray
    y: np.ndarray
    t: np.ndarray
    p: np.ndarray
    valid: np.ndarray
    num_events: np.ndarray
    occupied: np.ndarray
    duration_us: int
    labels: np.ndarray

    @property
    def batch_size(self) -> int:
        return int(self.x.shape[0])

    @property
    def max_events(self) -> int:
        return int(self.x.shape[1])


def pad_event_windows(
    windows,
    *,
    max_events: int | None = None,
    batch_size: int | None = None,
    duration_us: int | None = None,
) -> PaddedEventBatch:
    """Pack a list of :class:`EventWindow` (or ``None`` for empty slots)
    into a :class:`PaddedEventBatch`.

    Args:
      windows: sequence of windows; ``None`` entries become empty slots.
      max_events: pad target; defaults to the largest window. Must be
        >= every window's event count (no silent truncation).
      batch_size: pad the batch with trailing empty slots up to this size
        (the engine's fixed slot count); defaults to ``len(windows)``.
      duration_us: required if every entry is ``None``; otherwise taken
        from the windows (which must all agree).
    """
    windows = list(windows)
    b = batch_size if batch_size is not None else len(windows)
    if b == 0:
        raise ValueError("empty batch: give at least one window (slot) or "
                         "a batch_size > 0")
    if len(windows) > b:
        raise ValueError(f"{len(windows)} windows > batch_size={b}")
    windows = windows + [None] * (b - len(windows))

    durations = {w.duration_us for w in windows if w is not None}
    if len(durations) > 1:
        raise ValueError(f"mixed window durations in one batch: {durations}")
    if durations:
        duration_us = durations.pop()
    elif duration_us is None:
        raise ValueError("all slots empty: duration_us must be given")

    counts = [0 if w is None else w.num_events for w in windows]
    n = max_events if max_events is not None else max(max(counts), 1)
    if max(counts) > n:
        raise ValueError(f"max_events={n} < largest window ({max(counts)})")
    occupied = np.asarray([w is not None for w in windows])

    mk = lambda: np.zeros((b, n), np.int32)
    x, y, t, p = mk(), mk(), mk(), mk()
    valid = np.zeros((b, n), bool)
    labels = np.full(b, -1, np.int32)
    for i, w in enumerate(windows):
        if w is None:
            continue
        c = counts[i]
        x[i, :c], y[i, :c] = w.x, w.y
        t[i, :c], p[i, :c] = w.t, w.p
        valid[i, :c] = True
        labels[i] = w.label
    return PaddedEventBatch(
        x=x, y=y, t=t, p=p, valid=valid,
        num_events=np.asarray(counts, np.int64), occupied=occupied,
        duration_us=int(duration_us), labels=labels,
    )


def voxelize(
    x: jnp.ndarray,
    y: jnp.ndarray,
    t: jnp.ndarray,
    p: jnp.ndarray,
    *,
    duration_us: int,
    time_bins: int,
    height: int = DVS_SENSOR_H,
    width: int = DVS_SENSOR_W,
    valid: jnp.ndarray | None = None,
    binary: bool = True,
) -> jnp.ndarray:
    """Bin an event stream into a dense (T, 2, H, W) spike tensor.

    This is the cluster preprocessing step of the paper mapped to TPU idiom:
    a scatter-add implemented as ``segment_sum`` over linearized voxel
    indices (sorted-segment form is TPU-friendly; no per-event control
    flow).

    Args:
      x, y, t, p: event arrays, shape (N,). May be padded; see ``valid``.
      duration_us: window duration; timestamps are clipped to it.
      time_bins: number of temporal bins T (the SNN simulation steps).
      valid: optional bool mask (N,) marking real events in a padded batch.
      binary: if True the result is clipped to {0,1} spikes (SNE consumes
        unary spike trains); otherwise event counts are preserved.

    Returns:
      float32 tensor of shape (time_bins, 2, height, width).
    """
    n = x.shape[0]
    t = jnp.clip(t, 0, duration_us - 1)
    # Integer-divide by the bin width (avoids 64-bit t*time_bins overflow).
    bin_width = max(duration_us // time_bins, 1)
    tb = jnp.minimum(t // bin_width, time_bins - 1).astype(jnp.int32)
    flat = ((tb * 2 + p) * height + y) * width + x
    num_voxels = time_bins * 2 * height * width
    if valid is None:
        weights = jnp.ones((n,), jnp.float32)
    else:
        weights = valid.astype(jnp.float32)
        flat = jnp.where(valid, flat, num_voxels - 1)  # park padding in last voxel
        # padded events contribute weight 0, so parking is harmless
    counts = jax.ops.segment_sum(weights, flat, num_segments=num_voxels)
    vox = counts.reshape(time_bins, 2, height, width)
    if binary:
        vox = jnp.clip(vox, 0.0, 1.0)
    return vox


def voxelize_batch(
    x: jnp.ndarray,
    y: jnp.ndarray,
    t: jnp.ndarray,
    p: jnp.ndarray,
    valid: jnp.ndarray,
    *,
    duration_us: int,
    time_bins: int,
    height: int = DVS_SENSOR_H,
    width: int = DVS_SENSOR_W,
    binary: bool = True,
) -> jnp.ndarray:
    """Batched voxelization: padded (B, N) event arrays -> (B, T, 2, H, W).

    One flattened ``segment_sum`` over ``B * N`` events with per-stream
    voxel offsets -- a single scatter-add for the whole batch rather than
    ``B`` sequential ones (or a vmap of them), so the streaming engine
    voxelizes all its batch slots in one jit'd call. Because voxel counts
    are sums of exactly-representable 0/1 weights, the result is bitwise
    identical to per-window :func:`voxelize` regardless of batch size or
    padding amount.
    """
    b, n = x.shape
    t = jnp.clip(t, 0, duration_us - 1)
    bin_width = max(duration_us // time_bins, 1)
    tb = jnp.minimum(t // bin_width, time_bins - 1).astype(jnp.int32)
    flat = ((tb * 2 + p) * height + y) * width + x
    num_voxels = time_bins * 2 * height * width
    # Single-window voxelize drops out-of-range events via segment_sum's
    # out-of-bounds rule; after adding per-stream offsets that rule would
    # leak them into the NEXT stream's voxels instead, so mask them here
    # (weight 0, parked in the last slot) -- same drop semantics, and no
    # malformed event on one sensor can corrupt another stream.
    keep = valid & (flat >= 0) & (flat < num_voxels)
    offsets = jnp.arange(b, dtype=jnp.int32)[:, None] * num_voxels
    flat = jnp.where(keep, flat + offsets, b * num_voxels - 1)
    weights = keep.astype(jnp.float32)
    counts = jax.ops.segment_sum(
        weights.reshape(-1), flat.reshape(-1), num_segments=b * num_voxels
    )
    vox = counts.reshape(b, time_bins, 2, height, width)
    if binary:
        vox = jnp.clip(vox, 0.0, 1.0)
    return vox


def synthetic_gesture_events(
    rng: np.random.Generator,
    label: int,
    *,
    duration_us: int = 300_000,
    mean_events: int = 60_000,
    height: int = DVS_SENSOR_H,
    width: int = DVS_SENSOR_W,
    num_classes: int = 11,
) -> EventWindow:
    """Generate a synthetic DVS-Gesture-like event window.

    The DVS-Gesture classes are hand/arm motions (waves, circles, ...); a
    DVS camera reports events along moving edges. We synthesize a class-
    dependent parametric motion (distinct angular frequency / orbit / phase
    per class) of a small edge cluster plus uniform background noise, which
    yields event windows whose spatio-temporal statistics (event rate,
    spatial locality, motion coherence) are DVS-like and which a
    spatio-temporal classifier must integrate over time to separate.
    """
    assert 0 <= label < num_classes
    n = int(rng.poisson(mean_events))
    n = max(n, 1024)
    # Class-dependent motion parameters: deterministic per label.
    w0 = 2.0 * np.pi * (1.0 + 0.7 * label)           # angular frequency
    radius = 20.0 + 3.0 * (label % 4)                 # orbit radius
    cx = width / 2.0 + 12.0 * np.cos(2.0 * np.pi * label / num_classes)
    cy = height / 2.0 + 12.0 * np.sin(2.0 * np.pi * label / num_classes)
    phase = 2.0 * np.pi * label / num_classes
    vertical = label % 2 == 0                          # motion axis flavour

    t = np.sort(rng.integers(0, duration_us, size=n)).astype(np.int64)
    tau = t.astype(np.float64) / duration_us
    ang = w0 * tau + phase
    px = cx + radius * np.cos(ang)
    py = cy + radius * (np.sin(2 * ang) if vertical else np.sin(ang))
    # Events scatter around the moving edge.
    sx = rng.normal(0.0, 3.0, size=n)
    sy = rng.normal(0.0, 3.0, size=n)
    x = np.clip(np.round(px + sx), 0, width - 1).astype(np.int32)
    y = np.clip(np.round(py + sy), 0, height - 1).astype(np.int32)
    # Polarity follows the direction of intensity change along the motion.
    p = ((np.cos(ang) + rng.normal(0, 0.35, size=n)) > 0).astype(np.int32)
    # ~10% uniform background noise events.
    noise = rng.random(n) < 0.10
    x = np.where(noise, rng.integers(0, width, size=n), x).astype(np.int32)
    y = np.where(noise, rng.integers(0, height, size=n), y).astype(np.int32)
    p = np.where(noise, rng.integers(0, 2, size=n), p).astype(np.int32)
    return EventWindow(
        x=x, y=y, t=t.astype(np.int32), p=p,
        duration_us=duration_us, label=label,
    )
