"""Kraken/ColibriES energy & latency model (paper Tables I and III).

The paper's evaluation axes are energy and latency measured on silicon at
VDD = 0.65 V. Silicon cannot be measured in this container, so we model the
three Kraken power domains with the paper's measured idle/active powers and
workload-proportional stage latencies, calibrated such that the paper's
nominal DVS-Gesture workload (300 ms window) reproduces Table III:

    stage                 time        P_idle   P_active   energy
    Data acquisition (FC)   1.5 ms     3.5 mW    3.8 mW   0.006 mJ
    Preprocessing (cluster) 131  ms    6.5 mW   34   mW   4.6  mJ
    SNN inference (SNE)     32   ms    7.7 mW   44   mW   1.4  mJ
    Total                   164.5 ms  17.7 mW   35.6 mW   7.7  mJ

Latency scaling laws (documented modelling choices):
  * acquisition time  ~ events / uDMA interface rate,
  * preprocessing time ~ sum over layers of (input spikes x engine passes),
    i.e. the cluster re-assembles each layer's input stream once per tile
    pass of SNE's time-domain-multiplexed execution,
  * SNE inference time ~ synaptic operations (events x fanout), SNE being
    energy/latency-proportional to synops (Di Mauro et al. 2022).

Total energy follows the paper's note (b): sum of active-stage energy plus
idle energy of the inactive domains during each stage (sequential stages;
the FC is always on).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Sequence, Tuple

from repro.core.tiling import TilePlan

__all__ = [
    "PowerDomain",
    "KRAKEN_DOMAINS",
    "CUTIE_DOMAIN",
    "FRAME_DOMAINS",
    "StageExecution",
    "pipeline_energy",
    "KrakenModel",
    "NOMINAL",
    "NOMINAL_FRAME",
]


@dataclasses.dataclass(frozen=True)
class PowerDomain:
    name: str
    p_idle_mw: float
    p_active_mw: float


# Paper Table III, VDD = 0.65 V. These three domains are the *event-wing*
# accounting set: the paper's measured pipeline powers FC + cluster + SNE
# (CUTIE is power-gated during the event experiments, so it contributes no
# idle cross-term -- keeping this dict as-is preserves the Table III
# calibration bitwise).
KRAKEN_DOMAINS: Dict[str, PowerDomain] = {
    "fc": PowerDomain("fc", 3.5, 3.8),
    "cluster": PowerDomain("cluster", 6.5, 34.0),
    "sne": PowerDomain("sne", 7.7, 44.0),
}

# Kraken's second accelerator domain. The paper evaluates only the event
# wing ("the first step of full-system evaluation"), so CUTIE's figures are
# extrapolated from the CUTIE silicon results (Scherer et al., 2022: fully
# ternary MACs, ~10x the energy efficiency of the cluster on dense CNNs)
# at the same 0.65 V operating point -- documented modelling, not paper
# measurement.
CUTIE_DOMAIN = PowerDomain("cutie", 1.6, 14.0)

# Frame-wing accounting set: FC + cluster + CUTIE (SNE power-gated), the
# mirror image of the event wing's domain set.
FRAME_DOMAINS: Dict[str, PowerDomain] = {
    "fc": KRAKEN_DOMAINS["fc"],
    "cluster": KRAKEN_DOMAINS["cluster"],
    "cutie": CUTIE_DOMAIN,
}


@dataclasses.dataclass(frozen=True)
class StageExecution:
    """One sequential pipeline stage: ``domain`` active, others idle."""

    name: str
    domain: str
    time_ms: float


def pipeline_energy(
    stages: Sequence[StageExecution],
    domains: Mapping[str, PowerDomain] = KRAKEN_DOMAINS,
) -> Dict[str, object]:
    """Energy accounting per the paper's Table III conventions.

    Returns a dict with per-stage active energy, total time, total energy
    (active + idle-of-inactive), idle power, and average power.
    """
    total_ms = sum(s.time_ms for s in stages)
    per_stage = {}
    active_mj = 0.0
    idle_mj = 0.0
    for s in stages:
        act = domains[s.domain].p_active_mw * s.time_ms * 1e-3
        per_stage[s.name] = {
            "time_ms": s.time_ms,
            "active_energy_mj": act,
            "domain": s.domain,
        }
        active_mj += act
        for d in domains.values():
            if d.name != s.domain:
                idle_mj += d.p_idle_mw * s.time_ms * 1e-3
    total_mj = active_mj + idle_mj
    return {
        "stages": per_stage,
        "total_time_ms": total_ms,
        "active_energy_mj": active_mj,
        "idle_energy_mj": idle_mj,
        "total_energy_mj": total_mj,
        "p_idle_mw": sum(d.p_idle_mw for d in domains.values()),
        "p_avg_mw": total_mj / (total_ms * 1e-3) if total_ms else 0.0,
        # Paper Table III note (c) "average total power consumption during
        # inference" = time-weighted mean of the ACTIVE domains' power
        # (35.6 mW for the nominal workload; idle cross-terms excluded).
        "p_avg_active_mw": (active_mj / (total_ms * 1e-3)
                            if total_ms else 0.0),
    }


# ----------------------------------------------------------------------
# Workload -> latency calibration.
#
# Nominal paper workload (300 ms DVS-Gesture window). Event count per
# window is not printed in the paper; 60k events/window (200 kev/s) is the
# DVS-Gesture per-sample average reported by Amir et al. (2017) order of
# magnitude. All three rate constants below are solved so that the nominal
# workload reproduces Table III latencies exactly; other workloads scale
# linearly in their drivers (events, spike x pass traffic, synops).
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class NominalWorkload:
    window_ms: float = 300.0
    events: float = 60_000.0
    # Per-layer input spike counts per window for the Table II net at the
    # firing rates our trained SNN exhibits (~5% conv, ~10% fc), plus each
    # layer's engine passes from the tiling planner (conv1 runs in 2 passes:
    # 32*32*16 = 16384 neurons > 8192 capacity).
    layer_in_spikes: Tuple[float, ...] = (60_000.0, 13_107.0, 3_277.0, 819.0)
    layer_passes: Tuple[int, ...] = (2, 1, 1, 1)
    layer_fanout: Tuple[float, ...] = (144.0, 288.0, 512.0, 11.0)
    # Table III targets.
    t_acq_ms: float = 1.5
    t_pre_ms: float = 131.0
    t_sne_ms: float = 32.0

    @property
    def pre_traffic(self) -> float:
        return sum(s * p for s, p in zip(self.layer_in_spikes,
                                         self.layer_passes))

    @property
    def synops(self) -> float:
        return sum(s * f for s, f in zip(self.layer_in_spikes,
                                         self.layer_fanout))


NOMINAL = NominalWorkload()


@dataclasses.dataclass(frozen=True)
class NominalFrameWorkload:
    """Calibration point for the frame wing (modelled, see CUTIE_DOMAIN).

    A 128x128 grayscale frame through the CUTIE-sized TCN: acquisition
    over the parallel camera interface + uDMA, cluster normalization of
    the pixel buffer, then CUTIE's fixed dense schedule. CUTIE latency is
    workload-independent (dense MACs every frame); only switching energy
    varies with operand activity.
    """

    window_ms: float = 300.0
    pixels: float = 128.0 * 128.0
    # Dense MACs of the mirror TCN on a 128x128 input (conv1 147456 +
    # conv2 1179648 + fc1 1048576 + fc2 5632), the calibration anchor.
    macs: float = 2_381_312.0
    t_acq_ms: float = 0.6       # frame DMA (parallel IF is faster than DVS)
    t_pre_ms: float = 9.0       # cluster pixel normalization + packing
    t_cutie_ms: float = 2.2     # CUTIE dense schedule


NOMINAL_FRAME = NominalFrameWorkload()


class KrakenModel:
    """Calibrated latency/energy model of the ColibriES pipeline.

    ``closed_loop`` accounts the event wing (FC + cluster + SNE, paper
    Table III); ``frame_loop`` accounts the frame wing (FC + cluster +
    CUTIE, modelled -- see :data:`CUTIE_DOMAIN`). One instance serves both
    engines of the heterogeneous platform.
    """

    def __init__(self, nominal: NominalWorkload = NOMINAL,
                 nominal_frame: NominalFrameWorkload = NOMINAL_FRAME):
        self.nominal = nominal
        # Solve rate constants against Table III.
        self.acq_events_per_ms = nominal.events / nominal.t_acq_ms
        self.pre_traffic_per_ms = nominal.pre_traffic / nominal.t_pre_ms
        self.sne_synops_per_ms = nominal.synops / nominal.t_sne_ms
        # Frame-wing rate constants (same linear-scaling convention).
        self.nominal_frame = nominal_frame
        self.acq_pixels_per_ms = nominal_frame.pixels / nominal_frame.t_acq_ms
        self.pre_pixels_per_ms = nominal_frame.pixels / nominal_frame.t_pre_ms
        self.cutie_macs_per_ms = nominal_frame.macs / nominal_frame.t_cutie_ms

    # -- stage latencies -------------------------------------------------
    def t_acquisition_ms(self, events: float) -> float:
        return events / self.acq_events_per_ms

    def t_preprocess_ms(
        self,
        layer_in_spikes: Sequence[float],
        plans: Sequence[TilePlan] | None = None,
        layer_passes: Sequence[int] | None = None,
    ) -> float:
        if layer_passes is None:
            if plans is None:
                raise ValueError("need plans or layer_passes")
            layer_passes = [p.passes for p in plans]
        traffic = sum(s * p for s, p in zip(layer_in_spikes, layer_passes))
        return traffic / self.pre_traffic_per_ms

    def t_sne_ms(
        self,
        layer_in_spikes: Sequence[float],
        layer_fanout: Sequence[float],
    ) -> float:
        synops = sum(s * f for s, f in zip(layer_in_spikes, layer_fanout))
        return synops / self.sne_synops_per_ms

    # -- end-to-end ------------------------------------------------------
    def closed_loop(
        self,
        events: float,
        layer_in_spikes: Sequence[float],
        layer_fanout: Sequence[float],
        layer_passes: Sequence[int],
    ) -> Dict[str, object]:
        """Full acquisition -> preprocessing -> inference -> actuation loop.

        Actuation (PWM update) is < 1 us per the paper and accounted as
        zero-time (paper: "negligible compared to data acquisition and
        processing").
        """
        stages = [
            StageExecution("data_acquisition", "fc",
                           self.t_acquisition_ms(events)),
            StageExecution("preprocessing", "cluster",
                           self.t_preprocess_ms(layer_in_spikes,
                                                layer_passes=layer_passes)),
            StageExecution("snn_inference", "sne",
                           self.t_sne_ms(layer_in_spikes, layer_fanout)),
        ]
        out = pipeline_energy(stages)
        out["actuation_latency_us"] = 1.0  # upper bound per paper Sec. III
        return out

    def frame_loop(
        self,
        pixels: float,
        macs: float,
        activity: float = 1.0,
    ) -> Dict[str, object]:
        """Frame-wing loop: acquire -> normalize -> CUTIE infer -> actuate.

        Args:
          pixels: frame pixel count (drives acquisition + preprocessing).
          macs: dense MAC count of the TCN (drives CUTIE latency).
          activity: mean non-zero operand density in [0, 1]; CUTIE's
            switching energy scales with operand activity (Scherer et al.,
            2022), modelled as interpolating the active power between the
            domain's idle floor and its full-activity ceiling.
        """
        activity = min(max(float(activity), 0.0), 1.0)
        cutie = FRAME_DOMAINS["cutie"]
        domains = dict(FRAME_DOMAINS)
        domains["cutie"] = PowerDomain(
            cutie.name, cutie.p_idle_mw,
            cutie.p_idle_mw
            + (cutie.p_active_mw - cutie.p_idle_mw) * activity)
        stages = [
            StageExecution("data_acquisition", "fc",
                           pixels / self.acq_pixels_per_ms),
            StageExecution("preprocessing", "cluster",
                           pixels / self.pre_pixels_per_ms),
            StageExecution("tcn_inference", "cutie",
                           macs / self.cutie_macs_per_ms),
        ]
        out = pipeline_energy(stages, domains)
        out["actuation_latency_us"] = 1.0
        out["cutie_activity"] = activity
        return out
