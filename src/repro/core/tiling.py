"""Capacity-constrained tiled execution planning -- SNE's TDM mode on TPU.

Paper, Sec. III: "neural networks that exceed SNE's output neuron capacity
are executed on the accelerator in a tiled way, and the SNE is used in a
time-domain-multiplexing fashion. The preprocessing step performed on the
cluster is necessary to assemble a single input event stream from multiple
output tiles and create the tiled input streams for the tiles of the
successive layer."

The transferable mechanism is: *given a fixed on-engine capacity, split a
layer's output neurons into tiles that fit, execute tiles sequentially
(time-multiplexed), and re-assemble the output stream between layers*.

On TPU the capacity constraint is VMEM bytes instead of SNE's output-neuron
count. The same planner drives both:

  * the SNE-faithful path (``capacity_kind='neurons'``, SNE's 8192-neuron
    engine) used by the closed-loop pipeline's latency model, and
  * the Pallas ``lif_scan`` kernel's BlockSpec chooser
    (``capacity_kind='vmem_bytes'``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Sequence, Tuple

__all__ = ["TilePlan", "plan_layer_tiles", "plan_network", "SNE_NEURON_CAPACITY"]

# SNE engine capacity (Di Mauro et al. 2022: 8 slices x 1024 neurons).
SNE_NEURON_CAPACITY = 8192


@dataclasses.dataclass(frozen=True)
class TilePlan:
    """Tiling of one layer's output volume (H, W, C) into engine passes."""

    layer: str
    shape: Tuple[int, int, int]          # output (H, W, C)
    tile: Tuple[int, int, int]           # per-pass tile (h, w, c)
    grid: Tuple[int, int, int]           # number of tiles per dim
    passes: int                          # total sequential engine passes
    neurons_per_pass: int
    utilization: float                   # neurons_per_pass / capacity

    @property
    def tiled(self) -> bool:
        return self.passes > 1


def _split(n: int, max_piece: int) -> Tuple[int, int]:
    """Split extent n into ceil(n/p) pieces of size p <= max_piece, p | tiles
    chosen to minimize waste."""
    pieces = math.ceil(n / max_piece)
    piece = math.ceil(n / pieces)
    return piece, pieces


def plan_layer_tiles(
    layer: str,
    shape: Tuple[int, int, int],
    capacity: int = SNE_NEURON_CAPACITY,
    *,
    bytes_per_neuron: int = 1,
    capacity_kind: str = "neurons",
) -> TilePlan:
    """Plan the TDM tiling of one layer.

    Channel-first splitting (SNE maps output feature maps to slices), then
    spatial if a single channel plane still exceeds capacity.

    Args:
      shape: (H, W, C) output volume.
      capacity: neuron count (``capacity_kind='neurons'``) or VMEM byte
        budget (``'vmem_bytes'``, divided by ``bytes_per_neuron``).
    """
    h, w, c = shape
    cap = capacity if capacity_kind == "neurons" else capacity // bytes_per_neuron
    if cap <= 0:
        raise ValueError("capacity too small")

    plane = h * w
    if plane * c <= cap:
        tile, grid = (h, w, c), (1, 1, 1)
    elif plane <= cap:
        cmax = cap // plane
        cpiece, cgrid = _split(c, cmax)
        tile, grid = (h, w, cpiece), (1, 1, cgrid)
    else:
        # Split a single channel spatially (rows first, then columns).
        hmax = max(cap // w, 1)
        hpiece, hgrid = _split(h, hmax)
        if hpiece * w <= cap:
            tile, grid = (hpiece, w, 1), (hgrid, 1, c)
        else:
            wpiece, wgrid = _split(w, max(cap, 1))
            tile, grid = (1, wpiece, 1), (h, wgrid, c)

    passes = grid[0] * grid[1] * grid[2]
    neurons = tile[0] * tile[1] * tile[2]
    return TilePlan(
        layer=layer, shape=shape, tile=tile, grid=grid, passes=passes,
        neurons_per_pass=neurons, utilization=neurons / cap,
    )


def plan_network(
    layer_shapes: Sequence[Tuple[str, Tuple[int, int, int]]],
    capacity: int = SNE_NEURON_CAPACITY,
    **kw,
) -> List[TilePlan]:
    """Plan every layer of a network; list order == execution order."""
    return [plan_layer_tiles(name, shape, capacity, **kw)
            for name, shape in layer_shapes]
