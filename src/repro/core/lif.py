"""Leaky-integrate-and-fire neuron dynamics with surrogate gradients.

This models the neuron implemented in silicon by SNE (the Kraken SoC's
sparse neural engine). Per the paper (Sec. III), training uses
spatio-temporal backpropagation (STBP, Wu et al. 2018) with the neuron
dynamics "accurately modeled ... to closely reflect the hardware
implementation", i.e. a discrete-time LIF with multiplicative leak and
reset-to-zero:

    V[t] = alpha * V[t-1] * (1 - S[t-1]) + I[t]
    S[t] = Heaviside(V[t] - v_th)

The Heaviside gets a rectangular surrogate derivative (STBP eq. 24):
    dS/dV ~= 1/a * 1{|V - v_th| < a/2}

Two execution paths exist:
  * ``lif_scan_reference`` -- pure jnp ``lax.scan`` (the oracle; also the
    bwd path used by the custom VJP).
  * ``repro.kernels.lif_scan`` -- the fused Pallas kernel (SNE analogue;
    membrane state resident in VMEM for the whole temporal scan).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "LIFParams",
    "spike_surrogate",
    "lif_step",
    "lif_scan_reference",
]


@dataclasses.dataclass(frozen=True)
class LIFParams:
    """LIF neuron constants (hardware-calibrated in SNE's case)."""

    alpha: float = 0.875     # membrane leak per step (SNE uses 1 - 2^-k leaks)
    v_th: float = 0.5        # firing threshold
    surrogate_width: float = 2.0  # 'a' in the STBP rectangular surrogate


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def spike_surrogate(v: jnp.ndarray, v_th: jnp.ndarray, width: float = 1.0):
    """Heaviside spike with rectangular surrogate gradient (STBP)."""
    return (v >= v_th).astype(v.dtype)


def _spike_fwd(v, v_th, width):
    return spike_surrogate(v, v_th, width), (v, v_th)


def _spike_bwd(width, res, g):
    v, v_th = res
    inside = (jnp.abs(v - v_th) < (width / 2.0)).astype(v.dtype)
    grad_v = g * inside / width
    return (grad_v, -jnp.sum(grad_v).astype(v_th.dtype) * 0)  # v_th: no grad


spike_surrogate.defvjp(_spike_fwd, _spike_bwd)


def lif_step(
    v: jnp.ndarray,
    s_prev: jnp.ndarray,
    current: jnp.ndarray,
    p: LIFParams,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One LIF timestep. Returns (new membrane f32, new spikes).

    Membrane is carried in f32 (the kernel/oracle numerical contract --
    SNE keeps wide fixed-point state in-engine).
    """
    v_new = (p.alpha * v.astype(jnp.float32) * (1.0 - s_prev.astype(jnp.float32))
             + current.astype(jnp.float32))
    s_new = spike_surrogate(v_new, jnp.float32(p.v_th),
                            p.surrogate_width).astype(current.dtype)
    return v_new, s_new


def lif_scan_reference(
    currents: jnp.ndarray,
    p: LIFParams,
    v0: jnp.ndarray | None = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Scan LIF dynamics over time (pure jnp oracle).

    Args:
      currents: input currents, shape (T, ...) -- leading axis is time.
      p: neuron constants.
      v0: optional initial membrane, shape currents.shape[1:].

    Returns:
      (spikes, v_final): spikes has the same shape as ``currents``;
      v_final the final membrane state.

    Stateful-streaming contract: the initial spike state is the one
    *implied* by the membrane, ``s0 = (v0 >= v_th)`` -- ``v_final`` is
    returned pre-reset, so a caller chaining windows via
    ``v0=v_final`` gets exactly the uninterrupted scan
    (``scan(cur[:k]) ++ scan(cur[k:], v0=v_fin)`` == ``scan(cur)``,
    bitwise). This matches the Pallas kernel and ``lif_scan_ref``, whose
    reset masks are computed from the carried membrane directly.
    """
    if v0 is None:
        v0 = jnp.zeros(currents.shape[1:], jnp.float32)
        s0 = jnp.zeros(currents.shape[1:], currents.dtype)
    else:
        s0 = spike_surrogate(v0.astype(jnp.float32), jnp.float32(p.v_th),
                             p.surrogate_width).astype(currents.dtype)

    def step(carry, i_t):
        v, s = carry
        v, s = lif_step(v, s, i_t, p)
        return (v, s), s

    (v_final, _), spikes = jax.lax.scan(
        step, (v0.astype(jnp.float32), s0), currents)
    return spikes, v_final.astype(currents.dtype)
