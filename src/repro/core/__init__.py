"""ColibriES core: the paper's contribution as composable JAX modules.

Submodules:
  events   -- DVS event windows, voxelization (acquisition + preprocessing)
  lif      -- LIF neuron dynamics with STBP surrogate gradients (SNE model)
  snn      -- the Table II DVS-Gesture spiking CNN + STBP loss
  ternary  -- TWN ternary quantization + 2-bit packing (CUTIE model)
  tiling   -- capacity-constrained TDM tiling planner (SNE tiled execution)
  pipeline -- the closed acquisition->preprocess->infer->actuate loop
  energy   -- calibrated Kraken power/latency model (Tables I & III)
"""
from repro.core.lif import LIFParams, lif_scan_reference, lif_step, spike_surrogate
from repro.core.snn import SNNConfig, init_snn, snn_apply, snn_logits, snn_loss
from repro.core.ternary import pack2bit, ternarize, ternary_ste, unpack2bit
from repro.core.tiling import SNE_NEURON_CAPACITY, TilePlan, plan_layer_tiles, plan_network
from repro.core.energy import KRAKEN_DOMAINS, KrakenModel, NOMINAL, StageExecution, pipeline_energy
from repro.core.pipeline import (BatchedClosedLoop, ClosedLoopPipeline,
                                 ClosedLoopResult, pwm_from_logits)

__all__ = [
    "LIFParams", "lif_scan_reference", "lif_step", "spike_surrogate",
    "SNNConfig", "init_snn", "snn_apply", "snn_logits", "snn_loss",
    "pack2bit", "ternarize", "ternary_ste", "unpack2bit",
    "SNE_NEURON_CAPACITY", "TilePlan", "plan_layer_tiles", "plan_network",
    "KRAKEN_DOMAINS", "KrakenModel", "NOMINAL", "StageExecution",
    "pipeline_energy",
    "BatchedClosedLoop", "ClosedLoopPipeline", "ClosedLoopResult",
    "pwm_from_logits",
]
