"""ColibriES core: the paper's contribution as composable JAX modules.

Submodules:
  events   -- DVS event windows, voxelization (event-wing acquisition +
              preprocessing)
  frames   -- frame-camera windows, normalization (frame-wing acquisition
              + preprocessing)
  lif      -- LIF neuron dynamics with STBP surrogate gradients (SNE model)
  snn      -- the Table II DVS-Gesture spiking CNN + STBP loss
  tcn      -- the CUTIE ternary CNN (packed 2-bit weights, ternary
              activations, Pallas ternary-matmul fc layer)
  ternary  -- TWN ternary quantization + 2-bit packing (CUTIE format)
  tiling   -- capacity-constrained TDM tiling planner (SNE tiled execution)
  engine   -- the InferenceEngine protocol unifying both accelerator
              wings, plus FrameTCNEngine (the CUTIE wing)
  pipeline -- the closed acquisition->preprocess->infer->actuate loop:
              BatchedClosedLoop (the event/SNE wing of the protocol) and
              the single-window ClosedLoopPipeline wrapper
  energy   -- calibrated Kraken power/latency model (Tables I & III event
              wing; modelled CUTIE frame wing)
  _api     -- EngineConfig (the unified engine-construction surface) and
              one-shot deprecation warnings for the legacy call forms
              superseded by the session-handle / config APIs
"""
from repro.core._api import EngineConfig
from repro.core.lif import LIFParams, lif_scan_reference, lif_step, spike_surrogate
from repro.core.snn import (SNNConfig, SNN_STATE_LAYERS, init_snn,
                            snn_apply, snn_init_state, snn_logits, snn_loss)
from repro.core.ternary import pack2bit, ternarize, ternary_ste, unpack2bit
from repro.core.tiling import SNE_NEURON_CAPACITY, TilePlan, plan_layer_tiles, plan_network
from repro.core.energy import (KRAKEN_DOMAINS, CUTIE_DOMAIN, FRAME_DOMAINS,
                               KrakenModel, NOMINAL, NOMINAL_FRAME,
                               StageExecution, pipeline_energy)
from repro.core.pipeline import (BatchedClosedLoop, ClosedLoopPipeline,
                                 ClosedLoopResult, pwm_from_logits)
from repro.core.tcn import TCNConfig, init_tcn, pack_tcn, tcn_apply, tcn_layer_macs
from repro.core.engine import FrameTCNEngine, InferenceEngine

__all__ = [
    "EngineConfig",
    "LIFParams", "lif_scan_reference", "lif_step", "spike_surrogate",
    "SNNConfig", "SNN_STATE_LAYERS", "init_snn", "snn_apply",
    "snn_init_state", "snn_logits", "snn_loss",
    "pack2bit", "ternarize", "ternary_ste", "unpack2bit",
    "SNE_NEURON_CAPACITY", "TilePlan", "plan_layer_tiles", "plan_network",
    "KRAKEN_DOMAINS", "CUTIE_DOMAIN", "FRAME_DOMAINS", "KrakenModel",
    "NOMINAL", "NOMINAL_FRAME", "StageExecution", "pipeline_energy",
    "BatchedClosedLoop", "ClosedLoopPipeline", "ClosedLoopResult",
    "pwm_from_logits",
    "TCNConfig", "init_tcn", "pack_tcn", "tcn_apply", "tcn_layer_macs",
    "FrameTCNEngine", "InferenceEngine",
]
