"""The ColibriES closed control loop: acquire -> preprocess -> infer -> act.

Mirrors the paper's Sec. III decomposition ("data acquisition on the FC
through the dedicated DVS interface, data processing on the engines, which
includes a spike preprocessing step in the cluster and a spike train
inference step in the SNE, and actuators control using PWM signals").

The functional computation (voxelization + SCNN inference + control-signal
generation) runs in JAX; latency/energy are produced by the calibrated
:class:`~repro.core.energy.KrakenModel`. The pipeline also reports the
sustained closed-loop rate under double-buffered acquisition (the DVS
interface + uDMA run autonomously, so window N+1 is acquired while window N
is processed -- the paper's real-time claim: 164.5 ms processing fits in the
300 ms window period).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import events as ev
from repro.core.energy import KrakenModel, NOMINAL
from repro.core.snn import SNNConfig, snn_apply, snn_logits
from repro.core.tiling import SNE_NEURON_CAPACITY, plan_network

__all__ = ["ClosedLoopResult", "ClosedLoopPipeline", "pwm_from_logits"]


def pwm_from_logits(logits: jnp.ndarray, num_channels: int = 4) -> jnp.ndarray:
    """Map classifier logits to PWM duty cycles in [0, 1].

    A stand-in controller: a fixed linear map from class posteriors to
    ``num_channels`` actuation channels (e.g. quadrotor motor setpoints).
    The paper's PWM update itself is <1 us and negligible.
    """
    probs = jax.nn.softmax(logits, axis=-1)
    n_cls = probs.shape[-1]
    # Deterministic mixing matrix (no trainable state in the actuation stub).
    mix = (np.arange(n_cls)[:, None] * np.arange(1, num_channels + 1)[None, :])
    mix = np.cos(mix / n_cls * np.pi).astype(np.float32)
    duty = probs @ jnp.asarray(mix)
    return jnp.clip(0.5 + 0.5 * duty, 0.0, 1.0)


@dataclasses.dataclass
class ClosedLoopResult:
    label_pred: np.ndarray
    pwm: np.ndarray
    latency_ms: float
    energy_mj: float
    breakdown: Dict[str, Any]
    realtime: bool
    sustained_rate_hz: float


class ClosedLoopPipeline:
    """End-to-end event-window -> actuation pipeline with energy accounting."""

    def __init__(
        self,
        params,
        cfg: SNNConfig,
        *,
        model: Optional[KrakenModel] = None,
        lif_scan_fn: Optional[Callable] = None,
        window_ms: float = 300.0,
    ):
        self.params = params
        self.cfg = cfg
        self.model = model or KrakenModel()
        self.window_ms = window_ms
        sizes = cfg.spatial_sizes()
        # SNE executes conv1/conv2/fc1/fc2; tile plans sized by each layer's
        # output volume against SNE's neuron capacity.
        self.plans = plan_network(
            [("conv1", sizes["conv1"]), ("conv2", sizes["conv2"]),
             ("fc1", sizes["fc1"]), ("fc2", sizes["fc2"])],
            SNE_NEURON_CAPACITY,
        )
        self.fanouts = (
            9.0 * cfg.conv1_features,         # 3x3 kernel into conv1 features
            9.0 * cfg.conv2_features,
            float(cfg.hidden),
            float(cfg.num_classes),
        )
        self._infer = jax.jit(
            lambda p, vox: snn_apply(p, vox, cfg, mode="layer_serial",
                                     lif_scan_fn=lif_scan_fn)
        )

    def __call__(self, window: ev.EventWindow) -> ClosedLoopResult:
        cfg = self.cfg
        vox = ev.voxelize(
            jnp.asarray(window.x), jnp.asarray(window.y),
            jnp.asarray(window.t), jnp.asarray(window.p),
            duration_us=window.duration_us, time_bins=cfg.time_bins,
            height=cfg.height, width=cfg.width,
        )[None]  # (1, T, 2, H, W)
        out = self._infer(self.params, vox)
        logits = snn_logits(out, cfg) * 10.0
        pwm = pwm_from_logits(logits)

        # Workload drivers for the latency/energy model.
        t = cfg.time_bins
        sizes = cfg.spatial_sizes()
        vol = lambda s: float(np.prod(sizes[s]))
        rates = out["firing_rates"]
        layer_in_spikes = (
            float(window.num_events),                       # into conv1
            float(rates["conv1"]) * vol("conv1") * t,       # into conv2
            float(rates["conv2"]) * vol("conv2") * t,       # into fc1
            float(rates["fc1"]) * vol("fc1") * t,           # into fc2
        )
        acct = self.model.closed_loop(
            events=float(window.num_events),
            layer_in_spikes=layer_in_spikes,
            layer_fanout=self.fanouts,
            layer_passes=[p.passes for p in self.plans],
        )
        latency = float(acct["total_time_ms"])
        # Double-buffered acquisition: the uDMA acquires window N+1 during
        # processing of window N, so the sustained period is
        # max(window period, preprocessing + inference).
        proc_ms = (acct["stages"]["preprocessing"]["time_ms"]
                   + acct["stages"]["snn_inference"]["time_ms"])
        period_ms = max(self.window_ms, proc_ms)
        return ClosedLoopResult(
            label_pred=np.asarray(jnp.argmax(logits, -1)),
            pwm=np.asarray(pwm),
            latency_ms=latency,
            energy_mj=float(acct["total_energy_mj"]),
            breakdown=acct,
            realtime=latency <= self.window_ms,
            sustained_rate_hz=1000.0 / period_ms,
        )
