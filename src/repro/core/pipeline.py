"""The ColibriES closed control loop: acquire -> preprocess -> infer -> act.

Mirrors the paper's Sec. III decomposition ("data acquisition on the FC
through the dedicated DVS interface, data processing on the engines, which
includes a spike preprocessing step in the cluster and a spike train
inference step in the SNE, and actuators control using PWM signals").

The functional computation (voxelization + SCNN inference + control-signal
generation) runs in JAX; latency/energy are produced by the calibrated
:class:`~repro.core.energy.KrakenModel`. The pipeline also reports the
sustained closed-loop rate under double-buffered acquisition (the DVS
interface + uDMA run autonomously, so window N+1 is acquired while window N
is processed -- the paper's real-time claim: 164.5 ms processing fits in the
300 ms window period).

Two entry points share one batched substrate (and the batched engine is
the event wing of the :class:`~repro.core.engine.InferenceEngine`
protocol -- its frame-wing sibling is
:class:`~repro.core.engine.FrameTCNEngine`):

  * :class:`BatchedClosedLoop` -- the engine core: a padded
    :class:`~repro.core.events.PaddedEventBatch` of ``B`` event windows is
    voxelized and inferred in ONE jit'd call (batched segment-sum
    voxelization + batch folded through the SNN / LIF kernels), then each
    stream gets its own Kraken latency/energy accounting from per-stream
    firing rates and true (unpadded) event counts.
  * :class:`ClosedLoopPipeline` -- the paper's single-window loop, now a
    thin B=1 wrapper over the batched path; existing callers and the
    energy model are untouched.

Every per-stream op in the batched path (convs, pools, T*B-row matmuls,
per-row reductions, elementwise LIF dynamics, exact-integer voxel sums) is
row-independent, so results for a stream are bitwise identical whether it
runs alone or inside a batch -- asserted by the parity tests.
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import events as ev
from repro.core._api import (EngineConfig, suppress_api_deprecations,
                             warn_deprecated_call)
from repro.core.energy import KrakenModel, NOMINAL
from repro.core.snn import SNNConfig, snn_apply, snn_init_state, snn_logits
from repro.core.tiling import SNE_NEURON_CAPACITY, plan_network

__all__ = ["ClosedLoopResult", "BatchedClosedLoop", "ClosedLoopPipeline",
           "pwm_from_logits", "export_state_slot", "import_state_slot"]


def export_state_slot(state, slot: int):
    """One slot's row of a slot-major carried-state pytree, as a
    host-serializable (numpy) pytree.

    The generic implementation behind the engines' duck-typed
    ``export_state``: every leaf is sliced at ``slot`` along its leading
    (batch) axis and copied to the host. An engine whose state is not a
    plain leading-axis pytree overrides ``export_state`` instead.
    """
    return jax.tree_util.tree_map(lambda a: np.asarray(a[slot]), state)


def import_state_slot(state, slot: int, payload):
    """A new slot-major state equal to ``state`` with row ``slot``
    replaced by ``payload`` (an :func:`export_state_slot`-shaped host
    pytree). Bitwise inverse of export for f32 leaves: export -> import
    round-trips the carry exactly, which is what makes checkpoints
    migration-safe."""
    return jax.tree_util.tree_map(
        lambda a, p: a.at[slot].set(jnp.asarray(p, a.dtype)), state, payload)


# ----------------------------------------------------------------------
# Slot-axis sharding plumbing (shared by both engine wings).
#
# A mesh-attached engine runs ONE jit'd step over the whole device mesh
# with the batch-slot axis partitioned along the mesh's data axis. The
# mechanism is shard_map -- each device traces the same per-shard
# computation over its (B/n, ...) rows -- NOT GSPMD auto-partitioning:
# under GSPMD the voxelize scatter-add and the (T, B) -> (T*B) row
# merges inside the SNN would compile to all-reduce / all-gather pairs.
# shard_map makes collective-freedom structural (nothing in the step
# mentions another shard), and because every per-stream op in the step
# is row-independent (the PR 1 batch-size-invariance contract), each
# shard's rows are bitwise identical to the same rows of a full-batch
# single-device call.
# ----------------------------------------------------------------------

def _mesh_slot_info(mesh):
    """(axis name, axis size) the engines shard slots over."""
    from repro.distributed.mesh import slot_axis
    ax = slot_axis(mesh)
    return ax, dict(zip(mesh.axis_names, mesh.devices.shape))[ax]


def _replicate_to_mesh(tree, mesh):
    """Pin a pytree fully replicated on every mesh device (params)."""
    from jax.sharding import NamedSharding, PartitionSpec
    return jax.device_put(tree, NamedSharding(mesh, PartitionSpec()))


def _slot_shard_to_mesh(tree, mesh):
    """Pin a slot-major pytree with its leading axis over the slot axis."""
    from repro.distributed.sharding import slot_shardings
    return jax.device_put(tree, slot_shardings(mesh, tree))


def _check_slot_divisible(batch_size: int, mesh, what: str) -> None:
    ax, n = _mesh_slot_info(mesh)
    if batch_size % n != 0:
        raise ValueError(
            f"{what} batch size {batch_size} does not divide over the "
            f"mesh slot axis '{ax}' ({n} devices); size lanes/batches in "
            f"multiples of the mesh size (EngineConfig.max_streams)")


def _shard_wrap(run: Callable, mesh, state_tree) -> Callable:
    """shard_map ``run`` over the slot axis: batch arrays and the
    slot-major state split on their leading dim, params replicated,
    every output slot-major. ``check_rep=False``: replicated params are
    closed over per shard; nothing in the step crosses shards."""
    from jax.experimental.shard_map import shard_map
    from repro.distributed.sharding import slot_state_pspecs
    from jax.sharding import PartitionSpec as P
    ax, _ = _mesh_slot_info(mesh)
    row = P(ax, None)
    state_specs = slot_state_pspecs(state_tree, mesh)
    in_specs = (P(), row, row, row, row, row, state_specs)
    out_specs = (P(ax), row, row,
                 {k: P(ax) for k in state_tree}, state_specs)
    return shard_map(run, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


def pwm_from_logits(logits: jnp.ndarray, num_channels: int = 4) -> jnp.ndarray:
    """Map classifier logits to PWM duty cycles in [0, 1].

    A stand-in controller: a fixed linear map from class posteriors to
    ``num_channels`` actuation channels (e.g. quadrotor motor setpoints).
    The paper's PWM update itself is <1 us and negligible.
    """
    probs = jax.nn.softmax(logits, axis=-1)
    n_cls = probs.shape[-1]
    # Deterministic mixing matrix (no trainable state in the actuation stub).
    mix = (np.arange(n_cls)[:, None] * np.arange(1, num_channels + 1)[None, :])
    mix = np.cos(mix / n_cls * np.pi).astype(np.float32)
    # Broadcast-multiply-sum instead of ``probs @ mix``: a (1, n_cls) GEMV
    # and a (B, n_cls) GEMM accumulate in different orders on CPU; this
    # per-row reduction is batch-size invariant (bitwise B=1 == batched).
    duty = (probs[..., :, None] * jnp.asarray(mix)).sum(axis=-2)
    return jnp.clip(0.5 + 0.5 * duty, 0.0, 1.0)


def _check_scan_fn(fn: Optional[Callable]) -> None:
    """Reject two-argument legacy ``lif_scan_fn`` callables up front.

    The engine threads carried state (``v0``) through its scan hook, so
    a pre-stateful-streaming ``lambda c, p: ...`` would only fail with
    an opaque TypeError deep inside the first jit trace. Catch it at
    construction with a message that names the fix. Callables whose
    signature cannot be inspected are let through (they fail loudly at
    trace time if genuinely incompatible).
    """
    if fn is None:
        return
    try:
        sig = inspect.signature(fn)
    except (TypeError, ValueError):
        return
    n_pos = 0
    for p in sig.parameters.values():
        if p.kind == p.VAR_POSITIONAL:
            return
        if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD):
            n_pos += 1
    if n_pos < 3:
        raise ValueError(
            f"lif_scan_fn must accept (currents, lif_params, v0): the "
            f"engine threads carried state through the scan (stateful "
            f"streaming). Pass repro.kernels.ops.lif_scan itself -- it "
            f"already takes v0 -- instead of a two-argument wrapper "
            f"(got signature {sig})")


@dataclasses.dataclass
class ClosedLoopResult:
    label_pred: np.ndarray
    pwm: np.ndarray
    latency_ms: float
    energy_mj: float
    breakdown: Dict[str, Any]
    realtime: bool
    sustained_rate_hz: float
    # Pre-actuation classifier logits, (1, num_classes). Both wings emit
    # them so a FusionSession can combine modalities BEFORE actuation
    # (late logit fusion); None for engines that predate the field.
    logits: Optional[np.ndarray] = None


class BatchedClosedLoop:
    """Batched event-window -> actuation engine with per-stream accounting.

    One jit'd call voxelizes and infers a whole :class:`PaddedEventBatch`;
    the Kraken latency/energy model then runs per stream on that stream's
    true event count and firing rates. Empty batch slots (zero valid
    events) flow through the same computation and yield ``None`` results.

    Executables are cached explicitly per ``shape_key`` --
    ``(batch_size, max_events, duration_us)`` -- via the jax AOT API:
    :meth:`warmup` precompiles a set of keys up front so the first window
    of a new event-count bucket never pays compile time mid-stream, and
    :meth:`compiled_shape_keys` exposes what the cache holds. Callers that
    keep shapes fixed (the streaming engine's slot buffers, or the B=1
    wrapper's power-of-two event buckets) compile once per bucket.

    This is the event wing of the :class:`~repro.core.engine.
    InferenceEngine` protocol: ``validate``/``prepare``/``infer``/
    ``shape_key`` below are what the engine-agnostic
    :class:`~repro.serving.stream.StreamEngine` drives (plus the optional
    ``infer_dispatch``/``infer_collect`` split it uses to pipeline device
    compute against host packing). ``duration_us`` is the
    one-bin-width-per-engine contract: all windows served by one engine
    share a bin width (pass it at construction to pin it, or leave
    ``None`` to latch it from the first validated window).

    ``fuse_fc=True`` routes the fc1/fc2 layers through the fused
    synapse+LIF Pallas kernel (``kernels/fc_lif_scan.py``): their
    synaptic-current tensors never round-trip HBM, with bitwise-identical
    results to the unfused path.

    Carried state (stateful streaming): the SNN is stateful across the
    control loop, and this engine exposes that as a first-class slot-major
    pytree -- one (B, ...) membrane plane per LIF layer. ``init_state(B)``
    makes the zero (cold-start) state; ``infer(batch, state)`` returns
    ``(results, new_state)``, and feeding ``new_state`` back chains the
    windows bitwise-exactly into one uninterrupted scan (the ``s0 = v0 >=
    v_th`` contract from ``core/lif.py``). The state stays a device
    pytree end to end: ``infer_dispatch(batch, state)`` returns the new
    state as jax async-dispatch futures, so a pipelined caller threads
    membranes from step to step without any host round-trip. Calls
    without ``state`` run the same executable from the zero state and
    drop the final state -- the legacy stateless behaviour, bitwise
    unchanged.
    """

    modality = "event"

    def __init__(
        self,
        params,
        cfg: SNNConfig,
        *,
        model: Optional[KrakenModel] = None,
        lif_scan_fn: Optional[Callable] = None,
        window_ms: float = 300.0,
        duration_us: Optional[int] = None,
        fuse_fc: bool = False,
        mesh=None,
    ):
        self.params = params
        self.cfg = cfg
        self.mesh = None
        self.model = model or KrakenModel()
        self.window_ms = window_ms
        self.duration_us = duration_us
        self.fuse_fc = fuse_fc
        sizes = cfg.spatial_sizes()
        # SNE executes conv1/conv2/fc1/fc2; tile plans sized by each layer's
        # output volume against SNE's neuron capacity.
        self.plans = plan_network(
            [("conv1", sizes["conv1"]), ("conv2", sizes["conv2"]),
             ("fc1", sizes["fc1"]), ("fc2", sizes["fc2"])],
            SNE_NEURON_CAPACITY,
        )
        self.fanouts = (
            9.0 * cfg.conv1_features,         # 3x3 kernel into conv1 features
            9.0 * cfg.conv2_features,
            float(cfg.hidden),
            float(cfg.num_classes),
        )
        _check_scan_fn(lif_scan_fn)
        self._lif_scan_fn = lif_scan_fn
        # Explicit executable cache: shape_key -> AOT-compiled callable.
        self._exe: Dict[Any, Callable] = {}
        # Zero-state cache: stateless dispatches reuse one zero pytree per
        # batch size instead of re-allocating it every step.
        self._zero_state: Dict[int, Any] = {}
        if mesh is not None:
            self.attach_mesh(mesh)

    @classmethod
    def from_config(cls, params, cfg: SNNConfig, config: EngineConfig, *,
                    model: Optional[KrakenModel] = None,
                    lif_scan_fn: Optional[Callable] = None):
        """Construct from the unified :class:`EngineConfig` surface (the
        serving-irrelevant fields -- ``max_streams``, ``policy``,
        ``fair_quantum``, ``pipeline_depth`` -- belong to the
        ``StreamEngine`` layer and are ignored here)."""
        return cls(params, cfg, model=model, lif_scan_fn=lif_scan_fn,
                   window_ms=config.window_ms,
                   duration_us=config.duration_us,
                   fuse_fc=config.fuse_fc, mesh=config.mesh)

    # -- Slot-axis sharding ----------------------------------------------

    def attach_mesh(self, mesh) -> None:
        """Shard this engine's slot axis over ``mesh``'s data axis.

        Params are pinned replicated on every mesh device; from here on
        every executable compiles as one shard_map'd step over the mesh
        and every batch/state input is resharded slot-major on dispatch.
        Must happen before any executable is compiled (single-device
        executables bind unsharded layouts), and a second attach with a
        *different* mesh is an error -- re-attaching the same mesh is a
        no-op, which is what lets ``StreamEngine`` thread one mesh to
        caller-provided engines idempotently.
        """
        if mesh is None or mesh == self.mesh:
            return
        if self.mesh is not None:
            raise ValueError(
                "engine is already attached to a different mesh; one "
                "engine serves one mesh for its whole lifetime")
        if self._exe:
            raise RuntimeError(
                "attach_mesh after executables were compiled: attach the "
                "mesh at construction (EngineConfig(mesh=...)) or before "
                "the first infer/warmup call")
        self.mesh = mesh
        self.params = _replicate_to_mesh(self.params, mesh)
        self._zero_state.clear()    # rebuild slot-sharded on next use

    # -- InferenceEngine protocol ----------------------------------------

    def init_state(self, batch_size: int):
        """The zero carried-state pytree for ``batch_size`` slots.

        Slot-major: one (batch_size, ...) f32 membrane plane per LIF
        layer (see :func:`repro.core.snn.snn_init_state`). Zero membrane
        is the cold-start condition, so a window inferred from
        ``init_state`` is bitwise identical to a stateless call.

        On a mesh-attached engine the state comes back slot-sharded when
        ``batch_size`` divides over the slot axis; indivisible sizes
        (e.g. the B=1 scratch state the checkpoint-restore splice uses)
        stay plain host-side arrays -- they are only ever sliced and
        spliced, never inferred.
        """
        state = snn_init_state(self.cfg, batch_size)
        if self.mesh is not None:
            _, n = _mesh_slot_info(self.mesh)
            if batch_size % n == 0:
                state = _slot_shard_to_mesh(state, self.mesh)
        return state

    def _zero_state_for(self, batch_size: int):
        st = self._zero_state.get(batch_size)
        if st is None:
            st = self._zero_state[batch_size] = self.init_state(batch_size)
        return st

    def validate(self, window: ev.EventWindow) -> None:
        """Submission-time check: latch/enforce the engine bin width."""
        if self.duration_us is None:
            self.duration_us = window.duration_us
        elif window.duration_us != self.duration_us:
            raise ValueError(
                f"window duration {window.duration_us} != engine duration "
                f"{self.duration_us} (one bin width per engine)")

    def prepare(self, items: Sequence[Optional[ev.EventWindow]], *,
                batch_size: int) -> ev.PaddedEventBatch:
        """Pad one window per slot into the engine's fixed batch buffer.

        Event counts are padded to power-of-two buckets, so jit caches at
        most log2 distinct executables over the engine's lifetime and the
        buffer shrinks back after a burst window.
        """
        bucket = ev.next_pow2(max(
            (w.num_events for w in items if w is not None), default=1))
        return ev.pad_event_windows(
            items, max_events=bucket, batch_size=batch_size,
            duration_us=self.duration_us)

    def shape_key(self, batch: ev.PaddedEventBatch):
        return (batch.batch_size, batch.max_events, batch.duration_us)

    def _build_run(self, duration_us: int) -> Callable:
        """Voxelize + infer + readout for one window duration (unjitted).

        One executable serves both the stateless and the stateful path:
        it always takes the slot-major state pytree and always returns
        the per-layer final membranes (stateless callers feed the cached
        zero state and drop the output).
        """
        cfg, scan, fuse = self.cfg, self._lif_scan_fn, self.fuse_fc

        def run(params, x, y, t, p, valid, state):
            vox = ev.voxelize_batch(
                x, y, t, p, valid, duration_us=duration_us,
                time_bins=cfg.time_bins, height=cfg.height,
                width=cfg.width,
            )
            out = snn_apply(params, vox, cfg, mode="layer_serial",
                            lif_scan_fn=scan, fuse_fc=fuse, state=state)
            logits = snn_logits(out, cfg) * 10.0
            return (jnp.argmax(logits, -1), pwm_from_logits(logits), logits,
                    out["firing_rates_per_stream"], out["state"])

        return run

    def _executable(self, key) -> Callable:
        """AOT-compile (once) and return the executable for a shape key.

        ``key`` is ``(batch_size, max_events, duration_us)``. Compilation
        happens eagerly here -- not lazily inside jit on first call -- so
        :meth:`warmup` can pull the cost off the serving critical path.
        """
        exe = self._exe.get(key)
        if exe is None:
            b, n_ev, duration_us = key
            run = self._build_run(int(duration_us))
            shard = None
            if self.mesh is not None:
                from repro.distributed.sharding import slot_shardings
                from jax.sharding import NamedSharding, PartitionSpec as P
                _check_slot_divisible(b, self.mesh, "sharded-engine")
                run = _shard_wrap(run, self.mesh, self._zero_state_for(b))
                shard = dict(
                    params=NamedSharding(self.mesh, P()),
                    row=NamedSharding(
                        self.mesh,
                        P(_mesh_slot_info(self.mesh)[0], None)),
                    state=slot_shardings(self.mesh,
                                         self._zero_state_for(b)))
            row_sh = shard["row"] if shard else None
            ev_i32 = jax.ShapeDtypeStruct((b, n_ev), jnp.int32,
                                          sharding=row_sh)
            ev_bool = jax.ShapeDtypeStruct((b, n_ev), jnp.bool_,
                                           sharding=row_sh)

            def abstract(tree, sh_tree=None):
                one = lambda a, s=None: jax.ShapeDtypeStruct(
                    jnp.shape(a), jnp.asarray(a).dtype, sharding=s)
                if sh_tree is None:
                    return jax.tree_util.tree_map(one, tree)
                return jax.tree_util.tree_map(one, tree, sh_tree)

            params_abs = abstract(
                self.params,
                jax.tree_util.tree_map(lambda _: shard["params"],
                                       self.params) if shard else None)
            state_abs = abstract(self._zero_state_for(b),
                                 shard["state"] if shard else None)
            exe = jax.jit(run).lower(
                params_abs, ev_i32, ev_i32, ev_i32, ev_i32,
                ev_bool, state_abs).compile()
            self._exe[key] = exe
        return exe

    def warmup(self, shape_keys) -> None:
        """Precompile executables for the given shape keys.

        Each key is ``(batch_size, max_events, duration_us)``; a 2-tuple
        ``(batch_size, max_events)`` uses the engine's latched
        ``duration_us``. Call before serving so no window pays compile
        time mid-stream (``StreamEngine.warmup`` forwards here).
        """
        for key in shape_keys:
            key = tuple(key)
            if len(key) == 2:
                if self.duration_us is None:
                    raise ValueError(
                        "2-tuple shape key needs a latched duration_us; "
                        "pass (batch, max_events, duration_us) or pin "
                        "duration_us at construction")
                key = (*key, self.duration_us)
            if len(key) != 3:
                raise ValueError(
                    f"shape key must be (batch_size, max_events[, "
                    f"duration_us]), got {key}")
            self._executable(key)

    def compiled_shape_keys(self) -> set:
        """Shape keys with a compiled executable (stepped or warmed)."""
        return set(self._exe)

    # -- cross-wing megastep adapters ------------------------------------
    # The serving layer's fused megastep (EngineConfig.megastep) lowers
    # this wing's run function NEXT TO the frame wing's into one jit'd
    # program, so XLA schedules the fc_lif_scan SNN scan and the ternary
    # conv stack together and the engine pays one dispatch per step.
    # The run and abstract signature are exactly what `_executable`
    # lowers on its own, which is what keeps the fused call
    # bitwise-identical to this wing's separate executable.

    def _mega_parts(self, key):
        """``(run_fn, abstract_args)`` for a shape key, for fused
        cross-wing compilation. Single-device only (the serving layer
        rejects megastep + mesh before ever calling this)."""
        if self.mesh is not None:
            raise ValueError(
                "the fused megastep does not compose with a mesh-attached "
                "engine")
        b, n_ev, duration_us = key
        run = self._build_run(int(duration_us))
        ev_i32 = jax.ShapeDtypeStruct((b, n_ev), jnp.int32)
        ev_bool = jax.ShapeDtypeStruct((b, n_ev), jnp.bool_)
        abstract = lambda tree: jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(jnp.shape(a),
                                           jnp.asarray(a).dtype), tree)
        return run, (abstract(self.params), ev_i32, ev_i32, ev_i32,
                     ev_i32, ev_bool, abstract(self._zero_state_for(b)))

    def _mega_args(self, batch: ev.PaddedEventBatch, state):
        """Concrete argument tuple matching :meth:`_mega_parts`'s
        abstract signature (``state=None`` = the cached zero state,
        exactly as the stateless dispatch path)."""
        if state is None:
            state = self._zero_state_for(batch.batch_size)
        return (self.params, batch.x, batch.y, batch.t, batch.p,
                batch.valid, state)

    def _mega_split(self, out, batch: ev.PaddedEventBatch, state):
        """Split this wing's megastep outputs into the same
        ``(pending, new_state)`` pair :meth:`infer_dispatch` returns, so
        :meth:`infer_collect` (and every recovery path built on it)
        serves fused steps unchanged."""
        preds, pwm, logits, rates_ps, new_state = out
        return (batch, preds, pwm, logits, rates_ps), new_state

    def _account(self, num_events: int,
                 rates: Dict[str, float]) -> Dict[str, Any]:
        """Kraken latency/energy for one stream's window (pure float math)."""
        cfg = self.cfg
        t = cfg.time_bins
        sizes = cfg.spatial_sizes()
        vol = lambda s: float(np.prod(sizes[s]))
        layer_in_spikes = (
            float(num_events),                        # into conv1
            rates["conv1"] * vol("conv1") * t,        # into conv2
            rates["conv2"] * vol("conv2") * t,        # into fc1
            rates["fc1"] * vol("fc1") * t,            # into fc2
        )
        acct = self.model.closed_loop(
            events=float(num_events),
            layer_in_spikes=layer_in_spikes,
            layer_fanout=self.fanouts,
            layer_passes=[p.passes for p in self.plans],
        )
        # Per-layer mean firing rates for this window: observable per
        # stream (e.g. to watch carried membrane shift the dynamics).
        acct["firing_rates"] = dict(rates)
        return acct

    def infer_dispatch(self, batch: ev.PaddedEventBatch, state=None):
        """Launch the jit'd call for a padded batch WITHOUT host sync.

        Returns an opaque pending handle for :meth:`infer_collect` -- or,
        when ``state`` is given (a slot-major pytree from
        :meth:`init_state` or a previous dispatch), the pair
        ``(pending, new_state)``. The device arrays inside are jax
        futures (async dispatch): the caller can keep packing the next
        batch on the host while the device computes this one -- the
        overlap the pipelined ``StreamEngine.step`` exploits -- and
        ``new_state`` is itself made of futures, so chaining it into the
        next dispatch keeps membranes device-resident with no host sync.
        """
        stateless = state is None
        if stateless:
            state = self._zero_state_for(batch.batch_size)
        exe = self._executable(self.shape_key(batch))
        arrs = (jnp.asarray(batch.x), jnp.asarray(batch.y),
                jnp.asarray(batch.t), jnp.asarray(batch.p),
                jnp.asarray(batch.valid))
        if self.mesh is not None:
            # Reshard inputs to what the executable was lowered for. For
            # state chained from the previous dispatch this is a no-op
            # (already slot-sharded); host-rebuilt states (slot
            # reassignment, checkpoint splices) get scattered here --
            # the ONLY cross-device movement on the serving path.
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.distributed.sharding import slot_shardings
            row = NamedSharding(
                self.mesh, P(_mesh_slot_info(self.mesh)[0], None))
            arrs = jax.device_put(arrs, (row,) * 5)
            state = jax.device_put(state,
                                   slot_shardings(self.mesh, state))
        preds, pwm, logits, rates_ps, new_state = exe(
            self.params, *arrs, state)
        pending = (batch, preds, pwm, logits, rates_ps)
        return pending if stateless else (pending, new_state)

    def infer_collect(self, pending) -> List[Optional[ClosedLoopResult]]:
        """Fetch a dispatched batch's outputs and account each stream.

        This is the only point that blocks on the device (the implicit
        ``np.asarray`` device-to-host copies).
        """
        batch, preds, pwm, logits, rates_ps = pending
        preds = np.asarray(preds)
        pwm = np.asarray(pwm)
        logits = np.asarray(logits)
        rates_ps = {k: np.asarray(v) for k, v in rates_ps.items()}

        results: List[Optional[ClosedLoopResult]] = []
        for b in range(batch.batch_size):
            if not batch.occupied[b]:
                results.append(None)
                continue
            # A real-but-quiet window (zero events) is still occupied and
            # gets a result; only window=None slots yield None.
            n_ev = int(batch.num_events[b])
            acct = self._account(
                n_ev, {k: float(v[b]) for k, v in rates_ps.items()})
            latency = float(acct["total_time_ms"])
            # Double-buffered acquisition: the uDMA acquires window N+1
            # during processing of window N, so the sustained period is
            # max(window period, preprocessing + inference).
            proc_ms = (acct["stages"]["preprocessing"]["time_ms"]
                       + acct["stages"]["snn_inference"]["time_ms"])
            period_ms = max(self.window_ms, proc_ms)
            results.append(ClosedLoopResult(
                label_pred=preds[b:b + 1],
                pwm=pwm[b:b + 1],
                latency_ms=latency,
                energy_mj=float(acct["total_energy_mj"]),
                breakdown=acct,
                realtime=latency <= self.window_ms,
                sustained_rate_hz=1000.0 / period_ms,
                logits=logits[b:b + 1],
            ))
        return results

    def export_state(self, state, slot: int):
        """Host-serializable checkpoint of one slot's carried state (the
        per-layer membrane planes), engine-agnostic through the serving
        layer's duck-typed probe; see :func:`export_state_slot`."""
        return export_state_slot(state, slot)

    def import_state(self, state, slot: int, payload):
        """Splice an exported carry back into row ``slot`` of a
        slot-major state; see :func:`import_state_slot`."""
        return import_state_slot(state, slot, payload)

    def infer(self, batch: ev.PaddedEventBatch, state=None):
        """Run a padded batch; returns one result per slot (None if empty).

        Synchronous convenience: dispatch + collect back to back. With
        ``state`` (slot-major carried-state pytree) returns
        ``(results, new_state)``; without it, just the results (the
        legacy stateless call, run from the zero state -- deprecated as
        a direct call form: pass ``init_state(batch_size)`` explicitly,
        or serve through ``StreamEngine.open(...)``).
        """
        if state is None:
            warn_deprecated_call(
                self, "stateless-infer",
                "stateless BatchedClosedLoop.infer(batch) is a legacy "
                "call form; pass carried state -- infer(batch, "
                "init_state(batch_size)) -- or serve windows through the "
                "session API: StreamEngine.open(...).submit(window)")
            return self.infer_collect(self.infer_dispatch(batch))
        pending, new_state = self.infer_dispatch(batch, state)
        return self.infer_collect(pending), new_state

    def infer_windows(self, windows: Sequence[Optional[ev.EventWindow]],
                      *, max_events: Optional[int] = None,
                      batch_size: Optional[int] = None,
                      duration_us: Optional[int] = None,
                      ) -> List[Optional[ClosedLoopResult]]:
        """Convenience: pad a window list and run it as one batch."""
        if not windows and not batch_size:
            return []
        if max_events is None:
            counts = [w.num_events for w in windows if w is not None]
            max_events = ev.next_pow2(max(counts)) if counts else ev.next_pow2(1)
        batch = ev.pad_event_windows(
            windows, max_events=max_events, batch_size=batch_size,
            duration_us=duration_us)
        # The B=1-style compat surface drives the stateless call form on
        # purpose; the deprecation nudge is for direct infer() callers.
        with suppress_api_deprecations():
            return self.infer(batch)


class ClosedLoopPipeline:
    """The paper's single-window loop: a B=1 view of the batched engine.

    Event counts are padded to power-of-two buckets so repeated calls with
    similar-sized windows reuse one compiled executable (padding does not
    change any result; voxel sums are exact).
    """

    def __init__(
        self,
        params,
        cfg: SNNConfig,
        *,
        model: Optional[KrakenModel] = None,
        lif_scan_fn: Optional[Callable] = None,
        window_ms: float = 300.0,
        fuse_fc: bool = False,
    ):
        self.batched = BatchedClosedLoop(
            params, cfg, model=model, lif_scan_fn=lif_scan_fn,
            window_ms=window_ms, fuse_fc=fuse_fc)

    # Backwards-compatible attribute surface (pre-batched callers).
    params = property(lambda self: self.batched.params)
    cfg = property(lambda self: self.batched.cfg)
    model = property(lambda self: self.batched.model)
    window_ms = property(lambda self: self.batched.window_ms)
    plans = property(lambda self: self.batched.plans)
    fanouts = property(lambda self: self.batched.fanouts)

    def __call__(self, window: ev.EventWindow) -> ClosedLoopResult:
        return self.batched.infer_windows([window])[0]
