"""The CUTIE ternary CNN: ColibriES's frame-based inference network.

Kraken's second accelerator, CUTIE (Scherer et al., 2022), executes
fully-ternary CNNs: {-1, 0, +1} weights AND activations, unrolled ternary
MACs in silicon, with the final classifier kept full-precision. This module
builds that network in JAX on the repo's existing ternary substrate:

  * weights quantized with :func:`repro.core.ternary.ternarize` (TWN,
    per-output-channel scale),
  * the fully-connected layer stored 2-bit packed
    (:func:`repro.core.ternary.pack2bit`) and executed by the
    ``kernels/ternary_matmul`` Pallas kernel -- dequant-in-VMEM, the CUTIE
    weight-bandwidth win on TPU,
  * activations hard-ternarized between layers (CUTIE's ternary
    inter-layer format),
  * per-stream activation density reported alongside the logits: CUTIE's
    switching energy tracks non-zero operand activity, so the energy model
    (``KrakenModel.frame_loop``) charges each stream for its own activity,
    exactly as the SNN path charges per-stream firing rates.

The network family mirrors the Table II SCNN so the two wings are
comparable layer-for-layer (pool4 -> conv16 -> pool2 -> conv32 -> pool2 ->
fc -> classifier), just frame-in instead of spike-train-in.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.ternary import pack2bit, ternarize
from repro.kernels.ternary_matmul import ternary_matmul_pallas

__all__ = ["TCNConfig", "init_tcn", "pack_tcn", "tcn_apply",
           "tcn_layer_macs"]

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class TCNConfig:
    """Configuration of the CUTIE ternary CNN (reduced variants for tests)."""

    height: int = 128
    width: int = 128
    in_channels: int = 1
    pool0: int = 4            # cluster-side downsampling before conv1
    conv1_features: int = 16
    conv2_features: int = 32
    hidden: int = 512
    num_classes: int = 11
    # Activation ternarization threshold (fraction of each layer's mean
    # absolute pre-activation); CUTIE's inter-layer format is ternary.
    act_threshold: float = 0.7
    init_gain: float = 1.0

    @property
    def post_pool0(self) -> Tuple[int, int]:
        return self.height // self.pool0, self.width // self.pool0

    @property
    def flat_dim(self) -> int:
        h, w = self.post_pool0
        return (h // 4) * (w // 4) * self.conv2_features

    def spatial_sizes(self):
        """(H, W, C) after each stage, for the MAC/energy accounting."""
        h0, w0 = self.post_pool0
        return {
            "input": (self.height, self.width, self.in_channels),
            "pool0": (h0, w0, self.in_channels),
            "conv1": (h0, w0, self.conv1_features),
            "pool1": (h0 // 2, w0 // 2, self.conv1_features),
            "conv2": (h0 // 2, w0 // 2, self.conv2_features),
            "pool2": (h0 // 4, w0 // 4, self.conv2_features),
            "fc1": (1, 1, self.hidden),
            "fc2": (1, 1, self.num_classes),
        }


def tcn_layer_macs(cfg: TCNConfig) -> Tuple[float, ...]:
    """Dense MAC count per CUTIE layer (conv1, conv2, fc1, fc2).

    CUTIE executes the full dense schedule every frame (no event sparsity
    in time), so latency is workload-*independent*; only switching energy
    varies with operand activity.
    """
    sizes = cfg.spatial_sizes()
    vol = lambda s: float(sizes[s][0] * sizes[s][1] * sizes[s][2])
    return (
        vol("conv1") * 9.0 * cfg.in_channels,
        vol("conv2") * 9.0 * cfg.conv1_features,
        float(cfg.flat_dim * cfg.hidden),
        float(cfg.hidden * cfg.num_classes),
    )


def init_tcn(rng: jax.Array, cfg: TCNConfig, dtype=jnp.float32) -> Params:
    """He-init the float (pre-quantization) TCN parameters."""
    k1, k2, k3, k4 = jax.random.split(rng, 4)

    def he(key, shape, fan_in):
        return (jax.random.normal(key, shape, dtype)
                * (cfg.init_gain * jnp.sqrt(2.0 / fan_in)).astype(dtype))

    return {
        "conv1": {"w": he(k1, (3, 3, cfg.in_channels, cfg.conv1_features),
                          9 * cfg.in_channels)},
        "conv2": {"w": he(k2, (3, 3, cfg.conv1_features, cfg.conv2_features),
                          9 * cfg.conv1_features)},
        "fc1": {"w": he(k3, (cfg.flat_dim, cfg.hidden), cfg.flat_dim)},
        "fc2": {"w": he(k4, (cfg.hidden, cfg.num_classes), cfg.hidden)},
    }


def pack_tcn(params: Params) -> Params:
    """Quantize float TCN params into CUTIE's deployment format.

    Conv kernels become {q int8, scale} pairs (TWN per-output-channel);
    fc1 becomes the 2-bit packed (K//4, N) layout the Pallas kernel
    consumes; the classifier (fc2) stays full-precision, as CUTIE does.
    """
    out: Params = {}
    for name in ("conv1", "conv2"):
        q, scale = ternarize(params[name]["w"], axis=-1)
        out[name] = {"q": q, "scale": scale}
    k, n = params["fc1"]["w"].shape
    if k % 4:
        raise ValueError(f"fc1 K={k} must be a multiple of 4 for packing")
    q, scale = ternarize(params["fc1"]["w"], axis=-1)   # scale (1, N)
    out["fc1"] = {"packed": pack2bit(q.T).T,            # (K//4, N) uint8
                  "scale": scale.reshape(n).astype(jnp.float32)}
    out["fc2"] = {"w": params["fc2"]["w"]}
    return out


def _avg_pool(x: jnp.ndarray, k: int) -> jnp.ndarray:
    return jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, k, k, 1), (1, k, k, 1), "VALID"
    ) / float(k * k)


def _ternary_conv(x: jnp.ndarray, layer: Params) -> jnp.ndarray:
    """SAME 3x3 conv with dequantized ternary weights (q * scale)."""
    w = layer["q"].astype(x.dtype) * layer["scale"].astype(x.dtype)
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _ternarize_act(x: jnp.ndarray, threshold: float) -> jnp.ndarray:
    """CUTIE inter-layer format: hard-ternarize activations.

    Threshold is ``threshold * mean|x|`` per sample (reduced over every
    non-batch axis), so each batch row is ternarized independently --
    preserving the per-slot invariance the engine protocol relies on.
    """
    reduce_axes = tuple(range(1, x.ndim))
    delta = threshold * jnp.abs(x).mean(axis=reduce_axes, keepdims=True)
    return jnp.sign(x) * (jnp.abs(x) > delta).astype(x.dtype)


def tcn_apply(packed: Params, frames: jnp.ndarray, cfg: TCNConfig,
              ) -> Dict[str, jnp.ndarray]:
    """Run the CUTIE TCN on normalized frames.

    Args:
      packed: deployment params from :func:`pack_tcn`.
      frames: (B, H, W, C) float frames in [-1, 1]
        (see :func:`repro.core.frames.normalize_frames`).

    Returns:
      dict with ``logits`` (B, num_classes) and ``activity_per_stream`` --
      per-layer (B,) mean non-zero-activation densities, the operand
      activity that drives CUTIE's switching energy per stream.
    """
    # Per-stream density of non-zero ternary operands entering each layer.
    def density(s: jnp.ndarray) -> jnp.ndarray:
        axes = tuple(range(1, s.ndim))
        return (s != 0).astype(jnp.float32).mean(axis=axes)

    x0 = _avg_pool(frames, cfg.pool0)
    a1 = _ternary_conv(x0, packed["conv1"])
    s1 = _ternarize_act(a1, cfg.act_threshold)
    a2 = _ternary_conv(_avg_pool(s1, 2), packed["conv2"])
    s2 = _ternarize_act(a2, cfg.act_threshold)
    flat = _avg_pool(s2, 2).reshape(frames.shape[0], -1)
    # fc1 through the Pallas kernel: packed 2-bit weights dequantized in
    # VMEM (interpret mode off-TPU), f32 accumulation.
    h = ternary_matmul_pallas(flat, packed["fc1"]["packed"],
                              packed["fc1"]["scale"])
    s3 = _ternarize_act(h, cfg.act_threshold)
    logits = s3 @ packed["fc2"]["w"]
    return {
        "logits": logits,
        "activity_per_stream": {
            "conv1": density(x0), "conv2": density(s1),
            "fc1": density(s2), "fc2": density(s3),
        },
    }
