"""Ternary weight quantization -- the CUTIE wing of Kraken.

CUTIE (Scherer et al., 2022) is Kraken's ternary-weight ({-1, 0, +1}) CNN
accelerator. We reproduce its numerical contract in JAX:

  * TWN-style quantization (Li & Liu, 2016): per-output-channel threshold
    delta = 0.7 * mean|W|, ternarize, per-channel fp scale = mean |W| over
    the surviving weights.
  * Straight-through-estimator QAT so networks can be trained ternary.
  * 2-bit packing (4 weights/byte) -- the storage format consumed by the
    ``kernels/ternary_matmul`` Pallas kernel.

TPU adaptation (see DESIGN.md): CUTIE wins on *compute* by unrolling
ternary MACs in silicon; the MXU is fixed-function dense bf16, so the win
that transfers is *weight bandwidth*: 2-bit packed weights cut HBM->VMEM
weight traffic 8x vs bf16, which is exactly the bottleneck of memory-bound
LM decode. ``quantize``/``pack`` here are shared by the paper-faithful TNN
path and the beyond-paper LM serving path (``quant=ternary``).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "ternarize",
    "ternary_ste",
    "pack2bit",
    "unpack2bit",
    "TERNARY_DELTA_FACTOR",
]

TERNARY_DELTA_FACTOR = 0.7  # TWN threshold heuristic


def ternarize(
    w: jnp.ndarray, axis: int | None = -1
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Ternarize weights. Returns (q, scale) with q in {-1, 0, +1} int8.

    Args:
      w: float weights, any shape.
      axis: axis treated as the output channel (per-channel scale). ``None``
        gives a single per-tensor scale.
    """
    absw = jnp.abs(w)
    if axis is None:
        delta = TERNARY_DELTA_FACTOR * absw.mean()
        mask = absw > delta
        denom = jnp.maximum(mask.sum(), 1)
        scale = jnp.where(mask, absw, 0.0).sum() / denom
    else:
        reduce_axes = tuple(i for i in range(w.ndim) if i != axis % w.ndim)
        delta = TERNARY_DELTA_FACTOR * absw.mean(axis=reduce_axes,
                                                 keepdims=True)
        mask = absw > delta
        denom = jnp.maximum(mask.sum(axis=reduce_axes, keepdims=True), 1)
        scale = jnp.where(mask, absw, 0.0).sum(
            axis=reduce_axes, keepdims=True) / denom
    q = jnp.where(mask, jnp.sign(w), 0.0).astype(jnp.int8)
    return q, scale.astype(w.dtype)


@jax.custom_vjp
def ternary_ste(w: jnp.ndarray) -> jnp.ndarray:
    """Fake-quantized ternary weights with straight-through gradients (QAT)."""
    q, scale = ternarize(w)
    return q.astype(w.dtype) * scale


def _ste_fwd(w):
    return ternary_ste(w), None


def _ste_bwd(_, g):
    return (g,)  # straight-through


ternary_ste.defvjp(_ste_fwd, _ste_bwd)


def pack2bit(q: jnp.ndarray) -> jnp.ndarray:
    """Pack int8 ternary values {-1,0,1} 4-per-byte along the LAST axis.

    Encoding: value + 1 in {0,1,2}, 2 bits each, little-endian within the
    byte. The last axis length must be a multiple of 4.

    Returns a uint8 array with last axis shrunk 4x.
    """
    if q.shape[-1] % 4 != 0:
        raise ValueError(f"last axis {q.shape[-1]} not a multiple of 4")
    enc = (q.astype(jnp.int32) + 1).astype(jnp.uint8)  # {0,1,2}
    enc = enc.reshape(*q.shape[:-1], q.shape[-1] // 4, 4)
    packed = (enc[..., 0]
              | (enc[..., 1] << 2)
              | (enc[..., 2] << 4)
              | (enc[..., 3] << 6))
    return packed.astype(jnp.uint8)


def unpack2bit(packed: jnp.ndarray, *, out_dtype=jnp.int8) -> jnp.ndarray:
    """Inverse of :func:`pack2bit`: uint8 -> ternary values, last axis x4."""
    p = packed.astype(jnp.uint8)
    parts = [(p >> (2 * i)) & 0x3 for i in range(4)]
    enc = jnp.stack(parts, axis=-1)  # (..., n/4, 4)
    q = enc.astype(jnp.int32) - 1
    return q.reshape(*packed.shape[:-1], packed.shape[-1] * 4).astype(out_dtype)
