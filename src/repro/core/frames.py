"""Frame-stream handling: the ColibriES frame-camera acquisition wing.

ColibriES "includes event and frame interfaces and full processing
pipelines": next to the DVS path (``core/events.py``) the platform has a
parallel frame-camera interface feeding Kraken's CUTIE accelerator, the
ternary CNN engine. This module is the frame analogue of the event module:
acquisition delivers fixed-period grayscale frames, preprocessing on the
cluster normalizes them into the CUTIE input format.

The unit of work mirrors :class:`~repro.core.events.EventWindow` so the two
modalities ride the same engine protocol: a :class:`FrameWindow` is one
camera frame (one control tick), a :class:`PaddedFrameBatch` is the fixed
``(B, H, W, 1)`` buffer a :class:`~repro.core.engine.FrameTCNEngine` infers
in one jit'd call. Frames are dense, so unlike events there is no ragged
event-count axis: the jit shape is fixed by the sensor geometry alone.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

__all__ = [
    "FrameWindow",
    "PaddedFrameBatch",
    "pad_frame_windows",
    "normalize_frames",
    "synthetic_gesture_frames",
    "FRAME_SENSOR_H",
    "FRAME_SENSOR_W",
]

# Frame camera geometry; matched to the DVS128 so both wings of the
# platform observe the same scene at the same resolution.
FRAME_SENSOR_H = 128
FRAME_SENSOR_W = 128


@dataclasses.dataclass
class FrameWindow:
    """One camera frame: the frame-modality acquisition unit.

    Attributes:
      pixels: (H, W) uint8/float grayscale intensities in [0, 255].
      duration_us: frame period in microseconds (the control-tick length
        this frame covers, symmetric to ``EventWindow.duration_us``).
      label: optional int class label, -1 if unknown.
    """

    pixels: np.ndarray
    duration_us: int
    label: int = -1

    @property
    def num_pixels(self) -> int:
        return int(self.pixels.shape[0] * self.pixels.shape[1])

    @property
    def shape(self):
        return tuple(self.pixels.shape)


@dataclasses.dataclass
class PaddedFrameBatch:
    """A batch of frames in the engine's fixed slot buffer.

    Attributes:
      pixels: float32 (B, H, W, 1) raw intensities; empty slots are zeros.
      occupied: bool (B,), True where the slot holds a real frame.
      num_pixels: int64 (B,), true pixel count per slot (0 when empty) --
        drives the acquisition/preprocessing legs of the energy model.
      duration_us: shared frame period (one tick length per engine).
      labels: int32 (B,), -1 where unknown/empty.
    """

    pixels: np.ndarray
    occupied: np.ndarray
    num_pixels: np.ndarray
    duration_us: int
    labels: np.ndarray

    @property
    def batch_size(self) -> int:
        return int(self.pixels.shape[0])

    @property
    def frame_shape(self):
        return int(self.pixels.shape[1]), int(self.pixels.shape[2])


def pad_frame_windows(
    frames,
    *,
    batch_size: int | None = None,
    duration_us: int | None = None,
    height: int | None = None,
    width: int | None = None,
) -> PaddedFrameBatch:
    """Pack :class:`FrameWindow` entries (or ``None`` for empty slots)
    into a :class:`PaddedFrameBatch`.

    All frames must share one geometry and one frame period (the frame
    analogue of the event path's one-bin-width-per-engine contract).
    ``height``/``width`` are required only when every slot is empty.
    """
    frames = list(frames)
    b = batch_size if batch_size is not None else len(frames)
    if b == 0:
        raise ValueError("empty batch: give at least one frame (slot) or "
                         "a batch_size > 0")
    if len(frames) > b:
        raise ValueError(f"{len(frames)} frames > batch_size={b}")
    frames = frames + [None] * (b - len(frames))

    durations = {f.duration_us for f in frames if f is not None}
    if len(durations) > 1:
        raise ValueError(f"mixed frame periods in one batch: {durations}")
    if durations:
        duration_us = durations.pop()
    elif duration_us is None:
        raise ValueError("all slots empty: duration_us must be given")

    shapes = {f.shape for f in frames if f is not None}
    if len(shapes) > 1:
        raise ValueError(f"mixed frame geometries in one batch: {shapes}")
    if shapes:
        height, width = shapes.pop()
    elif height is None or width is None:
        raise ValueError("all slots empty: height/width must be given")

    pixels = np.zeros((b, height, width, 1), np.float32)
    occupied = np.zeros(b, bool)
    num_pixels = np.zeros(b, np.int64)
    labels = np.full(b, -1, np.int32)
    for i, f in enumerate(frames):
        if f is None:
            continue
        pixels[i, :, :, 0] = np.asarray(f.pixels, np.float32)
        occupied[i] = True
        num_pixels[i] = f.num_pixels
        labels[i] = f.label
    return PaddedFrameBatch(
        pixels=pixels, occupied=occupied, num_pixels=num_pixels,
        duration_us=int(duration_us), labels=labels,
    )


def normalize_frames(pixels: jnp.ndarray) -> jnp.ndarray:
    """Cluster preprocessing: [0, 255] intensities -> [-1, 1] floats.

    CUTIE consumes zero-centred ternary-friendly activations; the cluster
    performs this scaling while assembling the accelerator input buffer.
    Purely elementwise, so per-slot results never depend on batch size.
    """
    return pixels.astype(jnp.float32) * (2.0 / 255.0) - 1.0


def synthetic_gesture_frames(
    rng: np.random.Generator,
    label: int,
    *,
    duration_us: int = 300_000,
    height: int = FRAME_SENSOR_H,
    width: int = FRAME_SENSOR_W,
    num_classes: int = 11,
    exposure_steps: int = 24,
) -> FrameWindow:
    """Render a synthetic frame of the same gesture family as
    :func:`repro.core.events.synthetic_gesture_events`.

    The frame camera integrates light over the frame period, so the moving
    edge cluster that produces DVS events leaves a motion-blurred intensity
    trail. We render the identical class-parametric trajectory (same
    angular frequency / orbit / phase per label) sampled at
    ``exposure_steps`` points, splatted with a Gaussian spread, over a
    noisy background -- frames a spatial classifier can separate by the
    trail's shape.
    """
    assert 0 <= label < num_classes
    # Same per-class motion constants as the event generator.
    w0 = 2.0 * np.pi * (1.0 + 0.7 * label)
    radius = 20.0 + 3.0 * (label % 4)
    cx = width / 2.0 + 12.0 * np.cos(2.0 * np.pi * label / num_classes)
    cy = height / 2.0 + 12.0 * np.sin(2.0 * np.pi * label / num_classes)
    phase = 2.0 * np.pi * label / num_classes
    vertical = label % 2 == 0

    tau = np.linspace(0.0, 1.0, exposure_steps)
    ang = w0 * tau + phase
    px = cx + radius * np.cos(ang)
    py = cy + radius * (np.sin(2 * ang) if vertical else np.sin(ang))

    yy, xx = np.mgrid[0:height, 0:width]
    img = np.zeros((height, width), np.float64)
    for j in range(exposure_steps):
        d2 = (xx - px[j]) ** 2 + (yy - py[j]) ** 2
        img += np.exp(-d2 / (2.0 * 3.0 ** 2))
    img /= img.max() + 1e-9
    img = 40.0 + 180.0 * img + rng.normal(0.0, 6.0, size=img.shape)
    pixels = np.clip(np.round(img), 0, 255).astype(np.uint8)
    return FrameWindow(pixels=pixels, duration_us=duration_us, label=label)
