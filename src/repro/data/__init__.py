"""Data pipelines: deterministic synthetic LM + DVS-gesture streams."""
from repro.data.synthetic import (DVSBatch, TokenTaskConfig,
                                  dvs_gesture_batch, token_batch,
                                  token_stream)
