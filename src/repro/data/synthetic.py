"""Deterministic synthetic data pipelines (LM tokens + DVS gesture events).

Both pipelines expose an explicit cursor so the trainer can checkpoint and
resume the data stream exactly (fault tolerance: restart reproduces the
same batch sequence).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import events as ev

__all__ = ["TokenTaskConfig", "token_batch", "token_stream",
           "dvs_gesture_batch", "DVSBatch"]


# ----------------------------------------------------------------------
# LM toy task: second half of each sequence copies the first half through
# a fixed permutation -- learnable by any of the model families, with a
# loss floor well below the uniform baseline (used by convergence tests).
# ----------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TokenTaskConfig:
    vocab_size: int = 256
    seq_len: int = 64
    batch_size: int = 8
    task: str = "copy_map"   # "copy_map" (harder) | "repeat" (trivial)


def token_batch(cfg: TokenTaskConfig, step: int) -> Dict[str, jnp.ndarray]:
    """Deterministic batch for a given step index (the cursor)."""
    rng = np.random.default_rng(1234 + step)
    if cfg.task == "repeat":
        # One token repeated per sequence: after position 0 the next token
        # is fully determined -- fast-convergence probe for tests.
        tok = rng.integers(2, cfg.vocab_size, size=(cfg.batch_size, 1),
                           dtype=np.int64)
        toks = np.repeat(tok, cfg.seq_len, axis=1).astype(np.int32)
        targets = toks.copy()
        targets[:, 0] = -1
        return {"tokens": jnp.asarray(toks), "targets": jnp.asarray(targets)}
    half = cfg.seq_len // 2
    first = rng.integers(2, cfg.vocab_size,
                         size=(cfg.batch_size, half), dtype=np.int64)
    perm = (first * 7 + 3) % cfg.vocab_size        # fixed learnable map
    toks = np.concatenate([first, perm], axis=1).astype(np.int32)
    targets = toks.copy()
    targets[:, :half + 1] = -1                     # only score the copy half
    return {"tokens": jnp.asarray(toks), "targets": jnp.asarray(targets)}


def token_stream(cfg: TokenTaskConfig, start_step: int = 0
                 ) -> Iterator[Tuple[int, Dict[str, jnp.ndarray]]]:
    step = start_step
    while True:
        yield step, token_batch(cfg, step)
        step += 1


# ----------------------------------------------------------------------
# DVS-Gesture-like event batches for the SNN (paper wing).
# ----------------------------------------------------------------------


@dataclasses.dataclass
class DVSBatch:
    vox: jnp.ndarray        # (B, T, 2, H, W)
    labels: jnp.ndarray     # (B,)
    num_events: np.ndarray  # (B,) raw event counts (energy model driver)


def dvs_gesture_batch(
    batch_size: int, step: int, *,
    height: int = 128, width: int = 128, time_bins: int = 16,
    mean_events: int = 60_000, num_classes: int = 11,
    duration_us: int = 300_000,
) -> DVSBatch:
    """Deterministic synthetic gesture batch (cursor = step index)."""
    rng = np.random.default_rng(999 + step)
    labels = rng.integers(0, num_classes, size=batch_size)
    voxes, counts = [], []
    for i, lab in enumerate(labels):
        w = ev.synthetic_gesture_events(
            rng, int(lab), duration_us=duration_us,
            mean_events=mean_events, height=height, width=width,
            num_classes=num_classes)
        vox = ev.voxelize(
            jnp.asarray(w.x), jnp.asarray(w.y), jnp.asarray(w.t),
            jnp.asarray(w.p), duration_us=duration_us,
            time_bins=time_bins, height=height, width=width)
        voxes.append(vox)
        counts.append(w.num_events)
    return DVSBatch(
        vox=jnp.stack(voxes),
        labels=jnp.asarray(labels, jnp.int32),
        num_events=np.asarray(counts),
    )
