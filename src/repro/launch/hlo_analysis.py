"""Collective-byte accounting from compiled (post-SPMD) HLO text.

``cost_analysis()`` has no collective term, so we parse the per-device HLO
module: every all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute contributes its on-link byte volume, estimated with the
standard ring formulas on the op's replica-group size g:

    all-gather          result_bytes * (g-1)/g
    reduce-scatter      result_bytes * g * (g-1)/g   (input is g x result)
    all-reduce          result_bytes * 2 * (g-1)/g   (RS + AG)
    all-to-all          result_bytes * (g-1)/g
    collective-permute  result_bytes                  (point-to-point)

Async pairs (-start/-done) are counted once (on -start).
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

__all__ = ["collective_bytes", "parse_shape_bytes"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\](?:\{[^}]*\})?))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def parse_shape_bytes(shape_str: str) -> int:
    """Total bytes of 'bf16[8,128]' or a tuple '(bf16[8], f32[4,2])'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, object]:
    """Per-device on-link byte volume by collective kind (see module doc)."""
    by_kind: Dict[str, float] = {}
    count_by_kind: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        if "-done" in line and "all-" not in line.split("=")[0]:
            pass
        m = _OP_RE.search(line)
        if not m:
            continue
        shape_str, kind, _ = m.groups()
        result_bytes = parse_shape_bytes(shape_str)
        g = _group_size(line)
        if g <= 1:
            continue
        frac = (g - 1) / g
        if kind == "all-reduce":
            vol = 2.0 * result_bytes * frac
        elif kind == "reduce-scatter":
            vol = result_bytes * g * frac
        elif kind == "collective-permute":
            vol = float(result_bytes)
        else:  # all-gather, all-to-all
            vol = result_bytes * frac
        by_kind[kind] = by_kind.get(kind, 0.0) + vol
        count_by_kind[kind] = count_by_kind.get(kind, 0) + 1
    return {
        "bytes_by_kind": by_kind,
        "count_by_kind": count_by_kind,
        "total_bytes": sum(by_kind.values()),
    }


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1
