"""Step builders + abstract input specs for every (arch x shape) cell.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins (weak-type
correct, shardable, zero allocation) for each model input; the dry-run
lowers against them. ``make_*_step`` build the jit-able step functions:

  train_step(params, opt_state, batch) -> (params', opt_state', metrics)
  prefill_step(params, batch)          -> last-position logits
  serve_step(params, cache, tokens)    -> (next_tokens, cache')
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.shapes import ShapeSpec
from repro.distributed.annotate import execution_mode
from repro.models import build_model
from repro.models.config import ModelConfig
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update

__all__ = [
    "input_specs", "abstract_cache", "abstract_opt_state",
    "make_train_step", "make_prefill_step", "make_serve_step",
]

_I32 = jnp.int32


def _token_specs(b: int, s: int, with_targets: bool) -> Dict[str, Any]:
    out = {"tokens": jax.ShapeDtypeStruct((b, s), _I32)}
    if with_targets:
        out["targets"] = jax.ShapeDtypeStruct((b, s), _I32)
    return out


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Abstract model inputs for a cell (train/prefill batch, or the decode
    token batch; decode caches come from ``abstract_cache``)."""
    b = shape.global_batch
    s = shape.seq_len if shape.kind != "decode" else 1
    specs = _token_specs(b, s, with_targets=shape.kind == "train")
    if cfg.family == "encdec" and shape.kind != "decode":
        fd = cfg.frontend_dim or cfg.d_model
        specs["frames"] = jax.ShapeDtypeStruct(
            (b, shape.seq_len, fd), jnp.float32)
    if cfg.family == "vlm" and shape.kind != "decode":
        # Dynamic-resolution stub: 1/4 of the sequence is vision patches.
        n_vis = max(shape.seq_len // 4, 16)
        specs["patch_embeds"] = jax.ShapeDtypeStruct(
            (b, n_vis, cfg.d_model), jnp.float32)
    return specs


def abstract_cache(cfg: ModelConfig, shape: ShapeSpec) -> Any:
    """ShapeDtypeStruct tree of the decode cache for this cell."""
    model = build_model(cfg)
    return jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))


def abstract_opt_state(cfg: ModelConfig) -> Any:
    """ShapeDtypeStruct tree of the AdamW state (fp32 moments)."""
    model = build_model(cfg)
    params = model.abstract_params()
    return jax.eval_shape(adamw_init, params)


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig | None = None,
                    *, remat: bool = True,
                    scan_layers: bool = True) -> Callable:
    opt_cfg = opt_cfg or AdamWConfig()
    model = build_model(cfg)

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            return model.loss(p, batch, remat=remat,
                              scan_layers=scan_layers)
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        new_params, new_opt, om = adamw_update(grads, opt_state, params,
                                               opt_cfg)
        return new_params, new_opt, {**metrics, **om, "loss": loss}

    return train_step


def make_prefill_step(cfg: ModelConfig, *,
                      scan_layers: bool = True) -> Callable:
    model = build_model(cfg)

    def prefill_step(params, batch):
        logits, _ = model.apply(params, batch, scan_layers=scan_layers)
        return logits[:, -1]        # next-token distribution

    return prefill_step


def make_serve_step(cfg: ModelConfig, *,
                    scan_layers: bool = True) -> Callable:
    model = build_model(cfg)

    def serve_step(params, cache, tokens):
        with execution_mode("serve"):
            logits, new_cache = model.decode(params, cache, tokens,
                                             scan_layers=scan_layers)
        next_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(_I32)
        return next_tok, new_cache

    return serve_step
