"""Serving launcher: batched generation, optional CUTIE ternary weights.

Examples:
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke
  PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --smoke \\
      --quant ternary --requests 8 --new-tokens 24
"""
import argparse

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.models import build_model
from repro.serving import ServeConfig, generate, quantize_for_serving
from repro.serving.scheduler import BatchScheduler, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=ARCHS)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--quant", default=None, choices=["ternary"])
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.quant == "ternary":
        params, stats = quantize_for_serving(params)
        print(f"ternary: {stats['quantized']} tensors packed, "
              f"{stats['bytes_before'] / 1e6:.1f} -> "
              f"{stats['bytes_after'] / 1e6:.1f} MB weights")

    rng = np.random.default_rng(0)
    reqs = [Request(
        id=i,
        prompt=rng.integers(2, cfg.vocab_size,
                            size=rng.integers(2, args.prompt_len + 1)),
        max_new_tokens=args.new_tokens)
        for i in range(args.requests)]

    sched = BatchScheduler(model, params, max_batch=args.max_batch,
                           cache_len=args.prompt_len + args.new_tokens + 1)
    done = sched.run(reqs)
    for r in done:
        print(f"req {r.id}: prompt[{len(r.prompt)}] -> "
              f"{r.output[:10]}{'...' if len(r.output) > 10 else ''}")
    st = sched.stats
    print(f"served {len(done)} requests in {st['batches']} batches; "
          f"{st['decode_steps']} decode steps; "
          f"{st['tokens'] / max(st['wall_s'], 1e-9):.1f} tok/s host")


if __name__ == "__main__":
    main()
