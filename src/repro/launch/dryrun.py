import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_FLAGS")
                           or "--xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves, without hardware:
  * the sharding config is coherent (GSPMD partitions the step function
    over the production mesh without errors),
  * the program fits (``memory_analysis`` per-device bytes),
  * and it yields the roofline inputs (``cost_analysis`` FLOPs/bytes +
    collective bytes parsed from the compiled HLO).

Per single-pod cell we additionally compile unrolled depth-1 and depth-2
variants: XLA's HloCostAnalysis counts a scan body ONCE regardless of trip
count (verified empirically -- see EXPERIMENTS.md), so exact full-depth
costs come from the affine model  total(L) = base + L * (cost(L2) -
cost(L1)).  Results are written incrementally as JSON, one file per cell.

Usage:
  python -m repro.launch.dryrun                      # all 33 cells, both meshes
  python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
  python -m repro.launch.dryrun --mesh single --no-depth-variants
"""
import argparse
import dataclasses
import json
import pathlib
import time
import traceback

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.configs.shapes import SHAPES, cells_for
from repro.distributed import sharding as SH
from repro.launch import steps as ST
from repro.launch.hlo_analysis import collective_bytes
from repro.launch.mesh import make_production_mesh
from repro.models import build_model

OUT_DIR = pathlib.Path(os.environ.get("REPRO_DRYRUN_OUT",
                                      "results/dryrun"))


def _mesh_name(multi_pod: bool) -> str:
    return "pod2x16x16" if multi_pod else "pod16x16"


def _out_sharding_tree(mesh, struct_tree):
    """Replicated NamedShardings matching an output struct tree."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), struct_tree)


def lower_cell(cfg, shape, mesh, *, scan_layers=True, quant=None):
    """Lower + compile one cell. Returns (compiled, lowered).

    quant="ternary" (decode cells): abstract params pass through
    ``serving.quantize_for_serving`` -- the CUTIE 2-bit path; packed
    leaves get TP-on-last-dim specs.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P
    model = build_model(cfg)
    defs = model.defs()
    # Decode ALSO uses the 2-D (data x model) FSDP layout: with
    # execution_mode('serve') weights stay sharded at use, so per-device
    # weight reads are params/n_devices (Perf cycle 7). The 'serve'
    # replicated layout only pays off with very large decode batches.
    pspecs = SH.param_pspecs(defs, mesh, mode="train")
    params_abs = model.abstract_params()
    if quant == "ternary" and shape.kind == "decode":
        from repro.serving.serve import quantize_for_serving
        params_abs = jax.eval_shape(
            lambda p: quantize_for_serving(p)[0], params_abs)
        pspecs = _quantized_pspecs(pspecs, params_abs, mesh)
    param_sh = SH.shardings(mesh, pspecs)
    batch_abs = ST.input_specs(cfg, shape)

    if shape.kind == "train":
        opt_abs = ST.abstract_opt_state(cfg)
        opt_specs = SH.opt_pspecs(defs, mesh)
        opt_sh = SH.shardings(mesh, opt_specs)
        bspecs = SH.batch_pspecs(cfg, mesh, shape.global_batch, "train")
        batch_sh = {k: NamedSharding(mesh, bspecs.get(k, P()))
                    for k in batch_abs}
        step = ST.make_train_step(cfg, scan_layers=scan_layers)
        metrics_struct = jax.eval_shape(step, params_abs, opt_abs,
                                        batch_abs)[2]
        jitted = jax.jit(
            step,
            in_shardings=(param_sh, opt_sh, batch_sh),
            out_shardings=(param_sh, opt_sh,
                           _out_sharding_tree(mesh, metrics_struct)),
            donate_argnums=(0, 1),
        )
        lowered = jitted.lower(params_abs, opt_abs, batch_abs)
    elif shape.kind == "prefill":
        bspecs = SH.batch_pspecs(cfg, mesh, shape.global_batch, "prefill")
        batch_sh = {k: NamedSharding(mesh, bspecs.get(k, P()))
                    for k in batch_abs}
        step = ST.make_prefill_step(cfg, scan_layers=scan_layers)
        b = SH._batch_dim_spec(mesh, shape.global_batch)
        vshard = ("model" if cfg.vocab_size %
                  dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
                  == 0 else None)
        out_sh = NamedSharding(mesh, P(b, vshard))
        jitted = jax.jit(step, in_shardings=(param_sh, batch_sh),
                         out_shardings=out_sh)
        lowered = jitted.lower(params_abs, batch_abs)
    else:  # decode
        cache_abs = ST.abstract_cache(cfg, shape)
        cspecs = SH.cache_pspecs(cfg, mesh, cache_abs, shape.global_batch)
        cache_sh = {k: NamedSharding(mesh, s) for k, s in cspecs.items()}
        b = SH._batch_dim_spec(mesh, shape.global_batch)
        tok_sh = NamedSharding(mesh, P(b, None))
        step = ST.make_serve_step(cfg, scan_layers=scan_layers)
        jitted = jax.jit(
            step,
            in_shardings=(param_sh, cache_sh, tok_sh),
            out_shardings=(tok_sh, cache_sh),
            donate_argnums=(1,),
        )
        lowered = jitted.lower(params_abs, cache_abs,
                               jax.ShapeDtypeStruct(
                                   (shape.global_batch, 1), np.int32))
    compiled = lowered.compile()
    return compiled, lowered


def _quantized_pspecs(pspecs, params_abs, mesh):
    """Mirror float pspecs onto the quantized tree: packed keeps the
    source's output-dim sharding (divisibility-checked), scale follows."""
    from jax.sharding import PartitionSpec as P
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def walk(spec, abs_):
        if isinstance(abs_, dict) and "packed" in abs_:
            src = tuple(spec) + (None,) * (abs_["packed"].ndim - len(tuple(spec)))
            out_axis = src[-1]
            packed = [None] * abs_["packed"].ndim
            scale = [None] * abs_["scale"].ndim
            if (out_axis is not None
                    and abs_["packed"].shape[-1] % sizes.get(out_axis, 1) == 0):
                packed[-1] = out_axis
                scale[-1] = out_axis
            return {"packed": P(*packed), "scale": P(*scale)}
        if isinstance(abs_, dict):
            return {k: walk(spec[k], abs_[k]) for k in abs_}
        return spec

    return walk(pspecs, params_abs)


def analyze(compiled) -> dict:
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):   # older jax: one dict per device
        cost = cost[0] if cost else {}
    mem = compiled.memory_analysis()
    out = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "transcendentals": float(cost.get("transcendentals", 0.0)),
    }
    if mem is not None:
        out["memory"] = {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "code_bytes": int(mem.generated_code_size_in_bytes),
        }
    try:
        text = compiled.as_text()
        out["collectives"] = collective_bytes(text)
    except Exception as e:  # pragma: no cover
        out["collectives"] = {"error": str(e)}
    return out


def _depth_cfg(cfg, depth: int):
    """Reduced-depth config for the affine cost model (DESIGN.md Sec. 6).

    zamba2 uses depth = attn_every * k so each unit is one full stage
    (attn_every mamba layers + 1 shared-attn invocation); encdec scales
    encoder and decoder depth together.
    """
    kw = {"num_layers": depth}
    if cfg.family == "zamba2":
        kw = {"num_layers": cfg.attn_every * depth}
    if cfg.family == "encdec":
        kw.update(encoder_layers=depth, decoder_layers=depth)
    return dataclasses.replace(cfg, **kw)


def _depth_units(cfg) -> float:
    """Number of affine units in the full model."""
    if cfg.family == "zamba2":
        return cfg.num_layers / cfg.attn_every
    if cfg.family == "encdec":
        return float(cfg.encoder_layers)
    return float(cfg.num_layers)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             depth_variants: bool = True, force: bool = False,
             quant: str | None = None) -> dict:
    mesh_name = _mesh_name(multi_pod)
    suffix = f"__{quant}" if quant else ""
    out_path = OUT_DIR / f"{arch}__{shape_name}__{mesh_name}{suffix}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": shape.kind, "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
        "num_devices": int(np.prod(mesh.devices.shape)),
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
        "status": "running",
    }
    rec["quant"] = quant
    t0 = time.time()
    try:
        with mesh:
            compiled, _ = lower_cell(cfg, shape, mesh, quant=quant)
        rec["full"] = analyze(compiled)
        rec["compile_s"] = round(time.time() - t0, 1)
        del compiled
        if depth_variants and not multi_pod:
            base_d = 1
            d1, d2 = base_d, 2 * base_d
            for tag, d in (("L1", d1), ("L2", d2)):
                cfg_d = _depth_cfg(cfg, d)
                t1 = time.time()
                with mesh:
                    comp_d, _ = lower_cell(cfg_d, shape, mesh,
                                           scan_layers=False, quant=quant)
                rec[tag] = analyze(comp_d)
                rec[tag]["compile_s"] = round(time.time() - t1, 1)
                del comp_d
            rec["depth_units"] = _depth_units(cfg)
        rec["status"] = "ok"
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.time() - t0, 1)
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCHS + [None])
    ap.add_argument("--shape", default=None,
                    choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--no-depth-variants", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--quant", default=None, choices=["ternary", None])
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ARCHS
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    n_ok = n_err = 0
    for arch in archs:
        cfg = get_config(arch)
        cells = ([SHAPES[args.shape]] if args.shape
                 else cells_for(cfg))
        for cell in cells:
            if args.shape is None and cell not in cells_for(cfg):
                continue
            for mp in meshes:
                t0 = time.time()
                rec = run_cell(arch, cell.name, mp,
                               depth_variants=not args.no_depth_variants,
                               force=args.force, quant=args.quant)
                ok = rec["status"] == "ok"
                n_ok += ok
                n_err += not ok
                print(f"[{time.strftime('%H:%M:%S')}] {arch} x {cell.name}"
                      f" x {_mesh_name(mp)}: {rec['status']}"
                      f" ({rec.get('total_s', 0)}s)"
                      + ("" if ok else f"  {rec.get('error', '')[:200]}"),
                      flush=True)
    print(f"dry-run done: {n_ok} ok, {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
