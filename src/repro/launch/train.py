"""Training launcher: any assigned arch, CPU smoke or mesh-sharded.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \\
      --steps 50
  REPRO_XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \\
      --smoke --steps 10 --mesh 2x4

With --mesh, params/optimizer/batch are sharded with the production rules
(FSDP over 'data', TP over 'model') -- the same path the 512-chip dry-run
proves, executing eagerly on the host devices.
"""
import os
if os.environ.get("REPRO_XLA_FLAGS"):
    os.environ["XLA_FLAGS"] = os.environ["REPRO_XLA_FLAGS"]

import argparse

import jax

from repro.configs import ARCHS, get_config
from repro.data import TokenTaskConfig, token_batch
from repro.launch.mesh import make_mesh_for
from repro.models import build_model
from repro.training import AdamWConfig, Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=ARCHS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--mesh", default=None,
                    help="e.g. 2x4 -> (data=2, model=4) over host devices")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--grad-compression", type=float, default=None)
    ap.add_argument("--remat", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.family in ("encdec", "vlm") and args.smoke:
        print(f"note: {args.arch} needs frames/patches; using token-only "
              "batches against the decoder/backbone")
    model = build_model(cfg)
    print(f"{cfg.name}: {model.num_params() / 1e6:.1f}M params")

    tk = TokenTaskConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                         batch_size=args.batch, task="repeat")

    def batch_fn(step):
        b = token_batch(tk, step)
        if cfg.family == "encdec":
            import jax.numpy as jnp
            fd = cfg.frontend_dim or cfg.d_model
            b["frames"] = jax.random.normal(
                jax.random.PRNGKey(step), (args.batch, args.seq, fd))
        return b

    mesh_cm = None
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("data", "model")[:len(shape)] if len(shape) == 2 \
            else ("pod", "data", "model")
        mesh = make_mesh_for(shape, axes)
        mesh_cm = mesh
        # Under the mesh context the in-model constraints (vocab-sharded
        # logits, gather-at-use, attention TP/CP) shard the computation;
        # params are laid out by GSPMD from those constraints.
        print(f"mesh {shape} over {mesh.devices.size} devices")

    tcfg = TrainerConfig(
        total_steps=args.steps,
        ckpt_every=max(args.steps // 4, 1),
        ckpt_dir=args.ckpt_dir or f"checkpoints/{args.arch}",
        log_every=max(args.steps // 10, 1),
        remat=args.remat,
        grad_compression_ratio=args.grad_compression,
        opt=AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                        total_steps=args.steps),
    )
    trainer = Trainer(model, tcfg, batch_fn)
    rng = jax.random.PRNGKey(0)
    if mesh_cm is not None:
        with mesh_cm:
            res = trainer.run_with_restarts(rng)
    else:
        res = trainer.run_with_restarts(rng)
    h = res["history"]
    print(f"done: loss {h[0]['loss']:.4f} -> {h[-1]['loss']:.4f} over "
          f"{res['final_step']} steps; stragglers={trainer.straggler_steps}")


if __name__ == "__main__":
    main()
