"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (one TPU v5e pod's worth).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis only
carries data parallelism (gradient all-reduce over DCN), FSDP and TP stay
intra-pod (DESIGN.md).

A FUNCTION, not a module constant: importing this module must not touch
jax device state (the dry-run sets XLA_FLAGS before any jax init).
"""
from __future__ import annotations

import math

import jax
import numpy as np
from jax.sharding import Mesh

__all__ = ["make_production_mesh", "make_mesh_for"]


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_for(shape, axes)


def make_mesh_for(shape, axes) -> Mesh:
    """jax.make_mesh over the first prod(shape) devices (the container
    exposes 512 host devices; the single-pod mesh uses 256 of them)."""
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devices)}; the dry-run must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import")
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:   # pre-AxisType jax: plain Mesh is equivalent
        return Mesh(np.asarray(devices[:n]).reshape(shape), axes)
    auto = (axis_type.Auto,) * len(axes)
    try:
        return jax.make_mesh(shape, axes, axis_types=auto,
                             devices=devices[:n])
    except TypeError:  # older make_mesh without devices/axis_types kwarg
        return Mesh(np.asarray(devices[:n]).reshape(shape), axes)
