"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (one TPU v5e pod's worth).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis only
carries data parallelism (gradient all-reduce over DCN), FSDP and TP stay
intra-pod (DESIGN.md).

Mesh construction itself is unified in
:func:`repro.distributed.make_mesh` (one constructor for the launch
stack, the sharded serving engine, examples, and benchmarks);
``make_mesh_for`` remains as an alias of its explicit ``(shape, axes)``
form. FUNCTIONS, not module constants: importing this module must not
touch jax device state (the dry-run sets XLA_FLAGS before any jax init).
"""
from __future__ import annotations

from jax.sharding import Mesh

from repro.distributed.mesh import make_mesh

__all__ = ["make_production_mesh", "make_mesh_for", "make_mesh"]


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh_for(shape, axes) -> Mesh:
    """Alias of :func:`repro.distributed.make_mesh` (the container
    exposes 512 host devices; the single-pod mesh uses 256 of them)."""
    return make_mesh(shape, axes)
