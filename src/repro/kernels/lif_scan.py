"""Fused LIF temporal-scan Pallas kernel -- the SNE analogue on TPU.

SNE (Kraken's sparse neural engine) keeps neuron membrane state *inside the
engine* while a spike train streams through; networks bigger than the
engine's neuron capacity are executed in capacity-sized tiles,
time-domain-multiplexed (paper Sec. III). The TPU mapping of that insight
(DESIGN.md): membrane state stays resident in VMEM scratch for the entire
temporal scan while input currents stream HBM->VMEM tile by tile. A naive
jnp ``lax.scan`` materializes V to HBM every step (2x state traffic per
step); the fused kernel touches HBM only for currents-in / spikes-out.

Layout: currents are processed as (T, R, 128) -- neurons split into
R = N/128 lane-rows, so each timestep's update is a full-width (R, 128)
VPU operation (sublane-dim >= 8 keeps the VPU busy; a flat (N,) row per
step would waste 7/8 sublanes).

Grid: (R tiles, T chunks); the T-chunk axis is sequential ("arbitrary")
and carries V in VMEM scratch across chunks -- exactly SNE's
time-multiplexed pass structure with the neuron tile as the capacity unit
(see ``repro.core.tiling``).

Recurrence (reset-to-zero LIF, single carried state):
    V[t] = alpha * V[t-1] * (V[t-1] < v_th) + I[t]
    S[t] = V[t] >= v_th
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.lif import LIFParams
from repro.kernels._compat import CompilerParams as _CompilerParams

__all__ = ["lif_scan_pallas", "lif_scan_pallas_batched", "choose_blocks",
           "LANES"]

LANES = 128
_DEF_VMEM_BUDGET = 4 * 1024 * 1024  # conservative per-call VMEM budget


def choose_blocks(
    t: int, r: int, dtype, vmem_budget: int = _DEF_VMEM_BUDGET
) -> Tuple[int, int]:
    """Pick (block_t, block_r) so currents+spikes+state tiles fit VMEM.

    This is the SNE capacity computation with VMEM bytes as the capacity
    (cf. ``repro.core.tiling.plan_layer_tiles(capacity_kind='vmem_bytes')``):
    per neuron-row tile we hold block_t rows of currents and spikes plus
    three f32 state planes. The preferred block_t floor of 8 (sublane
    efficiency) is honoured only while it fits: with a tiny budget
    block_t is clamped down to what the budget allows (>= 1), and a
    budget too small for even a (block_t=1, block_r=8) tile raises
    rather than silently overcommitting VMEM.
    """
    esize = jnp.dtype(dtype).itemsize
    block_r = min(r, 64)  # 64*128 f32 state = 32 KiB; >=8 sublanes
    while True:
        state_bytes = 3 * 4 * block_r * LANES
        per_t = 2 * esize * block_r * LANES
        fit_t = (vmem_budget - state_bytes) // per_t  # may be <= 0
        block_t = int(min(max(fit_t, 8), t))
        if state_bytes + block_t * per_t <= vmem_budget:
            return block_t, block_r
        if block_r > 8:
            block_r //= 2
            continue
        # Smallest row tile: clamp block_t below the sublane floor
        # instead of exceeding the budget.
        if fit_t >= 1:
            return int(min(fit_t, t)), block_r
        raise ValueError(
            f"vmem_budget={vmem_budget} too small for the LIF scan: one "
            f"(block_t=1, block_r=8) tile needs "
            f"{state_bytes + per_t} bytes "
            f"({state_bytes} state + {per_t} per timestep)")


def _kernel(cur_ref, v0_ref, spk_ref, vfin_ref, v_scr,
            *, alpha: float, v_th: float, t_total: int, block_t: int):
    tc = pl.program_id(1)
    n_tc = pl.num_programs(1)

    @pl.when(tc == 0)
    def _init():
        v_scr[...] = v0_ref[...].astype(jnp.float32)

    def step(i, v):
        # Global timestep; guards the T padding tail (padded steps must not
        # advance the dynamics, or v_final would decay past the true T).
        in_range = tc * block_t + i < t_total
        cur = cur_ref[i, :, :].astype(jnp.float32)
        live = (v < v_th).astype(jnp.float32)       # reset-to-zero mask
        v_new = alpha * v * live + cur
        s = (v_new >= v_th).astype(spk_ref.dtype)
        spk_ref[i, :, :] = jnp.where(in_range, s, jnp.zeros_like(s))
        return jnp.where(in_range, v_new, v)

    v = jax.lax.fori_loop(0, block_t, step, v_scr[...])
    v_scr[...] = v

    @pl.when(tc == n_tc - 1)
    def _fin():
        vfin_ref[...] = v.astype(vfin_ref.dtype)


def lif_scan_pallas(
    currents: jnp.ndarray,
    p: LIFParams,
    v0: jnp.ndarray | None = None,
    *,
    block_t: int | None = None,
    block_r: int | None = None,
    interpret: bool | None = None,
    vmem_budget: int = _DEF_VMEM_BUDGET,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused LIF scan over (T, ...) currents. Returns (spikes, v_final).

    Forward-only (no AD rules); use ``repro.kernels.ops.lif_scan`` for the
    differentiable (STBP surrogate) wrapper.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    orig_shape = currents.shape
    t = orig_shape[0]
    n = 1
    for d in orig_shape[1:]:
        n *= d
    if v0 is None:
        v0 = jnp.zeros(orig_shape[1:], currents.dtype)

    cur = currents.reshape(t, n)
    v0f = v0.reshape(n)
    # Pad neurons to a whole number of 128-lane rows.
    n_pad = (-n) % LANES
    if n_pad:
        cur = jnp.pad(cur, ((0, 0), (0, n_pad)))
        v0f = jnp.pad(v0f, (0, n_pad))
    r = (n + n_pad) // LANES
    cur = cur.reshape(t, r, LANES)
    v0r = v0f.reshape(r, LANES)

    bt, br = choose_blocks(t, r, currents.dtype, vmem_budget)
    if block_t is not None:
        bt = block_t
    if block_r is not None:
        br = block_r
    # Pad T and R to block multiples (T tail masked inside the kernel).
    t_pad, r_pad = (-t) % bt, (-r) % br
    if t_pad or r_pad:
        cur = jnp.pad(cur, ((0, t_pad), (0, r_pad), (0, 0)))
        v0r = jnp.pad(v0r, ((0, r_pad), (0, 0)))
    tt, rr = t + t_pad, r + r_pad

    grid = (rr // br, tt // bt)
    kernel = functools.partial(
        _kernel, alpha=float(p.alpha), v_th=float(p.v_th),
        t_total=t, block_t=bt,
    )
    spikes, v_fin = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, br, LANES), lambda ri, ti: (ti, ri, 0)),
            pl.BlockSpec((br, LANES), lambda ri, ti: (ri, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bt, br, LANES), lambda ri, ti: (ti, ri, 0)),
            pl.BlockSpec((br, LANES), lambda ri, ti: (ri, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((tt, rr, LANES), currents.dtype),
            jax.ShapeDtypeStruct((rr, LANES), currents.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((br, LANES), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(cur, v0r)

    spikes = spikes[:t].reshape(t, (n + n_pad))[:, :n].reshape(orig_shape)
    v_fin = v_fin.reshape(rr * LANES)[:n].reshape(orig_shape[1:])
    return spikes, v_fin


def lif_scan_pallas_batched(
    currents: jnp.ndarray,
    p: LIFParams,
    v0: jnp.ndarray | None = None,
    **kw,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused LIF scan over a batch of streams: (B, T, ...) -> (spikes, v_final).

    One Pallas launch scans all ``B`` streams: each stream's neurons are
    padded to whole 128-lane rows and the per-stream rows are stacked along
    the neuron-row axis, so the kernel's parallel grid axis enumerates
    ``B * R`` rows and every stream's membrane state is VMEM-resident for
    the whole temporal scan -- SNE's time-multiplexed execution, stream-
    multiplexed too. LIF dynamics are elementwise per neuron, so results
    are bitwise identical to ``B`` independent :func:`lif_scan_pallas`
    calls.

    Returns ``spikes`` of shape (B, T, ...) and ``v_final`` of (B, ...).
    """
    if currents.ndim < 2:
        raise ValueError(f"need (B, T, ...) currents, got {currents.shape}")
    b, t = currents.shape[0], currents.shape[1]
    feat = currents.shape[2:]
    n = 1
    for d in feat:
        n *= d
    if v0 is None:
        v0 = jnp.zeros((b, *feat), currents.dtype)

    cur = currents.reshape(b, t, n)
    v0f = v0.reshape(b, n)
    # Per-stream lane padding: each stream occupies whole rows, keeping its
    # rows contiguous on the row axis (cheap unfold, no cross-stream lanes).
    n_pad = (-n) % LANES
    if n_pad:
        cur = jnp.pad(cur, ((0, 0), (0, 0), (0, n_pad)))
        v0f = jnp.pad(v0f, ((0, 0), (0, n_pad)))
    r_s = (n + n_pad) // LANES        # rows per stream
    cur_rows = jnp.transpose(cur.reshape(b, t, r_s, LANES), (1, 0, 2, 3))
    cur_rows = cur_rows.reshape(t, b * r_s, LANES)
    v0_rows = v0f.reshape(b * r_s, LANES)

    spikes, v_fin = lif_scan_pallas(cur_rows, p, v0_rows, **kw)

    spikes = spikes.reshape(t, b, r_s * LANES)[:, :, :n]
    spikes = jnp.transpose(spikes, (1, 0, 2)).reshape(b, t, *feat)
    v_fin = v_fin.reshape(b, r_s * LANES)[:, :n].reshape(b, *feat)
    return spikes, v_fin
