"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` function is the numerical ground truth the kernels are
validated against (tests sweep shapes/dtypes with assert_allclose). They are
also the CPU/autodiff fallbacks used by the higher layers when the kernel
path is disabled.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.lif import LIFParams
from repro.core.ternary import unpack2bit

__all__ = ["lif_scan_ref", "ternary_matmul_ref", "wkv6_ref"]


def lif_scan_ref(
    currents: jnp.ndarray,
    p: LIFParams,
    v0: jnp.ndarray | None = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """LIF dynamics over (T, ...) currents. Returns (spikes, v_final).

    Identical recurrence to the SNE hardware model (reset-to-zero):
        V[t] = alpha * V[t-1] * (V[t-1] < v_th) + I[t]
        S[t] = V[t] >= v_th

    Numerical contract (matches the Pallas kernel): the membrane state is
    carried in f32 regardless of input dtype -- SNE keeps wide fixed-point
    state in-engine; bf16 state would drift across long spike trains.
    """
    dt = currents.dtype
    if v0 is None:
        v0 = jnp.zeros(currents.shape[1:], jnp.float32)

    alpha = jnp.float32(p.alpha)
    v_th = jnp.float32(p.v_th)

    def step(v, i_t):
        v_new = alpha * v * (v < v_th).astype(jnp.float32) \
            + i_t.astype(jnp.float32)
        s = (v_new >= v_th).astype(dt)
        return v_new, s

    v_final, spikes = jax.lax.scan(step, v0.astype(jnp.float32), currents)
    return spikes, v_final.astype(dt)


def ternary_matmul_ref(
    x: jnp.ndarray,
    w_packed: jnp.ndarray,
    scale: jnp.ndarray,
) -> jnp.ndarray:
    """Packed-ternary matmul oracle.

    Args:
      x: (M, K) activations (f32/bf16).
      w_packed: (K // 4, N) uint8; byte row j holds ternary weights for
        K indices 4j..4j+3 (see ``repro.core.ternary.pack2bit`` semantics,
        packed along K).
      scale: (N,) per-output-channel dequant scale.

    Returns: (M, N) in x.dtype, accumulation in f32.
    """
    kp, n = w_packed.shape
    # Unpack along the packed (first) axis: move it last, unpack, restore.
    w_q = unpack2bit(w_packed.T).T  # (K, N) int8 in {-1, 0, 1}
    acc = jnp.dot(x.astype(jnp.float32), w_q.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    return (acc * scale[None, :].astype(jnp.float32)).astype(x.dtype)


def wkv6_ref(
    r: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    w: jnp.ndarray,
    u: jnp.ndarray,
    state0: jnp.ndarray | None = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """RWKV-6 (Finch) WKV recurrence oracle, one head.

        S_t = diag(w_t) S_{t-1} + k_t v_t^T
        o_t = r_t (S_{t-1} + diag(u) k_t v_t^T)        (bonus-u form)

    Args:
      r, k, w: (T, Dk); v: (T, Dv); u: (Dk,); w is the per-step decay in
        (0, 1) (already exp(-exp(..))-transformed).
      state0: optional (Dk, Dv) initial state.

    Returns: (o, state_final) with o (T, Dv), f32 accumulation.
    """
    t, dk = k.shape
    dv = v.shape[1]
    f32 = jnp.float32
    s0 = jnp.zeros((dk, dv), f32) if state0 is None else state0.astype(f32)

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp
        kv = jnp.outer(k_t, v_t).astype(f32)
        o_t = (r_t.astype(f32) @ (s + u.astype(f32)[:, None] * kv))
        s_new = w_t.astype(f32)[:, None] * s + kv
        return s_new, o_t

    s_fin, o = jax.lax.scan(step, s0, (r, k, v, w))
    return o.astype(r.dtype), s_fin
