"""Fused synapse+LIF Pallas kernel for the fully-connected SNN layers.

The plain ``layer_serial`` hot path materializes every fc layer's full
(T, B, N) synaptic-current tensor to HBM (``spikes @ W`` under vmap) and
then re-reads it inside the fused LIF scan. SNE never does that: spikes
stream *through* the engine while weights and membrane state stay inside
it. This kernel is the TPU mapping of that dataflow for the fc1/fc2
layers (2048 -> 512 -> 11, the FLOPs-dominant stages):

  * one launch computes ``spikes[t] @ W`` on the MXU *and* the LIF update
    on the VPU, timestep block by timestep block;
  * the (K, block_n) weight panel and the (B, block_n) membrane plane are
    VMEM-resident across the whole temporal scan (weight index map is
    constant in the sequential T-chunk grid axis, membrane lives in VMEM
    scratch);
  * synaptic currents are consumed the moment they are produced -- they
    never touch HBM. HBM traffic drops from
    ``T*B*(K + 3N)`` words (currents written + read, spikes out) to
    ``T*B*(K + N)`` (spikes in / spikes out) per layer.

Grid: (N tiles, T chunks). The N axis is parallel; the T-chunk axis is
sequential ("arbitrary") and carries the membrane plane in scratch --
SNE's time-domain-multiplexed pass structure with an output-neuron panel
as the capacity unit.

Numerics are bitwise identical to the unfused path (XLA computes each
output element of a f32 matmul as an independent K-dot, so chunking T or
padding N with zero columns changes nothing; the LIF update is the exact
expression of ``lif_scan_reference``) -- pinned by tests at B in
{1, 4, 8}.

Recurrence (reset-to-zero LIF, single carried state):
    I[t] = S_in[t] @ W
    V[t] = alpha * V[t-1] * (V[t-1] < v_th) + I[t]
    S[t] = V[t] >= v_th
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.lif import LIFParams
from repro.kernels._compat import CompilerParams as _CompilerParams

__all__ = ["fc_lif_scan_pallas", "fc_lif_scan_pallas_batched",
           "choose_fc_blocks"]

LANES = 128
# Weights + a T-block of spikes in/out + currents + state must fit; the
# full-model fc1 panel (2048 x 512 f32 = 4 MiB) plus a 16-step block at
# B=8 uses ~5.5 MiB of the 8 MiB default.
_DEF_VMEM_BUDGET = 8 * 1024 * 1024


def choose_fc_blocks(
    t: int, b: int, k: int, n: int, dtype,
    vmem_budget: int = _DEF_VMEM_BUDGET,
) -> Tuple[int, int]:
    """Pick (block_t, block_n) so the fused fc+LIF working set fits VMEM.

    Per (T-chunk, N-tile) step the kernel holds: the (K, block_n) weight
    panel, two f32 state planes (membrane scratch + v0), block_t rows of
    input spikes (B, K), and block_t rows of output spikes + currents
    (B, block_n). Shrinks block_n (lane-multiple) before block_t; raises
    when even a (1, LANES) tile exceeds the budget -- never silently
    overcommits.
    """
    esize = jnp.dtype(dtype).itemsize
    n_padded = n + ((-n) % LANES)
    block_n = min(n_padded, 4 * LANES)
    while True:
        w_bytes = 4 * k * block_n
        state_bytes = 2 * 4 * b * block_n
        per_t = b * (k * esize + block_n * (esize + 4))
        avail = vmem_budget - w_bytes - state_bytes
        if avail >= per_t:
            return int(min(max(avail // per_t, 1), t)), block_n
        if block_n > LANES:
            block_n = max((block_n // 2) // LANES * LANES, LANES)
            continue
        need = w_bytes + state_bytes + per_t
        raise ValueError(
            f"vmem_budget={vmem_budget} too small for fc_lif_scan: one "
            f"(block_t=1, block_n={LANES}) step over K={k}, B={b} needs "
            f"{need} bytes")


def _kernel(spk_ref, w_ref, v0_ref, out_ref, vfin_ref, v_scr,
            *, alpha: float, v_th: float, t_total: int, block_t: int):
    tc = pl.program_id(1)
    n_tc = pl.num_programs(1)

    @pl.when(tc == 0)
    def _init():
        v_scr[...] = v0_ref[...].astype(jnp.float32)

    # Synapse stage: all block_t timesteps' currents in one MXU call.
    # (block_t*B, K) @ (K, block_n) is bitwise the same per output element
    # as the unfused vmap-over-T matmul (independent K-dots).
    bt, b, k = spk_ref.shape
    cur_all = jnp.dot(
        spk_ref[...].reshape(bt * b, k).astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).reshape(bt, b, -1)

    def step(i, v):
        # Global timestep; guards the T padding tail (padded steps must
        # not advance the dynamics).
        in_range = tc * block_t + i < t_total
        cur = cur_all[i]
        live = (v < v_th).astype(jnp.float32)       # reset-to-zero mask
        v_new = alpha * v * live + cur
        s = (v_new >= v_th).astype(out_ref.dtype)
        out_ref[i, :, :] = jnp.where(in_range, s, jnp.zeros_like(s))
        return jnp.where(in_range, v_new, v)

    v = jax.lax.fori_loop(0, block_t, step, v_scr[...])
    v_scr[...] = v

    @pl.when(tc == n_tc - 1)
    def _fin():
        vfin_ref[...] = v.astype(vfin_ref.dtype)


def fc_lif_scan_pallas(
    spikes: jnp.ndarray,
    w: jnp.ndarray,
    p: LIFParams,
    v0: jnp.ndarray | None = None,
    *,
    block_t: int | None = None,
    block_n: int | None = None,
    interpret: bool | None = None,
    vmem_budget: int = _DEF_VMEM_BUDGET,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused ``spikes @ w`` + LIF scan. Returns (out_spikes, v_final).

    Args:
      spikes: (T, B, K) -- or (T, K), treated as B=1 -- input spike train.
      w: (K, N) synaptic weights.
      p: LIF constants.
      v0: optional initial membrane, (B, N) (or (N,) for 2-D spikes).

    Forward-only (no AD rules); use ``repro.kernels.ops.fc_lif_scan`` for
    the differentiable (STBP surrogate) wrapper.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    squeeze = spikes.ndim == 2
    if squeeze:
        spikes = spikes[:, None, :]
        if v0 is not None:
            v0 = v0[None]
    if spikes.ndim != 3:
        raise ValueError(f"need (T, B, K) spikes, got {spikes.shape}")
    t, b, k = spikes.shape
    kw, n = w.shape
    if kw != k:
        raise ValueError(f"spikes K={k} != weights K={kw}")
    if v0 is None:
        v0 = jnp.zeros((b, n), spikes.dtype)

    bt, bn = choose_fc_blocks(t, b, k, n, spikes.dtype, vmem_budget)
    if block_t is not None:
        bt = block_t
    if block_n is not None:
        bn = block_n
    if bn % LANES:
        raise ValueError(f"block_n={bn} must be a multiple of {LANES}")

    # Pad N to a block multiple with zero weight columns (each output
    # column is independent, so padding never changes live columns) and
    # T to a block multiple (tail masked inside the kernel). K is the
    # contraction axis and is deliberately NOT padded.
    n_pad = (-n) % bn
    t_pad = (-t) % bt
    w_p = jnp.pad(w, ((0, 0), (0, n_pad))) if n_pad else w
    v0_p = jnp.pad(v0, ((0, 0), (0, n_pad))) if n_pad else v0
    spk = jnp.pad(spikes, ((0, t_pad), (0, 0), (0, 0))) if t_pad else spikes
    tt, nn = t + t_pad, n + n_pad

    grid = (nn // bn, tt // bt)
    kernel = functools.partial(
        _kernel, alpha=float(p.alpha), v_th=float(p.v_th),
        t_total=t, block_t=bt,
    )
    out, v_fin = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # Input spikes revisit the same (block_t, B, K) slab for every
            # N tile; the weight panel's index map is constant along the
            # sequential T axis, so it stays VMEM-resident for the scan.
            pl.BlockSpec((bt, b, k), lambda ni, ti: (ti, 0, 0)),
            pl.BlockSpec((k, bn), lambda ni, ti: (0, ni)),
            pl.BlockSpec((b, bn), lambda ni, ti: (0, ni)),
        ],
        out_specs=[
            pl.BlockSpec((bt, b, bn), lambda ni, ti: (ti, 0, ni)),
            pl.BlockSpec((b, bn), lambda ni, ti: (0, ni)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((tt, b, nn), spikes.dtype),
            jax.ShapeDtypeStruct((b, nn), spikes.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((b, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(spk, w_p, v0_p)

    out = out[:t, :, :n]
    v_fin = v_fin[:, :n]
    if squeeze:
        out, v_fin = out[:, 0, :], v_fin[0]
    return out, v_fin


def fc_lif_scan_pallas_batched(
    spikes: jnp.ndarray,
    w: jnp.ndarray,
    p: LIFParams,
    v0: jnp.ndarray | None = None,
    **kw,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Stream-major entry: (B, T, K) spikes -> ((B, T, N), (B, N)).

    The kernel itself is batched (its sublane axis is B); this wrapper
    only transposes to the kernel's time-major layout and threads the
    per-stream ``v0`` -- the shape the stateful-streaming API hands over
    when carrying fc membrane across a stream's windows.
    """
    if spikes.ndim != 3:
        raise ValueError(f"need (B, T, K) spikes, got {spikes.shape}")
    out, v_fin = fc_lif_scan_pallas(
        jnp.transpose(spikes, (1, 0, 2)), w, p, v0, **kw)
    return jnp.transpose(out, (1, 0, 2)), v_fin
