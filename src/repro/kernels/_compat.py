"""Version compatibility shims shared by the Pallas kernels."""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu


def _resolve_compiler_params():
    """jax renamed TPUCompilerParams -> CompilerParams; support both."""
    for name in ("CompilerParams", "TPUCompilerParams"):
        cls = getattr(pltpu, name, None)
        if cls is not None:
            return cls
    raise ImportError(
        "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
        "TPUCompilerParams; this jax version is unsupported")


CompilerParams = _resolve_compiler_params()
