"""Fused RWKV-6 WKV recurrence Pallas kernel.

The same state-residency insight as ``lif_scan`` (SNE keeps neuron state
in-engine; DESIGN.md maps it to VMEM): the per-head (hd x hd) WKV state
stays in VMEM scratch across the whole sequence while r/k/v/decay stream
through, instead of being re-materialized to HBM every step (the naive
scan) or every chunk boundary (the chunked-parallel form).

    o_t = r_t (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T,   w_t = exp(logw_t)

Layout: heads x batch flattened to BH; blocks of ``block_bh`` heads are
processed per grid row with all per-step tensors (bh, hd) and the state
(bh, hd, hd) resident in VMEM. hd = 64 fills half a lane row -- an
acknowledged sub-optimality (a 2-head lane-packing variant is the next
hillclimb step on real hardware).

Grid: (BH tiles, T chunks); T sequential ("arbitrary") with the state in
scratch, exactly the lif_scan pattern.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

__all__ = ["wkv6_scan_pallas"]

_DEF_VMEM_BUDGET = 8 * 1024 * 1024


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, sfin_ref, s_scr,
            *, block_t: int, t_total: int):
    tc = pl.program_id(1)
    n_tc = pl.num_programs(1)

    @pl.when(tc == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    u = u_ref[...].astype(jnp.float32)              # (bh, hd)

    def step(i, s):
        in_range = tc * block_t + i < t_total
        r = r_ref[i, :, :].astype(jnp.float32)      # (bh, hd)
        k = k_ref[i, :, :].astype(jnp.float32)
        v = v_ref[i, :, :].astype(jnp.float32)
        w = jnp.exp(lw_ref[i, :, :].astype(jnp.float32))
        kv = k[:, :, None] * v[:, None, :]          # (bh, hd, hd)
        o = jnp.sum((s + u[:, :, None] * kv) * r[:, :, None], axis=1)
        o_ref[i, :, :] = jnp.where(in_range, o, 0.0).astype(o_ref.dtype)
        s_new = w[:, :, None] * s + kv
        return jnp.where(in_range, s_new, s)

    s = jax.lax.fori_loop(0, block_t, step, s_scr[...])
    s_scr[...] = s

    @pl.when(tc == n_tc - 1)
    def _fin():
        sfin_ref[...] = s.astype(sfin_ref.dtype)


def wkv6_scan_pallas(
    r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, logw: jnp.ndarray,
    u: jnp.ndarray,
    *,
    block_bh: int = 8,
    block_t: int | None = None,
    interpret: bool | None = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused WKV-6 scan. r/k/v/logw: (B, T, H, hd); u: (H, hd).

    Returns (o (B, T, H, hd), state (B, H, hd, hd) f32). Oracle:
    ``repro.kernels.ref.wkv6_ref`` (per head) / ``wkv6_chunked``.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, t, h, hd = r.shape
    bh = b * h

    def to_bh(x):  # (B, T, H, hd) -> (BH, T, hd) -> (T, BH, hd)
        return x.transpose(0, 2, 1, 3).reshape(bh, t, hd).transpose(1, 0, 2)

    rr, kk, vv, lw = (to_bh(x) for x in (r, k, v, logw))
    ub = jnp.broadcast_to(u[None], (b, h, hd)).reshape(bh, hd)

    pad_bh = (-bh) % block_bh
    if pad_bh:
        rr, kk, vv, lw = (jnp.pad(x, ((0, 0), (0, pad_bh), (0, 0)))
                          for x in (rr, kk, vv, lw))
        ub = jnp.pad(ub, ((0, pad_bh), (0, 0)))
    bhp = bh + pad_bh

    if block_t is None:
        esize = jnp.dtype(r.dtype).itemsize
        per_t = 5 * esize * block_bh * hd
        state = 4 * block_bh * hd * hd
        block_t = int(min(t, max((_DEF_VMEM_BUDGET - state) // per_t, 8)))
    pad_t = (-t) % block_t
    if pad_t:
        rr, kk, vv, lw = (jnp.pad(x, ((0, pad_t), (0, 0), (0, 0)))
                          for x in (rr, kk, vv, lw))
    tt = t + pad_t

    grid = (bhp // block_bh, tt // block_t)
    kernel = functools.partial(_kernel, block_t=block_t, t_total=t)
    seq_spec = pl.BlockSpec((block_t, block_bh, hd),
                            lambda bi, ti: (ti, bi, 0))
    o, s_fin = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[seq_spec, seq_spec, seq_spec, seq_spec,
                  pl.BlockSpec((block_bh, hd), lambda bi, ti: (bi, 0))],
        out_specs=[seq_spec,
                   pl.BlockSpec((block_bh, hd, hd),
                                lambda bi, ti: (bi, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((tt, bhp, hd), r.dtype),
                   jax.ShapeDtypeStruct((bhp, hd, hd), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((block_bh, hd, hd), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(rr, kk, vv, lw, ub)

    o = o[:t, :bh].transpose(1, 0, 2).reshape(b, h, t, hd)
    o = o.transpose(0, 2, 1, 3)
    s_fin = s_fin[:bh].reshape(b, h, hd, hd)
    return o, s_fin
