"""Packed-ternary matmul Pallas kernel -- the CUTIE analogue on TPU.

CUTIE (Kraken's ternary accelerator) executes {-1,0,+1}-weight networks
with silicon-unrolled ternary MACs. On TPU the dense bf16 MXU is fixed, so
the transferable win is *weight bandwidth* (DESIGN.md): weights live in HBM
packed 4-per-byte (2 bit each) and are unpacked + dequantized in VMEM right
before hitting the MXU. For memory-bound shapes (LM decode GEMVs) this cuts
weight traffic 8x vs bf16 -- the same reason CUTIE wins on energy.

Layout:
  x        (M, K)      activations, f32/bf16
  w_packed (K//4, N)   uint8; byte row j holds ternary weights for K
                       indices 4j..4j+3 (little-endian 2-bit fields)
  scale    (1, N)      per-output-channel dequant scale
  out      (M, N)      x.dtype, f32 accumulation

Grid (M tiles, N tiles, K tiles); K is the sequential accumulation axis
with an f32 VMEM scratch accumulator, epilogue applies the channel scale.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

__all__ = ["ternary_matmul_pallas", "choose_blocks_tmm"]

_DEF_VMEM_BUDGET = 8 * 1024 * 1024


def choose_blocks_tmm(
    m: int, n: int, k: int, dtype, vmem_budget: int = _DEF_VMEM_BUDGET
) -> Tuple[int, int, int]:
    """MXU-aligned (block_m, block_n, block_k) within the VMEM budget."""
    esize = jnp.dtype(dtype).itemsize
    bm = min(max(8, m), 256)
    bn = min(max(128, n), 512)
    bk = min(max(128, k), 512)

    def fits(bm, bn, bk):
        x_b = bm * bk * esize
        w_b = (bk // 4) * bn            # uint8
        unpack_b = bk * bn * 4          # f32 unpack temp (upper bound)
        acc_b = bm * bn * 4
        out_b = bm * bn * esize
        return x_b + w_b + unpack_b + acc_b + out_b <= vmem_budget

    while not fits(bm, bn, bk) and bk > 128:
        bk //= 2
    while not fits(bm, bn, bk) and bn > 128:
        bn //= 2
    while not fits(bm, bn, bk) and bm > 8:
        bm //= 2
    return bm, bn, bk


def _kernel(x_ref, w_ref, s_ref, o_ref, acc_ref, *, block_k: int,
            out_dtype):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    b = w_ref[...]  # (block_k // 4, block_n) uint8
    # Unpack 4 ternary weights per byte: value (j*4+i, n) lives in bits
    # [2i, 2i+2) of byte (j, n), biased by +1 (see core.ternary.pack2bit).
    parts = [((b >> (2 * i)) & 0x3).astype(jnp.int8) for i in range(4)]
    wq = jnp.stack(parts, axis=1)                      # (bk//4, 4, bn)
    wq = wq.reshape(block_k, b.shape[1])               # (bk, bn)
    w_deq = (wq.astype(jnp.float32) - 1.0).astype(x_ref.dtype)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_deq,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(ki == nk - 1)
    def _epilogue():
        scale = s_ref[...].astype(jnp.float32)          # (1, bn)
        o_ref[...] = (acc_ref[...] * scale).astype(out_dtype)


def ternary_matmul_pallas(
    x: jnp.ndarray,
    w_packed: jnp.ndarray,
    scale: jnp.ndarray,
    *,
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
    interpret: bool | None = None,
    vmem_budget: int = _DEF_VMEM_BUDGET,
) -> jnp.ndarray:
    """out = x @ unpack(w_packed) * scale. See module docstring for layout."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m, k = x.shape
    kp, n = w_packed.shape
    if kp * 4 != k:
        raise ValueError(f"w_packed rows {kp} != K/4 = {k // 4}")
    scale = scale.reshape(1, n)

    bm, bn, bk = choose_blocks_tmm(m, n, k, x.dtype, vmem_budget)
    if block_m is not None:
        bm = block_m
    if block_n is not None:
        bn = block_n
    if block_k is not None:
        bk = block_k
    if bk % 4:
        raise ValueError("block_k must be a multiple of 4")

    # Pad to block multiples; zero K padding contributes 0 (x rows are 0),
    # ternary padding bytes encode +1 each but meet zero activations.
    mp, np_, kp_ = (-m) % bm, (-n) % bn, (-k) % bk
    if mp or kp_:
        x = jnp.pad(x, ((0, mp), (0, kp_)))
    if kp_ or np_:
        w_packed = jnp.pad(w_packed, ((0, kp_ // 4), (0, np_)),
                           constant_values=0x55)  # 0x55 = four '+0' fields
    if np_:
        scale = jnp.pad(scale, ((0, 0), (0, np_)))
    mm, nn, kk = m + mp, n + np_, k + kp_

    grid = (mm // bm, nn // bn, kk // bk)
    kernel = functools.partial(_kernel, block_k=bk, out_dtype=x.dtype)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((bk // 4, bn), lambda mi, ni, ki: (ki, ni)),
            pl.BlockSpec((1, bn), lambda mi, ni, ki: (0, ni)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((mm, nn), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, w_packed, scale)
    return out[:m, :n]
