"""Pallas TPU kernels for ColibriES's two accelerator analogues.

  lif_scan.py       -- SNE: fused LIF temporal scan (VMEM-resident state)
  fc_lif_scan.py    -- SNE: fused synapse(matmul)+LIF scan for fc layers
                       (weights + membrane VMEM-resident; currents never
                       reach HBM)
  ternary_matmul.py -- CUTIE: packed 2-bit ternary GEMM (dequant-in-kernel)
  wkv6_scan.py      -- RWKV-6 WKV recurrence (state-resident scan; the SNE
                       insight applied to the rwkv6-7b assigned arch)
  ops.py            -- jit'd differentiable wrappers (public API)
  ref.py            -- pure-jnp oracles (tests assert_allclose against them)

All kernels are written for TPU (pl.pallas_call + BlockSpec VMEM tiling)
and validated in interpret mode on CPU.
"""
from repro.kernels.ops import (fc_lif_scan, fc_lif_scan_batched, lif_scan,
                               lif_scan_batched, pack_ternary_weights,
                               ternary_matmul)
from repro.kernels.ref import lif_scan_ref, ternary_matmul_ref, wkv6_ref
from repro.kernels.wkv6_scan import wkv6_scan_pallas

__all__ = [
    "lif_scan", "lif_scan_batched", "fc_lif_scan", "fc_lif_scan_batched",
    "pack_ternary_weights", "ternary_matmul",
    "lif_scan_ref", "ternary_matmul_ref", "wkv6_ref", "wkv6_scan_pallas",
]
