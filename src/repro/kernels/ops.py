"""Jit'd public wrappers around the Pallas kernels.

``lif_scan``       -- differentiable fused LIF scan (STBP surrogate VJP).
``fc_lif_scan``    -- differentiable fused synapse(matmul)+LIF scan for
                      the fully-connected layers (currents never hit HBM).
``ternary_matmul`` -- packed-ternary GEMM (serving path, fwd-only).
``pack_ternary_weights`` -- float weights -> (packed uint8, scale) in the
                            kernel's (K//4, N) layout.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.lif import LIFParams, lif_scan_reference
from repro.core.ternary import pack2bit, ternarize
from repro.kernels.fc_lif_scan import fc_lif_scan_pallas
from repro.kernels.lif_scan import lif_scan_pallas, lif_scan_pallas_batched
from repro.kernels.ternary_matmul import ternary_matmul_pallas

__all__ = ["lif_scan", "lif_scan_batched", "fc_lif_scan",
           "fc_lif_scan_batched", "ternary_matmul", "pack_ternary_weights"]


# ----------------------------------------------------------------------
# LIF scan: Pallas forward, STBP-surrogate backward (recompute-based, i.e.
# the backward re-runs the cheap reference scan under jax.vjp -- a remat
# policy, not an approximation; forward values are bit-identical).
# ----------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _lif_scan_cv(currents, v0, p: LIFParams):
    return lif_scan_pallas(currents, p, v0)


def _lif_fwd(currents, v0, p):
    out = _lif_scan_cv(currents, v0, p)
    return out, (currents, v0)


def _lif_bwd(p, res, cotangents):
    currents, v0 = res
    _, vjp = jax.vjp(lambda c, v: lif_scan_reference(c, p, v), currents, v0)
    return vjp(cotangents)


_lif_scan_cv.defvjp(_lif_fwd, _lif_bwd)


def lif_scan(
    currents: jnp.ndarray,
    p: LIFParams = LIFParams(),
    v0: jnp.ndarray | None = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused LIF scan over (T, ...) currents -> (spikes, v_final).

    Drop-in for :func:`repro.core.lif.lif_scan_reference` (same numerics,
    same STBP surrogate gradients), with the temporal scan fused into a
    single Pallas kernel (membrane state VMEM-resident; see
    ``kernels/lif_scan.py``).
    """
    if v0 is None:
        v0 = jnp.zeros(currents.shape[1:], currents.dtype)
    return _lif_scan_cv(currents, v0, p)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _lif_scan_batched_cv(currents, v0, p: LIFParams):
    return lif_scan_pallas_batched(currents, p, v0)


def _lif_b_fwd(currents, v0, p):
    return _lif_scan_batched_cv(currents, v0, p), (currents, v0)


def _lif_b_bwd(p, res, cotangents):
    currents, v0 = res
    ref = jax.vmap(lambda c, v: lif_scan_reference(c, p, v))
    _, vjp = jax.vjp(ref, currents, v0)
    return vjp(cotangents)


_lif_scan_batched_cv.defvjp(_lif_b_fwd, _lif_b_bwd)


def lif_scan_batched(
    currents: jnp.ndarray,
    p: LIFParams = LIFParams(),
    v0: jnp.ndarray | None = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused LIF scan over a batch of streams: (B, T, ...) -> (spikes, v_final).

    One Pallas launch for all ``B`` streams (batch folded into the kernel's
    neuron-row grid axis; see ``kernels/lif_scan.py``), with the same STBP
    surrogate gradients as :func:`lif_scan` (backward recomputes via the
    vmapped reference scan).

    Note the closed-loop engine reaches the same fold implicitly:
    ``layer_serial`` feeds :func:`lif_scan` currents shaped (T, B, ...),
    whose feature flattening already packs B into the row axis. This
    explicit (B, T, ...) entry additionally pads each stream to whole
    lane-rows (no cross-stream lanes) and threads a per-stream ``v0`` --
    the API for carrying membrane state across a stream's windows
    (stateful streaming, a ROADMAP open item).
    """
    if v0 is None:
        v0 = jnp.zeros((currents.shape[0], *currents.shape[2:]),
                       currents.dtype)
    return _lif_scan_batched_cv(currents, v0, p)


# ----------------------------------------------------------------------
# Fused synapse+LIF scan for the fully-connected layers: the matmul and
# the LIF update share one Pallas launch (currents never touch HBM).
# Backward recomputes through the matmul + reference scan (same remat
# policy as lif_scan; forward values are bit-identical to unfused).
# ----------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _fc_lif_scan_cv(spikes, w, v0, p: LIFParams):
    return fc_lif_scan_pallas(spikes, w, p, v0)


def _fc_fwd(spikes, w, v0, p):
    return _fc_lif_scan_cv(spikes, w, v0, p), (spikes, w, v0)


def _fc_bwd(p, res, cotangents):
    spikes, w, v0 = res

    def ref(s, w_, v):
        return lif_scan_reference(jnp.matmul(s, w_), p, v)

    _, vjp = jax.vjp(ref, spikes, w, v0)
    return vjp(cotangents)


_fc_lif_scan_cv.defvjp(_fc_fwd, _fc_bwd)


def fc_lif_scan(
    spikes: jnp.ndarray,
    w: jnp.ndarray,
    p: LIFParams = LIFParams(),
    v0: jnp.ndarray | None = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused ``spikes @ w`` + LIF scan -> (out_spikes, v_final).

    Drop-in for ``lif_scan_reference(spikes @ w, p, v0)`` (bitwise-equal
    forward, same STBP surrogate gradients) with the synaptic matmul and
    the temporal scan fused into one Pallas launch: weights and membrane
    stay VMEM-resident, the (T, B, N) current tensor never exists in HBM
    (see ``kernels/fc_lif_scan.py``).

    ``spikes``: (T, B, K) or (T, K); ``w``: (K, N); ``v0``: (B, N)/(N,).
    """
    if v0 is None:
        shape = ((spikes.shape[1], w.shape[1]) if spikes.ndim == 3
                 else (w.shape[1],))
        v0 = jnp.zeros(shape, spikes.dtype)
    return _fc_lif_scan_cv(spikes, w, v0, p)


def fc_lif_scan_batched(
    spikes: jnp.ndarray,
    w: jnp.ndarray,
    p: LIFParams = LIFParams(),
    v0: jnp.ndarray | None = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Stream-major fused fc+LIF: (B, T, K) -> ((B, T, N), (B, N)).

    The kernel is natively batched (B is its sublane axis); this wrapper
    transposes to time-major and threads the per-stream ``v0`` -- the
    entry point for carrying fc membrane state across a stream's windows.
    Differentiable via the same custom VJP as :func:`fc_lif_scan`.
    """
    if spikes.ndim != 3:
        raise ValueError(f"need (B, T, K) spikes, got {spikes.shape}")
    out, v_fin = fc_lif_scan(jnp.transpose(spikes, (1, 0, 2)), w, p, v0)
    return jnp.transpose(out, (1, 0, 2)), v_fin


# ----------------------------------------------------------------------
# Ternary GEMM (serving path).
# ----------------------------------------------------------------------

def pack_ternary_weights(
    w: jnp.ndarray,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Quantize (K, N) float weights to the kernel's packed layout.

    Returns (w_packed (K//4, N) uint8, scale (N,) f32). Per-output-channel
    TWN quantization (axis=-1 of the (K, N) matrix = output channel N).
    """
    k, n = w.shape
    if k % 4:
        raise ValueError(f"K={k} must be a multiple of 4 for 2-bit packing")
    q, scale = ternarize(w, axis=-1)          # q int8 (K, N); scale (1, N)
    packed = pack2bit(q.T).T                  # pack along K -> (K//4, N)
    return packed, scale.reshape(n).astype(jnp.float32)


@jax.jit
def ternary_matmul(
    x: jnp.ndarray,
    w_packed: jnp.ndarray,
    scale: jnp.ndarray,
) -> jnp.ndarray:
    """x (M, K) @ ternary (K, N) with in-kernel dequant; f32 accumulation."""
    return ternary_matmul_pallas(x, w_packed, scale)
