"""Fault-tolerant training loop.

Production behaviors implemented (and exercised by tests):

  * step-atomic checkpoint/restart -- params + optimizer + data cursor +
    rng are saved every ``ckpt_every`` steps; ``Trainer.run`` always
    resumes from the newest intact checkpoint (corrupt ones are skipped).
  * simulated node failure -- ``failure_hook`` raises mid-run; the outer
    ``run_with_restarts`` loop restores and continues, and tests assert
    bit-identical loss curves vs an uninterrupted run.
  * straggler mitigation -- per-step wall times feed an EWMA; steps slower
    than ``straggler_factor`` x median are counted and surfaced in metrics
    (on real pods this signal drives backup-worker dispatch; here it
    degrades to monitoring since the host is single-process).
  * elastic scaling -- checkpoints store logical (unsharded) arrays;
    ``Trainer`` re-applies shardings for whatever mesh is active, so a
    restart on a different device count resumes transparently.
  * optional gradient compression (cross-pod DCN trick, see
    ``training.compression``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.training import checkpoint as CKPT
from repro.training.compression import compress_grads, compression_init
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update

__all__ = ["TrainerConfig", "Trainer"]


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "checkpoints"
    keep_last: int = 3
    log_every: int = 10
    remat: bool = False
    grad_compression_ratio: Optional[float] = None  # e.g. 0.05
    straggler_factor: float = 3.0
    opt: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)


class Trainer:
    """Drives ``model`` over a cursor-addressable batch function."""

    def __init__(self, model: Model, cfg: TrainerConfig,
                 batch_fn: Callable[[int], Dict[str, jnp.ndarray]],
                 *, shardings: Any = None):
        self.model = model
        self.cfg = cfg
        self.batch_fn = batch_fn
        self.shardings = shardings
        self._step_fn = jax.jit(self._build_step())
        self.step_times: List[float] = []
        self.straggler_steps = 0

    # -- step ------------------------------------------------------------
    def _build_step(self):
        cfg = self.cfg
        model = self.model

        def step(params, opt_state, err_state, batch):
            def loss_fn(p):
                return model.loss(p, batch, remat=cfg.remat)
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params)
            cmetrics = {}
            if cfg.grad_compression_ratio is not None:
                grads, err_state, cmetrics = compress_grads(
                    grads, err_state, ratio=cfg.grad_compression_ratio)
            params, opt_state, om = adamw_update(grads, opt_state, params,
                                                 cfg.opt)
            return params, opt_state, err_state, {
                "loss": loss, **metrics, **om, **cmetrics}

        return step

    # -- state lifecycle ---------------------------------------------------
    def init_state(self, rng: jax.Array) -> Dict[str, Any]:
        params = self.model.init(rng)
        state = {
            "params": params,
            "opt": adamw_init(params),
            "err": (compression_init(params)
                    if self.cfg.grad_compression_ratio is not None
                    else jnp.zeros(())),
        }
        if self.shardings is not None:
            state = jax.device_put(state, self.shardings)
        return state

    def restore(self, template: Dict[str, Any]):
        out = CKPT.restore_latest(self.cfg.ckpt_dir, template)
        if out is None:
            return None
        step, state, extra = out
        if self.shardings is not None:  # elastic re-shard onto current mesh
            state = jax.device_put(state, self.shardings)
        return step, state, extra

    # -- main loop ---------------------------------------------------------
    def run(self, rng: jax.Array, *, start_state=None, start_step=0,
            failure_hook: Optional[Callable[[int], None]] = None
            ) -> Dict[str, Any]:
        cfg = self.cfg
        state = start_state if start_state is not None \
            else self.init_state(rng)
        history = []
        step = start_step
        while step < cfg.total_steps:
            if failure_hook is not None:
                failure_hook(step)          # may raise (simulated crash)
            batch = self.batch_fn(step)
            t0 = time.perf_counter()
            p, o, e, metrics = self._step_fn(
                state["params"], state["opt"], state["err"], batch)
            metrics = jax.tree.map(lambda x: np.asarray(x), metrics)
            dt = time.perf_counter() - t0
            state = {"params": p, "opt": o, "err": e}
            self._track_stragglers(dt)
            step += 1
            history.append({"step": step, "loss": float(metrics["loss"]),
                            "time_s": dt})
            if step % cfg.log_every == 0:
                print(f"  step {step:5d} loss {metrics['loss']:.4f} "
                      f"({dt * 1e3:.0f} ms)", flush=True)
            if step % cfg.ckpt_every == 0 or step == cfg.total_steps:
                CKPT.save_checkpoint(
                    cfg.ckpt_dir, step, state,
                    extra={"data_cursor": step,
                           "straggler_steps": self.straggler_steps},
                    keep_last=cfg.keep_last)
        return {"state": state, "history": history, "final_step": step}

    def run_with_restarts(self, rng: jax.Array, *,
                          failure_hook=None, max_restarts: int = 5):
        """Crash-resilient outer loop: restore-and-continue on failure."""
        template = jax.eval_shape(lambda: {
            "params": self.model.abstract_params(),
            "opt": None,
            "err": None,
        })
        attempts = 0
        start_state, start_step = None, 0
        while True:
            try:
                return self.run(rng, start_state=start_state,
                                start_step=start_step,
                                failure_hook=failure_hook)
            except RuntimeError as e:
                attempts += 1
                if attempts > max_restarts:
                    raise
                fresh = self.init_state(rng)     # structure template
                restored = self.restore(fresh)
                if restored is None:
                    start_state, start_step = fresh, 0
                else:
                    start_step, start_state, _ = restored
                print(f"[trainer] restart #{attempts} from step "
                      f"{start_step} after: {e}", flush=True)

    # -- straggler tracking --------------------------------------------------
    def _track_stragglers(self, dt: float):
        self.step_times.append(dt)
        if len(self.step_times) >= 5:
            med = float(np.median(self.step_times[-50:]))
            if dt > self.cfg.straggler_factor * med:
                self.straggler_steps += 1
