"""AdamW with fp32 moments over (possibly bf16) parameter pytrees.

Pure-pytree implementation (no optax dependency): the moments carry the
exact parameter structure so the FSDP/TP PartitionSpecs of the params apply
verbatim to the optimizer state (``distributed.sharding.opt_pspecs``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule",
           "global_norm", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def cosine_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * frac


def global_norm(tree: Any) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree: Any, max_norm: float) -> Tuple[Any, jnp.ndarray]:
    gn = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale
                                   ).astype(x.dtype), tree), gn


def adamw_init(params: Any) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(
    grads: Any,
    opt_state: Dict[str, Any],
    params: Any,
    cfg: AdamWConfig,
) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = opt_state["step"] + 1
    lr = cosine_schedule(cfg, step)
    grads, gn = clip_by_global_norm(grads, cfg.grad_clip)

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * gf
        v_new = b2 * v + (1 - b2) * gf * gf
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gn, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
