"""Training substrate: optimizer, fault-tolerant trainer, checkpointing,
gradient compression."""
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from repro.training.trainer import Trainer, TrainerConfig
from repro.training import checkpoint
from repro.training.compression import compress_grads, compression_init
