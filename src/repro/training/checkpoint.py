"""Step-atomic checkpointing with integrity manifests + elastic restore.

Layout (one directory per step):

    <root>/step_000120/
        arrays.npz       -- every pytree leaf, keyed by "/"-joined path
        manifest.json    -- step, tree spec, shapes/dtypes, fingerprints,
                            data-pipeline cursor, rng state, wall time

Write protocol is crash-safe: serialize into ``step_X.tmp-<pid>`` and
atomically rename; a partially-written checkpoint is never visible.
``restore_latest`` verifies the manifest fingerprints and falls back to
the previous step on corruption (fault tolerance: a node dying mid-write
costs at most ``ckpt_every`` steps).

Elastic scaling: arrays are stored logically (unsharded). On restore the
caller re-applies whatever NamedSharding matches the *current* mesh, so a
job restarted on a different device count resumes transparently
(``repro.training.trainer.Trainer.restore``).
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import shutil
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "restore_latest",
           "latest_step", "list_steps"]

_MANIFEST = "manifest.json"
_ARRAYS = "arrays.npz"


def _flatten_with_paths(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def _fingerprint(arr: np.ndarray) -> str:
    h = hashlib.sha256()
    h.update(str(arr.shape).encode())
    h.update(str(arr.dtype).encode())
    # sample-based fingerprint: fast yet catches truncation/corruption
    flat = arr.reshape(-1)
    step = max(flat.size // 4096, 1)
    h.update(np.ascontiguousarray(flat[::step]).tobytes())
    return h.hexdigest()[:16]


def save_checkpoint(
    root: str | os.PathLike,
    step: int,
    state: Dict[str, Any],
    *,
    extra: Optional[Dict[str, Any]] = None,
    keep_last: int = 3,
) -> pathlib.Path:
    """Atomically persist ``state`` (arbitrary pytree dict) at ``step``."""
    root = pathlib.Path(root)
    root.mkdir(parents=True, exist_ok=True)
    final = root / f"step_{step:08d}"
    tmp = root / f"step_{step:08d}.tmp-{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    flat = _flatten_with_paths(state)
    np.savez(tmp / _ARRAYS, **flat)
    manifest = {
        "step": step,
        "time": time.time(),
        "keys": sorted(flat),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "fingerprints": {k: _fingerprint(v) for k, v in flat.items()},
        "extra": extra or {},
    }
    (tmp / _MANIFEST).write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)                     # atomic publish

    for old in list_steps(root)[:-keep_last]:
        shutil.rmtree(root / f"step_{old:08d}", ignore_errors=True)
    return final


def list_steps(root: str | os.PathLike):
    root = pathlib.Path(root)
    steps = []
    if root.exists():
        for p in root.iterdir():
            if p.name.startswith("step_") and ".tmp" not in p.name:
                try:
                    steps.append(int(p.name.split("_")[1]))
                except ValueError:
                    pass
    return sorted(steps)


def latest_step(root) -> Optional[int]:
    steps = list_steps(root)
    return steps[-1] if steps else None


def _verify(path: pathlib.Path, manifest: dict,
            arrays: Dict[str, np.ndarray]) -> bool:
    for k in manifest["keys"]:
        if k not in arrays:
            return False
        if _fingerprint(arrays[k]) != manifest["fingerprints"][k]:
            return False
    return True


def restore_checkpoint(
    root: str | os.PathLike, step: int, template: Dict[str, Any]
) -> Tuple[Dict[str, Any], dict]:
    """Load step ``step`` into the structure of ``template``.

    Returns (state, manifest-extra). Raises on integrity failure.
    """
    path = pathlib.Path(root) / f"step_{step:08d}"
    manifest = json.loads((path / _MANIFEST).read_text())
    with np.load(path / _ARRAYS) as z:
        arrays = {k: z[k] for k in z.files}
    if not _verify(path, manifest, arrays):
        raise IOError(f"checkpoint {path} failed integrity check")

    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in paths:
        key = "/".join(_path_str(x) for x in p)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key]
        leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype")
                      else arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["extra"]


def restore_latest(
    root: str | os.PathLike, template: Dict[str, Any]
) -> Optional[Tuple[int, Dict[str, Any], dict]]:
    """Restore the newest intact checkpoint, falling back past corrupt
    ones. Returns (step, state, extra) or None if nothing usable."""
    for step in reversed(list_steps(root)):
        try:
            state, extra = restore_checkpoint(root, step, template)
            return step, state, extra
        except Exception:
            continue
    return None
