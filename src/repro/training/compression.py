"""Gradient compression for the cross-pod (DCN) all-reduce.

Top-k magnitude sparsification with error feedback (Deep Gradient
Compression style): each step transmits only the largest ``ratio`` of
gradient entries per leaf; the residual is accumulated locally and added
back next step, so the compressed optimizer provably tracks the dense one.
On the production mesh this shrinks the slow cross-pod gradient
all-reduce by ~1/ratio while FSDP reduce-scatters stay dense intra-pod
(DESIGN.md, distributed-optimization tricks).

Pure-pytree implementation; ``compress`` is jit-compatible and runs inside
``train_step`` when enabled.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["compression_init", "compress_grads"]


def compression_init(grads_like: Any) -> Any:
    """Zero error-feedback buffers matching the gradient pytree."""
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                        grads_like)


def _topk_mask(x: jnp.ndarray, ratio: float) -> jnp.ndarray:
    k = max(int(x.size * ratio), 1)
    flat = jnp.abs(x.reshape(-1))
    # threshold = k-th largest magnitude
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(x) >= thresh).astype(x.dtype)


def compress_grads(
    grads: Any, error_state: Any, *, ratio: float = 0.01
) -> Tuple[Any, Any, Dict[str, jnp.ndarray]]:
    """Sparsify grads to top-``ratio`` entries with error feedback.

    Returns (compressed grads -- dense tensors with zeros off-mask, new
    error state, metrics). The dense-with-zeros form keeps downstream ops
    unchanged; on the wire the zeros compress (or map to sparse
    all-reduce where available).
    """
    def one(g, e):
        acc = g.astype(jnp.float32) + e
        mask = _topk_mask(acc, ratio)
        sent = acc * mask
        residual = acc - sent
        return sent.astype(g.dtype), residual

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error_state)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    sent = jax.tree.unflatten(treedef, [o[0] for o in outs])
    resid = jax.tree.unflatten(treedef, [o[1] for o in outs])
    sent_norm = jnp.sqrt(sum(jnp.sum(jnp.square(o[0].astype(jnp.float32)))
                             for o in outs))
    metrics = {"compressed_grad_norm": sent_norm}
    return sent, resid, metrics
