"""repro: ColibriES (Rutishauser et al., 2023) as a production-scale JAX
framework -- event-driven SNN + ternary accelerator analogues, a model zoo
of 10 assigned architectures, multi-pod distribution, and a calibrated
energy/latency model of the Kraken SoC."""
__version__ = "0.1.0"
