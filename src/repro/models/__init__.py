"""Model substrate: every assigned architecture family as pure JAX."""
from repro.models.config import ModelConfig
from repro.models.model import Model, build_model, lm_loss
from repro.models.params import ParamDef, abstract, materialize, tree_num_params

__all__ = [
    "ModelConfig", "Model", "build_model", "lm_loss",
    "ParamDef", "abstract", "materialize", "tree_num_params",
]
