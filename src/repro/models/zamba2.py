"""Zamba2 hybrid backbone: Mamba2 (SSD) layers + a weight-shared attention
block invoked every ``attn_every`` layers (arXiv:2411.15242).

Mamba2 layers use the chunked SSD form for training (scalar per-head decay
=> exactly bounded intra-chunk factorization, no clamping needed) and the
O(1) stepwise recurrence for decode. The shared attention block is a
standard pre-norm attn+MLP pair, weight-tied across its invocations
(DESIGN.md documents the simplifications vs the published model: no
original-embedding concat, no per-invocation LoRA).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.annotate import constrain, unshard_fsdp
from repro.models.config import ModelConfig
from repro.models import layers as L
from repro.models.params import ParamDef

__all__ = ["zamba2_defs", "zamba2_apply", "zamba2_decode",
           "init_zamba_cache", "mamba2_chunked"]


def _mamba_defs(cfg: ModelConfig, nl: int) -> Dict[str, Any]:
    d = cfg.d_model
    din = cfg.ssm_d_inner
    n = cfg.ssm_state
    h = cfg.ssm_heads
    k = cfg.conv_kernel
    conv_dim = din + 2 * n

    def pd(shape, axes, **kw):
        return ParamDef((nl,) + shape, ("layers",) + axes, **kw)

    return {
        "ln": pd((d,), ("norm",), init="ones"),
        "in_proj": pd((d, 2 * din + 2 * n + h), ("embed", "mlp"),
                      fan_in_axes=(1,)),
        "conv_w": pd((k, conv_dim), (None, "conv"), scale=1.0,
                     fan_in_axes=(0,)),
        "conv_b": pd((conv_dim,), ("conv",), init="zeros"),
        "a_log": pd((h,), ("heads",), init="constant", constant=0.0),
        "dt_bias": pd((h,), ("heads",), init="zeros"),
        "d_skip": pd((h,), ("heads",), init="ones"),
        "norm_s": pd((din,), ("norm",), init="ones"),
        "out_proj": pd((din, d), ("mlp", "embed"), fan_in_axes=(0,)),
    }


def zamba2_defs(cfg: ModelConfig) -> Dict[str, Any]:
    d, v = cfg.d_model, cfg.vocab_size
    defs: Dict[str, Any] = {
        "embed": ParamDef((v, d), ("vocab", "embed"), fan_in_axes=(1,)),
        "layers": _mamba_defs(cfg, cfg.num_layers),
        # ONE shared attention block, weight-tied across invocations.
        "shared": {
            "ln1": ParamDef((d,), ("norm",), init="ones"),
            "ln2": ParamDef((d,), ("norm",), init="ones"),
            "attn": L.attention_defs(cfg),
            "mlp": L.mlp_defs(cfg),
        },
        "ln_f": ParamDef((d,), ("norm",), init="ones"),
        "lm_head": ParamDef((d, v), ("embed", "vocab"), fan_in_axes=(0,)),
    }
    return defs


# ----------------------------------------------------------------------
# Mamba2 SSD core
# ----------------------------------------------------------------------


def mamba2_chunked(
    x: jnp.ndarray,        # (B, S, H, P) inputs (post conv/silu)
    dt: jnp.ndarray,       # (B, S, H) softplus'd step sizes
    a: jnp.ndarray,        # (H,) negative decay rates (-exp(a_log))
    b_in: jnp.ndarray,     # (B, S, N) input projections (ngroups=1)
    c_in: jnp.ndarray,     # (B, S, N)
    state0: Optional[jnp.ndarray] = None,
    chunk: int = 64,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan. Returns (y (B,S,H,P), state (B,H,P,N)). f32 inside.

    h_t = exp(a*dt_t) h_{t-1} + dt_t x_t B_t^T ;  y_t = h_t C_t + skip.
    (skip applied by caller). All decay exponents are <= 0 inside chunks,
    so the factorized form is numerically exact in f32.
    """
    bsz, s, h, p = x.shape
    n = b_in.shape[-1]
    c = min(chunk, s)
    if s % c:
        raise ValueError(f"seq {s} %% chunk {c} != 0")
    nc = s // c
    f32 = jnp.float32
    xc = x.reshape(bsz, nc, c, h, p).astype(f32)
    dtc = dt.reshape(bsz, nc, c, h).astype(f32)
    bc = b_in.reshape(bsz, nc, c, n).astype(f32)
    cc = c_in.reshape(bsz, nc, c, n).astype(f32)
    s0 = (jnp.zeros((bsz, h, p, n), f32) if state0 is None
          else state0.astype(f32))
    a = a.astype(f32)

    def body(state, inp):
        x_, dt_, b_, c_ = inp                  # (bsz, c, h, p/…)
        logdec = a[None, None] * dt_           # (bsz, c, h) <= 0
        cum = jnp.cumsum(logdec, axis=1)
        # intra-chunk: att[b,h,t,s] = exp(cum_t - cum_s) (C_t . B_s), s<=t
        scores = jnp.einsum("btn,bsn->bts", c_, b_)
        ldiff = cum[:, :, None, :] - cum[:, None, :, :]  # (b, t, s, h)
        mask = jnp.tril(jnp.ones((c, c), bool))
        att = jnp.where(mask[None, :, :, None],
                        jnp.exp(ldiff), 0.0) * scores[..., None]
        dtx = x_ * dt_[..., None]              # (b, c, h, p)
        y = jnp.einsum("btsh,bshp->bthp", att, dtx)
        # cross-chunk: y += exp(cum_t) * C_t . state0
        y_cross = jnp.einsum("btn,bhpn->bthp", c_, state)
        y = y + y_cross * jnp.exp(cum)[..., None]
        # state update
        cum_end = cum[:, -1]                   # (b, h)
        k_tail = jnp.exp(cum_end[:, None] - cum)   # (b, c, h)
        state = (jnp.exp(cum_end)[..., None, None] * state
                 + jnp.einsum("bchp,bcn->bhpn", dtx * k_tail[..., None], b_))
        return state, y

    inp = tuple(z.transpose(1, 0, 2, 3, *([4] if z.ndim == 5 else []))
                for z in (xc, dtc, bc, cc))
    state, y = jax.lax.scan(body, s0, inp)
    y = y.transpose(1, 0, 2, 3, 4).reshape(bsz, s, h, p)
    return y.astype(x.dtype), state


def _mamba_step(x, dt, a, b_in, c_in, state):
    """One-token SSD update. x (B,H,P); dt (B,H); b/c (B,N);
    state (B,H,P,N)."""
    f32 = jnp.float32
    dec = jnp.exp(a.astype(f32)[None] * dt.astype(f32))        # (B,H)
    dbx = jnp.einsum("bhp,bn->bhpn", x.astype(f32) * dt.astype(f32)[..., None],
                     b_in.astype(f32))
    state = dec[..., None, None] * state + dbx
    y = jnp.einsum("bhpn,bn->bhp", state, c_in.astype(f32))
    return y.astype(x.dtype), state


def _mamba_forward(lp, x, cfg: ModelConfig, *, conv_state=None,
                   ssm_state=None, decode: bool = False):
    """Apply one Mamba2 layer (pre-norm, residual added by caller).

    Returns (out, (conv_state, ssm_state)).
    """
    bsz, s, d = x.shape
    din, n, h = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
    p = cfg.ssm_head_dim
    k = cfg.conv_kernel

    proj = L.dense(x, lp["in_proj"])
    z, xbc, dt_raw = jnp.split(proj, [din, 2 * din + 2 * n], axis=-1)

    # Depthwise causal conv over (x, B, C) channels.
    if decode:
        # conv_state: (B, k-1, conv_dim) previous inputs
        window = jnp.concatenate([conv_state, xbc], axis=1)    # (B, k, cd)
        conv_out = jnp.einsum("bkc,kc->bc", window, lp["conv_w"])[:, None]
        new_conv_state = window[:, 1:]
    else:
        pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
        conv_out = sum(
            pad[:, i:i + s] * lp["conv_w"][i][None, None]
            for i in range(k))
        new_conv_state = pad[:, -(k - 1):]
    xbc = jax.nn.silu(conv_out + lp["conv_b"])
    xs, b_in, c_in = jnp.split(xbc, [din, din + n], axis=-1)
    xs = xs.reshape(bsz, -1, h, p)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + lp["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(lp["a_log"].astype(jnp.float32))

    if decode:
        y, ssm_state = _mamba_step(xs[:, 0], dt[:, 0], a, b_in[:, 0],
                                   c_in[:, 0], ssm_state)
        y = y[:, None]
    else:
        y, ssm_state = mamba2_chunked(xs, dt, a, b_in, c_in, ssm_state,
                                      chunk=min(cfg.chunk_size * 2, s))
    y = y + xs * lp["d_skip"][None, None, :, None]
    y = y.reshape(bsz, -1, din)
    y = L.rms_norm(y * jax.nn.silu(z), lp["norm_s"], cfg.norm_eps)
    out = L.dense(y, lp["out_proj"])
    return out, (new_conv_state, ssm_state)


def _shared_block(sp, h, positions, cfg, *, window=None):
    a_in = L.rms_norm(h, sp["ln1"], cfg.norm_eps)
    h = h + L.attention_apply(sp["attn"], a_in, positions, cfg,
                              causal=True, window=window)
    m_in = L.rms_norm(h, sp["ln2"], cfg.norm_eps)
    return h + L.mlp_apply(sp["mlp"], m_in, cfg)


def _stage_bounds(cfg: ModelConfig):
    """Mamba-layer index ranges between shared-attn invocations."""
    period = cfg.attn_every or cfg.num_layers
    bounds = []
    i = 0
    while i < cfg.num_layers:
        j = min(i + period, cfg.num_layers)
        bounds.append((i, j))
        i = j
    return bounds


def zamba2_apply(params: Dict[str, Any], tokens: jnp.ndarray,
                 cfg: ModelConfig, *, scan_layers: bool = True,
                 remat: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b, s = tokens.shape
    h = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def mamba_body(carry, lp):
        out, st = _mamba_forward(lp, L.rms_norm(carry, lp["ln"],
                                                cfg.norm_eps), cfg)
        return carry + out, None

    if remat:
        mamba_body = jax.checkpoint(mamba_body)
    for (i, j) in _stage_bounds(cfg):
        stage = jax.tree.map(lambda x: x[i:j], params["layers"])
        if scan_layers:
            h, _ = jax.lax.scan(mamba_body, h, stage)
        else:
            for li in range(j - i):
                lp = jax.tree.map(lambda x: x[li], stage)
                h, _ = mamba_body(h, lp)
        h = _shared_block(params["shared"], h, positions, cfg)

    h = L.rms_norm(h, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h,
                        unshard_fsdp(params["lm_head"], (None, "model")),
                        preferred_element_type=jnp.float32)
    logits = constrain(logits, ("batch", None, "model"))
    return logits, jnp.float32(0.0)


def init_zamba_cache(cfg: ModelConfig, batch: int, cache_len: int,
                     dtype=None) -> Dict[str, jnp.ndarray]:
    """Mamba conv+SSM states per layer, plus one KV cache per shared-attn
    invocation. At long context the shared block runs with a sliding
    window (long_context_window), bounding the KV caches."""
    dt = jnp.dtype(dtype or cfg.dtype)
    nl, d = cfg.num_layers, cfg.d_model
    din, n = cfg.ssm_d_inner, cfg.ssm_state
    h, p, k = cfg.ssm_heads, cfg.ssm_head_dim, cfg.conv_kernel
    n_inv = len(_stage_bounds(cfg))
    if cfg.long_context_window is not None:
        cache_len = min(cache_len, cfg.long_context_window)
    return {
        "conv": jnp.zeros((nl, batch, k - 1, din + 2 * n), dt),
        "ssm": jnp.zeros((nl, batch, h, p, n), jnp.float32),
        "attn_k": jnp.zeros((n_inv, batch, cache_len, cfg.num_kv_heads,
                             cfg.head_dim), dt),
        "attn_v": jnp.zeros((n_inv, batch, cache_len, cfg.num_kv_heads,
                             cfg.head_dim), dt),
        "pos": jnp.zeros((), jnp.int32),
    }


def zamba2_decode(params: Dict[str, Any], cache: Dict[str, jnp.ndarray],
                  tokens: jnp.ndarray, cfg: ModelConfig,
                  *, scan_layers: bool = True
                  ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    h = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    pos = cache["pos"]
    # Ring-buffer attention iff the cache was clamped to the long-context
    # window at init (i.e. true context exceeds the window).
    ck_len = cache["attn_k"].shape[2]
    ring = (cfg.long_context_window is not None
            and ck_len == cfg.long_context_window)
    window_arg = ck_len if ring else None
    bounds = _stage_bounds(cfg)

    def mamba_body(carry, inp):
        h = carry
        lp, conv_st, ssm_st = inp
        out, (conv_new, ssm_new) = _mamba_forward(
            lp, L.rms_norm(h, lp["ln"], cfg.norm_eps), cfg,
            conv_state=conv_st, ssm_state=ssm_st, decode=True)
        return h + out, (conv_new, ssm_new)

    conv_all, ssm_all = [], []
    k_all, v_all = [], []
    for si, (i, j) in enumerate(bounds):
        stage = jax.tree.map(lambda x: x[i:j], params["layers"])
        conv_st = cache["conv"][i:j]
        ssm_st = cache["ssm"][i:j]
        if scan_layers:
            h, (conv_new, ssm_new) = jax.lax.scan(
                mamba_body, h, (stage, conv_st, ssm_st))
        else:
            cs, ss = [], []
            for li in range(j - i):
                lp = jax.tree.map(lambda x: x[li], stage)
                h, (c_n, s_n) = mamba_body(h, (lp, conv_st[li], ssm_st[li]))
                cs.append(c_n)
                ss.append(s_n)
            conv_new, ssm_new = jnp.stack(cs), jnp.stack(ss)
        conv_all.append(conv_new)
        ssm_all.append(ssm_new)
        sp = params["shared"]
        a_in = L.rms_norm(h, sp["ln1"], cfg.norm_eps)
        att, new_kv = L.attention_decode(
            sp["attn"], a_in,
            {"k": cache["attn_k"][si], "v": cache["attn_v"][si],
             "pos": pos}, cfg,
            window=window_arg)
        h = h + att
        m_in = L.rms_norm(h, sp["ln2"], cfg.norm_eps)
        h = h + L.mlp_apply(sp["mlp"], m_in, cfg)
        k_all.append(new_kv["k"])
        v_all.append(new_kv["v"])

    h = L.rms_norm(h, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h,
                        unshard_fsdp(params["lm_head"], (None, "model")),
                        preferred_element_type=jnp.float32)
    logits = constrain(logits, ("batch", None, "model"))
    new_cache = {
        "conv": jnp.concatenate(conv_all, axis=0),
        "ssm": jnp.concatenate(ssm_all, axis=0),
        "attn_k": jnp.stack(k_all),
        "attn_v": jnp.stack(v_all),
        "pos": pos + 1,
    }
    return logits, new_cache
