"""Unified model configuration covering every assigned architecture family.

One frozen dataclass parameterizes dense / MoE / SSM / hybrid / enc-dec /
VLM backbones; each ``repro/configs/<arch>.py`` instantiates it with the
exact published numbers plus a reduced smoke variant.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ModelConfig"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | rwkv6 | zamba2 | encdec | vlm
    num_layers: int
    d_model: int
    vocab_size: int
    d_ff: int
    # Attention (ignored by rwkv6).
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None      # SWA width (h2o-danube)
    # At long context (>= long_context_threshold cache), archs that support
    # it clamp attention to this window (zamba2's shared block; see
    # DESIGN.md long_500k notes).
    long_context_window: Optional[int] = None
    activation: str = "swiglu"    # swiglu | squared_relu | gelu
    tie_embeddings: bool = False
    # MoE.
    num_experts: int = 0
    top_k: int = 0
    num_shared_experts: int = 0
    expert_d_ff: int = 0
    capacity_factor: float = 1.25
    moe_group_size: int = 512     # group-wise einsum dispatch (T5X-style)
    # SSM / RWKV / hybrid.
    ssm_state: int = 0            # Mamba2 state size N
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_kernel: int = 4
    rwkv_head_dim: int = 64
    rwkv_lora_rank: int = 32
    attn_every: int = 0           # zamba2: shared attn block period
    chunk_size: int = 32          # chunked linear-recurrence length
    # Enc-dec.
    encoder_layers: int = 0
    decoder_layers: int = 0
    frontend_dim: int = 0         # stubbed modality frontend output dim
    # VLM.
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # t/h/w of head_dim/2
    # Quantization (the CUTIE / ternary serving path).
    quant: Optional[str] = None   # None | "ternary"
    # Numerics.
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    logits_softcap: float = 0.0

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim",
                               self.d_model // self.num_heads)
        if self.num_kv_heads == 0 and self.num_heads:
            object.__setattr__(self, "num_kv_heads", self.num_heads)

    # -- derived ----------------------------------------------------------
    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    @property
    def is_sub_quadratic(self) -> bool:
        """Whether long_500k decode is admissible (bounded per-step state)."""
        return (self.family in ("rwkv6", "zamba2")
                or self.sliding_window is not None)

    def param_count(self) -> int:
        """Analytic parameter count (exact for our implementations; used by
        MODEL_FLOPS roofline terms)."""
        d, l, v, f = self.d_model, self.num_layers, self.vocab_size, self.d_ff
        if self.family == "rwkv6":
            r = self.rwkv_lora_rank
            tm = d * (5 * r) + 5 * r * d          # ddlerp loras
            tm += d * r + r * d                    # decay lora (w1, w2)
            tm += 4 * d * d + d * d                # r,k,v,g + out
            tm += 2 * d                            # ln scales (2 norms)
            tm += 3 * self.rwkv_heads * self.rwkv_head_dim  # u, w0(bias), gn
            cm = 2 * d * f // 1 if False else d * f + f * d + d * d  # k,v,r
            per_layer = tm + cm + 2 * d
            return v * d + l * per_layer + d + (0 if self.tie_embeddings
                                                else v * d)
        # attention params (dense/moe/vlm/encdec/zamba2-shared)
        hd = self.head_dim
        attn = d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd \
            + self.num_heads * hd * d
        if self.activation == "swiglu":
            mlp = 3 * d * f
        else:
            mlp = 2 * d * f
        if self.family == "moe":
            ef = self.expert_d_ff or f
            routed = self.num_experts * 3 * d * ef
            shared = self.num_shared_experts * 3 * d * ef
            router = d * self.num_experts
            mlp = routed + shared + router
        per_layer = attn + mlp + 2 * d
        if self.family == "zamba2":
            # mamba2 layer params
            din = self.ssm_d_inner
            n = self.ssm_state
            h = self.ssm_heads
            m_in = d * (2 * din + 2 * n * 1 + h)   # z,x,B,C,dt heads
            conv = (din + 2 * n) * self.conv_kernel
            m_out = din * d
            mamba = m_in + conv + m_out + 3 * h + d
            n_attn = self.num_layers // max(self.attn_every, 1)
            shared_attn = attn + 3 * d * f + 2 * d
            return (v * d + self.num_layers * mamba + shared_attn
                    + d + (0 if self.tie_embeddings else v * d))
        if self.family == "encdec":
            cross = attn
            enc = self.encoder_layers * (attn + mlp + 2 * d)
            dec = self.decoder_layers * (attn + cross + mlp + 3 * d)
            return v * d + enc + dec + 2 * d + (0 if self.tie_embeddings
                                                else v * d)
        total = v * d + l * per_layer + d
        if not self.tie_embeddings:
            total += v * d
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if self.family != "moe":
            return self.param_count()
        d, l = self.d_model, self.num_layers
        ef = self.expert_d_ff or self.d_ff
        hd = self.head_dim
        attn = d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd \
            + self.num_heads * hd * d
        active_mlp = (self.top_k + self.num_shared_experts) * 3 * d * ef \
            + d * self.num_experts
        per_layer = attn + active_mlp + 2 * d
        total = self.vocab_size * d + l * per_layer + d
        if not self.tie_embeddings:
            total += self.vocab_size * d
        return total
