"""RWKV-6 "Finch" (arXiv:2404.05892): attention-free LM with token-shift
and data-dependent per-channel decay.

Training uses the chunked-parallel WKV form (intra-chunk factorized decay
attention + inter-chunk recurrent state); decode is the O(1)-state
recurrence. Both are validated against ``repro.kernels.ref.wkv6_ref``.

Numerics note (documented deviation): the per-step log-decay is clamped to
>= -4 so the intra-chunk factorization exp(-cumsum) stays in f32 range at
chunk 16 (exp(64) ~ 6e27 < f32 max). Official RWKV-6 decay values
(w = exp(-exp(w_raw)), w_raw in [-8, 1]) give log-decay in [-2.72, -3e-4],
so the clamp binds only in the far tail.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.annotate import constrain, unshard_fsdp
from repro.models.config import ModelConfig
from repro.models import layers as L
from repro.models.params import ParamDef

__all__ = ["rwkv6_defs", "rwkv6_apply", "rwkv6_decode", "init_rwkv_cache",
           "wkv6_chunked"]

_LOGW_MIN = -4.0
_WKV_CHUNK = 16


def rwkv6_defs(cfg: ModelConfig) -> Dict[str, Any]:
    d, v, nl, r = cfg.d_model, cfg.vocab_size, cfg.num_layers, \
        cfg.rwkv_lora_rank
    h, hd = cfg.rwkv_heads, cfg.rwkv_head_dim

    def pd(shape, axes, **kw):
        return ParamDef((nl,) + shape, ("layers",) + axes, **kw)

    layer = {
        "ln1_s": pd((d,), ("norm",), init="ones"),
        "ln1_b": pd((d,), ("norm",), init="zeros"),
        "ln2_s": pd((d,), ("norm",), init="ones"),
        "ln2_b": pd((d,), ("norm",), init="zeros"),
        "tm": {
            # ddlerp: 5 interpolation targets (r, k, v, w, g). lora_a is
            # (D, 5, r) so no sharded-dim-splitting reshape is ever needed
            # (GSPMD "involuntary full remat" hazard -- Perf cycle 4).
            "mu": pd((5, d), (None, "norm"), init="zeros"),
            "lora_a": pd((d, 5, r), ("embed", None, "lora"),
                         fan_in_axes=(2,)),
            "lora_b": pd((5, r, d), (None, "lora", "embed"),
                         fan_in_axes=(2,), scale=0.1),
            # data-dependent decay lora + base.
            "w0": pd((d,), ("norm",), init="constant", constant=-0.6),
            "wa": pd((d, r), ("embed", "lora"), fan_in_axes=(1,)),
            "wb": pd((r, d), ("lora", "embed"), fan_in_axes=(1,),
                     scale=0.1),
            "u": pd((h, hd), ("heads", "head_dim"), init="zeros"),
            "wr": pd((d, d), ("embed", "heads_x"), fan_in_axes=(1,)),
            "wk": pd((d, d), ("embed", "heads_x"), fan_in_axes=(1,)),
            "wv": pd((d, d), ("embed", "heads_x"), fan_in_axes=(1,)),
            "wg": pd((d, d), ("embed", "heads_x"), fan_in_axes=(1,)),
            "wo": pd((d, d), ("heads_x", "embed"), fan_in_axes=(1,)),
            "gn_s": pd((d,), ("norm",), init="ones"),
            "gn_b": pd((d,), ("norm",), init="zeros"),
        },
        "cm": {
            "mu_k": pd((d,), ("norm",), init="zeros"),
            "mu_r": pd((d,), ("norm",), init="zeros"),
            "wk": pd((d, cfg.d_ff), ("embed", "mlp"), fan_in_axes=(1,)),
            "wv": pd((cfg.d_ff, d), ("mlp", "embed"), fan_in_axes=(1,)),
            "wr": pd((d, d), ("embed", "heads_x"), fan_in_axes=(1,)),
        },
    }
    return {
        "embed": ParamDef((v, d), ("vocab", "embed"), fan_in_axes=(1,)),
        "ln0_s": ParamDef((d,), ("norm",), init="ones"),
        "ln0_b": ParamDef((d,), ("norm",), init="zeros"),
        "layers": layer,
        "ln_f_s": ParamDef((d,), ("norm",), init="ones"),
        "ln_f_b": ParamDef((d,), ("norm",), init="zeros"),
        "lm_head": ParamDef((d, v), ("embed", "vocab"), fan_in_axes=(0,)),
    }


# ----------------------------------------------------------------------
# WKV recurrence -- chunked (train) and stepwise (decode)
# ----------------------------------------------------------------------


def wkv6_chunked(
    r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, logw: jnp.ndarray,
    u: jnp.ndarray, state0: Optional[jnp.ndarray] = None,
    chunk: int = _WKV_CHUNK,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked WKV-6. r/k/v/logw: (B, S, H, hd); u: (H, hd).

    Returns (o (B,S,H,hd), state (B,H,hd,hd)). f32 internally.
    o_t = r_t (S_{t-1} + diag(u) k_t v_t^T);  S_t = diag(w_t) S_{t-1}
          + k_t v_t^T, with w = exp(logw).
    """
    b, s, h, hd = r.shape
    c = min(chunk, s)
    if s % c:
        raise ValueError(f"seq {s} not divisible by chunk {c}")
    nc = s // c
    f32 = jnp.float32
    rc, kc, vc, wc = (x.reshape(b, nc, c, h, hd).astype(f32)
                      for x in (r, k, v, logw))
    s0 = (jnp.zeros((b, h, hd, hd), f32) if state0 is None
          else state0.astype(f32))

    def body(state, inp):
        r_, k_, v_, lw = inp                     # (b, c, h, hd)
        cum = jnp.cumsum(lw, axis=1)             # inclusive
        cum_prev = cum - lw                      # cum_{t-1}
        r_dec = r_ * jnp.exp(cum_prev)
        k_dec = k_ * jnp.exp(-cum)
        att = jnp.einsum("bthi,bshi->bhts", r_dec, k_dec)
        mask = jnp.tril(jnp.ones((c, c), bool), k=-1)
        att = jnp.where(mask[None, None], att, 0.0)
        intra = jnp.einsum("bhts,bshj->bthj", att, v_)
        bonus = jnp.einsum("bthi,hi,bthi->bth", r_, u.astype(f32), k_)
        intra = intra + bonus[..., None] * v_
        cross = jnp.einsum("bthi,bhij->bthj", r_dec, state)
        o = cross + intra
        cum_end = cum[:, -1:]                    # (b, 1, h, hd)
        k_tail = k_ * jnp.exp(cum_end - cum)
        state = (jnp.exp(cum_end[:, 0])[..., None] * state
                 + jnp.einsum("bshi,bshj->bhij", k_tail, v_))
        return state, o

    # scan over chunks (time-major)
    inp = tuple(x.transpose(1, 0, 2, 3, 4) for x in (rc, kc, vc, wc))
    state, o = jax.lax.scan(body, s0, inp)
    o = o.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hd)
    return o.astype(r.dtype), state


def _wkv6_step(r, k, v, logw, u, state):
    """Single-token WKV step. r/k/v/logw (B,H,hd); state (B,H,hd,hd)."""
    f32 = jnp.float32
    r_, k_, v_, w_ = (x.astype(f32) for x in (r, k, v, jnp.exp(logw)))
    kv = jnp.einsum("bhi,bhj->bhij", k_, v_)
    o = jnp.einsum("bhi,bhij->bhj", r_,
                   state + u.astype(f32)[None, :, :, None] * kv)
    state = w_[..., None] * state + kv
    return o.astype(r.dtype), state


# ----------------------------------------------------------------------
# Blocks
# ----------------------------------------------------------------------


def _token_shift(x: jnp.ndarray, last: Optional[jnp.ndarray] = None):
    """Previous-token tensor; ``last`` (B, D) seeds position 0 (decode)."""
    if last is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return jnp.concatenate([last[:, None], x[:, :-1]], axis=1)


def _ddlerp(tm, x, sx):
    """Data-dependent interpolation producing (r,k,v,w,g) inputs."""
    xx = sx - x
    base = x + xx * tm["mu"][:, None, None]            # (5, B, S, D)
    lora_a = unshard_fsdp(tm["lora_a"])                # tiny: replicate
    lora_b = unshard_fsdp(tm["lora_b"])
    lora = jnp.tanh(jnp.einsum("bsd,dkr->bskr", x + xx * 0.5, lora_a))
    lora = constrain(lora, ("batch", None, None, None))
    adj = jnp.einsum("bskr,krd->kbsd", lora, lora_b)
    adj = constrain(adj, (None, "batch", None, None))
    return base + xx[None] * adj                        # (5, B, S, D)


def _time_mix(tm, x, cfg: ModelConfig, *, sx=None, state0=None,
              decode: bool = False):
    b, s, d = x.shape
    h, hd = cfg.rwkv_heads, cfg.rwkv_head_dim
    sx = _token_shift(x, sx)
    xr, xk, xv, xw, xg = _ddlerp(tm, x, sx)
    r = L.dense(xr, tm["wr"]).reshape(b, s, h, hd)
    k = L.dense(xk, tm["wk"]).reshape(b, s, h, hd)
    v = L.dense(xv, tm["wv"]).reshape(b, s, h, hd)
    g = L.dense(xg, tm["wg"])
    dec = jnp.einsum("bsr,rd->bsd", jnp.tanh(
        jnp.einsum("bsd,dr->bsr", xw, unshard_fsdp(tm["wa"]))),
        unshard_fsdp(tm["wb"]))
    logw = -jnp.exp((tm["w0"] + dec).astype(jnp.float32))
    logw = jnp.maximum(logw, _LOGW_MIN).reshape(b, s, h, hd)

    if decode:
        o, state = _wkv6_step(r[:, 0], k[:, 0], v[:, 0], logw[:, 0],
                              tm["u"], state0)
        o = o[:, None]                                   # (B, 1, H, hd)
    else:
        o, state = wkv6_chunked(r, k, v, logw, tm["u"], state0,
                                chunk=min(_WKV_CHUNK, s))
    o = o.reshape(b, s, d)
    # Per-head group norm, then SiLU(g) gate (RWKV-6 output block).
    o = o.reshape(b, s, h, hd)
    mu = o.mean(-1, keepdims=True)
    var = jnp.var(o, axis=-1, keepdims=True)
    o = ((o - mu) * jax.lax.rsqrt(var + 64e-5)).reshape(b, s, d)
    o = o * tm["gn_s"] + tm["gn_b"]
    o = o * jax.nn.silu(g)
    return L.dense(o, tm["wo"], role="down"), state


def _channel_mix(cm, x, *, sx=None):
    sx = _token_shift(x, sx)
    xx = sx - x
    xk = x + xx * cm["mu_k"]
    xr = x + xx * cm["mu_r"]
    kk = jnp.square(jax.nn.relu(L.dense(xk, cm["wk"])))
    kv = L.dense(kk, cm["wv"], role="down")
    return jax.nn.sigmoid(L.dense(xr, cm["wr"])) * kv


def rwkv6_apply(params: Dict[str, Any], tokens: jnp.ndarray,
                cfg: ModelConfig, *, scan_layers: bool = True,
                remat: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward. Returns (logits f32, aux=0)."""
    h = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    h = L.layer_norm(h, params["ln0_s"], params["ln0_b"], cfg.norm_eps)

    def body(h, lp):
        x = L.layer_norm(h, lp["ln1_s"], lp["ln1_b"], cfg.norm_eps)
        tm_out, _ = _time_mix(lp["tm"], x, cfg)
        h = h + tm_out
        x = L.layer_norm(h, lp["ln2_s"], lp["ln2_b"], cfg.norm_eps)
        return h + _channel_mix(lp["cm"], x), None

    if remat:
        body = jax.checkpoint(body)
    if scan_layers:
        h, _ = jax.lax.scan(lambda c, lp: body(c, lp), h, params["layers"])
    else:
        for i in range(cfg.num_layers):
            lp = jax.tree.map(lambda x: x[i], params["layers"])
            h, _ = body(h, lp)
    h = L.layer_norm(h, params["ln_f_s"], params["ln_f_b"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h,
                        unshard_fsdp(params["lm_head"], (None, "model")),
                        preferred_element_type=jnp.float32)
    logits = constrain(logits, ("batch", None, "model"))
    return logits, jnp.float32(0.0)


def init_rwkv_cache(cfg: ModelConfig, batch: int, cache_len: int = 0,
                    dtype=None) -> Dict[str, jnp.ndarray]:
    """O(1) recurrent cache: WKV state + token-shift states per layer.

    ``cache_len`` is ignored (constant-size state) -- the property that
    makes the long_500k cell admissible for this family.
    """
    del cache_len
    nl, d = cfg.num_layers, cfg.d_model
    h, hd = cfg.rwkv_heads, cfg.rwkv_head_dim
    dt = jnp.dtype(dtype or cfg.dtype)
    return {
        "state": jnp.zeros((nl, batch, h, hd, hd), jnp.float32),
        "tm_x": jnp.zeros((nl, batch, d), dt),
        "cm_x": jnp.zeros((nl, batch, d), dt),
        "pos": jnp.zeros((), jnp.int32),
    }


def rwkv6_decode(params: Dict[str, Any], cache: Dict[str, jnp.ndarray],
                 tokens: jnp.ndarray, cfg: ModelConfig,
                 *, scan_layers: bool = True
                 ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One decode step. tokens (B, 1)."""
    h = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    h = L.layer_norm(h, params["ln0_s"], params["ln0_b"], cfg.norm_eps)

    def body(h, inp):
        lp, state, tm_x, cm_x = inp
        x = L.layer_norm(h, lp["ln1_s"], lp["ln1_b"], cfg.norm_eps)
        tm_out, state_new = _time_mix(lp["tm"], x, cfg, sx=tm_x,
                                      state0=state, decode=True)
        h = h + tm_out
        x2 = L.layer_norm(h, lp["ln2_s"], lp["ln2_b"], cfg.norm_eps)
        h = h + _channel_mix(lp["cm"], x2, sx=cm_x)
        return h, (state_new, x[:, 0], x2[:, 0])

    if scan_layers:
        h, (state, tm_x, cm_x) = jax.lax.scan(
            body, h, (params["layers"], cache["state"], cache["tm_x"],
                      cache["cm_x"]))
    else:
        outs = []
        for i in range(cfg.num_layers):
            lp = jax.tree.map(lambda x: x[i], params["layers"])
            h, o = body(h, (lp, cache["state"][i], cache["tm_x"][i],
                            cache["cm_x"][i]))
            outs.append(o)
        state, tm_x, cm_x = (jnp.stack([o[j] for o in outs])
                             for j in range(3))
    h = L.layer_norm(h, params["ln_f_s"], params["ln_f_b"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h,
                        unshard_fsdp(params["lm_head"], (None, "model")),
                        preferred_element_type=jnp.float32)
    logits = constrain(logits, ("batch", None, "model"))
    return logits, {"state": state, "tm_x": tm_x, "cm_x": cm_x,
                    "pos": cache["pos"] + 1}
