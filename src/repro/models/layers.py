"""Shared neural layers: norms, RoPE/M-RoPE, GQA attention, MLPs, MoE.

All layers are functional: ``*_defs`` returns the ParamDef tree,
``*_apply`` consumes the materialized params. Attention uses a blockwise
(online-softmax) formulation so no (S, S) score tensor is ever
materialized -- required for the 32k prefill cells.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.ternary import unpack2bit
from repro.distributed.annotate import constrain, current_mesh, unshard_fsdp
from repro.models.config import ModelConfig
from repro.models.params import ParamDef

__all__ = [
    "rms_norm", "rope_freqs", "apply_rope", "mrope_positions",
    "attention_defs", "attention_apply", "attention_decode",
    "mlp_defs", "mlp_apply", "moe_defs", "moe_apply", "dense",
]

# ----------------------------------------------------------------------
# Basic ops
# ----------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(dt) * scale


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
               eps: float) -> jnp.ndarray:
    """Standard LayerNorm (RWKV uses LN, not RMSNorm)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(dt) * scale + bias


def dense(x: jnp.ndarray, w: Any, role: str = "up") -> jnp.ndarray:
    """Matmul against a float weight or a ternary-packed weight dict.

    The ternary dict {"packed": (K//4, N) uint8, "scale": (N,)} is the
    CUTIE-analogue serving format (see kernels/ternary_matmul.py). Here the
    dequant runs as jnp ops so the path lowers/shards under pjit on any
    backend; on-TPU callers use ``repro.kernels.ternary_matmul`` for the
    fused VMEM dequant (numerics identical; tests assert so).

    ``role`` sets the Megatron TP orientation of the weight at use time
    (Perf cycles 1-2): "up" = column-parallel (output dim sharded),
    "down" = row-parallel (contraction dim sharded; output gets one
    small all-reduce instead of the hidden being all-gathered).
    """
    if isinstance(w, dict) and "packed" in w:
        wq = unpack2bit(w["packed"].T).T.astype(x.dtype)  # (K, N)
        y = jnp.einsum("...k,kn->...n", x, wq,
                       preferred_element_type=jnp.float32)
        return (y * w["scale"].astype(jnp.float32)).astype(x.dtype)
    # FSDP gather-at-use: keep only the TP dim sharded for the contraction
    # (Perf cycle 1 -- avoids activation all-reduce over the data axis).
    if role == "down":
        w = unshard_fsdp(w, ("model", None), (None, "model"))
    else:
        w = unshard_fsdp(w, (None, "model"), ("model", None))
    return jnp.einsum("...k,kn->...n", x, w)


# ----------------------------------------------------------------------
# Rotary embeddings (RoPE + Qwen2-VL M-RoPE)
# ----------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies, shape (head_dim // 2,), f32."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(
    x: jnp.ndarray,
    positions: jnp.ndarray,
    theta: float,
    mrope_sections: Optional[Tuple[int, int, int]] = None,
) -> jnp.ndarray:
    """Rotate (B, S, H, hd). ``positions``: (B, S) or (3, B, S) for M-RoPE.

    M-RoPE (Qwen2-VL): the head_dim/2 frequency slots are split into
    (t, h, w) sections; each section takes its angle from the matching
    position row. Text tokens have t == h == w so M-RoPE degenerates to
    1-D RoPE for them.
    """
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                       # (hd/2,)
    if positions.ndim == 2:
        ang = positions[..., None].astype(jnp.float32) * inv  # (B,S,hd/2)
    else:
        if mrope_sections is None:
            raise ValueError("3-row positions require mrope_sections")
        secs = mrope_sections
        if sum(secs) != hd // 2:
            raise ValueError(f"mrope sections {secs} != head_dim/2 {hd//2}")
        ang3 = positions[..., None].astype(jnp.float32) * inv  # (3,B,S,hd/2)
        parts = []
        off = 0
        for i, s in enumerate(secs):
            parts.append(ang3[i, ..., off:off + s])
            off += s
        ang = jnp.concatenate(parts, axis=-1)          # (B,S,hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def mrope_positions(
    batch: int, seq: int, num_vision: int, vision_grid: Tuple[int, int]
) -> jnp.ndarray:
    """Qwen2-VL position rows (3, B, S): vision patches first, then text.

    Patches at sequence slots [0, num_vision) carry (t=0, h=row, w=col) of
    an (gh, gw) grid; text tokens continue with t=h=w running positions.
    """
    gh, gw = vision_grid
    idx = jnp.arange(seq)
    h_pos = jnp.where(idx < num_vision, (idx // gw) % gh,
                      idx - num_vision + max(gh, gw))
    w_pos = jnp.where(idx < num_vision, idx % gw,
                      idx - num_vision + max(gh, gw))
    t_pos = jnp.where(idx < num_vision, 0, idx - num_vision + max(gh, gw))
    pos = jnp.stack([t_pos, h_pos, w_pos])            # (3, S)
    return jnp.broadcast_to(pos[:, None, :], (3, batch, seq))


# ----------------------------------------------------------------------
# Attention (GQA, optional sliding window, blockwise online softmax)
# ----------------------------------------------------------------------


def attention_defs(cfg: ModelConfig, layers: Optional[int] = None
                   ) -> Dict[str, ParamDef]:
    """QKV/O projections, optionally stacked over a leading layer axis."""
    d, h, kvh, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    lead = () if layers is None else (layers,)
    lax_ = () if layers is None else ("layers",)

    def pd(shape, axes, fan):
        return ParamDef(lead + shape, lax_ + axes,
                        fan_in_axes=tuple(len(lead) + a for a in fan))

    return {
        "wq": pd((d, h, hd), ("embed", "heads", "head_dim"), (0,)),
        "wk": pd((d, kvh, hd), ("embed", "kv_heads", "head_dim"), (0,)),
        "wv": pd((d, kvh, hd), ("embed", "kv_heads", "head_dim"), (0,)),
        "wo": pd((h, hd, d), ("heads", "head_dim", "embed"), (0, 1)),
    }


def _chunk_mask(q_pos, k_pos, causal: bool, window: Optional[int]):
    """(Sq, Sk) validity mask from absolute positions."""
    diff = q_pos[..., :, None] - k_pos[..., None, :]
    mask = jnp.ones_like(diff, dtype=bool)
    if causal:
        mask &= diff >= 0
    if window is not None:
        mask &= diff < window
    return mask


def blockwise_attention(
    q: jnp.ndarray,            # (B, Sq, H, hd)
    k: jnp.ndarray,            # (B, Sk, KVH, hd)
    v: jnp.ndarray,            # (B, Sk, KVH, hd)
    *,
    causal: bool,
    window: Optional[int] = None,
    q_offset: int | jnp.ndarray = 0,
    kv_chunk: int = 2048,
) -> jnp.ndarray:
    """Memory-efficient attention: scan over KV chunks, online softmax.

    Never materializes (Sq, Sk) scores; peak extra memory is one
    (B, H, Sq, kv_chunk) block. GQA handled by folding the q-per-kv group
    into the head dim of a 5-D einsum.
    """
    b, sq, h, hd = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    kv_chunk = min(kv_chunk, sk)
    n_chunks = math.ceil(sk / kv_chunk)
    pad = n_chunks * kv_chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, n_chunks, kv_chunk, kvh, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, kv_chunk, kvh, hd).transpose(1, 0, 2, 3, 4)

    qg = q.reshape(b, sq, kvh, g, hd).astype(jnp.float32)
    scale = 1.0 / math.sqrt(hd)
    q_pos = q_offset + jnp.arange(sq)

    def body(carry, inp):
        m_prev, l_prev, acc = carry
        ci, k_blk, v_blk = inp
        k_pos = ci * kv_chunk + jnp.arange(kv_chunk)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg,
                       k_blk.astype(jnp.float32)) * scale
        mask = _chunk_mask(q_pos, k_pos, causal, window)      # (Sq, kc)
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)
        corr = jnp.where(jnp.isneginf(m_prev), 0.0,
                         jnp.exp(m_prev - m_safe))
        l_new = l_prev * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p, v_blk.astype(jnp.float32))
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, kvh, g, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, sq), jnp.float32)
    acc0 = jnp.zeros((b, kvh, g, sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0),
        (jnp.arange(n_chunks), kc, vc))
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd)
    return out.astype(q.dtype)


def attention_apply(
    p: Dict[str, jnp.ndarray],
    x: jnp.ndarray,
    positions: jnp.ndarray,
    cfg: ModelConfig,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    kv_x: Optional[jnp.ndarray] = None,
    kv_positions: Optional[jnp.ndarray] = None,
    mrope: bool = False,
) -> jnp.ndarray:
    """Full-sequence attention (training / prefill). kv_x enables cross-attn."""
    kv_src = x if kv_x is None else kv_x
    # Heads-TP when the head count divides the model axis; otherwise
    # sequence-CP: shard q rows over 'model', replicate (small) K/V --
    # avoids partial-sum all-reduces of f32 score blocks (Perf cycle 3;
    # llama4's 40 heads % 16 != 0 fallback used to shard head_dim, putting
    # the TP axis on the CONTRACTION dim of the score einsum).
    mesh = current_mesh()
    tp = (dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
          if mesh is not None else 1)
    heads_tp = cfg.num_heads % tp == 0
    kv_tp = cfg.num_kv_heads % tp == 0
    wq = unshard_fsdp(p["wq"], (None, "model", None))
    wk = unshard_fsdp(p["wk"], (None, "model", None) if kv_tp
                      else (None, None, None))
    wv = unshard_fsdp(p["wv"], (None, "model", None) if kv_tp
                      else (None, None, None))
    q = jnp.einsum("bsd,dhk->bshk", x, wq)
    k = jnp.einsum("bsd,dhk->bshk", kv_src, wk)
    v = jnp.einsum("bsd,dhk->bshk", kv_src, wv)
    if heads_tp:
        q = constrain(q, ("batch", None, "model", None))
        kv_spec = ("batch", None, "model" if kv_tp else None, None)
        k = constrain(k, kv_spec)
        v = constrain(v, kv_spec)
    else:
        q = constrain(q, ("batch", "model", None, None))
        k = constrain(k, ("batch", None, None, None))
        v = constrain(v, ("batch", None, None, None))
    secs = cfg.mrope_sections if mrope else None
    if kv_x is None:  # self-attention: rotate both
        q = apply_rope(q, positions, cfg.rope_theta, secs)
        k = apply_rope(k, positions, cfg.rope_theta, secs)
    out = blockwise_attention(q, k, v, causal=causal,
                              window=window or cfg.sliding_window)
    out = constrain(out, ("batch", None, "model", None) if heads_tp
                    else ("batch", "model", None, None))
    # o-proj: row-parallel over heads when heads-TP (one bf16 all-reduce
    # of (B,S,D)); fully gathered weight in the CP fallback.
    wo = unshard_fsdp(p["wo"], ("model", None, None) if heads_tp
                      else (None, None, None))
    y = jnp.einsum("bshk,hkd->bsd", out, wo)
    return constrain(y, ("batch", None, None))


def attention_decode(
    p: Dict[str, jnp.ndarray],
    x: jnp.ndarray,                 # (B, 1, D)
    cache: Dict[str, jnp.ndarray],  # {"k","v": (B, S, KVH, hd), "pos": ()}
    cfg: ModelConfig,
    *,
    window: Optional[int] = None,
    mrope: bool = False,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Single-token decode with (rolling, for SWA) KV cache update."""
    pos = cache["pos"]              # scalar int32: tokens already cached
    b = x.shape[0]
    secs = cfg.mrope_sections if mrope else None
    posb = jnp.broadcast_to(pos[None, None], (3, b, 1)) if mrope \
        else jnp.broadcast_to(pos[None, None], (b, 1))
    wq = unshard_fsdp(p["wq"], (None, "model", None), (None, None, "model"))
    wk = unshard_fsdp(p["wk"], (None, "model", None), (None, None, "model"))
    wv = unshard_fsdp(p["wv"], (None, "model", None), (None, None, "model"))
    q = jnp.einsum("bsd,dhk->bshk", x, wq)
    k = jnp.einsum("bsd,dhk->bshk", x, wk)
    v = jnp.einsum("bsd,dhk->bshk", x, wv)
    q = apply_rope(q, posb, cfg.rope_theta, secs)
    k = apply_rope(k, posb, cfg.rope_theta, secs)

    s_cache = cache["k"].shape[1]
    # SWA: rolling ring-buffer slot; full attention: append at pos.
    slot = pos % s_cache if window is not None \
        else jnp.minimum(pos, s_cache - 1)
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), slot, axis=1)

    kvh, hd, h = cfg.num_kv_heads, cfg.head_dim, cfg.num_heads
    g = h // kvh
    qg = q.reshape(b, 1, kvh, g, hd).astype(jnp.float32)
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg,
                   k_cache.astype(jnp.float32)) * scale
    if window is not None:
        # Rolling cache: every resident entry is within the window once
        # pos >= s_cache; before that, mask unwritten slots.
        k_idx = jnp.arange(s_cache)
        valid = jnp.where(pos >= s_cache, jnp.ones_like(k_idx, bool),
                          k_idx <= pos)
    else:
        valid = jnp.arange(s_cache) <= pos
    s = jnp.where(valid[None, None, None, None, :], s, -jnp.inf)
    w_att = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bkgqd", w_att,
                     v_cache.astype(jnp.float32))
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, 1, h, hd).astype(x.dtype)
    wo = unshard_fsdp(p["wo"], ("model", None, None), (None, "model", None))
    y = jnp.einsum("bshk,hkd->bsd", out, wo)
    return y, {"k": k_cache, "v": v_cache, "pos": pos + 1}


# ----------------------------------------------------------------------
# MLPs
# ----------------------------------------------------------------------


def mlp_defs(cfg: ModelConfig, layers: Optional[int] = None,
             d_ff: Optional[int] = None) -> Dict[str, ParamDef]:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    lead = () if layers is None else (layers,)
    lax_ = () if layers is None else ("layers",)

    def pd(shape, axes, fan):
        return ParamDef(lead + shape, lax_ + axes,
                        fan_in_axes=tuple(len(lead) + a for a in fan))

    out = {"w_up": pd((d, f), ("embed", "mlp"), (0,)),
           "w_down": pd((f, d), ("mlp", "embed"), (0,))}
    if cfg.activation == "swiglu":
        out["w_gate"] = pd((d, f), ("embed", "mlp"), (0,))
    return out


def mlp_apply(p: Dict[str, jnp.ndarray], x: jnp.ndarray,
              cfg: ModelConfig) -> jnp.ndarray:
    if cfg.activation == "swiglu":
        h = jax.nn.silu(dense(x, p["w_gate"])) * dense(x, p["w_up"])
    elif cfg.activation == "squared_relu":
        h = jnp.square(jax.nn.relu(dense(x, p["w_up"])))
    else:
        h = jax.nn.gelu(dense(x, p["w_up"]))
    return dense(h, p["w_down"], role="down")


# ----------------------------------------------------------------------
# MoE (shared + routed experts, group-wise einsum dispatch, GShard-style
# capacity with token dropping; see DESIGN.md)
# ----------------------------------------------------------------------


def moe_defs(cfg: ModelConfig, layers: Optional[int] = None
             ) -> Dict[str, Any]:
    d = cfg.d_model
    ef = cfg.expert_d_ff or cfg.d_ff
    e = cfg.num_experts
    lead = () if layers is None else (layers,)
    lax_ = () if layers is None else ("layers",)

    def pd(shape, axes, fan):
        return ParamDef(lead + shape, lax_ + axes,
                        fan_in_axes=tuple(len(lead) + a for a in fan))

    defs: Dict[str, Any] = {
        "router": pd((d, e), ("embed", "experts"), (0,)),
        "we_gate": pd((e, d, ef), ("experts", "embed", "mlp"), (1,)),
        "we_up": pd((e, d, ef), ("experts", "embed", "mlp"), (1,)),
        "we_down": pd((e, ef, d), ("experts", "mlp", "embed"), (1,)),
    }
    if cfg.num_shared_experts:
        sf = ef * cfg.num_shared_experts
        defs["shared"] = {
            "w_gate": pd((d, sf), ("embed", "mlp"), (0,)),
            "w_up": pd((d, sf), ("embed", "mlp"), (0,)),
            "w_down": pd((sf, d), ("mlp", "embed"), (0,)),
        }
    return defs


def moe_apply(p: Dict[str, Any], x: jnp.ndarray, cfg: ModelConfig
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output, aux_load_balance_loss)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    g = min(cfg.moe_group_size, b * s)
    n = b * s
    ng = n // g
    cap = int(math.ceil(g * k * cfg.capacity_factor / e))
    cap = min(cap, g)
    xg = x.reshape(ng, g, d)

    logits = jnp.einsum("ngd,de->nge", xg,
                        unshard_fsdp(p["router"], (None, "model"))
                        ).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)             # (ng, g, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balance aux loss.
    me = probs.mean(axis=(0, 1))
    ce_frac = jax.nn.one_hot(gate_idx[..., 0], e).mean(axis=(0, 1))
    aux = e * jnp.sum(me * ce_frac)

    # Position of each (token, choice) in its expert's capacity buffer.
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)   # (ng,g,k,e)
    flat = onehot.reshape(ng, g * k, e)
    pos = (jnp.cumsum(flat, axis=1) - flat).reshape(ng, g, k, e)
    pos = jnp.sum(pos * onehot, axis=-1)                      # (ng, g, k)
    keep = pos < cap
    gate_vals = gate_vals * keep

    # dispatch (ng, g, e, cap) one-hot routing tensor -- bf16 buffer.
    pos_oh = jax.nn.one_hot(pos, cap, dtype=x.dtype) * keep[..., None].astype(x.dtype)
    disp = jnp.einsum("ngke,ngkc->ngec", onehot.astype(x.dtype), pos_oh)
    xe = jnp.einsum("ngd,ngec->necd", xg, disp)               # (ng,e,cap,d)

    we_gate = unshard_fsdp(p["we_gate"], ("model", None, None))
    we_up = unshard_fsdp(p["we_up"], ("model", None, None))
    we_down = unshard_fsdp(p["we_down"], ("model", None, None))
    hg = jax.nn.silu(jnp.einsum("necd,edf->necf", xe, we_gate))
    hu = jnp.einsum("necd,edf->necf", xe, we_up)
    ye = jnp.einsum("necf,efd->necd", hg * hu, we_down)

    # combine: gate-weighted inverse of dispatch.
    comb = jnp.einsum("ngke,ngkc->ngec",
                      (onehot * gate_vals[..., None]).astype(x.dtype), pos_oh)
    y = jnp.einsum("ngec,necd->ngd", comb, ye)
    out = y.reshape(b, s, d)

    if cfg.num_shared_experts:
        sh = p["shared"]
        hs = jax.nn.silu(dense(x, sh["w_gate"])) * dense(x, sh["w_up"])
        out = out + dense(hs, sh["w_down"], role="down")
    return out, aux
