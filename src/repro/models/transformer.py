"""Decoder-only transformer backbone: dense, MoE and VLM families.

Layers are stacked along a leading ``layers`` axis and executed with
``lax.scan`` (compact HLO at any depth -- nemotron's 96 layers compile as
fast as 16; the roofline harness separately lowers unrolled depth-1/2
variants for exact FLOP accounting, see DESIGN.md Sec. 6). Set
``scan_layers=False`` to unroll.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.annotate import constrain, unshard_fsdp
from repro.models.config import ModelConfig
from repro.models import layers as L
from repro.models.params import ParamDef

__all__ = [
    "transformer_defs", "transformer_apply", "transformer_decode",
    "init_kv_cache", "unembed",
]


def transformer_defs(cfg: ModelConfig) -> Dict[str, Any]:
    d, v, nl = cfg.d_model, cfg.vocab_size, cfg.num_layers
    layer: Dict[str, Any] = {
        "ln1": ParamDef((nl, d), ("layers", "norm"), init="ones"),
        "ln2": ParamDef((nl, d), ("layers", "norm"), init="ones"),
        "attn": L.attention_defs(cfg, layers=nl),
    }
    if cfg.family == "moe":
        layer["moe"] = L.moe_defs(cfg, layers=nl)
    else:
        layer["mlp"] = L.mlp_defs(cfg, layers=nl)
    defs: Dict[str, Any] = {
        "embed": ParamDef((v, d), ("vocab", "embed"), scale=1.0,
                          fan_in_axes=(1,)),
        "layers": layer,
        "ln_f": ParamDef((d,), ("norm",), init="ones"),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((d, v), ("embed", "vocab"),
                                   fan_in_axes=(0,))
    return defs


def unembed(params: Dict[str, Any], h: jnp.ndarray, cfg: ModelConfig
            ) -> jnp.ndarray:
    """Final norm + LM head; logits in f32."""
    h = L.rms_norm(h, params["ln_f"], cfg.norm_eps)
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    w = unshard_fsdp(w, (None, "model"))
    logits = jnp.einsum("bsd,dv->bsv", h, w,
                        preferred_element_type=jnp.float32)
    # Keep vocab sharded through the loss: avoids a replicated (B,S,V)
    # f32 tensor (33 GB/device at nemotron scale -- see EXPERIMENTS.md).
    logits = constrain(logits, ("batch", None, "model"))
    if cfg.logits_softcap:
        c = cfg.logits_softcap
        logits = c * jnp.tanh(logits / c)
    return logits


def _layer_body(h, lp, positions, cfg, *, mrope):
    a_in = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
    h = h + L.attention_apply(lp["attn"], a_in, positions, cfg,
                              causal=True, mrope=mrope)
    m_in = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        mo, aux = L.moe_apply(lp["moe"], m_in, cfg)
        return h + mo, aux
    return h + L.mlp_apply(lp["mlp"], m_in, cfg), jnp.float32(0.0)


def transformer_apply(
    params: Dict[str, Any],
    tokens: jnp.ndarray,                  # (B, S) int32
    cfg: ModelConfig,
    *,
    positions: Optional[jnp.ndarray] = None,
    extra_embeds: Optional[jnp.ndarray] = None,  # VLM patch embeddings
    scan_layers: bool = True,
    remat: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward. Returns (logits (B,S,V) f32, moe_aux_loss)."""
    b, s = tokens.shape
    h = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    h = constrain(h, ("batch", None, None))
    mrope = cfg.family == "vlm"
    if extra_embeds is not None:
        # VLM: first n_vis sequence slots carry patch embeddings.
        n_vis = extra_embeds.shape[1]
        h = jnp.concatenate(
            [extra_embeds.astype(h.dtype), h[:, n_vis:]], axis=1)
    if positions is None:
        if mrope:
            n_vis = 0 if extra_embeds is None else extra_embeds.shape[1]
            side = max(int(n_vis ** 0.5), 1)
            positions = L.mrope_positions(b, s, n_vis, (side, side))
        else:
            positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    body = functools.partial(_layer_body, positions=positions, cfg=cfg,
                             mrope=mrope)
    if remat:
        body = jax.checkpoint(body)
    if scan_layers:
        def scan_fn(carry, lp):
            h, aux = carry
            h, a = body(h, lp)
            return (h, aux + a), None
        (h, aux), _ = jax.lax.scan(scan_fn, (h, jnp.float32(0.0)),
                                   params["layers"])
    else:
        aux = jnp.float32(0.0)
        for i in range(cfg.num_layers):
            lp = jax.tree.map(lambda x: x[i], params["layers"])
            h, a = body(h, lp)
            aux = aux + a
    return unembed(params, h, cfg), aux


def init_kv_cache(cfg: ModelConfig, batch: int, cache_len: int,
                  dtype=None) -> Dict[str, jnp.ndarray]:
    """Stacked per-layer KV cache. SWA archs get a ring buffer of window
    size -- the reason h2o-danube's long_500k cell is admissible."""
    dt = jnp.dtype(dtype or cfg.dtype)
    if cfg.sliding_window is not None:
        cache_len = min(cache_len, cfg.sliding_window)
    shape = (cfg.num_layers, batch, cache_len, cfg.num_kv_heads,
             cfg.head_dim)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt),
            "pos": jnp.zeros((), jnp.int32)}


def transformer_decode(
    params: Dict[str, Any],
    cache: Dict[str, jnp.ndarray],
    tokens: jnp.ndarray,                  # (B, 1)
    cfg: ModelConfig,
    *,
    window_override: Optional[int] = None,
    scan_layers: bool = True,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One decode step over the stacked cache. Returns (logits, cache)."""
    h = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    mrope = cfg.family == "vlm"
    window = window_override or cfg.sliding_window
    pos = cache["pos"]

    def scan_fn(h, inp):
        lp, k_l, v_l = inp
        a_in = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
        att, new = L.attention_decode(
            lp["attn"], a_in, {"k": k_l, "v": v_l, "pos": pos}, cfg,
            window=window, mrope=mrope)
        h = h + att
        m_in = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
        if cfg.family == "moe":
            mo, _ = L.moe_apply(lp["moe"], m_in, cfg)
            h = h + mo
        else:
            h = h + L.mlp_apply(lp["mlp"], m_in, cfg)
        return h, (new["k"], new["v"])

    if scan_layers:
        h, (k_new, v_new) = jax.lax.scan(
            scan_fn, h, (params["layers"], cache["k"], cache["v"]))
    else:
        ks, vs = [], []
        for i in range(cfg.num_layers):
            lp = jax.tree.map(lambda x: x[i], params["layers"])
            h, (k_i, v_i) = scan_fn(h, (lp, cache["k"][i], cache["v"][i]))
            ks.append(k_i)
            vs.append(v_i)
        k_new, v_new = jnp.stack(ks), jnp.stack(vs)
    logits = unembed(params, h, cfg)
    return logits, {"k": k_new, "v": v_new, "pos": pos + 1}
