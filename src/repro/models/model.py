"""Unified model API: build any assigned architecture from a ModelConfig.

``build_model(cfg)`` returns a :class:`Model` bundle exposing:

  defs()                      -> ParamDef tree (init + sharding + dry-run)
  init(rng, dtype)            -> parameter pytree
  apply(params, batch)        -> (logits, aux) full-sequence forward
  loss(params, batch)         -> (scalar loss, metrics) next-token CE
  init_cache(batch, cache_len)-> decode cache pytree (zeros)
  decode(params, cache, tok)  -> (logits, new cache) one serve step
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import encdec as ED
from repro.models import params as P
from repro.models import rwkv6 as RW
from repro.models import transformer as TF
from repro.models import zamba2 as ZB

__all__ = ["Model", "build_model", "lm_loss"]


def lm_loss(logits: jnp.ndarray, targets: jnp.ndarray,
            aux: jnp.ndarray = None, aux_coef: float = 0.01
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Next-token cross-entropy (f32). targets: (B, S) int32, -1 = pad."""
    mask = (targets >= 0).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    tgt = jnp.maximum(targets, 0)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (nll * mask).sum() / denom
    metrics = {"ce": loss, "tokens": mask.sum()}
    if aux is not None:
        metrics["aux"] = aux
        loss = loss + aux_coef * aux
    return loss, metrics


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    defs: Callable[[], Any]
    apply: Callable[..., Tuple[jnp.ndarray, jnp.ndarray]]
    init_cache: Callable[..., Any]
    decode: Callable[..., Tuple[jnp.ndarray, Any]]

    def init(self, rng: jax.Array, dtype=None) -> Any:
        dt = jnp.dtype(dtype or self.cfg.dtype)
        return P.materialize(self.defs(), rng, dt)

    def abstract_params(self, dtype=None) -> Any:
        dt = jnp.dtype(dtype or self.cfg.dtype)
        return P.abstract(self.defs(), dt)

    def loss(self, params, batch, *, scan_layers: bool = True,
             remat: bool = False):
        logits, aux = self.apply(params, batch, scan_layers=scan_layers,
                                 remat=remat)
        return lm_loss(logits[:, :-1], batch["targets"][:, 1:], aux)

    def num_params(self) -> int:
        return P.tree_num_params(self.defs())


def build_model(cfg: ModelConfig) -> Model:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        def apply_fn(params, batch, *, scan_layers=True, remat=False):
            return TF.transformer_apply(
                params, batch["tokens"], cfg,
                extra_embeds=batch.get("patch_embeds"),
                scan_layers=scan_layers, remat=remat)
        return Model(cfg, lambda: TF.transformer_defs(cfg), apply_fn,
                     lambda b, s, dtype=None: TF.init_kv_cache(
                         cfg, b, s, dtype),
                     lambda p, c, t, **kw: TF.transformer_decode(p, c, t, cfg, **kw))
    if fam == "rwkv6":
        def apply_fn(params, batch, *, scan_layers=True, remat=False):
            return RW.rwkv6_apply(params, batch["tokens"], cfg,
                                  scan_layers=scan_layers, remat=remat)
        return Model(cfg, lambda: RW.rwkv6_defs(cfg), apply_fn,
                     lambda b, s, dtype=None: RW.init_rwkv_cache(
                         cfg, b, s, dtype),
                     lambda p, c, t, **kw: RW.rwkv6_decode(p, c, t, cfg, **kw))
    if fam == "zamba2":
        def apply_fn(params, batch, *, scan_layers=True, remat=False):
            return ZB.zamba2_apply(params, batch["tokens"], cfg,
                                   scan_layers=scan_layers, remat=remat)
        return Model(cfg, lambda: ZB.zamba2_defs(cfg), apply_fn,
                     lambda b, s, dtype=None: ZB.init_zamba_cache(
                         cfg, b, s, dtype),
                     lambda p, c, t, **kw: ZB.zamba2_decode(p, c, t, cfg, **kw))
    if fam == "encdec":
        def apply_fn(params, batch, *, scan_layers=True, remat=False):
            return ED.encdec_apply(params, batch, cfg,
                                   scan_layers=scan_layers, remat=remat)
        return Model(cfg, lambda: ED.encdec_defs(cfg), apply_fn,
                     lambda b, s, dtype=None: ED.init_encdec_cache(
                         cfg, b, s, dtype),
                     lambda p, c, t, **kw: ED.encdec_decode(p, c, t, cfg, **kw))
    raise ValueError(f"unknown family: {fam}")
