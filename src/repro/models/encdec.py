"""Encoder-decoder backbone (Seamless-M4T medium's transformer core).

The audio frontend is a stub per the assignment: the encoder consumes
precomputed frame embeddings (B, S, frontend_dim) projected into d_model.
Encoder blocks are bidirectional; decoder blocks are causal self-attention
+ cross-attention to the encoder output.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.annotate import constrain, unshard_fsdp
from repro.models.config import ModelConfig
from repro.models import layers as L
from repro.models.params import ParamDef

__all__ = ["encdec_defs", "encdec_apply", "encode", "encdec_decode",
           "init_encdec_cache"]


def encdec_defs(cfg: ModelConfig) -> Dict[str, Any]:
    d, v = cfg.d_model, cfg.vocab_size
    ne, nd = cfg.encoder_layers, cfg.decoder_layers
    fd = cfg.frontend_dim or d

    enc_layer = {
        "ln1": ParamDef((ne, d), ("layers", "norm"), init="ones"),
        "ln2": ParamDef((ne, d), ("layers", "norm"), init="ones"),
        "attn": L.attention_defs(cfg, layers=ne),
        "mlp": L.mlp_defs(cfg, layers=ne),
    }
    dec_layer = {
        "ln1": ParamDef((nd, d), ("layers", "norm"), init="ones"),
        "ln2": ParamDef((nd, d), ("layers", "norm"), init="ones"),
        "ln3": ParamDef((nd, d), ("layers", "norm"), init="ones"),
        "self_attn": L.attention_defs(cfg, layers=nd),
        "cross_attn": L.attention_defs(cfg, layers=nd),
        "mlp": L.mlp_defs(cfg, layers=nd),
    }
    return {
        "frontend_proj": ParamDef((fd, d), ("embed", "embed_out"),
                                  fan_in_axes=(0,)),
        "embed": ParamDef((v, d), ("vocab", "embed"), fan_in_axes=(1,)),
        "encoder": enc_layer,
        "decoder": dec_layer,
        "ln_enc": ParamDef((d,), ("norm",), init="ones"),
        "ln_f": ParamDef((d,), ("norm",), init="ones"),
        "lm_head": ParamDef((d, v), ("embed", "vocab"), fan_in_axes=(0,)),
    }


def encode(params: Dict[str, Any], frames: jnp.ndarray, cfg: ModelConfig,
           *, remat: bool = False) -> jnp.ndarray:
    """frames (B, S_enc, frontend_dim) -> encoder output (B, S_enc, D)."""
    h = jnp.einsum("bsf,fd->bsd", frames.astype(jnp.dtype(cfg.dtype)),
                   params["frontend_proj"])
    b, s = h.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def body(h, lp):
        a_in = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
        h = h + L.attention_apply(lp["attn"], a_in, positions, cfg,
                                  causal=False)
        m_in = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
        return h + L.mlp_apply(lp["mlp"], m_in, cfg), None

    if remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, params["encoder"])
    return L.rms_norm(h, params["ln_enc"], cfg.norm_eps)


def _decoder(params, tokens, enc_out, cfg, *, scan_layers=True,
             remat=False):
    b, s = tokens.shape
    h = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def body(h, lp):
        a_in = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
        h = h + L.attention_apply(lp["self_attn"], a_in, positions, cfg,
                                  causal=True)
        c_in = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
        h = h + L.attention_apply(lp["cross_attn"], c_in, positions, cfg,
                                  causal=False, kv_x=enc_out)
        m_in = L.rms_norm(h, lp["ln3"], cfg.norm_eps)
        return h + L.mlp_apply(lp["mlp"], m_in, cfg), None

    if remat:
        body = jax.checkpoint(body)
    if scan_layers:
        h, _ = jax.lax.scan(body, h, params["decoder"])
    else:
        for i in range(cfg.decoder_layers):
            lp = jax.tree.map(lambda x: x[i], params["decoder"])
            h, _ = body(h, lp)
    return h


def encdec_apply(params: Dict[str, Any], batch: Dict[str, jnp.ndarray],
                 cfg: ModelConfig, *, scan_layers: bool = True,
                 remat: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Training forward: frames + decoder tokens -> logits."""
    enc_out = encode(params, batch["frames"], cfg, remat=remat)
    h = _decoder(params, batch["tokens"], enc_out, cfg,
                 scan_layers=scan_layers, remat=remat)
    h = L.rms_norm(h, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h,
                        unshard_fsdp(params["lm_head"], (None, "model")),
                        preferred_element_type=jnp.float32)
    logits = constrain(logits, ("batch", None, "model"))
    return logits, jnp.float32(0.0)


def init_encdec_cache(cfg: ModelConfig, batch: int, cache_len: int,
                      dtype=None) -> Dict[str, jnp.ndarray]:
    """Decoder self-attn KV cache + *precomputed* cross-attn K/V.

    Cross keys/values are projected once from the encoder output at
    prefill (``prefill_cross_kv``) -- recomputing them per decode step
    would add 2*S_enc*D*KV FLOPs/step and dominate decode.
    """
    dt = jnp.dtype(dtype or cfg.dtype)
    nd = cfg.decoder_layers
    shape = (nd, batch, cache_len, cfg.num_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dt),
        "v": jnp.zeros(shape, dt),
        "ck": jnp.zeros(shape, dt),
        "cv": jnp.zeros(shape, dt),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill_cross_kv(params: Dict[str, Any], enc_out: jnp.ndarray,
                     cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Project encoder output into stacked per-layer cross K/V."""
    ck = jnp.einsum("bsd,ldhk->lbshk", enc_out,
                    params["decoder"]["cross_attn"]["wk"])
    cv = jnp.einsum("bsd,ldhk->lbshk", enc_out,
                    params["decoder"]["cross_attn"]["wv"])
    return ck, cv


def encdec_decode(params: Dict[str, Any], cache: Dict[str, jnp.ndarray],
                  tokens: jnp.ndarray, cfg: ModelConfig,
                  *, scan_layers: bool = True
                  ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One decoder step attending precomputed cross K/V."""
    h = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    pos = cache["pos"]

    def body(h, inp):
        lp, k_l, v_l, ck_l, cv_l = inp
        a_in = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
        att, new = L.attention_decode(
            lp["self_attn"], a_in, {"k": k_l, "v": v_l, "pos": pos}, cfg)
        h = h + att
        c_in = L.rms_norm(h, lp["ln2"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", c_in, lp["cross_attn"]["wq"])
        cross = L.blockwise_attention(q, ck_l, cv_l, causal=False)
        h = h + jnp.einsum("bshk,hkd->bsd", cross, lp["cross_attn"]["wo"])
        m_in = L.rms_norm(h, lp["ln3"], cfg.norm_eps)
        h = h + L.mlp_apply(lp["mlp"], m_in, cfg)
        return h, (new["k"], new["v"])

    if scan_layers:
        h, (k_new, v_new) = jax.lax.scan(
            body, h, (params["decoder"], cache["k"], cache["v"],
                      cache["ck"], cache["cv"]))
    else:
        ks, vs = [], []
        for i in range(cfg.decoder_layers):
            lp = jax.tree.map(lambda x: x[i], params["decoder"])
            h, (k_i, v_i) = body(h, (lp, cache["k"][i], cache["v"][i],
                                     cache["ck"][i], cache["cv"][i]))
            ks.append(k_i)
            vs.append(v_i)
        k_new, v_new = jnp.stack(ks), jnp.stack(vs)
    h = L.rms_norm(h, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h,
                        unshard_fsdp(params["lm_head"], (None, "model")),
                        preferred_element_type=jnp.float32)
    logits = constrain(logits, ("batch", None, "model"))
    return logits, {"k": k_new, "v": v_new, "ck": cache["ck"],
                    "cv": cache["cv"], "pos": pos + 1}
