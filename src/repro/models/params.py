"""Parameter-definition system: one code path yields init + sharding specs.

Every model declares its parameters as a pytree of :class:`ParamDef` (shape
+ logical axis names + init scale). From that single declaration we derive:

  * ``materialize(defs, rng, dtype)``  -> the actual parameter pytree,
  * ``to_pspecs(defs, rules, mesh)``   -> a matching PartitionSpec pytree,
  * ``abstract(defs, dtype)``          -> ShapeDtypeStruct tree (dry-run).

Logical-axis vocabulary (resolved to mesh axes by ``repro.distributed.
sharding`` rules, with divisibility-aware fallbacks -- e.g. 40 heads on a
16-way model axis falls back to sharding head_dim):

  vocab, embed, heads, kv_heads, head_dim, mlp, experts, state, conv,
  lora, norm (never sharded), layers (stacked scan dim, never sharded).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["ParamDef", "materialize", "abstract", "tree_num_params"]


@dataclasses.dataclass(frozen=True)
class ParamDef:
    """Declarative parameter: shape + logical axes + init law."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    # init: 'normal' (std = scale / sqrt(fan_in_axis_size)), 'zeros',
    # 'ones', 'constant'
    init: str = "normal"
    scale: float = 1.0
    fan_in_axes: Tuple[int, ...] = ()   # axes whose product is fan-in
    constant: float = 0.0

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes}")


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def materialize(defs: Any, rng: jax.Array, dtype=jnp.float32) -> Any:
    """Instantiate a ParamDef tree into parameter arrays."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=_is_def)
    keys = jax.random.split(rng, len(leaves))

    def make(d: ParamDef, key):
        if d.init == "zeros":
            return jnp.zeros(d.shape, dtype)
        if d.init == "ones":
            return jnp.ones(d.shape, dtype)
        if d.init == "constant":
            return jnp.full(d.shape, d.constant, dtype)
        fan_axes = d.fan_in_axes or tuple(range(len(d.shape) - 1))
        fan_in = max(int(np.prod([d.shape[a] for a in fan_axes])), 1)
        std = d.scale / math.sqrt(fan_in)
        return jax.random.normal(key, d.shape, dtype) * jnp.asarray(std, dtype)

    return jax.tree.unflatten(
        treedef, [make(d, k) for d, k in zip(leaves, keys)]
    )


def abstract(defs: Any, dtype=jnp.float32) -> Any:
    """ShapeDtypeStruct tree for dry-run lowering (no allocation)."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype), defs, is_leaf=_is_def
    )


def tree_num_params(defs_or_params: Any) -> int:
    """Total parameter count of a ParamDef or array pytree."""
    def size(x):
        if isinstance(x, ParamDef):
            return int(np.prod(x.shape))
        return int(np.prod(x.shape))
    return sum(size(l) for l in jax.tree.leaves(defs_or_params,
                                                is_leaf=_is_def))
