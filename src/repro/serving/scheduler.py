"""Batched request scheduler: fixed-slot continuous batching.

A production serving loop in miniature: requests queue up, a fixed number
of batch slots decode in lock-step (one jit'd serve step for the whole
batch), finished slots are refilled from the queue without stopping the
running ones (continuous batching a la Orca/vLLM, with per-slot position
offsets into a shared-length cache). Padding tokens drive empty slots.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model

__all__ = ["Request", "BatchScheduler"]


@dataclasses.dataclass
class Request:
    id: int
    prompt: np.ndarray                 # (P,) int token ids
    max_new_tokens: int = 16
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class BatchScheduler:
    """Lock-step decode over ``max_batch`` slots with refill."""

    def __init__(self, model: Model, params: Any, *, max_batch: int = 4,
                 cache_len: int = 128):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.cache_len = cache_len
        self._decode = jax.jit(model.decode)
        self.stats: Dict[str, float] = {"batches": 0, "decode_steps": 0,
                                        "tokens": 0, "wall_s": 0.0}

    def _fresh_cache(self):
        return self.model.init_cache(self.max_batch, self.cache_len)

    def run(self, requests: List[Request]) -> List[Request]:
        """Serve all requests; returns them with ``output`` filled.

        Slots advance in lock-step (shared ``pos``), so a batch drains
        when all its members finish; the queue refills the next batch.
        This is batch-level continuous batching -- slot-level refill
        (true vLLM-style) needs per-slot positions, which the per-family
        caches support via their ``pos`` being broadcastable; kept
        batch-level here for cross-family uniformity.
        """
        t0 = time.perf_counter()
        queue = list(requests)
        finished: List[Request] = []
        while queue:
            batch = queue[:self.max_batch]
            queue = queue[self.max_batch:]
            self._run_batch(batch)
            finished.extend(batch)
            self.stats["batches"] += 1
        self.stats["wall_s"] = time.perf_counter() - t0
        return finished

    def _run_batch(self, batch: List[Request]):
        b = self.max_batch
        cache = self._fresh_cache()
        max_prompt = max(len(r.prompt) for r in batch)
        max_new = max(r.max_new_tokens for r in batch)
        # left-align prompts; pad short ones with token 0
        toks = np.zeros((b, max_prompt), np.int32)
        for i, r in enumerate(batch):
            toks[i, :len(r.prompt)] = r.prompt
        logits = None
        for i in range(max_prompt):
            logits, cache = self._decode(self.params, cache,
                                         jnp.asarray(toks[:, i:i + 1]))
            self.stats["decode_steps"] += 1
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1),
                         np.int32)[:, None]
        for step in range(max_new):
            for i, r in enumerate(batch):
                if not r.done and len(r.output) < r.max_new_tokens:
                    r.output.append(int(nxt[i, 0]))
                    self.stats["tokens"] += 1
                    if len(r.output) >= r.max_new_tokens:
                        r.done = True
            if all(r.done for r in batch):
                break
            logits, cache = self._decode(self.params, cache,
                                         jnp.asarray(nxt))
            self.stats["decode_steps"] += 1
            nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1),
                             np.int32)[:, None]
