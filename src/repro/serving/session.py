"""Cross-modal fusion sessions and stream checkpoint/restore payloads.

ColibriES's headline scenario is one sensor head driving BOTH Kraken
wings: the DVS event stream through the SNE (spiking CNN) and the frame
stream through CUTIE (ternary CNN), fused into a single actuation
decision per control tick -- the ColibriUAV deployment. The serving
stack expresses that as a :class:`FusionSession`: one event
:class:`~repro.serving.stream.StreamHandle` and one frame handle bound
into a single logical stream. Each ``submit`` queues one control tick's
paired windows; each wing is served by its own engine lane (one jit'd
call per wing per step, exactly as unfused streams are), and the session
pairs the per-wing results back up by tick, applies a pluggable fusion
rule (:func:`late_logit_fusion` by default -- a convex combination of
the wings' pre-actuation logits), and emits ONE fused
:class:`~repro.serving.stream.StreamResult` per tick with combined PWM
actuation and a per-wing latency/energy breakdown.

:class:`StreamCheckpoint` is the migration payload behind
``StreamHandle.checkpoint()`` / ``restore()``: a host-serializable
(picklable: numpy + plain Python) snapshot of one stream -- carried
state exported through the engine's duck-typed ``export_state``, any
still-queued windows, and the sequence position -- that can be restored
into a handle on a *different* engine process, after which the remaining
windows complete bitwise-identical to the uninterrupted run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Hashable, List, Optional, Tuple

import jax
import numpy as np

from repro.core.pipeline import ClosedLoopResult, pwm_from_logits
from repro.serving.stream import StreamEngine, StreamHandle, StreamResult

__all__ = ["StreamCheckpoint", "FusionSession", "late_logit_fusion"]

# pwm_from_logits, jitted once: the fuse runs per tick on the host side
# of the serving loop, and the eager op-by-op dispatch overhead would
# otherwise dominate the fused cell of the benchmark.
_PWM_JIT = None


def _fused_pwm(logits: np.ndarray) -> np.ndarray:
    global _PWM_JIT
    if _PWM_JIT is None:
        _PWM_JIT = jax.jit(pwm_from_logits)
    return np.asarray(_PWM_JIT(logits))


@dataclasses.dataclass(frozen=True)
class StreamCheckpoint:
    """One stream, frozen for migration between engine processes.

    Everything inside is host-resident and picklable: ``state`` is the
    engine's exported carry (a numpy pytree; ``None`` = cold start),
    ``queued`` holds still-unserved windows as ``(window, seq,
    deadline)`` tuples, and ``next_seq`` is where per-stream numbering
    resumes. ``duration_us`` pins the one-bin-width-per-engine contract
    across the migration. Accounting (``StreamStats``) deliberately does
    NOT migrate -- stats describe an engine process, not a stream.
    """

    stream_id: Hashable
    modality: str
    stateful: bool
    next_seq: int
    duration_us: Optional[int]
    state: Optional[Any]
    deadline: Optional[float] = None
    queued: Tuple[Tuple[Any, int, Optional[float]], ...] = ()


def late_logit_fusion(event_weight: float = 0.5,
                      frame_weight: float = 0.5) -> Callable:
    """The default fusion rule: a convex combination of the two wings'
    pre-actuation logits (late fusion -- each wing runs its full
    accelerator schedule; only the classifier outputs meet).

    Returns ``rule(event_result, frame_result) -> fused_logits`` for
    :class:`FusionSession`. Custom rules plug in with the same
    signature and may read anything on the per-wing
    :class:`~repro.core.pipeline.ClosedLoopResult` rows (e.g. gate on
    the SNE firing rates or the CUTIE operand activity).
    """

    def rule(event_result: ClosedLoopResult,
             frame_result: ClosedLoopResult) -> np.ndarray:
        return (event_weight * np.asarray(event_result.logits)
                + frame_weight * np.asarray(frame_result.logits))

    rule.name = f"late_logit(event={event_weight:g}, frame={frame_weight:g})"
    return rule


def _rule_name(rule: Callable) -> Optional[str]:
    """A fusion rule's identity for checkpoints: the explicit ``name``
    attribute when set (parameterized rules like late_logit_fusion bake
    their weights into it), else the callable's ``__name__`` -- so even
    a plain function is recorded and a mismatched restore can raise."""
    return getattr(rule, "name", getattr(rule, "__name__", None))


class FusionSession:
    """One logical stream across both accelerator wings.

    Binds one event handle and one frame handle on a shared
    :class:`~repro.serving.stream.StreamEngine` (opened by the session,
    or passed in pre-opened via ``event_handle=`` / ``frame_handle=``).
    ``submit(event_window, frame_window)`` queues one control tick on
    both wings under the SAME sequence number; ``step()`` / ``run()``
    drive the engine and return the session's fused results in tick
    order -- each one a ``StreamResult`` with ``modality="fusion"``
    whose :class:`~repro.core.pipeline.ClosedLoopResult` carries the
    fused prediction, the combined PWM actuation, summed energy with a
    ``per_wing_energy_mj`` attribution, and both wings' full Kraken
    breakdowns.

    The wings need not finish in the same engine step (their lanes
    contend independently); the session buffers whichever wing lands
    first and emits a tick only when both halves are in. Results from
    OTHER streams sharing the engine are never swallowed: they
    accumulate on ``unclaimed`` for the caller.

    **Graceful degradation** (with ``EngineConfig.recovery`` set): a
    wing's quarantined window or dead lane surfaces as a ``failed``
    per-wing row, and the session emits a single-wing tick instead of
    stalling -- ``status="degraded"``, carrying the surviving wing's
    full result with the downed wing and its error noted in the
    breakdown. Both wings failing a tick emits a ``failed`` row. Tick
    pairing and ordering are preserved throughout (every tick emits
    exactly one row, fused, degraded, or failed, in sequence order);
    ``ticks_degraded``/``wing_failures`` count the damage and
    :meth:`wing_health` snapshots per-wing liveness for telemetry.

    ``stateful=True`` opts both wings into carried state (the event
    wing's LIF membranes chain across ticks; the frame wing's carry is
    trivially empty), and ``checkpoint()`` / ``restore`` compose the
    per-handle primitives so a whole fusion stream can migrate.
    """

    def __init__(
        self,
        engine: StreamEngine,
        *,
        session_id: Optional[Hashable] = None,
        stateful: bool = False,
        deadline: Optional[float] = None,
        fusion: Optional[Callable] = None,
        event_handle: Optional[StreamHandle] = None,
        frame_handle: Optional[StreamHandle] = None,
    ):
        self.engine = engine
        if session_id is None:
            taken = engine.handles
            n = 0
            while (f"fusion-{n}:event" in taken
                   or f"fusion-{n}:frame" in taken):
                n += 1
            session_id = f"fusion-{n}"
        self.session_id = session_id
        self.fusion = fusion or late_logit_fusion()
        # Pre-opened handles are checked BEFORE anything is opened, so a
        # rejected construction leaves no auto-opened stream behind on
        # the engine.
        for handle, want in ((event_handle, "event"),
                             (frame_handle, "frame")):
            if handle is not None and handle.modality != want:
                raise ValueError(
                    f"{want}_handle is bound to modality "
                    f"{handle.modality!r}")
        self.event = event_handle or engine.open(
            modality="event", stream_id=f"{session_id}:event",
            stateful=stateful, deadline=deadline)
        self.frame = frame_handle or engine.open(
            modality="frame", stream_id=f"{session_id}:frame",
            stateful=stateful, deadline=deadline)
        pair = getattr(engine, "pair_streams", None)
        if pair is not None:
            # Register the wings as one fusion pair so the engine's
            # co-scheduler lands both windows of a tick in the same
            # step (and the megastep, when enabled, fuses their
            # dispatch). close() unpairs via the handles.
            pair(self.event.stream_id, self.frame.stream_id)
        self._pending = {"event": {}, "frame": {}}
        self._emit_next = 0
        self.ticks_fused = 0
        self.ticks_degraded = 0
        self.ticks_failed = 0
        self.wing_failures = {"event": 0, "frame": 0}
        self.unclaimed: List[StreamResult] = []

    # -- submission ------------------------------------------------------

    def submit(self, event_window: Any, frame_window: Any, *,
               deadline: Optional[float] = None) -> int:
        """Queue one control tick: the paired event and frame windows.
        Returns the tick's sequence number (shared by both wings).

        Atomic: desynchronized wings are detected and BOTH windows are
        validated before EITHER is queued, so a rejected tick (rogue
        out-of-session submit, bad geometry, wrong duration) queues
        nothing and cannot mispair later ticks.
        """
        seq_e, seq_f = self.event.next_seq, self.frame.next_seq
        if seq_e != seq_f:
            raise RuntimeError(
                f"fusion session {self.session_id!r} desynchronized: "
                f"event wing is at seq {seq_e}, frame wing at {seq_f} "
                f"(were the wing handles submitted to outside the "
                f"session?)")
        self.event.validate(event_window)
        self.frame.validate(frame_window)
        seq = self.event.submit(event_window, deadline=deadline)
        self.frame.submit(frame_window, deadline=deadline)
        return seq

    # -- completion ------------------------------------------------------

    def absorb(self, results: List[StreamResult]) -> List[StreamResult]:
        """File this session's per-wing rows out of ``results``; returns
        the foreign rows (other streams on the shared engine)."""
        foreign = []
        for r in results:
            if r.stream_id == self.event.stream_id:
                self._pending["event"][r.seq] = r
            elif r.stream_id == self.frame.stream_id:
                self._pending["frame"][r.seq] = r
            else:
                foreign.append(r)
        return foreign

    def drain(self) -> List[StreamResult]:
        """Emit every buffered tick whose two halves have both landed,
        in tick order. ``step()``/``run()`` call this for you; call it
        directly when routing results between several sessions sharing
        one engine (``other.absorb(...)`` then ``other.drain()``).

        A tick with one failed wing emits degraded (the surviving
        wing's result, flagged); both wings failed emits a failed row.
        """
        out = []
        while (self._emit_next in self._pending["event"]
               and self._emit_next in self._pending["frame"]):
            e = self._pending["event"].pop(self._emit_next)
            f = self._pending["frame"].pop(self._emit_next)
            out.append(self._emit_tick(e, f))
            self._emit_next += 1
        return out

    def _emit_tick(self, e: StreamResult, f: StreamResult) -> StreamResult:
        seq = self._emit_next
        for wing, row in (("event", e), ("frame", f)):
            if not row.ok:
                self.wing_failures[wing] += 1
        if e.ok and f.ok:
            self.ticks_fused += 1
            return StreamResult(
                stream_id=self.session_id, seq=seq,
                result=self._fuse(e.result, f.result), modality="fusion")
        if e.ok or f.ok:
            # Single-wing degraded tick: actuation continues on the
            # surviving wing's full result, the downed wing is flagged
            # in the breakdown, and the session does not stall.
            ok_wing, ok_row = ("event", e) if e.ok else ("frame", f)
            bad_wing, bad_row = ("frame", f) if e.ok else ("event", e)
            self.ticks_degraded += 1
            degraded = dataclasses.replace(
                ok_row.result,
                breakdown={**ok_row.result.breakdown,
                           "degraded_wing": bad_wing,
                           "surviving_wing": ok_wing,
                           "wing_error": bad_row.error})
            return StreamResult(
                stream_id=self.session_id, seq=seq, result=degraded,
                modality="fusion", status="degraded",
                error=f"{bad_wing} wing failed: {bad_row.error}")
        self.ticks_failed += 1
        return StreamResult(
            stream_id=self.session_id, seq=seq, result=None,
            modality="fusion", status="failed",
            error=(f"both wings failed: event: {e.error}; "
                   f"frame: {f.error}"))

    def _fuse(self, e: ClosedLoopResult,
              f: ClosedLoopResult) -> ClosedLoopResult:
        logits = np.asarray(self.fusion(e, f))
        pwm = _fused_pwm(logits)
        return ClosedLoopResult(
            label_pred=np.argmax(logits, axis=-1),
            pwm=pwm,
            # The wings run concurrently (one jit'd call per lane per
            # step): the tick completes when the slower wing does.
            latency_ms=max(e.latency_ms, f.latency_ms),
            energy_mj=e.energy_mj + f.energy_mj,
            breakdown={
                "fusion_rule": _rule_name(self.fusion)
                or repr(self.fusion),
                "per_wing_energy_mj": {"event": e.energy_mj,
                                       "frame": f.energy_mj},
                "per_wing_latency_ms": {"event": e.latency_ms,
                                        "frame": f.latency_ms},
                "event": e.breakdown,
                "frame": f.breakdown,
            },
            realtime=e.realtime and f.realtime,
            sustained_rate_hz=min(e.sustained_rate_hz,
                                  f.sustained_rate_hz),
            logits=logits,
        )

    def step(self) -> List[StreamResult]:
        """One engine step; returns any newly complete fused ticks."""
        self.unclaimed.extend(self.absorb(self.engine.step()))
        return self.drain()

    def run(self) -> List[StreamResult]:
        """Drain the engine; returns this session's fused ticks in
        order (foreign results accumulate on ``unclaimed``)."""
        self.unclaimed.extend(self.absorb(self.engine.run()))
        return self.drain()

    # -- lifecycle -------------------------------------------------------

    @property
    def stats(self) -> dict:
        """Per-wing accounting plus the fused/degraded tick counts."""
        return {"event": self.event.stats, "frame": self.frame.stats,
                "ticks_fused": self.ticks_fused,
                "ticks_degraded": self.ticks_degraded,
                "ticks_failed": self.ticks_failed,
                "wing_failures": dict(self.wing_failures)}

    def wing_health(self) -> dict:
        """Per-wing liveness snapshot: the wing's lane's fault telemetry
        plus this session's observed wing failures. Feeds dashboards and
        the fleet control plane's unhealthy-lane scoring."""
        out = {}
        for wing, handle in (("event", self.event), ("frame", self.frame)):
            tel = self.engine.telemetry(handle.modality)
            out[wing] = {
                "dead": tel.dead,
                "retries": tel.retries,
                "quarantined": tel.quarantined,
                "fault_rate": tel.fault_rate,
                "failures_seen": self.wing_failures[wing],
            }
        return out

    def reset_state(self) -> None:
        """Gesture boundary across the whole session: zero both wings'
        carries (a no-op for wings opened stateless)."""
        for handle in (self.event, self.frame):
            if handle.stateful:
                handle.reset_state()

    def checkpoint(self) -> dict:
        """Both wings' checkpoints plus the session's pairing cursor
        (host-serializable; see :meth:`restore`). Requires both wings to
        be pairwise drained -- no half-fused ticks in the buffers."""
        if self._pending["event"] or self._pending["frame"]:
            raise ValueError(
                f"fusion session {self.session_id!r} has half-fused "
                f"ticks buffered; run()/step() until drained before "
                f"checkpointing")
        return {"session_id": self.session_id,
                "next_tick": self._emit_next,
                "fusion_rule": _rule_name(self.fusion),
                "event": self.event.checkpoint(),
                "frame": self.frame.checkpoint()}

    def checkpoint_to(self, store, ckpt_id: Optional[str] = None) -> str:
        """Capture this session into a
        :class:`~repro.fleet.store.CheckpointStore`; returns the id.

        The whole session payload (both wings + pairing cursor) crosses
        the store's pickle boundary as ONE blob, so a session can never
        be half-migrated: either both wings restore or the id stays in
        the store. Serializability is proven at put time, exactly as for
        single-stream checkpoints.
        """
        return store.put(self.checkpoint(), ckpt_id)

    @classmethod
    def restore_from(cls, engine: StreamEngine, store, ckpt_id: str, *,
                     fusion: Optional[Callable] = None) -> "FusionSession":
        """Replay a stored session checkpoint into ``engine`` and consume
        its id (single-use, like every store restore). A failed restore
        -- rule mismatch, rejected wing, duration conflict -- leaves the
        checkpoint in the store and the engine clean."""
        session = cls.restore(engine, store.get(ckpt_id), fusion=fusion)
        store.consume(ckpt_id)
        return session

    @classmethod
    def restore(cls, engine: StreamEngine, ckpt: dict, *,
                fusion: Optional[Callable] = None) -> "FusionSession":
        """Rebuild a checkpointed session on ``engine`` (typically a
        fresh process): both wing handles are restored through the
        engine-agnostic payloads and the tick cursor resumes, so fused
        results continue bitwise-identical to the uninterrupted run.
        ``fusion`` must be re-supplied when the original rule was not
        the default (rules are code, not data): the checkpoint records
        the rule's name, and a mismatch between it and the supplied (or
        default) rule raises rather than silently changing the fused
        actuation mid-migration."""
        rule = fusion or late_logit_fusion()
        recorded = ckpt.get("fusion_rule")
        supplied = _rule_name(rule)
        if recorded is not None and recorded != supplied:
            raise ValueError(
                f"checkpoint was fused with rule {recorded!r} but "
                f"restore got {supplied!r}; pass fusion= matching the "
                f"original rule (rules are code, not data)")
        # Restore the wings one at a time with cleanup: a frame-side
        # rejection must not strand the already-restored event stream
        # (with its carry and queued windows) on the target engine.
        event_handle = engine.restore(ckpt["event"])
        try:
            frame_handle = engine.restore(ckpt["frame"])
        except Exception:
            event_handle.close()
            raise
        try:
            session = cls(
                engine,
                session_id=ckpt["session_id"],
                fusion=rule,
                event_handle=event_handle,
                frame_handle=frame_handle,
            )
        except Exception:
            event_handle.close()
            frame_handle.close()
            raise
        session._emit_next = int(ckpt["next_tick"])
        return session

    def close(self) -> int:
        """Close both wing handles; returns discarded queued windows."""
        return self.event.close() + self.frame.close()
