"""Batched serving: prefill + decode loop with optional ternary weights.

The serving path is where the paper's CUTIE insight lands at scale: with
``quantize_for_serving`` the 2-D projection weights are converted to the
packed 2-bit ternary format, cutting weight HBM traffic 8x for the
memory-bound decode GEMVs (kernels/ternary_matmul.py). ``dense()`` in the
model layers dispatches on the packed format transparently.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import pack_ternary_weights
from repro.models.model import Model

__all__ = ["ServeConfig", "quantize_for_serving", "generate",
           "ServeStats"]

# Leaves eligible for ternary serving quantization: 2-D (K, N) projections
# with both dims >= this (embeddings/norms/tiny projections stay fp).
_MIN_QUANT_DIM = 256


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    greedy: bool = True
    temperature: float = 1.0


@dataclasses.dataclass
class ServeStats:
    prefill_s: float
    decode_s: float
    tokens_generated: int

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_generated / max(self.decode_s, 1e-9)


def _quantizable(path: str, leaf) -> bool:
    if not hasattr(leaf, "ndim") or leaf.ndim not in (2, 3):
        return False
    k, n = leaf.shape[-2:]     # 3-D = layer-stacked (L, K, N)
    if k < _MIN_QUANT_DIM or n < _MIN_QUANT_DIM or k % 4:
        return False
    # never quantize the embedding table (gather path, shared w/ lm head
    # when tied) or the LM head (einsum'd directly in unembed; ternary
    # logits also cost the most quality -- CUTIE likewise keeps the
    # classifier full-precision). Everything else (K, N)-shaped is a GEMM
    # weight dispatched through layers.dense().
    return "embed" not in path and "lm_head" not in path


def quantize_for_serving(params: Any) -> Tuple[Any, Dict[str, int]]:
    """Convert eligible weight matrices to {"packed","scale"} leaves.

    Returns (new params, stats {quantized, kept, bytes_before, bytes_after}).
    """
    stats = {"quantized": 0, "kept": 0, "bytes_before": 0, "bytes_after": 0}

    def walk(tree, prefix=""):
        if isinstance(tree, dict):
            if "packed" in tree:
                return tree
            return {k: walk(v, f"{prefix}/{k}") for k, v in tree.items()}
        leaf = tree
        nbytes = leaf.size * leaf.dtype.itemsize
        if _quantizable(prefix, leaf):
            fn = pack_ternary_weights
            if leaf.ndim == 3:          # layer-stacked: pack per layer
                fn = jax.vmap(pack_ternary_weights)
            packed, scale = fn(leaf.astype(jnp.float32))
            stats["quantized"] += 1
            stats["bytes_before"] += nbytes
            stats["bytes_after"] += packed.size + scale.size * 4
            return {"packed": packed, "scale": scale}
        stats["kept"] += 1
        stats["bytes_before"] += nbytes
        stats["bytes_after"] += nbytes
        return leaf

    return walk(params), stats


def generate(
    model: Model,
    params: Any,
    prompts: jnp.ndarray,            # (B, S_prompt) int32
    cfg: ServeConfig = ServeConfig(),
    *,
    cache_len: Optional[int] = None,
    rng: Optional[jax.Array] = None,
) -> Tuple[np.ndarray, ServeStats]:
    """Prefill on the prompt, then decode ``max_new_tokens`` greedily."""
    b, s_prompt = prompts.shape
    total = (cache_len or (s_prompt + cfg.max_new_tokens))

    t0 = time.perf_counter()
    cache = model.init_cache(b, total)
    # Prefill by stepping the decoder over the prompt (cache-correct for
    # every family; a fused prefill kernel is a serving optimization).
    decode = jax.jit(model.decode)
    logits = None
    for i in range(s_prompt):
        logits, cache = decode(params, cache, prompts[:, i:i + 1])
    jax.block_until_ready(logits)
    t1 = time.perf_counter()

    out = []
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    for step in range(cfg.max_new_tokens):
        out.append(np.asarray(tok))
        logits, cache = decode(params, cache, tok)
        if cfg.greedy:
            tok = jnp.argmax(logits[:, -1], axis=-1)
        else:
            rng, sub = jax.random.split(rng)
            tok = jax.random.categorical(
                sub, logits[:, -1] / cfg.temperature)
        tok = tok.astype(jnp.int32)[:, None]
    jax.block_until_ready(tok)
    t2 = time.perf_counter()

    tokens = np.concatenate(out, axis=1)
    return tokens, ServeStats(prefill_s=t1 - t0, decode_s=t2 - t1,
                              tokens_generated=int(tokens.size))
