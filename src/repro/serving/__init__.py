"""Serving substrate: batched generate loop, ternary serving quantization,
and continuous batching over heterogeneous sensor streams (the unified
event-SNN / frame-TCN closed loop behind the InferenceEngine protocol,
served through the session-handle API: StreamEngine.open -> StreamHandle,
FusionSession for cross-modal event+frame streams, StreamCheckpoint for
stream migration between engine processes)."""
from repro.serving.serve import ServeConfig, ServeStats, generate, quantize_for_serving
from repro.serving.scheduler import BatchScheduler, Request
from repro.serving.session import (FusionSession, StreamCheckpoint,
                                   late_logit_fusion)
from repro.serving.stream import (DeadLetter, DeadlinePolicy, EngineConfig,
                                  FairQuantumPolicy, LaneTelemetry,
                                  RecoveryConfig, SlotPolicy, StreamEngine,
                                  StreamHandle, StreamResult, StreamStats,
                                  StreamStatsSnapshot)
