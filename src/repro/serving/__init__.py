"""Serving substrate: batched generate loop + ternary serving quantization."""
from repro.serving.serve import ServeConfig, ServeStats, generate, quantize_for_serving
from repro.serving.scheduler import BatchScheduler, Request
