"""Serving substrate: batched generate loop, ternary serving quantization,
and continuous batching over event streams (the SNN closed loop)."""
from repro.serving.serve import ServeConfig, ServeStats, generate, quantize_for_serving
from repro.serving.scheduler import BatchScheduler, Request
from repro.serving.stream import StreamEngine, StreamResult, StreamStats
