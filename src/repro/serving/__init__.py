"""Serving substrate: batched generate loop, ternary serving quantization,
and continuous batching over heterogeneous sensor streams (the unified
event-SNN / frame-TCN closed loop behind the InferenceEngine protocol)."""
from repro.serving.serve import ServeConfig, ServeStats, generate, quantize_for_serving
from repro.serving.scheduler import BatchScheduler, Request
from repro.serving.stream import (DeadlinePolicy, FairQuantumPolicy,
                                  SlotPolicy, StreamEngine, StreamResult,
                                  StreamStats)
