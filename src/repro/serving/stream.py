"""Continuous batching over heterogeneous sensor streams.

The paper closes one loop: a single DVS camera feeding one 300 ms window
at a time into the SNE. A production deployment (many sensors / many
clients -- the ColibriUAV multi-sensor scenario, Ev-Edge's heterogeneous
event+frame workloads) must serve *many* concurrent streams across *both*
of Kraken's accelerator wings. :class:`StreamEngine` is the scheduler that
does this, and it is engine-agnostic: any
:class:`~repro.core.engine.InferenceEngine` (the event->SNN
:class:`~repro.core.pipeline.BatchedClosedLoop`, the frame->TCN
:class:`~repro.core.engine.FrameTCNEngine`, or a user-supplied engine)
plugs in unchanged.

Architecture:

  * streams declare a modality at ``submit`` (implicit when the engine
    set has exactly one); a stream is bound to its modality for life,
  * slots are partitioned per engine: each engine owns a fixed number of
    batch slots and runs ONE jit'd call per ``step()`` over its constant
    slot buffer -- a mixed event+frame step is exactly two jit'd calls,
  * per-stream FIFO window queues (``submit`` never blocks); windows
    within a stream are processed strictly in submission order, at most
    one in flight per stream per step, preserving closed-loop causality,
  * slot assignment is a pluggable :class:`SlotPolicy`:
    :class:`FairQuantumPolicy` (default) reproduces the
    fairness-quantum rotation -- a slot is pinned to a stream while it
    has queued windows and handed over when it drains, or after
    ``fair_quantum`` consecutive windows when other streams wait;
    :class:`DeadlinePolicy` adds earliest-deadline-first selection with
    aging, so urgent control loops preempt slack ones without starving
    anyone,
  * per-stream latency/energy accounting: every window gets its own
    Kraken breakdown (SNE wing: true event counts + firing rates; CUTIE
    wing: pixel counts + operand activity), bitwise identical to running
    that window alone through the single-window pipeline.

One-bin-width-per-engine contract: every window an engine serves shares
one ``duration_us`` (events are voxelized with one bin width; frames share
one tick period). Pin it with the ``duration_us`` constructor argument --
validated on every ``submit`` -- or leave it ``None`` to latch the first
submitted window's duration for the engine's lifetime. There is no reset:
construct a new engine (or pass a fresh ``engines=`` set) to change it.

Stateful streaming (``submit(..., stateful=True)``): the paper's SNN is
stateful across the control loop -- the LIF membranes integrate evidence
continuously -- yet a stateless server resets them at every window
boundary. A stream submitted with ``stateful=True`` instead carries its
engine state (the event wing: per-layer membrane planes) from window to
window: the lane keeps a slot-major state pytree next to its batch
slots, and on every dispatch each slot is fed the carry of the stream it
currently holds. State follows the STREAM, not the slot index: when the
policy moves a stream to another slot (rotation, deadline preemption) its
carry is gathered along; when a stream loses its slot its carry is
parked and re-attached on the next slot it wins. Slots are always
zeroed on admission -- a stream newly admitted into a slot previously
held by another (a "dirty" slot) starts from the cold-start state,
bitwise identical to a fresh B=1 run -- and stateless streams are fed
the zero state every window, so their results never depend on slot
history. ``reset_state(stream_id)`` zeroes a live stream's carry (the
gesture-boundary escape hatch) and ``retire(stream_id)`` drops a stream
and its state entirely. The state pytree is device-resident end to end:
in pipelined mode the carry chains dispatch-to-dispatch as jax
async-dispatch futures and never round-trips the host.

Session-handle API (the serving surface): a stream is opened, not
implied. ``StreamEngine.open(modality=..., stateful=..., deadline=...)``
returns a :class:`StreamHandle` that owns the stream's whole lifecycle:
``submit(window)`` queues work, ``reset_state()`` zeroes the carry,
``checkpoint()`` captures a host-serializable :class:`StreamCheckpoint`
(carry + queued windows + sequence position) that ``restore(ckpt)``
replays into a handle on a DIFFERENT engine process -- stream migration
-- and ``close()`` retires the stream. Modality and statefulness are
latched at ``open``; per-window metadata (deadlines) defaults to the
handle's and can be overridden per submit. The legacy id-keyed
``submit(stream_id, window, ...)`` form remains as a thin shim that
opens (or finds) the id's handle and forwards -- bitwise-identical
results -- while nudging callers to the handle API with a one-shot
``DeprecationWarning``. Cross-modal fusion (one sensor head driving BOTH
Kraken wings into a single actuation decision) binds one event handle
and one frame handle through :class:`~repro.serving.session.FusionSession`.

Fleet hooks (the control-plane surface ``repro.fleet`` drives): every
completed window feeds a sliding-horizon telemetry window on its
:class:`StreamStats` (``snapshot()`` freezes a consistent view with
derived rates -- windows/s, queue-depth p95, deadline-miss rate), and
``telemetry(modality)`` aggregates a whole lane into a
:class:`LaneTelemetry` row. Deadline-miss accounting interprets a finite
``deadline`` as an instant on the engine's ``deadline_clock`` (defaults
to ``time.perf_counter``; a fleet driver may install a shared logical
clock): a window collected after its deadline counts as missed.
``resize_lane`` changes a lane's slot count live -- kept streams stay
slotted, evicted streams rejoin the FRONT of the waiting line, carried
state is parked and re-attached, and the new batch size is pre-warmed
through the engines' per-``shape_key`` AOT caches so a resize costs one
warmed compile instead of a mid-serve stall. ``drain_lane`` collects
ONE lane's in-flight pipelined steps (other lanes stay dispatched),
which is what lets a stream checkpoint live without flushing the whole
engine.

Pipelining (``pipeline_depth >= 1``): ``step()`` dispatches each lane's
jit'd call asynchronously (no device sync on the critical path) and
returns the results of the step dispatched ``pipeline_depth`` steps ago,
so host-side window packing of step k+1 overlaps device compute of step
k. The emitted ``StreamResult`` sequence -- order and values -- is
bitwise identical to the synchronous engine; only *when* each result is
handed back (and therefore the wall-clock attribution) changes. Call
``flush()`` (or ``run()``, which drains automatically) to collect the
tail. Trade-off vs the synchronous default: windows are consumed from
their queues at dispatch, so a device-side failure surfaces at the later
collect, after the batch can no longer be retried by simply re-stepping.

Fault recovery (``EngineConfig.recovery``): with a
:class:`~repro.core._api.RecoveryConfig` attached, an engine failure is
a per-lane event, not an engine-wide crash:

  * a failed lane step is *retried* -- the synchronous two-phase
    dispatch leaves the failed lane's queues untouched, and a pipelined
    collect failure re-queues the poisoned records' windows at their
    seq positions with each stream's carry rolled back to its
    pre-window value -- after ``backoff_steps`` engine steps of lane
    cooldown (deterministic: backoff is counted in steps, not wall
    time);
  * a window failing ``max_retries`` times, or returning non-finite
    logits, is *quarantined*: moved to the lane's dead-letter queue,
    its ``StreamResult`` emitted with ``status="failed"``, the carry
    rolled back, the stream kept alive (subsequent windows chain from
    the pre-quarantine carry);
  * ``dead_after`` consecutive failed lane steps declare the lane
    *dead*: it stops calling its engine and fails queued windows fast
    (``status="failed"`` without touching the device), which keeps
    paired :class:`~repro.serving.session.FusionSession` ticks
    completing in degraded single-wing mode until
    ``replace_lane_engine`` installs a rebuilt engine (the
    :class:`~repro.fleet.supervisor.LaneSupervisor` automates rebuild +
    checkpoint-restore + replay).

Every retry/quarantine/dead transition is appended to
``StreamEngine.fault_log`` and counted on ``StreamStats`` /
:class:`LaneTelemetry`, so the fleet rebalancer scores unhealthy lanes.
With ``recovery=None`` (default) every failure path is bitwise-identical
to the pre-recovery engine: exceptions propagate, outputs are served
as-is.
"""
from __future__ import annotations

import dataclasses
import math
import time
from collections import deque
from typing import (Any, Callable, Deque, Dict, Hashable, List, Mapping,
                    Optional, Sequence, Union)

import jax
import jax.numpy as jnp
import numpy as np

from repro.core._api import (EngineConfig, RecoveryConfig,
                             suppress_api_deprecations,
                             warn_deprecated_call)
from repro.core.energy import KrakenModel
from repro.core.engine import InferenceEngine
from repro.core.pipeline import (BatchedClosedLoop, ClosedLoopResult,
                                 _check_slot_divisible, export_state_slot,
                                 import_state_slot)
from repro.core.snn import SNNConfig

__all__ = ["StreamResult", "StreamStats", "StreamStatsSnapshot",
           "LaneTelemetry", "DeadLetter", "StreamEngine", "StreamHandle",
           "SlotPolicy", "FairQuantumPolicy", "DeadlinePolicy",
           "EngineConfig", "RecoveryConfig"]

# Distinguishes "kwarg not passed" from an explicit None in the legacy
# construction shim (an explicitly-passed legacy kwarg must both warn
# and win over the EngineConfig default).
_UNSET_KW = object()


@dataclasses.dataclass
class StreamResult:
    """One served window: which stream, which window index, and the
    closed-loop outcome (prediction, PWM, latency/energy breakdown).

    ``status`` is ``"ok"`` for a normally served window. Under fault
    recovery a quarantined or dead-lane-failed window is still emitted
    -- closed-loop callers need to know the tick happened -- with
    ``status="failed"``, ``result=None`` and the failure reason in
    ``error``; :class:`~repro.serving.session.FusionSession` emits
    ``status="degraded"`` ticks when one wing failed.
    """

    stream_id: Hashable
    seq: int                      # submission-time sequence number
    result: Optional[ClosedLoopResult]
    modality: str = "event"
    status: str = "ok"            # "ok" | "failed" | "degraded"
    error: Optional[str] = None

    @property
    def ok(self) -> bool:
        return self.status == "ok"


@dataclasses.dataclass(frozen=True)
class DeadLetter:
    """One quarantined window, parked on its lane's dead-letter queue:
    enough to re-submit it by hand (the window itself, its stream and
    sequence position) plus why it was poisoned."""

    stream_id: Hashable
    seq: int
    modality: str
    item: Any
    deadline: Optional[float]
    error: str


@dataclasses.dataclass(frozen=True)
class StreamStatsSnapshot:
    """A frozen, host-side view of one stream's accounting.

    The autoscaler/rebalancer read THIS, not the live mutable counters:
    every derived rate inside is computed from one consistent point in
    time. Cumulative fields mirror :class:`StreamStats`; the
    ``horizon_*`` fields and derived rates cover only the last
    ``horizon`` completions (the sliding telemetry window), so a stream
    that was hot an hour ago but idle now scores idle.
    """

    windows: int
    queued: int
    energy_mj: float
    mean_latency_ms: float
    realtime_fraction: float
    deadline_windows: int         # completed windows that carried a deadline
    deadline_missed: int          # ... collected after their deadline
    horizon: int                  # completions the sliding fields cover (max)
    horizon_windows: int          # completions actually in the window
    horizon_deadline_windows: int
    horizon_missed: int
    windows_per_s: float          # completion rate over the sliding window
    queue_depth_p95: float        # p95 of at-completion queue depths
    deadline_miss_rate: float     # horizon_missed / horizon_deadline_windows
    retries: int = 0              # failed dispatch/collect attempts
    quarantined: int = 0          # windows moved to the dead-letter queue
    fusion_ticks: int = 0         # paired-stream ticks observed at dispatch
    fusion_ticks_paired: int = 0  # ... whose wings shared one engine step
    paired_tick_rate: float = 1.0  # paired / observed (1.0 when unpaired)


@dataclasses.dataclass
class StreamStats:
    """Per-stream accounting, accumulated as windows complete.

    Besides the cumulative counters, every completion is sampled into a
    bounded sliding window (``horizon`` most recent completions: wall
    time, queue depth left behind, deadline outcome) so
    :meth:`snapshot` can derive recent rates -- windows/s, queue-depth
    p95, deadline-miss rate -- without unbounded history.
    """

    windows: int = 0
    energy_mj: float = 0.0
    latency_ms_sum: float = 0.0
    realtime_windows: int = 0
    queued: int = 0               # still waiting in this stream's queue
    deadline_windows: int = 0     # completed windows that had a deadline
    deadline_missed: int = 0      # ... that completed past it
    retries: int = 0              # failed attempts charged to this stream
    quarantined: int = 0          # windows dead-lettered
    fusion_ticks: int = 0         # ticks of a paired (fusion) stream seen
    fusion_ticks_paired: int = 0  # ... both wings dispatched the same step
    horizon: int = 64             # sliding-window length (completions)
    samples: Deque = dataclasses.field(default_factory=deque, repr=False)

    def __post_init__(self):
        self.samples = deque(self.samples, maxlen=self.horizon)

    def note_completion(self, wall_t: float, queue_depth: int,
                        missed: Optional[bool]) -> None:
        """Record one completed window: wall-clock instant, the queue
        depth it left behind, and its deadline outcome (``None`` = the
        window carried no deadline)."""
        if missed is not None:
            self.deadline_windows += 1
            if missed:
                self.deadline_missed += 1
        self.samples.append((wall_t, queue_depth, missed))

    @property
    def mean_latency_ms(self) -> float:
        return self.latency_ms_sum / self.windows if self.windows else 0.0

    @property
    def realtime_fraction(self) -> float:
        return self.realtime_windows / self.windows if self.windows else 0.0

    @property
    def mean_power_mw(self) -> float:
        """Average power while processing (energy over busy time)."""
        return (self.energy_mj / (self.latency_ms_sum * 1e-3)
                if self.latency_ms_sum else 0.0)

    def snapshot(self) -> StreamStatsSnapshot:
        """Freeze a consistent view with derived sliding-horizon rates."""
        samples = list(self.samples)
        n = len(samples)
        span = samples[-1][0] - samples[0][0] if n >= 2 else 0.0
        wps = (n - 1) / span if span > 0.0 else 0.0
        depths = sorted(s[1] for s in samples)
        p95 = (float(depths[max(0, math.ceil(0.95 * n) - 1)])
               if depths else 0.0)
        dated = [s[2] for s in samples if s[2] is not None]
        missed = sum(1 for m in dated if m)
        return StreamStatsSnapshot(
            windows=self.windows, queued=self.queued,
            energy_mj=self.energy_mj,
            mean_latency_ms=self.mean_latency_ms,
            realtime_fraction=self.realtime_fraction,
            deadline_windows=self.deadline_windows,
            deadline_missed=self.deadline_missed,
            horizon=self.horizon, horizon_windows=n,
            horizon_deadline_windows=len(dated), horizon_missed=missed,
            windows_per_s=wps, queue_depth_p95=p95,
            deadline_miss_rate=missed / len(dated) if dated else 0.0,
            retries=self.retries, quarantined=self.quarantined,
            fusion_ticks=self.fusion_ticks,
            fusion_ticks_paired=self.fusion_ticks_paired,
            paired_tick_rate=(self.fusion_ticks_paired / self.fusion_ticks
                              if self.fusion_ticks else 1.0))


@dataclasses.dataclass(frozen=True)
class LaneTelemetry:
    """One engine lane, aggregated for the fleet control plane.

    ``backlog_per_slot`` is the autoscaler's grow signal (queued windows
    per batch slot); ``deadline_miss_rate`` pools every stream's sliding
    horizon (missed / with-deadline completions), so it reacts to recent
    pressure, not lifetime averages. ``streams`` holds the consistent
    per-stream :class:`StreamStatsSnapshot` rows the aggregate was
    computed from.
    """

    modality: str
    slots: int
    occupied: int                 # slots currently pinned to a stream
    waiting: int                  # streams in the waiting line
    queued: int                   # windows queued across the lane
    in_flight: int                # dispatched-but-uncollected windows
    windows: int                  # completed windows (cumulative)
    windows_per_s: float          # summed sliding-horizon completion rate
    deadline_miss_rate: float     # pooled over the streams' horizons
    streams: Dict[Hashable, StreamStatsSnapshot] = dataclasses.field(
        default_factory=dict)
    retries: int = 0              # cumulative failed attempts on the lane
    quarantined: int = 0          # cumulative dead-lettered windows
    dead: bool = False            # lane declared dead (fail-fast mode)
    paired_tick_rate: float = 1.0  # fusion ticks co-scheduled, pooled
                                   # over the lane's paired streams

    @property
    def fault_rate(self) -> float:
        """Retries + quarantines per completed-or-quarantined window;
        the rebalancer's unhealthiness signal."""
        denom = self.windows + self.quarantined
        return ((self.retries + self.quarantined) / denom
                if denom else 0.0)

    @property
    def backlog_per_slot(self) -> float:
        return self.queued / self.slots if self.slots else 0.0

    @property
    def occupancy(self) -> float:
        return self.occupied / self.slots if self.slots else 0.0


class _FreeSlot:
    """Sentinel for an unassigned batch slot (distinct from any stream id,
    including ``None``, which is a legal Hashable stream id)."""

    def __repr__(self):
        return "<free slot>"


_FREE = _FreeSlot()


@dataclasses.dataclass
class _Queued:
    """One queued submission: the item plus its submission-time metadata."""

    item: Any
    seq: int
    deadline: Optional[float] = None


@dataclasses.dataclass
class _InflightLane:
    """One lane's share of a dispatched (not yet collected) step.

    ``entries`` is slot-aligned: ``(stream_id, seq)`` per served slot,
    ``None`` per empty one. ``kind`` says what ``pending`` holds:
    ``"results"`` -- the finished per-slot results (synchronous mode,
    where infer completes before any queue state moves -- the retry-safe
    path); ``"handle"`` -- the engine's opaque async-dispatch handle;
    ``"batch"`` -- a prepared batch for an engine without the async
    split, inferred (synchronously) at collect time.

    Recovery bookkeeping (populated only when the engine has a
    :class:`~repro.core._api.RecoveryConfig`): ``items`` keeps the
    popped :class:`_Queued` objects slot-aligned so a failed record can
    re-queue its windows under their original sequence numbers;
    ``prev_carry`` maps each dispatched stateful stream to the device
    slice of its PRE-window carry, the value quarantine rolls back to.
    """

    lane: "EngineLane"
    key: Hashable
    entries: List[Optional[tuple]]
    kind: str
    pending: Any
    items: Optional[List[Optional["_Queued"]]] = None
    prev_carry: Optional[Dict[Hashable, Any]] = None


@dataclasses.dataclass
class EngineLane:
    """One engine's scheduling state: its slots, queues, and waiting line.

    This is the view a :class:`SlotPolicy` operates on. Slots hold stream
    ids (or the free sentinel); ``queues`` maps every stream of this
    modality to its FIFO of :class:`_Queued` entries; ``waiting`` holds
    streams without a slot, in arrival order.

    Carried-state fields (engines exposing ``init_state``):
    ``state`` is the slot-major device pytree fed to the NEXT dispatch
    row-aligned with ``slots`` at that dispatch; ``state_streams`` tracks,
    per row, which stateful stream's carry the row holds (rows of
    stateless or free slots are dead and zeroed on reuse); ``parked``
    holds the carries of stateful streams that currently have no slot;
    ``stateful`` is the set of streams that opted into carry at submit.
    Invariant: a stateful stream's carry lives in exactly one of a state
    row or ``parked`` (or nowhere, meaning cold start).
    """

    modality: str
    engine: InferenceEngine
    slots: List[Hashable]
    slot_runs: List[int]
    waiting: Deque[Hashable]
    queues: Dict[Hashable, Deque[_Queued]]
    shape_keys: set
    supports_state: bool = False
    stateful: set = dataclasses.field(default_factory=set)
    state: Any = None
    state_streams: List[Hashable] = dataclasses.field(default_factory=list)
    parked: Dict[Hashable, Any] = dataclasses.field(default_factory=dict)
    zero_state: Any = None
    # Fault-recovery state (only ever mutated when the engine carries a
    # RecoveryConfig; all-defaults otherwise).
    dead: bool = False            # fail-fast mode until engine replaced
    fail_streak: int = 0          # consecutive failed lane steps
    cooldown: int = 0             # backoff steps left before redispatch
    retries: Dict[tuple, int] = dataclasses.field(default_factory=dict)
    dead_letter: Deque = dataclasses.field(default_factory=deque)
    n_retries: int = 0            # cumulative, for telemetry
    n_quarantined: int = 0

    def pending(self) -> int:
        return sum(len(q) for q in self.queues.values())


# ----------------------------------------------------------------------
# Slot policies.
# ----------------------------------------------------------------------

class SlotPolicy:
    """Decides which streams hold an engine's batch slots each step.

    ``assign(lane)`` runs once per lane per step, before the batch is
    gathered: it frees slots (drained or rotated streams) and fills free
    slots from the waiting line. Policies must keep the invariant that a
    schedulable stream is tracked by exactly one of: a held slot or a
    waiting-line entry.

    Policies keeping per-stream bookkeeping (aging counters, histories)
    should additionally implement ``forget(stream_id)`` -- the engine
    calls it when a stream is retired, so a later stream reusing the id
    cannot inherit the old stream's bookkeeping. The hook is duck-typed
    (probed with ``getattr``), like the engines' optional extensions.
    """

    def assign(self, lane: EngineLane) -> None:
        raise NotImplementedError


class FairQuantumPolicy(SlotPolicy):
    """The default: pin-until-drained with a fairness quantum.

    A slot stays pinned to its stream while the stream has queued windows;
    it is handed to the next waiting stream the moment the stream drains,
    or after ``fair_quantum`` consecutive windows when other streams are
    waiting (the pinned stream is rotated to the back of the waiting
    line). Free slots are filled in arrival order. No stream starves
    under continuous submission.
    """

    def __init__(self, fair_quantum: int = 4):
        if fair_quantum < 1:
            raise ValueError(
                f"fair_quantum must be >= 1, got {fair_quantum}")
        self.fair_quantum = fair_quantum

    def assign(self, lane: EngineLane) -> None:
        contended = any(lane.queues[s] for s in lane.waiting)
        for i, sid in enumerate(lane.slots):
            if sid is _FREE:
                continue
            if not lane.queues[sid]:
                lane.slots[i] = _FREE
                lane.slot_runs[i] = 0
            elif contended and lane.slot_runs[i] >= self.fair_quantum:
                # Rotate: back of the waiting line, slot to the next stream.
                lane.waiting.append(sid)
                lane.slots[i] = _FREE
                lane.slot_runs[i] = 0
        self._note_round(lane)
        for i, sid in enumerate(lane.slots):
            if sid is _FREE:
                cand = self._take(lane)
                if cand is None:
                    break   # no more waiting work
                lane.slots[i] = cand
                lane.slot_runs[i] = 0

    def _note_round(self, lane: EngineLane) -> None:
        """Hook: called once per assign round, after rotation, before any
        slot is filled. Subclasses may update per-round bookkeeping."""

    def _take(self, lane: EngineLane) -> Optional[Hashable]:
        """Pop the next waiting stream with queued work (arrival order);
        drained waiting entries are discarded as encountered (they re-enter
        on their next submit)."""
        while lane.waiting:
            cand = lane.waiting.popleft()
            if lane.queues[cand]:
                return cand
        return None


class DeadlinePolicy(FairQuantumPolicy):
    """Deadline/priority-aware slot assignment (EDF + aging + wait bound).

    Streams submit windows with an optional ``deadline`` (any consistent
    unit -- e.g. control-tick index or wall milliseconds; smaller = more
    urgent; ``None`` = slack). Free slots go to the waiting stream whose
    head window has the earliest *effective* deadline:

        effective = deadline - aging * rounds_passed_over

    with ``None`` sorting after every finite deadline. Aging bounds the
    lateness of finite-deadline streams, but cannot by itself protect an
    undeadlined stream from a continuous feed of urgent work -- so the
    policy additionally enforces a hard anti-starvation bound: a live
    waiting stream passed over ``max_wait`` times is served next
    regardless of deadlines. Together with the inherited fairness quantum
    (which bounds how long a pinned stream may hold a slot while others
    wait), every live stream is guaranteed a slot within
    ``O(max_wait * fair_quantum)`` engine steps.
    """

    _NO_DEADLINE = math.inf

    def __init__(self, fair_quantum: int = 4, *, aging: float = 1.0,
                 max_wait: int = 16):
        super().__init__(fair_quantum)
        if aging < 0:
            raise ValueError(f"aging must be >= 0, got {aging}")
        if max_wait < 1:
            raise ValueError(f"max_wait must be >= 1, got {max_wait}")
        self.aging = aging
        self.max_wait = max_wait
        self._waited: Dict[Hashable, int] = {}

    def _note_round(self, lane: EngineLane) -> None:
        """Once per scheduling round: discard drained waiting entries
        (they re-enter on their next submit, exactly as the base policy
        discards them lazily) and age every live waiting stream by one
        round -- regardless of how many free slots this round fills."""
        live = [sid for sid in lane.waiting if lane.queues[sid]]
        if len(live) != len(lane.waiting):
            dropped = set(lane.waiting) - set(live)
            lane.waiting.clear()
            lane.waiting.extend(live)
            for sid in dropped:
                self._waited.pop(sid, None)
        for sid in live:
            self._waited[sid] = self._waited.get(sid, 0) + 1

    def _take(self, lane: EngineLane) -> Optional[Hashable]:
        best = None
        best_key = None
        for pos, sid in enumerate(lane.waiting):
            if not lane.queues[sid]:
                continue        # submitted mid-round; picked next round
            waited = self._waited.get(sid, 0)
            if waited >= self.max_wait:
                # Hard bound: the longest-passed-over stream goes first.
                key = (-1, -waited, pos)
            else:
                head = lane.queues[sid][0].deadline
                base = self._NO_DEADLINE if head is None else head
                key = (0, base - self.aging * waited, pos)
            if best is None or key < best_key:
                best, best_key = sid, key
        if best is None:
            return None
        lane.waiting.remove(best)
        self._waited.pop(best, None)
        return best

    def forget(self, stream_id: Hashable) -> None:
        """Drop the stream's aging counter (engine calls this on retire
        so a reused id starts with fresh aging)."""
        self._waited.pop(stream_id, None)


# ----------------------------------------------------------------------
# The session-handle serving surface.
# ----------------------------------------------------------------------

def _export_carry(engine: InferenceEngine, state, slot: int):
    """One slot's carry as a host pytree, via the engine's duck-typed
    ``export_state`` (falling back to the generic leading-axis slice
    for engines that do not implement it)."""
    export = getattr(engine, "export_state", export_state_slot)
    return export(state, slot)


def _import_carry(engine: InferenceEngine, payload):
    """An exported carry back on device, in the serving layer's parked
    (per-stream, no slot axis) form, via the engine's duck-typed
    ``import_state`` splicing into a fresh 1-slot zero state."""
    import_ = getattr(engine, "import_state", import_state_slot)
    lifted = import_(engine.init_state(1), 0, payload)
    return jax.tree_util.tree_map(lambda a: a[0], lifted)


class StreamHandle:
    """One stream's lifecycle, owned: the object ``StreamEngine.open``
    returns and the primary serving surface.

    A handle latches its stream's identity for life -- modality (which
    engine lane serves it), statefulness (whether engine state carries
    across its windows), and a default ``deadline`` for deadline-aware
    slot policies. Everything a caller does to a stream goes through its
    handle:

      * ``submit(window[, deadline=...])`` -- queue one window; returns
        the per-stream sequence number later reported by
        ``StreamResult.seq``. Never blocks.
      * ``reset_state()`` -- zero the carried state (gesture boundary);
        the next dispatched window starts cold.
      * ``checkpoint()`` -- capture the stream as a host-serializable
        :class:`~repro.serving.session.StreamCheckpoint`: the carried
        state (exported through the engine's duck-typed
        ``export_state``), any still-queued windows, and the sequence
        position. Requires no windows in flight (``flush()`` first).
      * ``restore(ckpt)`` -- replay a checkpoint into THIS handle (which
        must be fresh): the carry is imported and parked until the
        stream wins a slot, queued windows are re-queued under their
        original sequence numbers, and numbering resumes -- results
        after migration are bitwise identical to the uninterrupted run.
      * ``close()`` -- retire the stream: queue, slot, waiting entry and
        carry are dropped (idempotent; returns discarded window count).

    Handles do not collect results -- ``step()``/``run()``/``flush()``
    on the engine remain the completion surface, emitting
    :class:`StreamResult` rows for every open stream.
    """

    def __init__(self, engine: "StreamEngine", lane: EngineLane,
                 stream_id: Hashable, stateful: bool,
                 deadline: Optional[float]):
        self._engine = engine
        self._lane = lane
        self.stream_id = stream_id
        self.stateful = bool(stateful)
        self.deadline = deadline
        self.closed = False

    def __repr__(self):
        state = "closed" if self.closed else "open"
        return (f"<StreamHandle {self.stream_id!r} {self._lane.modality} "
                f"stateful={self.stateful} {state}>")

    @property
    def modality(self) -> str:
        return self._lane.modality

    @property
    def engine(self) -> "StreamEngine":
        """The owning engine (the completion surface for this stream's
        results, and the lane-level control surface the fleet drives)."""
        return self._engine

    @property
    def stats(self) -> StreamStats:
        """This stream's accumulated accounting."""
        return self._engine.stream_stats[self.stream_id]

    @property
    def queued(self) -> int:
        """Windows still waiting in this stream's queue."""
        return 0 if self.closed else len(self._lane.queues[self.stream_id])

    @property
    def next_seq(self) -> int:
        """The sequence number the next ``submit`` will return."""
        self._check_open()
        return self._engine._seq[self.stream_id]

    def _check_open(self) -> None:
        if self.closed:
            raise ValueError(
                f"handle for stream {self.stream_id!r} is closed")

    def _check_not_inflight(self, verb: str) -> None:
        for step_recs in self._engine._inflight:
            for rec in step_recs:
                for entry in rec.entries:
                    if entry is not None and entry[0] == self.stream_id:
                        raise ValueError(
                            f"stream {self.stream_id!r} has in-flight "
                            f"windows; flush() before {verb}")

    # -- submission ------------------------------------------------------

    def validate(self, window: Any) -> None:
        """Check ``window`` against this stream's engine without queueing
        it (raises exactly what ``submit`` would). Lets a caller
        coordinating multiple handles (e.g. a FusionSession tick)
        validate every window BEFORE queueing any, keeping the group
        submit atomic."""
        self._check_open()
        self._lane.engine.validate(window)

    def submit(self, window: Any, *,
               deadline: Optional[float] = None) -> int:
        """Queue one window; returns its per-stream sequence number.

        ``deadline`` overrides the handle's default for this window
        (consumed by deadline-aware policies; smaller = more urgent).
        The window is validated by the engine BEFORE any queue state
        moves, so a rejected submit burns no sequence number.
        """
        self._check_open()
        lane, sid, eng = self._lane, self.stream_id, self._engine
        lane.engine.validate(window)
        seq = eng._seq[sid]
        eng._seq[sid] = seq + 1
        lane.queues[sid].append(_Queued(
            window, seq, self.deadline if deadline is None else deadline))
        # A stream is schedulable via exactly one of: a held slot or a
        # waiting-line entry (covers streams that drained and come back).
        if sid not in lane.slots and sid not in lane.waiting:
            lane.waiting.append(sid)
        eng.stream_stats[sid].queued += 1
        return seq

    # -- carried state ---------------------------------------------------

    def reset_state(self) -> None:
        """Zero the carried state without retiring the stream -- the
        gesture-boundary escape hatch. Applies from the next dispatch;
        windows already in flight keep the old carry."""
        self._check_open()
        lane, sid = self._lane, self.stream_id
        if not self.stateful:
            raise ValueError(f"stream {sid!r} is not stateful")
        lane.parked.pop(sid, None)
        for j, owner in enumerate(lane.state_streams):
            if owner is not _FREE and owner == sid:
                lane.state_streams[j] = _FREE

    def checkpoint(self):
        """Capture this stream for migration: carried state (host
        numpy), still-queued windows, and the sequence position, as a
        :class:`~repro.serving.session.StreamCheckpoint`.

        The engine keeps serving the stream afterwards -- a checkpoint
        is a copy, not a detach. Raises while windows are in flight
        (their state commits have not landed yet; ``flush()`` first).
        """
        self._check_open()
        self._check_not_inflight("checkpointing")
        from repro.serving.session import StreamCheckpoint
        lane, sid = self._lane, self.stream_id
        payload = None
        if self.stateful:
            row = next((j for j, owner in enumerate(lane.state_streams)
                        if owner is not _FREE and owner == sid), None)
            if row is not None:
                payload = _export_carry(lane.engine, lane.state, row)
            elif sid in lane.parked:
                lifted = jax.tree_util.tree_map(lambda a: a[None],
                                                lane.parked[sid])
                payload = _export_carry(lane.engine, lifted, 0)
            # else: cold start -- a None payload restores to zero state.
        return StreamCheckpoint(
            stream_id=sid, modality=lane.modality, stateful=self.stateful,
            next_seq=self._engine._seq[sid],
            duration_us=lane.engine.duration_us, state=payload,
            deadline=self.deadline,
            queued=tuple((q.item, q.seq, q.deadline)
                         for q in lane.queues[sid]))

    def restore(self, ckpt) -> "StreamHandle":
        """Replay ``ckpt`` into this handle; returns the handle.

        The handle must be fresh (nothing submitted, no carry) and match
        the checkpoint's modality and statefulness; the lane's engine
        must agree on ``duration_us`` (an unlatched engine latches the
        checkpoint's). Remaining windows then continue bitwise-identical
        to the uninterrupted run on the original engine.
        """
        self._check_open()
        lane, sid, eng = self._lane, self.stream_id, self._engine
        if (eng._seq[sid] != 0 or lane.queues[sid] or sid in lane.parked
                or any(o is not _FREE and o == sid
                       for o in lane.state_streams)):
            raise ValueError(
                f"restore needs a fresh handle; stream {sid!r} already "
                f"has submitted windows or a carry")
        if ckpt.modality != lane.modality:
            raise ValueError(
                f"checkpoint is {ckpt.modality!r}, handle is bound to "
                f"{lane.modality!r}")
        if bool(ckpt.stateful) != self.stateful:
            raise ValueError(
                f"checkpoint stateful={ckpt.stateful} != handle "
                f"stateful={self.stateful}; open the handle to match")
        # Re-queued windows get the same validate-before-any-state-moves
        # treatment as submit(): an engine that cannot serve them (e.g.
        # different frame geometry) rejects the restore here, not later
        # mid-dispatch. Validation may latch an unlatched engine's
        # duration; roll that back too if anything rejects, so a failed
        # restore leaves the engine exactly as it found it.
        prev_duration = lane.engine.duration_us
        try:
            if ckpt.duration_us is not None:
                if lane.engine.duration_us is None:
                    lane.engine.duration_us = ckpt.duration_us
                elif lane.engine.duration_us != ckpt.duration_us:
                    raise ValueError(
                        f"checkpoint duration_us={ckpt.duration_us} != "
                        f"engine duration_us={lane.engine.duration_us}")
            for item, _seq, _deadline in ckpt.queued:
                lane.engine.validate(item)
        except Exception:
            lane.engine.duration_us = prev_duration
            raise
        if ckpt.state is not None:
            lane.parked[sid] = _import_carry(lane.engine, ckpt.state)
        eng._seq[sid] = int(ckpt.next_seq)
        if self.deadline is None:
            self.deadline = ckpt.deadline
        for item, seq, deadline in ckpt.queued:
            lane.queues[sid].append(_Queued(item, seq, deadline))
            eng.stream_stats[sid].queued += 1
        if lane.queues[sid] and sid not in lane.slots \
                and sid not in lane.waiting:
            lane.waiting.append(sid)
        return self

    # -- retirement ------------------------------------------------------

    def close(self) -> int:
        """Retire the stream entirely: queue, slot, waiting entry, and
        carried state. Returns the number of queued windows discarded
        (idempotent: closing a closed handle returns 0).

        The slot it held is freed with its buffers dead: the next stream
        admitted there starts from the zero state. Closing with windows
        in flight (pipelined) discards exactly this stream's in-flight
        records -- their results are never emitted and count toward the
        returned discard total -- while lane-mates sharing the
        dispatched steps stay in flight untouched.
        ``stream_stats`` keeps the history until the id is reused; a
        later ``open`` with the same id is a brand-new stream (fresh seq
        numbering, fresh state).
        """
        if self.closed:
            return 0
        lane, sid, eng = self._lane, self.stream_id, self._engine
        # Scrub this stream out of any dispatched-but-uncollected step:
        # the slot's device compute still runs, but its result slot is
        # orphaned (skipped at collect). Lane-mates are untouched.
        dropped = 0
        for step_recs in self._engine._inflight:
            for rec in step_recs:
                if rec.lane is not lane:
                    continue
                for i, entry in enumerate(rec.entries):
                    if entry is not None and entry[0] == sid:
                        rec.entries[i] = None
                        if rec.items is not None:
                            rec.items[i] = None
                        dropped += 1
        queued_dropped = len(lane.queues.pop(sid))
        dropped += queued_dropped
        if sid in lane.waiting:
            lane.waiting.remove(sid)
        for i, owner in enumerate(lane.slots):
            if owner is not _FREE and owner == sid:
                lane.slots[i] = _FREE
                lane.slot_runs[i] = 0
        for j, owner in enumerate(lane.state_streams):
            if owner is not _FREE and owner == sid:
                lane.state_streams[j] = _FREE
        lane.parked.pop(sid, None)
        lane.stateful.discard(sid)
        for key in [k for k in lane.retries if k[0] == sid]:
            del lane.retries[key]
        eng.unpair_streams(sid)
        del eng._stream_lane[sid]
        eng._seq.pop(sid, None)
        eng._handles.pop(sid, None)
        # In-flight scrubs were already uncounted from the queued stat
        # at dispatch; only the still-queued windows adjust it here.
        eng.stream_stats[sid].queued -= queued_dropped
        # Policies with per-stream bookkeeping (e.g. DeadlinePolicy's
        # aging counters) drop it via the duck-typed forget hook, so a
        # reused id cannot inherit the retired stream's state.
        forget = getattr(eng.policy, "forget", None)
        if forget is not None:
            forget(sid)
        self.closed = True
        return dropped


# ----------------------------------------------------------------------
# The engine-agnostic streaming scheduler.
# ----------------------------------------------------------------------

class StreamEngine:
    """Continuous batching of sensor windows over per-engine batch slots.

    The serving surface is the session-handle API:
    ``open(modality=..., stateful=..., deadline=...)`` returns a
    :class:`StreamHandle` owning one stream's lifecycle (``submit`` /
    ``reset_state`` / ``checkpoint`` / ``restore`` / ``close``);
    ``step()`` / ``run()`` / ``flush()`` emit completed
    :class:`StreamResult` rows across all open streams. The legacy
    id-keyed ``submit(stream_id, ...)`` form is a thin shim over
    handles -- bitwise-identical scheduling and results -- kept for
    pre-session callers (it warns once per engine).

    Construction is unified behind :class:`~repro.core._api.
    EngineConfig` -- everything that shapes the engine (slots, policy,
    pipelining, kernel fusion, the device mesh) is one frozen value:

      * ``StreamEngine(params, cfg, EngineConfig(max_streams=8))`` --
        builds one :class:`~repro.core.pipeline.BatchedClosedLoop`
        internally; a bare ``StreamEngine(params, cfg)`` uses the
        default config,
      * ``StreamEngine(engines=[event_engine, frame_engine],
        config=...)`` -- heterogeneous form: any set of
        :class:`~repro.core.engine.InferenceEngine` objects, one lane
        (slot partition + jit'd call per step) per engine, keyed by each
        engine's declared ``modality``.

    The pre-config kwarg spellings (``max_streams=``, ``policy=``,
    ``pipeline_depth=``, ...) still work as a shim that builds the same
    ``EngineConfig`` internally -- bitwise-identical engines -- and
    announces the migration once per engine. ``config=`` and legacy
    kwargs are mutually exclusive.

    ``max_streams`` is the slot count per engine (or a
    ``{modality: count}`` mapping). ``duration_us`` pins the
    one-bin-width-per-engine contract up front (validated on every
    submit); ``None`` latches each engine's first submitted duration.

    ``config.mesh`` shards every lane's slot axis across the mesh's
    data axis: one collective-free jit'd step per lane spanning all
    devices, bitwise-identical to the single-device engine (see
    ``repro.distributed.make_mesh``). Slot gathers, parking, and
    reassignment stay host-side row splices exactly as on one device --
    the resharding ``device_put`` inside each engine's dispatch is the
    only cross-device movement. Every lane's slot count must divide by
    the mesh's slot-axis size; caller-provided engines are attached via
    their ``attach_mesh`` (an engine already pinned to a different mesh
    is rejected).
    """

    def __init__(
        self,
        params=None,
        cfg: Optional[SNNConfig] = None,
        config: Optional[EngineConfig] = None,
        *,
        engines: Union[None, InferenceEngine,
                       Sequence[InferenceEngine],
                       Mapping[str, InferenceEngine]] = None,
        model: Optional[KrakenModel] = None,
        lif_scan_fn: Optional[Callable] = None,
        max_streams=_UNSET_KW,
        fair_quantum=_UNSET_KW,
        policy=_UNSET_KW,
        duration_us=_UNSET_KW,
        window_ms=_UNSET_KW,
        fuse_fc=_UNSET_KW,
        pipeline_depth=_UNSET_KW,
    ):
        legacy = {k: v for k, v in dict(
            max_streams=max_streams, fair_quantum=fair_quantum,
            policy=policy, duration_us=duration_us, window_ms=window_ms,
            fuse_fc=fuse_fc, pipeline_depth=pipeline_depth,
        ).items() if v is not _UNSET_KW}
        if config is not None:
            if not isinstance(config, EngineConfig):
                raise TypeError(
                    f"config must be an EngineConfig, got "
                    f"{type(config).__name__}")
            if legacy:
                raise ValueError(
                    f"config= and legacy construction kwargs are "
                    f"mutually exclusive (got both config= and "
                    f"{sorted(legacy)}); fold the kwargs into the "
                    f"EngineConfig")
        else:
            if legacy:
                warn_deprecated_call(
                    self, "kwargs-construction",
                    "StreamEngine construction kwargs (max_streams=, "
                    "policy=, pipeline_depth=, ...) are a legacy "
                    "spelling; pass one EngineConfig instead: "
                    "StreamEngine(params, cfg, EngineConfig(...)) / "
                    "StreamEngine(engines=..., config=EngineConfig(...))")
            config = EngineConfig(**legacy)
        self.config = config
        self.mesh = config.mesh
        self.pipeline_depth = config.pipeline_depth
        self.recovery: Optional[RecoveryConfig] = config.recovery
        # Chronological record of every fault-recovery transition:
        # {"step", "kind": "retry"|"quarantine"|"lane_dead"|"requeue"|
        #  "lane_replaced", "modality", "stream", "seq", "error"}.
        # Feeds the chaos-soak assertions and the bench recovery metric.
        self.fault_log: List[dict] = []
        # Failed StreamResults produced during dispatch (sync retry
        # exhaustion, dead-lane fail-fast); drained into step() output.
        self._pending_failures: List[StreamResult] = []
        self._inflight: Deque[List[_InflightLane]] = deque()
        if engines is None:
            if params is None or cfg is None:
                raise ValueError("give (params, cfg) or engines=")
            engines = [BatchedClosedLoop.from_config(
                params, cfg, config, model=model, lif_scan_fn=lif_scan_fn)]
        else:
            if params is not None or cfg is not None:
                raise ValueError("(params, cfg) and engines= are "
                                 "mutually exclusive")
            if config.fuse_fc:
                raise ValueError(
                    "fuse_fc configures the internally-built event "
                    "engine; with engines= pass "
                    "BatchedClosedLoop(..., fuse_fc=True) yourself")
            if isinstance(engines, Mapping):
                engines = list(engines.values())
            elif not isinstance(engines, Sequence):
                engines = [engines]
            for e in engines:
                if config.duration_us is not None:
                    if e.duration_us is None:
                        e.duration_us = config.duration_us
                    elif e.duration_us != config.duration_us:
                        raise ValueError(
                            f"engine '{e.modality}' duration "
                            f"{e.duration_us} != duration_us="
                            f"{config.duration_us}")
                if config.mesh is not None:
                    # Thread the serving mesh onto caller-provided
                    # engines; attach_mesh is idempotent for the same
                    # mesh and rejects a conflicting one.
                    attach = getattr(e, "attach_mesh", None)
                    if attach is None:
                        raise ValueError(
                            f"engine '{e.modality}' has no attach_mesh; "
                            f"a sharded StreamEngine needs every lane "
                            f"engine to support slot-axis sharding")
                    attach(config.mesh)

        self.policy = config.policy or FairQuantumPolicy(
            4 if config.fair_quantum is None else config.fair_quantum)
        self._lanes: Dict[str, EngineLane] = {}
        if not engines:
            raise ValueError("engines= must name at least one engine")
        max_streams = config.max_streams
        modalities = {e.modality for e in engines}
        if isinstance(max_streams, Mapping):
            unknown = set(max_streams) - modalities
            if unknown:
                raise ValueError(
                    f"max_streams keys {sorted(unknown)} match no engine "
                    f"modality (have {sorted(modalities)})")
        for e in engines:
            if e.modality in self._lanes:
                raise ValueError(
                    f"duplicate engine modality {e.modality!r}")
            slots = (max_streams.get(e.modality, 8)
                     if isinstance(max_streams, Mapping) else max_streams)
            if slots < 1:
                raise ValueError(f"max_streams must be >= 1, got {slots}")
            if config.mesh is not None:
                _check_slot_divisible(slots, config.mesh,
                                      f"lane '{e.modality}'")
            self._lanes[e.modality] = EngineLane(
                modality=e.modality, engine=e,
                slots=[_FREE] * slots, slot_runs=[0] * slots,
                waiting=deque(), queues={}, shape_keys=set(),
                supports_state=hasattr(e, "init_state"),
                state_streams=[_FREE] * slots)

        # Fusion-aware co-scheduling: ``_pairs`` is the bidirectional
        # stream-pairing registry (pair_streams/unpair_streams; a
        # FusionSession pairs its wings automatically); with
        # ``coschedule`` on, _dispatch fixes slot assignments up so
        # paired streams share an engine step. ``_pair_dispatch`` holds
        # the step number a paired window was dispatched at until its
        # partner's same-seq window dispatches (the paired_tick_rate
        # bookkeeping).
        self.coschedule = bool(config.coschedule)
        self._pairs: Dict[Hashable, Hashable] = {}
        self._pair_dispatch: Dict[tuple, int] = {}
        self._dispatch_no = 0
        # The fused cross-wing megastep: one jit'd dispatch serving both
        # wings' kernels, cached per (event shape key, frame shape key).
        self.megastep = bool(config.megastep)
        self._mega_exe: Dict[tuple, Callable] = {}
        if self.megastep:
            if sorted(self._lanes) != ["event", "frame"]:
                raise ValueError(
                    f"EngineConfig.megastep needs exactly one event and "
                    f"one frame lane; this engine has "
                    f"{sorted(self._lanes)}")
            for lane in self._lanes.values():
                if not hasattr(lane.engine, "_mega_parts"):
                    raise ValueError(
                        f"engine for modality {lane.modality!r} "
                        f"({type(lane.engine).__name__}) does not "
                        f"support the fused megastep")
        self._stream_lane: Dict[Hashable, str] = {}
        self._seq: Dict[Hashable, int] = {}
        self._handles: Dict[Hashable, StreamHandle] = {}
        self._auto_id = 0
        self.stream_stats: Dict[Hashable, StreamStats] = {}
        self.stats: Dict[str, float] = {
            "steps": 0, "windows": 0, "wall_s": 0.0,
        }
        # The clock finite deadlines are measured against for miss
        # telemetry (NOT for scheduling -- policies only order by
        # deadline value). Defaults to wall time; fleet drivers and
        # tests install a shared logical clock for determinism.
        self.deadline_clock: Callable[[], float] = time.perf_counter

    # -- introspection ---------------------------------------------------

    @property
    def engines(self) -> Dict[str, InferenceEngine]:
        """Engines by modality."""
        return {m: lane.engine for m, lane in self._lanes.items()}

    @property
    def loop(self) -> InferenceEngine:
        """Backwards-compatible alias: the single engine (event-only
        construction). Raises if the engine set is heterogeneous."""
        if len(self._lanes) != 1:
            raise AttributeError(
                "StreamEngine.loop is ambiguous with multiple engines; "
                "use .engines[modality]")
        return next(iter(self._lanes.values())).engine

    def modality_of(self, stream_id: Hashable) -> str:
        return self._stream_lane[stream_id]

    def compiled_shapes(self, modality: Optional[str] = None) -> set:
        """Distinct jit shape keys an engine has been stepped with."""
        if modality is None:
            if len(self._lanes) != 1:
                raise ValueError(
                    "modality required with multiple engines; have "
                    f"{sorted(self._lanes)}")
            modality = next(iter(self._lanes))
        if modality not in self._lanes:
            raise ValueError(f"no engine for modality {modality!r}; "
                             f"have {sorted(self._lanes)}")
        return set(self._lanes[modality].shape_keys)

    def warmup(self, shape_keys, modality: Optional[str] = None) -> None:
        """Precompile an engine's executables for the given shape keys.

        ``shape_keys`` is an iterable of the engine's ``shape_key``
        tuples -- for the event wing ``(batch_size, max_events,
        duration_us)``, where ``batch_size`` is normally this lane's slot
        count and ``max_events`` a power-of-two event bucket (see
        ``events.next_pow2``). Run it before the first ``submit`` so the
        first window of a new event-count bucket stops paying jit compile
        time mid-stream. ``modality`` selects the engine (optional when
        only one is configured).
        """
        if modality is None:
            if len(self._lanes) != 1:
                raise ValueError(
                    "modality required with multiple engines; have "
                    f"{sorted(self._lanes)}")
            modality = next(iter(self._lanes))
        if modality not in self._lanes:
            raise ValueError(f"no engine for modality {modality!r}; "
                             f"have {sorted(self._lanes)}")
        engine = self._lanes[modality].engine
        warm = getattr(engine, "warmup", None)
        if warm is None:
            raise ValueError(
                f"engine for modality {modality!r} "
                f"({type(engine).__name__}) does not implement warmup()")
        warm(shape_keys)

    def warmup_megastep(self, key_pairs) -> None:
        """Precompile fused megastep executables.

        ``key_pairs`` is an iterable of ``(event_shape_key,
        frame_shape_key)`` pairs -- each wing's full shape-key tuple
        (``(batch, max_events, duration_us)`` / ``(batch, height,
        width, duration_us)``). The megastep keeps its own AOT cache,
        separate from the per-engine caches, so warm it explicitly
        before serving a fused workload.
        """
        if not self.megastep:
            raise ValueError(
                "warmup_megastep on an engine without "
                "EngineConfig.megastep=True")
        ev_lane, fr_lane = self._lanes["event"], self._lanes["frame"]
        for ev_key, fr_key in key_pairs:
            self._mega_executable(ev_lane, fr_lane, tuple(ev_key),
                                  tuple(fr_key))

    def compiled_megastep_keys(self) -> set:
        """``(event_key, frame_key)`` pairs with a compiled fused
        executable (stepped or warmed)."""
        return set(self._mega_exe)

    # -- fusion pairing ---------------------------------------------------

    def pair_streams(self, a: Hashable, b: Hashable) -> None:
        """Declare two open streams (on different lanes) as the wings of
        one fusion tick: with ``coschedule`` on, the scheduler pulls
        both into the SAME engine step whenever either wins a slot, and
        the pair's same-step fraction is surfaced as
        ``paired_tick_rate`` in stream/lane telemetry.
        :class:`~repro.serving.session.FusionSession` registers its
        wings automatically; call this directly only for hand-rolled
        pairings. Idempotent for the same pair; re-pairing a stream to a
        different partner requires :meth:`unpair_streams` first."""
        for sid in (a, b):
            if sid not in self._stream_lane:
                raise KeyError(f"unknown stream {sid!r}")
        if self._stream_lane[a] == self._stream_lane[b]:
            raise ValueError(
                f"paired streams must live on different lanes; both "
                f"{a!r} and {b!r} are {self._stream_lane[a]!r}")
        if self._pairs.get(a) == b:
            return
        for sid in (a, b):
            if sid in self._pairs:
                raise ValueError(
                    f"stream {sid!r} is already paired with "
                    f"{self._pairs[sid]!r}; unpair_streams() first")
        self._pairs[a] = b
        self._pairs[b] = a

    def unpair_streams(self, stream_id: Hashable) -> None:
        """Dissolve a stream's pairing (no-op for unpaired streams);
        called automatically when either wing closes."""
        partner = self._pairs.pop(stream_id, None)
        if partner is not None:
            self._pairs.pop(partner, None)
        for key in [k for k in self._pair_dispatch
                    if k[0] == stream_id or k[0] == partner]:
            del self._pair_dispatch[key]

    # -- fleet control-plane hooks ---------------------------------------

    def _lane_named(self, modality: Optional[str]) -> EngineLane:
        """Resolve a lane by modality (optional when only one lane)."""
        if modality is None:
            if len(self._lanes) != 1:
                raise ValueError(
                    "modality required with multiple engines; have "
                    f"{sorted(self._lanes)}")
            return next(iter(self._lanes.values()))
        if modality not in self._lanes:
            raise ValueError(f"no engine for modality {modality!r}; "
                             f"have {sorted(self._lanes)}")
        return self._lanes[modality]

    def telemetry(self, modality: Optional[str] = None) -> LaneTelemetry:
        """A consistent control-plane view of one lane: aggregate queue
        depth, in-flight count, pooled sliding-horizon deadline-miss
        rate and completion rate, plus every stream's frozen
        :class:`StreamStatsSnapshot` (the rows the aggregate was
        computed from)."""
        lane = self._lane_named(modality)
        snaps = {sid: self.stream_stats[sid].snapshot()
                 for sid in lane.queues}
        in_flight = sum(
            1
            for step_recs in self._inflight
            for rec in step_recs if rec.lane is lane
            for entry in rec.entries if entry is not None)
        h_dated = sum(s.horizon_deadline_windows for s in snaps.values())
        h_missed = sum(s.horizon_missed for s in snaps.values())
        f_ticks = sum(s.fusion_ticks for s in snaps.values())
        f_paired = sum(s.fusion_ticks_paired for s in snaps.values())
        return LaneTelemetry(
            modality=lane.modality,
            slots=len(lane.slots),
            occupied=sum(1 for s in lane.slots if s is not _FREE),
            waiting=len(lane.waiting),
            queued=lane.pending(),
            in_flight=in_flight,
            windows=sum(s.windows for s in snaps.values()),
            windows_per_s=sum(s.windows_per_s for s in snaps.values()),
            deadline_miss_rate=h_missed / h_dated if h_dated else 0.0,
            streams=snaps,
            retries=lane.n_retries,
            quarantined=lane.n_quarantined,
            dead=lane.dead,
            paired_tick_rate=f_paired / f_ticks if f_ticks else 1.0)

    def dead_letters(self, modality: Optional[str] = None
                     ) -> List[DeadLetter]:
        """The lane's quarantined windows, oldest first (a copy; the
        queue itself is engine-owned)."""
        return list(self._lane_named(modality).dead_letter)

    def resize_lane(self, modality: Optional[str] = None, *,
                    slots: int, warm: bool = True) -> List[Hashable]:
        """Change one lane's batch-slot count live; returns the streams
        evicted from their slots (shrink only; they rejoin the FRONT of
        the waiting line in slot order, keeping their scheduling
        priority over never-slotted arrivals).

        Safe at any point between steps, including with pipelined
        windows in flight (collection is positional into the dispatched
        batch, so already-dispatched steps are untouched). Carried
        state survives: every live carry is parked and re-attached on
        the stream's next dispatch, so a stateful stream's windows stay
        bitwise-identical to an uninterrupted scan across the resize.
        Policy bookkeeping (e.g. ``DeadlinePolicy`` aging counters) is
        deliberately NOT touched: waiting streams keep their aging,
        evicted streams start aging from the front of the line.

        ``warm=True`` (default) amortizes the recompile: for every shape
        key the engine has already compiled at the OLD slot count, the
        corresponding new-slot-count key is precompiled through the
        engine's per-``shape_key`` AOT warmup cache, so the first step
        after the resize runs a warmed executable instead of stalling on
        a mid-serve compile. On a mesh-attached engine the new count
        must still divide over the mesh slot axis.
        """
        lane = self._lane_named(modality)
        if slots < 1:
            raise ValueError(f"slots must be >= 1, got {slots}")
        if self.mesh is not None:
            _check_slot_divisible(slots, self.mesh,
                                  f"lane '{lane.modality}' resize")
        old = len(lane.slots)
        if slots == old:
            return []
        # Park every live carry: the state buffer is shaped by the slot
        # count, so it is rebuilt (lazily, from parked + zero rows) at
        # the next stateful dispatch. Parking slices whatever the rows
        # hold -- including pipelined async-dispatch futures.
        if lane.state is not None:
            for j, owner in enumerate(lane.state_streams):
                if owner is not _FREE and owner in lane.stateful:
                    lane.parked[owner] = jax.tree_util.tree_map(
                        lambda a, j=j: a[j], lane.state)
            lane.state = None
            lane.zero_state = None
        lane.state_streams = [_FREE] * slots
        evicted: List[Hashable] = []
        if slots > old:
            lane.slots.extend([_FREE] * (slots - old))
            lane.slot_runs.extend([0] * (slots - old))
        else:
            held = [(sid, run) for sid, run in
                    zip(lane.slots, lane.slot_runs) if sid is not _FREE]
            kept, dropped = held[:slots], held[slots:]
            lane.slots = ([sid for sid, _ in kept]
                          + [_FREE] * (slots - len(kept)))
            lane.slot_runs = ([run for _, run in kept]
                              + [0] * (slots - len(kept)))
            evicted = [sid for sid, _ in dropped]
            # Front of the waiting line, slot order preserved: an
            # evicted stream was being served and must not requeue
            # behind streams that never had a slot.
            lane.waiting.extendleft(reversed(evicted))
        if warm:
            warmer = getattr(lane.engine, "warmup", None)
            compiled = getattr(lane.engine, "compiled_shape_keys", None)
            if warmer is not None:
                have = (set(compiled()) if compiled is not None
                        else set(lane.shape_keys))
                # Engine shape keys lead with the batch size (both
                # wings' contract): re-key every old-count key at the
                # new count and precompile the ones not already cached.
                want = {(slots,) + tuple(k[1:])
                        for k in have if k and k[0] == old}
                fresh = sorted(want - have)
                if fresh:
                    warmer(fresh)
        return evicted

    def drain_lane(self, modality: Optional[str] = None
                   ) -> List[StreamResult]:
        """Collect every in-flight pipelined step of ONE lane (oldest
        first), leaving other lanes' dispatched work in flight.

        This is the live-migration primitive: checkpointing a stream
        requires its lane's pending results on the host, but flushing
        the WHOLE engine would stall every other lane's pipeline. Steps
        that still hold other lanes' records stay queued (in order);
        steps left empty are dropped.

        Exception-safe: a collect failure (engine raise without
        recovery configured) leaves the in-flight deque consistent --
        already-collected records removed, everything else (this lane's
        uncollected records and every other lane's) still in flight, in
        dispatch order.
        """
        lane = self._lane_named(modality)
        out: List[StreamResult] = []
        done: Deque[List[_InflightLane]] = deque()
        try:
            while self._inflight:
                step_recs = self._inflight[0]
                # Collect this lane's records one at a time, removing
                # each from the step as it lands, so an exception
                # leaves exactly the uncollected suffix in place.
                i = 0
                while i < len(step_recs):
                    rec = step_recs[i]
                    if rec.lane is lane:
                        out.extend(self._collect_one(rec))
                        step_recs.pop(i)
                    else:
                        i += 1
                self._inflight.popleft()
                if step_recs:
                    done.append(step_recs)
        finally:
            # Steps that still hold other lanes' records go back in
            # front of whatever was not reached, preserving dispatch
            # order whether we finished or an exception unwound us.
            self._inflight.extendleft(reversed(done))
        return out

    def abort_lane(self, modality: Optional[str] = None) -> int:
        """Drop one lane's in-flight records WITHOUT collecting them
        (the lane's engine is presumed broken -- collecting would block
        on, or re-raise from, poisoned device work) and re-queue their
        windows at their sequence positions; returns the re-queued
        count. Other lanes' dispatched steps stay in flight.

        The lane's carried state is dropped wholesale -- it lived on
        the dead engine. Unsupervised stateful streams restart cold;
        supervised ones are restored from their last checkpoint by the
        :class:`~repro.fleet.supervisor.LaneSupervisor`, which is the
        caller this hook exists for (followed by
        ``replace_lane_engine``).
        """
        lane = self._lane_named(modality)
        requeue: List[tuple] = []
        remaining: Deque[List[_InflightLane]] = deque()
        while self._inflight:
            step_recs = self._inflight.popleft()
            rest = [r for r in step_recs if r.lane is not lane]
            for rec in step_recs:
                if rec.lane is not lane:
                    continue
                for i, entry in enumerate(rec.entries):
                    if entry is None:
                        continue
                    if rec.items is not None and rec.items[i] is not None:
                        requeue.append((entry[0], rec.items[i]))
            if rest:
                remaining.append(rest)
        self._inflight = remaining
        lane.state = None
        lane.zero_state = None
        lane.state_streams = [_FREE] * len(lane.slots)
        lane.parked.clear()
        self._requeue(lane, requeue)
        return len(requeue)

    def replace_lane_engine(self, modality: Optional[str] = None, *,
                            engine: InferenceEngine) -> None:
        """Swap one lane's engine for a rebuilt instance, clearing the
        lane's fault state (dead flag, fail streak, cooldown, retry
        counters -- the dead-letter queue is kept: it is history, not
        state). Streams, queues, slots, and policy bookkeeping survive;
        carried state does NOT (it lived on the old engine) -- restore
        stateful streams from checkpoints afterwards.

        The lane must have no windows in flight (``abort_lane`` or
        ``drain_lane`` first). The replacement must serve the same
        modality, agree on the latched ``duration_us`` (an unlatched
        replacement inherits it), support carried state if any open
        stream on the lane is stateful, and accept the engine's mesh
        when one is attached.
        """
        lane = self._lane_named(modality)
        for step_recs in self._inflight:
            for rec in step_recs:
                if rec.lane is lane and any(
                        e is not None for e in rec.entries):
                    raise ValueError(
                        f"lane {lane.modality!r} has in-flight windows; "
                        f"abort_lane() or drain_lane() before replacing "
                        f"its engine")
        if engine.modality != lane.modality:
            raise ValueError(
                f"replacement engine serves modality "
                f"{engine.modality!r}, lane is {lane.modality!r}")
        if lane.stateful and not hasattr(engine, "init_state"):
            raise ValueError(
                f"lane {lane.modality!r} has stateful streams but the "
                f"replacement engine has no carried-state support")
        if lane.engine.duration_us is not None:
            if engine.duration_us is None:
                engine.duration_us = lane.engine.duration_us
            elif engine.duration_us != lane.engine.duration_us:
                raise ValueError(
                    f"replacement duration_us={engine.duration_us} != "
                    f"lane duration_us={lane.engine.duration_us}")
        if self.mesh is not None:
            attach = getattr(engine, "attach_mesh", None)
            if attach is None:
                raise ValueError(
                    f"replacement engine for lane {lane.modality!r} has "
                    f"no attach_mesh; this engine is sharded")
            attach(self.mesh)
        if self.megastep:
            if not hasattr(engine, "_mega_parts"):
                raise ValueError(
                    f"replacement engine for lane {lane.modality!r} "
                    f"({type(engine).__name__}) does not support the "
                    f"fused megastep this engine is configured for")
            # Fused executables were lowered against the old engine's
            # abstract parameter shapes; drop them so the rebuild's
            # first fused step re-lowers against the replacement.
            self._mega_exe.clear()
        lane.engine = engine
        lane.supports_state = hasattr(engine, "init_state")
        lane.shape_keys = set()
        lane.state = None
        lane.zero_state = None
        lane.state_streams = [_FREE] * len(lane.slots)
        lane.parked.clear()
        lane.dead = False
        lane.fail_streak = 0
        lane.cooldown = 0
        lane.retries.clear()
        self._log_fault("lane_replaced", lane, None, None, None)

    # -- the session-handle API ------------------------------------------

    def open(self, modality: Optional[str] = None, *,
             stream_id: Optional[Hashable] = None,
             stateful: bool = False,
             deadline: Optional[float] = None) -> StreamHandle:
        """Open a new stream and return its :class:`StreamHandle`.

        ``modality`` selects the engine lane (optional when only one is
        configured). ``stateful=True`` opts the stream into carried
        state: its engine state (the event wing: LIF membranes) chains
        across its windows, following the stream through any slot
        reassignment, until ``reset_state`` or ``close``. ``deadline``
        is the handle's default per-window deadline for deadline-aware
        policies. Modality and statefulness are latched for the
        stream's life. ``stream_id`` names the stream (auto-generated
        ``"<modality>-<n>"`` when omitted); opening an id that is
        already open raises -- close it first, or keep the old handle.
        """
        if modality is None:
            if len(self._lanes) != 1:
                raise ValueError(
                    f"modality required to open a stream with engines "
                    f"{sorted(self._lanes)}")
            lane = next(iter(self._lanes.values()))
        elif modality not in self._lanes:
            raise ValueError(f"no engine for modality {modality!r}; "
                             f"have {sorted(self._lanes)}")
        else:
            lane = self._lanes[modality]
        if stateful and not lane.supports_state:
            raise ValueError(
                f"engine for modality {lane.modality!r} "
                f"({type(lane.engine).__name__}) has no carried-state "
                f"support (no init_state); submit stateless")
        if stream_id is None:
            while True:
                stream_id = f"{lane.modality}-{self._auto_id}"
                self._auto_id += 1
                if stream_id not in self._stream_lane:
                    break
        elif stream_id in self._stream_lane:
            raise ValueError(
                f"stream {stream_id!r} is already open (bound to "
                f"modality {self._stream_lane[stream_id]!r}); close() it "
                f"before reopening the id")
        lane.queues[stream_id] = deque()
        self._stream_lane[stream_id] = lane.modality
        self._seq[stream_id] = 0
        self.stream_stats[stream_id] = StreamStats()
        if stateful:
            lane.stateful.add(stream_id)
        handle = StreamHandle(self, lane, stream_id, stateful, deadline)
        self._handles[stream_id] = handle
        return handle

    def restore(self, ckpt, *,
                stream_id: Optional[Hashable] = None) -> StreamHandle:
        """Open a stream from a :class:`~repro.serving.session.
        StreamCheckpoint` -- ``open`` + ``StreamHandle.restore`` in one
        call. The stream keeps the checkpoint's id (unless ``stream_id``
        renames it) and its default deadline."""
        handle = self.open(modality=ckpt.modality,
                           stream_id=ckpt.stream_id
                           if stream_id is None else stream_id,
                           stateful=ckpt.stateful,
                           deadline=ckpt.deadline)
        try:
            return handle.restore(ckpt)
        except Exception:
            handle.close()
            raise

    @property
    def handles(self) -> Dict[Hashable, StreamHandle]:
        """Open handles by stream id (a copy; close via the handle)."""
        return dict(self._handles)

    # -- submission (legacy id-keyed shim) -------------------------------

    def submit(self, stream_id: Hashable, window: Any, *,
               modality: Optional[str] = None,
               deadline: Optional[float] = None,
               stateful: Optional[bool] = None) -> int:
        """Queue one window on an id-keyed stream (LEGACY shim).

        The pre-session call form: the first submit of a new id opens a
        handle under the hood, later submits forward to it --
        scheduling and results are bitwise identical to driving the
        handle directly. Prefer ``open(...)`` + ``handle.submit(...)``;
        this form warns once per engine.

        ``modality`` selects the engine for a NEW stream (optional when
        only one engine is configured); known streams are bound to their
        lane. ``deadline`` is scheduling metadata consumed by
        deadline-aware policies (smaller = more urgent). ``stateful=True``
        opts a NEW stream into carried state. Like modality, statefulness
        is latched for the stream's life (default False; pass ``None``
        to leave a known stream's binding alone).
        """
        warn_deprecated_call(
            self, "id-keyed-submit",
            "StreamEngine.submit(stream_id, window, ...) is a legacy "
            "call form; use the session-handle API instead: handle = "
            "engine.open(modality=..., stateful=...); handle.submit("
            "window)")
        lane = self._resolve_lane(stream_id, modality)
        # Validation happens BEFORE any queue/seq state changes, so a
        # rejected submit neither burns a sequence number nor corrupts
        # scheduling state.
        if stateful and not lane.supports_state:
            raise ValueError(
                f"engine for modality {lane.modality!r} "
                f"({type(lane.engine).__name__}) has no carried-state "
                f"support (no init_state); submit stateless")
        handle = self._handles.get(stream_id)
        if (handle is not None and stateful is not None
                and bool(stateful) != handle.stateful):
            raise ValueError(
                f"stream {stream_id!r} is bound to stateful="
                f"{handle.stateful}; statefulness is latched "
                f"at the stream's first submit")
        if handle is None:
            # Validate BEFORE open so a rejected first submit registers
            # no stream at all (no handle, no stats entry) -- the price
            # is one redundant validate inside handle.submit (validate
            # is idempotent once the engine's duration is latched).
            lane.engine.validate(window)
            handle = self.open(modality=lane.modality,
                               stream_id=stream_id,
                               stateful=bool(stateful))
        return handle.submit(window, deadline=deadline)

    def _resolve_lane(self, stream_id: Hashable,
                      modality: Optional[str]) -> EngineLane:
        bound = self._stream_lane.get(stream_id)
        if bound is not None:
            if modality is not None and modality != bound:
                raise ValueError(
                    f"stream {stream_id!r} is bound to modality "
                    f"{bound!r}, got {modality!r}")
            return self._lanes[bound]
        if modality is None:
            if len(self._lanes) == 1:
                return next(iter(self._lanes.values()))
            raise ValueError(
                f"modality required for new stream {stream_id!r} with "
                f"engines {sorted(self._lanes)}")
        if modality not in self._lanes:
            raise ValueError(f"no engine for modality {modality!r}; "
                             f"have {sorted(self._lanes)}")
        return self._lanes[modality]

    def pending(self) -> int:
        """Windows queued across all streams and engines."""
        return sum(lane.pending() for lane in self._lanes.values())

    # -- carried state ---------------------------------------------------

    def stateful_of(self, stream_id: Hashable) -> bool:
        """Whether a known stream carries state across its windows."""
        return self._handle_of(stream_id).stateful

    def _handle_of(self, stream_id: Hashable) -> StreamHandle:
        handle = self._handles.get(stream_id)
        if handle is None:
            raise KeyError(f"unknown stream {stream_id!r}")
        return handle

    def handle(self, stream_id: Hashable) -> StreamHandle:
        """The open :class:`StreamHandle` of a known stream id (the
        lookup a fleet rebalancer uses to pick a migration victim from
        telemetry rows). Raises ``KeyError`` for unknown ids."""
        return self._handle_of(stream_id)

    def has_stream(self, stream_id: Hashable) -> bool:
        """Whether ``stream_id`` is currently open on this engine."""
        return stream_id in self._handles

    def reset_state(self, stream_id: Hashable) -> None:
        """Zero a stateful stream's carried state without retiring it;
        forwards to :meth:`StreamHandle.reset_state`."""
        self._handle_of(stream_id).reset_state()

    def retire(self, stream_id: Hashable) -> int:
        """Remove a stream entirely; forwards to
        :meth:`StreamHandle.close` (see there for semantics). Returns
        the number of queued windows discarded."""
        return self._handle_of(stream_id).close()

    def _lane_state_in(self, lane: EngineLane):
        """Phase-1 state planning for one lane's dispatch.

        Returns ``(state_in, commit)``: the slot-major state pytree to
        dispatch with (``None`` for engines without state support) and a
        ``commit(new_state)`` thunk that advances the lane's state
        tracking -- called only after EVERY lane's phase 1 succeeded, so
        a failed synchronous step leaves carried state as untouched as it
        leaves the queues.
        """
        if not lane.supports_state or not lane.stateful:
            # No stream on this lane carries state: serve it through the
            # legacy stateless call forms. Engines start from their own
            # zero state internally (bitwise identical), the lane pays
            # nothing per step, and a split-less engine keeps the
            # pipelined deferred-"batch" fallback it would lose on the
            # stateful path.
            return None, None
        if lane.state is None:       # first stateful dispatch: zero state
            lane.zero_state = lane.engine.init_state(len(lane.slots))
            lane.state = lane.zero_state

        slots = list(lane.slots)
        pos = {owner: j for j, owner in enumerate(lane.state_streams)
               if owner is not _FREE}
        # Per slot: ("row", j) = carry already in the buffer at row j;
        # ("parked", sid) = carry parked off-buffer; None = zero row
        # (free slot, stateless stream, or cold-start stateful stream).
        src: List[Any] = []
        for sid in slots:
            if sid is _FREE or sid not in lane.stateful:
                src.append(None)
            elif sid in pos:
                src.append(("row", pos[sid]))
            elif sid in lane.parked:
                src.append(("parked", sid))
            else:
                src.append(None)
        # Fast path: every occupied slot is a stateful stream whose carry
        # already sits in its own row. Free slots' rows are dead (their
        # results are discarded), so they never force a rebuild.
        identity = all(sid is _FREE or s == ("row", i)
                       for i, (sid, s) in enumerate(zip(slots, src)))
        if identity:
            state_in = lane.state
        else:
            leaves, treedef = jax.tree_util.tree_flatten(lane.state)
            zeros = jax.tree_util.tree_flatten(lane.zero_state)[0]
            parked = {s[1]: jax.tree_util.tree_flatten(lane.parked[s[1]])[0]
                      for s in src if s is not None and s[0] == "parked"}
            new_leaves = []
            for li, leaf in enumerate(leaves):
                rows = []
                for s in src:
                    if s is None:
                        rows.append(zeros[li][0])
                    elif s[0] == "row":
                        rows.append(leaf[s[1]])
                    else:
                        rows.append(parked[s[1]][li])
                new_leaves.append(jnp.stack(rows))
            state_in = jax.tree_util.tree_unflatten(treedef, new_leaves)

        old_state = lane.state
        old_owners = list(lane.state_streams)
        scheduled = {sid for sid in slots if sid is not _FREE}

        def commit(new_state):
            for j, owner in enumerate(old_owners):
                if owner is _FREE or owner in scheduled:
                    continue
                # The stream lost its slot this step: park its carry
                # (from the PRE-dispatch buffer) so it can follow the
                # stream to whichever slot it wins next.
                lane.parked[owner] = jax.tree_util.tree_map(
                    lambda a: a[j], old_state)
            for sid in scheduled:
                lane.parked.pop(sid, None)
            lane.state = new_state
            lane.state_streams = [
                sid if (sid is not _FREE and sid in lane.stateful)
                else _FREE
                for sid in slots]

        return state_in, commit

    # -- scheduling ------------------------------------------------------

    def step(self) -> List[StreamResult]:
        """Serve one batch per engine with queued work: the head window of
        every slotted stream, one jit'd call per engine.

        Synchronous mode (``pipeline_depth == 0``, the default): returns
        this step's completed windows, and is retry-safe across the whole
        heterogeneous step -- queues are only peeked until EVERY engine's
        infer has returned, so if any engine raises (transient device
        error, OOM) no window is consumed, no stat moves, and the step can
        simply be retried.

        Pipelined mode (``pipeline_depth >= 1``): dispatches this step's
        jit'd calls without blocking on the device and returns the results
        of the step dispatched ``pipeline_depth`` steps ago (empty lists
        while the pipeline fills; ``flush()``/``run()`` drain the tail).
        The result sequence is bitwise identical to synchronous mode;
        windows are consumed at dispatch, so device failures surface at
        the later collect instead of at this call.
        """
        t0 = time.perf_counter()
        if self.pipeline_depth == 0:
            ran = self._dispatch(eager=True)
            failed = self._take_failures()
            if not ran and not failed:
                return []
            out = failed + self._collect(ran)
        else:
            ran = self._dispatch(eager=False)
            if ran:
                self._inflight.append(ran)
            out = self._take_failures()
            while len(self._inflight) > self.pipeline_depth:
                out.extend(self._collect_step(self._inflight[0]))
                self._inflight.popleft()
            if not ran and self._inflight:
                # No new work: drain one in-flight step so a caller
                # looping on step() always makes progress.
                out.extend(self._collect_step(self._inflight[0]))
                self._inflight.popleft()
            if not ran and not out:
                return []
        # A no-op call (nothing dispatched, nothing collected) does not
        # count as a step; a failed one raises before reaching here.
        self.stats["steps"] += 1
        self.stats["wall_s"] += time.perf_counter() - t0
        return out

    def _dispatch(self, *, eager: bool) -> List[_InflightLane]:
        """Assign slots and launch every lane's jit'd call.

        Phase 1 assigns every servable lane's slots (then, with fusion
        pairs registered, runs the co-scheduling fixup so paired wings
        share this step). Phase 2 peeks the queue heads and, per lane,
        either runs infer to completion (``eager``, the synchronous
        retry-safe mode: an exception from ANY lane leaves every queue
        untouched), dispatches asynchronously (pipelined, engine has the
        async split), or just prepares the batch (pipelined fallback) --
        with ``megastep``, both wings instead go through ONE fused jit'd
        call. Phase 3 commits the pops, slot run counts, and
        carried-state tracking only after every lane's dispatch
        succeeded.
        """
        self._dispatch_no += 1
        active: List[EngineLane] = []
        for lane in self._lanes.values():
            if self.recovery is not None:
                if lane.dead:
                    # Fail-fast: a dead lane never calls its engine;
                    # queued windows are dead-lettered immediately so
                    # paired fusion ticks keep completing (degraded)
                    # until replace_lane_engine installs a rebuild.
                    self._fail_fast_lane(lane)
                    continue
                if lane.cooldown > 0:
                    # Deterministic backoff: sit out whole engine steps
                    # (not wall time) after a failed lane step.
                    lane.cooldown -= 1
                    continue
            self.policy.assign(lane)
            active.append(lane)
        if self._pairs and self.coschedule:
            self._coschedule(active)
        work: List[tuple] = []
        for lane in active:
            heads = [
                lane.queues[sid][0].item if sid is not _FREE else None
                for sid in lane.slots
            ]
            if any(w is not None for w in heads):
                work.append((lane, heads))
        ran: List[_InflightLane] = []
        state_commits: List[tuple] = []
        if self.megastep and len(work) == 2:
            # Both wings have work this step: one fused jit'd dispatch
            # serves the whole step (megastep requires exactly the
            # event+frame lanes, so len(work)==2 identifies them). A
            # single-winged step falls through to the per-lane path
            # below -- that is the degraded case, and it keeps the
            # ordinary dispatch semantics.
            try:
                recs, commits = self._mega_dispatch(work, eager)
            except Exception:
                if self.recovery is None:
                    raise
                # The fused call serves both wings, so a fault in
                # either aborts it with every queue and carry untouched
                # (state planning commits only on success). Fall back
                # to per-lane dispatch for this very step: the failure
                # localizes to the wing that actually faulted and
                # ordinary recovery (retry/cooldown/quarantine) applies
                # to it alone, exactly as without the megastep.
                recs = None
            if recs is not None:
                ran.extend(recs)
                state_commits.extend(commits)
                work = []
        for lane, heads in work:
            try:
                rec, commit = self._dispatch_lane(lane, heads, eager)
            except Exception as exc:
                if self.recovery is None:
                    raise
                # Queues are untouched (heads were only peeked):
                # charge a retry to every window in the attempted
                # batch, put the lane on cooldown, and keep serving
                # other lanes.
                self._note_lane_failure(lane, heads, exc)
                continue
            ran.append(rec)
            if commit is not None:
                state_commits.append(commit)
        # Commit: every lane dispatched -- pop the served heads and
        # advance each lane's carried state.
        for commit, new_state in state_commits:
            commit(new_state)
        for rec in ran:
            lane = rec.lane
            rec.items = [None] * len(rec.entries)
            for i, slot in enumerate(rec.entries):
                if slot is None:
                    continue
                sid = lane.slots[slot]
                entry = lane.queues[sid].popleft()
                lane.slot_runs[slot] += 1
                self.stream_stats[sid].queued -= 1
                rec.entries[i] = (sid, entry.seq, entry.deadline)
                rec.items[i] = entry
                if self._pairs:
                    self._note_pair_dispatch(sid, entry.seq)
        return ran

    def _dispatch_lane(self, lane: EngineLane, heads: List,
                       eager: bool) -> tuple:
        """One lane's dispatch (phase 2 of :meth:`_dispatch`): returns
        ``(record, state_commit_or_None)``; raises with the lane's
        queues untouched."""
        batch = lane.engine.prepare(heads, batch_size=len(lane.slots))
        key = lane.engine.shape_key(batch)
        state_in, state_commit = self._lane_state_in(lane)
        dispatch = getattr(lane.engine, "infer_dispatch", None)
        collect = getattr(lane.engine, "infer_collect", None)
        has_split = dispatch is not None and collect is not None
        new_state = None
        if eager or (state_in is not None and not has_split):
            # Synchronous infer. A stateful engine WITHOUT the async
            # split also lands here under pipelining: its carry must
            # advance in dispatch order, so its infer cannot wait for
            # the (later) collect.
            if state_in is None:
                # Stateless lanes ride the engines' legacy call form by
                # design; the deprecation nudge is for end users.
                with suppress_api_deprecations():
                    results = lane.engine.infer(batch)
                kind, pending = "results", results
            else:
                results, new_state = lane.engine.infer(batch, state_in)
                kind, pending = "results", results
        elif has_split:
            if state_in is None:
                kind, pending = "handle", dispatch(batch)
            else:
                # Async dispatch: new_state is a pytree of device
                # futures, threaded into the NEXT dispatch without ever
                # blocking on (or copying to) the host.
                pending, new_state = dispatch(batch, state_in)
                kind = "handle"
        else:
            kind, pending = "batch", batch
        rec = _InflightLane(
            lane=lane, key=key,
            entries=[None if w is None else slot
                     for slot, w in enumerate(heads)],
            kind=kind, pending=pending,
            prev_carry=self._prev_carry(lane, heads, state_in))
        commit = ((state_commit, new_state)
                  if state_commit is not None else None)
        return rec, commit

    def _prev_carry(self, lane: EngineLane, heads: List, state_in):
        """The rollback target quarantine restores: each dispatched
        stateful stream's pre-window carry, as a lazy device slice of
        the state that was fed in (recovery only)."""
        if self.recovery is None or state_in is None:
            return None
        prev = {}
        for slot, sid in enumerate(lane.slots):
            if (sid is not _FREE and sid in lane.stateful
                    and heads[slot] is not None):
                prev[sid] = jax.tree_util.tree_map(
                    lambda a, s=slot: a[s], state_in)
        return prev

    # -- fusion co-scheduling and the fused megastep ---------------------

    def _coschedule(self, lanes: List[EngineLane]) -> None:
        """Fusion-aware fixup after policy assignment: for every paired
        stream holding a slot with queued work, pull its partner into
        the partner's lane for this SAME step -- into a free slot when
        one exists, else by evicting a seated stream that is not itself
        half of a co-scheduled pair (the evictee rejoins the FRONT of
        its waiting line, keeping its priority over never-seated
        arrivals). Dead, cooling, or drained partner lanes are left
        alone: a surviving wing is never blocked on a wing that cannot
        run. Scheduling-only -- per-window results are bitwise
        unchanged; only WHICH step serves a window moves."""
        by_mod = {lane.modality: lane for lane in lanes}
        for lane in lanes:
            for sid in lane.slots:
                if sid is _FREE or not lane.queues.get(sid):
                    continue
                partner = self._pairs.get(sid)
                if partner is None:
                    continue
                plane = by_mod.get(self._stream_lane.get(partner))
                if (plane is None or partner in plane.slots
                        or not plane.queues.get(partner)):
                    continue
                self._seat_partner(plane, partner)

    def _seat_partner(self, lane: EngineLane, sid: Hashable) -> bool:
        """Seat ``sid`` in ``lane`` for this step (co-scheduling only);
        returns whether a slot was won."""
        free = next((i for i, cur in enumerate(lane.slots)
                     if cur is _FREE), None)
        if free is None:
            # Evict: the first victim whose own pairing does not tie it
            # to this step (unpaired, or its partner is not seated).
            for i, cur in enumerate(lane.slots):
                p = self._pairs.get(cur)
                if p is None:
                    free = i
                    break
                plane = self._lanes.get(self._stream_lane.get(p, ""))
                if plane is None or p not in plane.slots:
                    free = i
                    break
            if free is None:
                return False
            evicted = lane.slots[free]
            lane.slot_runs[free] = 0
            if lane.queues.get(evicted):
                # Front of the line: the evictee was seated and must
                # not requeue behind streams that never had a slot
                # (the resize_lane eviction rule).
                lane.waiting.appendleft(evicted)
        lane.slots[free] = sid
        lane.slot_runs[free] = 0
        try:
            lane.waiting.remove(sid)
        except ValueError:
            pass
        # Mirror the policies' take-side bookkeeping: a seated stream's
        # aging restarts exactly as if the policy had taken it.
        forget = getattr(self.policy, "forget", None)
        if forget is not None:
            forget(sid)
        return True

    def _note_pair_dispatch(self, sid: Hashable, seq: int) -> None:
        """Pair bookkeeping at dispatch commit: when both wings of a
        paired tick have dispatched, credit a fusion tick to both
        streams' stats (paired when the wings shared one engine step).
        """
        partner = self._pairs.get(sid)
        if partner is None:
            return
        other_step = self._pair_dispatch.pop((partner, seq), None)
        if other_step is None:
            self._pair_dispatch[(sid, seq)] = self._dispatch_no
            return
        paired = int(other_step == self._dispatch_no)
        for s in (sid, partner):
            st = self.stream_stats.get(s)
            if st is not None:
                st.fusion_ticks += 1
                st.fusion_ticks_paired += paired

    def _mega_executable(self, ev_lane: EngineLane, fr_lane: EngineLane,
                         ev_key, fr_key) -> Callable:
        """AOT-compile (once) the fused two-wing executable for a pair
        of per-wing shape keys. The program is the wings' OWN run
        functions lowered side by side -- XLA schedules the SNN scan and
        the ternary conv stack in one compiled call -- so each wing's
        half stays bitwise-identical to that wing's separate executable.
        """
        cache_key = (ev_key, fr_key)
        exe = self._mega_exe.get(cache_key)
        if exe is None:
            ev_run, ev_abs = ev_lane.engine._mega_parts(ev_key)
            fr_run, fr_abs = fr_lane.engine._mega_parts(fr_key)

            def mega(ev_args, fr_args):
                return ev_run(*ev_args), fr_run(*fr_args)

            exe = jax.jit(mega).lower(ev_abs, fr_abs).compile()
            self._mega_exe[cache_key] = exe
        return exe

    def _mega_dispatch(self, work: List[tuple], eager: bool) -> tuple:
        """Both wings' dispatch through one fused jit'd call; returns
        ``(records, state_commits)`` shaped exactly as two ordinary
        per-lane dispatches, so collection, recovery, quarantine, and
        pipelining downstream are unchanged. Raises with every queue
        untouched (the caller charges the failure to both lanes)."""
        by_mod = {lane.modality: (lane, heads) for lane, heads in work}
        ev_lane, ev_heads = by_mod["event"]
        fr_lane, fr_heads = by_mod["frame"]
        ev_batch = ev_lane.engine.prepare(
            ev_heads, batch_size=len(ev_lane.slots))
        ev_key = ev_lane.engine.shape_key(ev_batch)
        fr_batch = fr_lane.engine.prepare(
            fr_heads, batch_size=len(fr_lane.slots))
        fr_key = fr_lane.engine.shape_key(fr_batch)
        ev_state, ev_commit = self._lane_state_in(ev_lane)
        fr_state, fr_commit = self._lane_state_in(fr_lane)
        exe = self._mega_executable(ev_lane, fr_lane, ev_key, fr_key)
        ev_out, fr_out = exe(
            ev_lane.engine._mega_args(ev_batch, ev_state),
            fr_lane.engine._mega_args(fr_batch, fr_state))
        ev_pending, ev_new = ev_lane.engine._mega_split(
            ev_out, ev_batch, ev_state)
        fr_pending, fr_new = fr_lane.engine._mega_split(
            fr_out, fr_batch, fr_state)
        if eager:
            # Synchronous mode stays retry-safe: materialize BOTH
            # wings' results before any queue state moves.
            ev_kind, ev_pending = "results", ev_lane.engine.infer_collect(
                ev_pending)
            fr_kind, fr_pending = "results", fr_lane.engine.infer_collect(
                fr_pending)
        else:
            ev_kind = fr_kind = "handle"
        recs: List[_InflightLane] = []
        commits: List[tuple] = []
        for lane, heads, key, kind, pending, state_in, commit, new in (
                (ev_lane, ev_heads, ev_key, ev_kind, ev_pending,
                 ev_state, ev_commit, ev_new),
                (fr_lane, fr_heads, fr_key, fr_kind, fr_pending,
                 fr_state, fr_commit, fr_new)):
            recs.append(_InflightLane(
                lane=lane, key=key,
                entries=[None if w is None else slot
                         for slot, w in enumerate(heads)],
                kind=kind, pending=pending,
                prev_carry=self._prev_carry(lane, heads, state_in)))
            if commit is not None:
                commits.append((commit, new))
        # Records in lane declaration order, exactly as the per-lane
        # path emits them, so result ordering is bitwise unchanged.
        order = {m: i for i, m in enumerate(self._lanes)}
        recs.sort(key=lambda r: order[r.lane.modality])
        return recs, commits

    def _collect(self, ran: List[_InflightLane]) -> List[StreamResult]:
        """Block on a dispatched step's device results and emit them."""
        out: List[StreamResult] = []
        for rec in ran:
            out.extend(self._collect_one(rec))
        return out

    def _collect_step(self, step_recs: List[_InflightLane]
                      ) -> List[StreamResult]:
        """Collect one in-flight step's records, removing each from the
        (still-enqueued) step list as it lands -- so an exception from
        an engine without recovery configured leaves exactly the
        uncollected suffix in flight instead of desynchronizing the
        shared deque (pop-or-restore)."""
        out: List[StreamResult] = []
        while step_recs:
            out.extend(self._collect_one(step_recs[0]))
            step_recs.pop(0)
        return out

    def _collect_one(self, rec: _InflightLane) -> List[StreamResult]:
        """Collect one lane's record of one dispatched step."""
        lane = rec.lane
        try:
            if rec.kind == "results":
                results = rec.pending
            elif rec.kind == "handle":
                results = lane.engine.infer_collect(rec.pending)
            else:
                with suppress_api_deprecations():
                    results = lane.engine.infer(rec.pending)
        except Exception as exc:
            if self.recovery is None:
                raise
            return self._recover_record(rec, exc)
        lane.shape_keys.add(rec.key)
        lane.fail_streak = 0
        out: List[StreamResult] = []
        wall_t = time.perf_counter()
        rcfg = self.recovery
        for slot, entry in enumerate(rec.entries):
            if entry is None:
                continue
            sid, seq, deadline = entry
            res = results[slot]
            if (rcfg is not None and rcfg.quarantine_nonfinite
                    and res.logits is not None
                    and not np.all(np.isfinite(np.asarray(res.logits)))):
                # Poison: NaNs are deterministic, a retry would just
                # recompute them -- quarantine immediately, roll the
                # carry back, keep the stream alive.
                out.append(self._quarantine_entry(
                    rec, slot, "non-finite logits"))
                continue
            lane.retries.pop((sid, seq), None)
            st = self.stream_stats[sid]
            st.windows += 1
            st.energy_mj += res.energy_mj
            st.latency_ms_sum += res.latency_ms
            st.realtime_windows += int(res.realtime)
            # Deadline-miss telemetry: a finite deadline is an
            # instant on the engine's deadline_clock; collecting the
            # window after that instant is a miss. Feeds the sliding
            # per-stream horizon the fleet control plane reads.
            missed = (None if deadline is None
                      else self.deadline_clock() > deadline)
            st.note_completion(wall_t, st.queued, missed)
            out.append(StreamResult(
                stream_id=sid, seq=seq, result=res,
                modality=lane.modality))
            self.stats["windows"] += 1
        return out

    # -- fault recovery --------------------------------------------------

    def _log_fault(self, kind: str, lane: EngineLane,
                   sid: Optional[Hashable], seq: Optional[int],
                   error: Optional[str]) -> None:
        self.fault_log.append({
            "step": int(self.stats["steps"]), "kind": kind,
            "modality": lane.modality, "stream": sid, "seq": seq,
            "error": error})

    def _take_failures(self) -> List[StreamResult]:
        out, self._pending_failures = self._pending_failures, []
        return out

    def _rollback_carry(self, rec: _InflightLane,
                        sid: Hashable) -> None:
        """Restore a stream's carry to its pre-window value (captured
        at this record's dispatch) and orphan any state rows it owns."""
        lane = rec.lane
        if rec.prev_carry is None or sid not in rec.prev_carry:
            return
        lane.parked[sid] = rec.prev_carry[sid]
        for j, owner in enumerate(lane.state_streams):
            if owner is not _FREE and owner == sid:
                lane.state_streams[j] = _FREE

    def _scrub_stream_inflight(self, lane: EngineLane, sid: Hashable,
                               skip: Optional[_InflightLane] = None
                               ) -> List[tuple]:
        """Remove a stream's windows from the lane's still-in-flight
        records (their device results chained on a rolled-back carry
        and must not be served); returns ``(sid, _Queued)`` rows to
        re-queue."""
        requeue: List[tuple] = []
        for step_recs in self._inflight:
            for r in step_recs:
                if r is skip or r.lane is not lane:
                    continue
                for i, entry in enumerate(r.entries):
                    if entry is not None and entry[0] == sid:
                        r.entries[i] = None
                        if r.items is not None and r.items[i] is not None:
                            requeue.append((sid, r.items[i]))
                            r.items[i] = None
        return requeue

    def _requeue(self, lane: EngineLane, entries: List[tuple]) -> None:
        """Put failed windows back on their streams' queues at their
        sequence positions (stable merge by seq -- re-queued windows
        precede later submissions, and re-queues from successive failed
        records interleave correctly)."""
        by_sid: Dict[Hashable, List[_Queued]] = {}
        for sid, q in entries:
            by_sid.setdefault(sid, []).append(q)
        for sid, qs in by_sid.items():
            if sid not in lane.queues:
                continue             # stream closed while in flight
            lane.queues[sid] = deque(sorted(
                list(lane.queues[sid]) + qs, key=lambda e: e.seq))
            self.stream_stats[sid].queued += len(qs)
            if sid not in lane.slots and sid not in lane.waiting:
                lane.waiting.append(sid)
            for q in qs:
                self._log_fault("requeue", lane, sid, q.seq, None)

    def _quarantine_entry(self, rec: _InflightLane, slot: int,
                          error: str) -> StreamResult:
        """Dead-letter one window of a collected record: emit its
        failed result, roll back the stream's carry, and pull the
        stream's still-in-flight successors (they chained on the
        poisoned carry) back onto the queue."""
        lane = rec.lane
        sid, seq, deadline = rec.entries[slot]
        item = None
        if rec.items is not None and rec.items[slot] is not None:
            item = rec.items[slot].item
        lane.retries.pop((sid, seq), None)
        lane.dead_letter.append(DeadLetter(
            stream_id=sid, seq=seq, modality=lane.modality, item=item,
            deadline=deadline, error=error))
        lane.n_quarantined += 1
        self.stream_stats[sid].quarantined += 1
        self._log_fault("quarantine", lane, sid, seq, error)
        if sid in lane.stateful:
            self._rollback_carry(rec, sid)
            self._requeue(lane,
                          self._scrub_stream_inflight(lane, sid, skip=rec))
        return StreamResult(
            stream_id=sid, seq=seq, result=None, modality=lane.modality,
            status="failed", error=error)

    def _recover_record(self, rec: _InflightLane,
                        exc: Exception) -> List[StreamResult]:
        """A record failed at collect (pipelined): retry its windows --
        re-queued at their seq positions with carries rolled back -- or
        quarantine the ones that exhausted ``max_retries``; put the
        lane on backoff and maybe declare it dead."""
        lane = rec.lane
        rcfg = self.recovery
        err = f"{type(exc).__name__}: {exc}"
        out: List[StreamResult] = []
        requeue: List[tuple] = []
        for slot, entry in enumerate(rec.entries):
            if entry is None:
                continue
            sid, seq, _deadline = entry
            count = lane.retries.get((sid, seq), 0) + 1
            if count > rcfg.max_retries:
                out.append(self._quarantine_entry(rec, slot, err))
                continue
            lane.retries[(sid, seq)] = count
            lane.n_retries += 1
            self.stream_stats[sid].retries += 1
            self._log_fault("retry", lane, sid, seq, err)
            if sid in lane.stateful:
                self._rollback_carry(rec, sid)
                requeue.extend(
                    self._scrub_stream_inflight(lane, sid, skip=rec))
            if rec.items is not None and rec.items[slot] is not None:
                requeue.append((sid, rec.items[slot]))
        self._requeue(lane, requeue)
        lane.fail_streak += 1
        lane.cooldown = max(lane.cooldown, rcfg.backoff_steps)
        if lane.fail_streak >= rcfg.dead_after and not lane.dead:
            lane.dead = True
            self._log_fault("lane_dead", lane, None, None, err)
        return out

    def _note_lane_failure(self, lane: EngineLane, heads: List,
                           exc: Exception) -> None:
        """A lane's synchronous dispatch failed with its queues still
        untouched (two-phase dispatch only peeks until every lane's
        infer returns): charge a retry to each window in the attempted
        batch, quarantine the ones over budget, back the lane off."""
        rcfg = self.recovery
        err = f"{type(exc).__name__}: {exc}"
        for slot, sid in enumerate(lane.slots):
            if sid is _FREE or heads[slot] is None:
                continue
            entry = lane.queues[sid][0]
            count = lane.retries.get((sid, entry.seq), 0) + 1
            if count > rcfg.max_retries:
                lane.queues[sid].popleft()
                self.stream_stats[sid].queued -= 1
                lane.retries.pop((sid, entry.seq), None)
                lane.dead_letter.append(DeadLetter(
                    stream_id=sid, seq=entry.seq, modality=lane.modality,
                    item=entry.item, deadline=entry.deadline, error=err))
                lane.n_quarantined += 1
                self.stream_stats[sid].quarantined += 1
                self._log_fault("quarantine", lane, sid, entry.seq, err)
                self._pending_failures.append(StreamResult(
                    stream_id=sid, seq=entry.seq, result=None,
                    modality=lane.modality, status="failed", error=err))
                continue
            lane.retries[(sid, entry.seq)] = count
            lane.n_retries += 1
            self.stream_stats[sid].retries += 1
            self._log_fault("retry", lane, sid, entry.seq, err)
        lane.fail_streak += 1
        lane.cooldown = max(lane.cooldown, rcfg.backoff_steps)
        if lane.fail_streak >= rcfg.dead_after and not lane.dead:
            lane.dead = True
            self._log_fault("lane_dead", lane, None, None, err)

    def _fail_fast_lane(self, lane: EngineLane) -> None:
        """Dead-lane mode: dead-letter everything queued without
        touching the engine, emitting failed results immediately so
        closed-loop callers (and fusion pairing) keep ticking."""
        for sid in list(lane.queues):
            q = lane.queues[sid]
            while q:
                entry = q.popleft()
                self.stream_stats[sid].queued -= 1
                lane.dead_letter.append(DeadLetter(
                    stream_id=sid, seq=entry.seq, modality=lane.modality,
                    item=entry.item, deadline=entry.deadline,
                    error="lane dead"))
                lane.n_quarantined += 1
                self.stream_stats[sid].quarantined += 1
                self._log_fault("quarantine", lane, sid, entry.seq,
                                "lane dead")
                self._pending_failures.append(StreamResult(
                    stream_id=sid, seq=entry.seq, result=None,
                    modality=lane.modality, status="failed",
                    error="lane dead"))

    def flush(self) -> List[StreamResult]:
        """Collect every in-flight pipelined step (oldest first)."""
        out: List[StreamResult] = []
        while self._inflight:
            out.extend(self._collect_step(self._inflight[0]))
            self._inflight.popleft()
        return out

    @property
    def in_flight(self) -> int:
        """Dispatched-but-uncollected pipeline steps."""
        return len(self._inflight)

    def run(self) -> List[StreamResult]:
        """Drain every queue (and the pipeline); results in completion
        order -- identical, order and values, for any ``pipeline_depth``."""
        out: List[StreamResult] = []
        while self.pending() or self._inflight:
            out.extend(self.step())
        return out

    @property
    def mean_occupancy(self) -> float:
        """Average served windows per step (batching efficiency; with
        multiple engines this sums over the per-engine batches)."""
        return (self.stats["windows"] / self.stats["steps"]
                if self.stats["steps"] else 0.0)
