"""Continuous batching over event streams: the SNN closed loop at scale.

The paper closes one loop: a single DVS camera feeding one 300 ms window at
a time. A production deployment (many sensors / many clients -- the
ColibriUAV multi-sensor scenario, Ev-Edge's heterogeneous event workloads)
must serve *many* concurrent event streams. :class:`StreamEngine` does for
the SNN closed loop what ``BatchScheduler`` does for LM decoding:

  * per-stream FIFO window queues (``submit`` never blocks),
  * a fixed number of batch slots -- one jit'd
    :class:`~repro.core.pipeline.BatchedClosedLoop` call per step over a
    constant ``(max_streams, max_events)`` buffer, so shapes stay stable
    and the engine compiles once per event-count bucket,
  * refill-without-stall: a slot is pinned to a stream while it has
    queued windows and handed to the next waiting stream the moment it
    drains -- or after ``fair_quantum`` consecutive windows when other
    streams are waiting, so no stream starves under continuous
    submission; idle slots run as empty (zero-event) rows without a
    recompile,
  * per-stream latency/energy accounting: every window gets its own
    Kraken model breakdown from its true event count and per-stream
    firing rates -- bitwise identical to running that window alone
    through :class:`~repro.core.pipeline.ClosedLoopPipeline`.

Windows within a stream are processed strictly in submission order (at
most one in-flight window per stream per step), preserving the closed-loop
causality of each control loop.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, Hashable, List, Optional

from repro.core import events as ev
from repro.core.energy import KrakenModel
from repro.core.pipeline import BatchedClosedLoop, ClosedLoopResult
from repro.core.snn import SNNConfig

__all__ = ["StreamResult", "StreamStats", "StreamEngine"]


@dataclasses.dataclass
class StreamResult:
    """One served window: which stream, which window index, and the
    closed-loop outcome (prediction, PWM, latency/energy breakdown)."""

    stream_id: Hashable
    seq: int                      # per-stream window index (submission order)
    result: ClosedLoopResult


@dataclasses.dataclass
class StreamStats:
    """Per-stream accounting, accumulated as windows complete."""

    windows: int = 0
    energy_mj: float = 0.0
    latency_ms_sum: float = 0.0
    realtime_windows: int = 0
    queued: int = 0               # still waiting in this stream's queue

    @property
    def mean_latency_ms(self) -> float:
        return self.latency_ms_sum / self.windows if self.windows else 0.0

    @property
    def realtime_fraction(self) -> float:
        return self.realtime_windows / self.windows if self.windows else 0.0

    @property
    def mean_power_mw(self) -> float:
        """Average power while processing (energy over busy time)."""
        return (self.energy_mj / (self.latency_ms_sum * 1e-3)
                if self.latency_ms_sum else 0.0)


class _FreeSlot:
    """Sentinel for an unassigned batch slot (distinct from any stream id,
    including ``None``, which is a legal Hashable stream id)."""

    def __repr__(self):
        return "<free slot>"


_FREE = _FreeSlot()


class StreamEngine:
    """Continuous batching of event-stream windows over fixed batch slots."""

    def __init__(
        self,
        params,
        cfg: SNNConfig,
        *,
        max_streams: int = 8,
        fair_quantum: int = 4,
        model: Optional[KrakenModel] = None,
        lif_scan_fn: Optional[Callable] = None,
        window_ms: float = 300.0,
    ):
        self.loop = BatchedClosedLoop(
            params, cfg, model=model, lif_scan_fn=lif_scan_fn,
            window_ms=window_ms)
        if max_streams < 1:
            raise ValueError(f"max_streams must be >= 1, got {max_streams}")
        if fair_quantum < 1:
            raise ValueError(f"fair_quantum must be >= 1, got {fair_quantum}")
        self.max_streams = max_streams
        # Fairness bound: a stream may serve this many consecutive windows
        # from its slot while other streams wait; it is then rotated to the
        # back of the waiting queue, so no stream starves under continuous
        # submission with more live streams than slots.
        self.fair_quantum = fair_quantum
        self._queues: Dict[Hashable, Deque[ev.EventWindow]] = {}
        self._seq: Dict[Hashable, int] = {}
        self._slots: List[Hashable] = [_FREE] * max_streams
        self._slot_runs: List[int] = [0] * max_streams  # windows on this pin
        self._waiting: Deque[Hashable] = deque()   # streams without a slot
        self._duration_us: Optional[int] = None
        self.stream_stats: Dict[Hashable, StreamStats] = {}
        self.stats: Dict[str, float] = {
            "steps": 0, "windows": 0, "wall_s": 0.0,
        }

    # -- submission ------------------------------------------------------

    def submit(self, stream_id: Hashable, window: ev.EventWindow) -> int:
        """Queue one window on a stream; returns its per-stream sequence
        number. Never blocks; the window runs at the next step in which
        its stream holds a slot and this window is at the queue head."""
        if self._duration_us is None:
            self._duration_us = window.duration_us
        elif window.duration_us != self._duration_us:
            raise ValueError(
                f"window duration {window.duration_us} != engine duration "
                f"{self._duration_us} (one bin width per engine)")
        if stream_id not in self._queues:
            self._queues[stream_id] = deque()
            self._seq[stream_id] = 0
            self.stream_stats[stream_id] = StreamStats()
        self._queues[stream_id].append(window)
        # A stream is schedulable via exactly one of: a held slot or a
        # waiting-queue entry (covers streams that drained and come back).
        if stream_id not in self._slots and stream_id not in self._waiting:
            self._waiting.append(stream_id)
        self.stream_stats[stream_id].queued += 1
        seq = self._seq[stream_id]
        self._seq[stream_id] += 1
        return seq

    def pending(self) -> int:
        """Windows queued across all streams."""
        return sum(len(q) for q in self._queues.values())

    # -- scheduling ------------------------------------------------------

    def _assign_slots(self) -> None:
        """Free slots whose stream has drained -- or exhausted its fairness
        quantum while others wait -- then hand free slots to waiting
        streams in arrival order (refill-without-stall)."""
        contended = any(self._queues[s] for s in self._waiting)
        for i, sid in enumerate(self._slots):
            if sid is _FREE:
                continue
            if not self._queues[sid]:
                self._slots[i] = _FREE
                self._slot_runs[i] = 0
            elif contended and self._slot_runs[i] >= self.fair_quantum:
                # Rotate: back of the waiting line, slot to the next stream.
                self._waiting.append(sid)
                self._slots[i] = _FREE
                self._slot_runs[i] = 0
        for i, sid in enumerate(self._slots):
            if sid is _FREE:
                while self._waiting:
                    cand = self._waiting.popleft()
                    if self._queues[cand]:
                        self._slots[i] = cand
                        self._slot_runs[i] = 0
                        break
                if self._slots[i] is _FREE:
                    break   # no more waiting work

    def step(self) -> List[StreamResult]:
        """Serve one batch: the head window of every slotted stream, in a
        single jit'd closed-loop call. Returns the completed windows."""
        t0 = time.perf_counter()
        self._assign_slots()
        # Peek (don't pop): if infer raises -- transient device error, OOM
        # -- every window stays queued and stats stay truthful; the step
        # can simply be retried.
        heads: List[Optional[ev.EventWindow]] = [
            self._queues[sid][0] if sid is not _FREE else None
            for sid in self._slots
        ]
        if all(w is None for w in heads):
            return []
        # Power-of-two event padding per step: jit caches one executable
        # per (B, max_events) shape, so there are at most log2 distinct
        # buckets over the engine's lifetime -- and the buffer shrinks
        # back after a burst window instead of padding every later step.
        bucket = ev.next_pow2(
            max(w.num_events for w in heads if w is not None))
        batch = ev.pad_event_windows(
            heads, max_events=bucket, batch_size=self.max_streams,
            duration_us=self._duration_us)
        results = self.loop.infer(batch)

        out: List[StreamResult] = []
        for slot, (w, res) in enumerate(zip(heads, results)):
            if w is None:
                continue
            self._queues[self._slots[slot]].popleft()
            self._slot_runs[slot] += 1
            sid = self._slots[slot]
            st = self.stream_stats[sid]
            st.windows += 1
            st.queued -= 1
            st.energy_mj += res.energy_mj
            st.latency_ms_sum += res.latency_ms
            st.realtime_windows += int(res.realtime)
            out.append(StreamResult(
                stream_id=sid, seq=st.windows - 1, result=res))
            self.stats["windows"] += 1
        self.stats["steps"] += 1
        self.stats["wall_s"] += time.perf_counter() - t0
        return out

    def run(self) -> List[StreamResult]:
        """Drain every queue; returns all results in completion order."""
        out: List[StreamResult] = []
        while self.pending():
            out.extend(self.step())
        return out

    @property
    def mean_occupancy(self) -> float:
        """Average filled slots per step (batching efficiency)."""
        return (self.stats["windows"] / self.stats["steps"]
                if self.stats["steps"] else 0.0)
