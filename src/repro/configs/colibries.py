"""The paper's own architecture: the Table II DVS-Gesture SCNN executed by
SNE, plus the pipeline constants (300 ms windows, DVS128 input) and the
mirror CUTIE ternary CNN for the frame wing."""
from repro.core.lif import LIFParams
from repro.core.snn import SNNConfig
from repro.core.tcn import TCNConfig

# Full paper network (Table II): 128x128x2 -> pool4 -> conv16 -> pool2 ->
# conv32 -> pool2 -> fc512 -> fc11.
CONFIG = SNNConfig(
    height=128, width=128, in_channels=2, pool0=4,
    conv1_features=16, conv2_features=32, hidden=512, num_classes=11,
    time_bins=16, lif=LIFParams(alpha=0.875, v_th=0.5,
                                surrogate_width=2.0),
)

# Reduced smoke variant (same family, 32x32 sensor crop).
SMOKE = SNNConfig(
    height=32, width=32, in_channels=2, pool0=4,
    conv1_features=4, conv2_features=8, hidden=32, num_classes=11,
    time_bins=8,
)

# Frame wing: the CUTIE ternary CNN mirroring the SCNN layer-for-layer
# (frames in, same pooling/feature schedule, fp classifier).
TCN_CONFIG = TCNConfig(
    height=128, width=128, in_channels=1, pool0=4,
    conv1_features=16, conv2_features=32, hidden=512, num_classes=11,
)

TCN_SMOKE = TCNConfig(
    height=32, width=32, in_channels=1, pool0=4,
    conv1_features=4, conv2_features=8, hidden=32, num_classes=11,
)

WINDOW_MS = 300.0
