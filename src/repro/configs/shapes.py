"""Assigned input-shape sets (identical across the 10 LM-family archs).

  train_4k     seq 4096   global_batch 256   -> train_step
  prefill_32k  seq 32768  global_batch 32    -> prefill (full forward)
  decode_32k   seq 32768  global_batch 128   -> serve_step (1 new token,
                                                32k cache)
  long_500k    seq 524288 global_batch 1     -> serve_step; only for
                                                sub-quadratic archs
                                                (see DESIGN.md skip list)
"""
from __future__ import annotations

import dataclasses
from typing import List

from repro.models.config import ModelConfig

__all__ = ["ShapeSpec", "SHAPES", "cells_for"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def cells_for(cfg: ModelConfig) -> List[ShapeSpec]:
    """The dry-run cells this arch runs (long_500k only if sub-quadratic)."""
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if cfg.is_sub_quadratic:
        out.append(SHAPES["long_500k"])
    return out
