"""zamba2-1.2b [arXiv:2411.15242]: Mamba2 backbone + weight-shared
attention block every 6 layers. 38L d_model=2048 32H (kv=32) d_ff=8192
ssm_state=64. Hybrid => long_500k admissible (SSM state + windowed shared
attention, window 4096 at long context -- DESIGN.md)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="zamba2",
    num_layers=38, d_model=2048, vocab_size=32_000, d_ff=8192,
    num_heads=32, num_kv_heads=32, head_dim=64,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, conv_kernel=4,
    attn_every=6, long_context_window=4096, chunk_size=32,
)

SMOKE = ModelConfig(
    name="zamba2-smoke", family="zamba2",
    num_layers=4, d_model=64, vocab_size=256, d_ff=128,
    num_heads=4, num_kv_heads=4, head_dim=16,
    ssm_state=8, ssm_head_dim=16, attn_every=2,
    long_context_window=16, chunk_size=8, dtype="float32",
)
