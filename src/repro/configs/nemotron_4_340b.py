"""nemotron-4-340b [arXiv:2402.16819]: dense, GQA, squared-ReLU MLP.
96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b", family="dense",
    num_layers=96, d_model=18_432, vocab_size=256_000, d_ff=73_728,
    num_heads=96, num_kv_heads=8, head_dim=192,
    rope_theta=10_000.0, activation="squared_relu",
)

SMOKE = ModelConfig(
    name="nemotron-4-340b-smoke", family="dense",
    num_layers=2, d_model=96, vocab_size=256, d_ff=384,
    num_heads=4, num_kv_heads=2, head_dim=24,
    activation="squared_relu", dtype="float32",
)
