"""h2o-danube-1.8b [arXiv:2401.16818; hf]: llama+mistral mix with
sliding-window attention. 24L d_model=2560 32H (GQA kv=8) d_ff=6912
vocab=32000. SWA makes long_500k admissible (bounded ring-buffer KV)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b", family="dense",
    num_layers=24, d_model=2560, vocab_size=32_000, d_ff=6912,
    num_heads=32, num_kv_heads=8, head_dim=80,
    sliding_window=4096, rope_theta=10_000.0, activation="swiglu",
)

SMOKE = ModelConfig(
    name="h2o-danube-1.8b-smoke", family="dense",
    num_layers=2, d_model=64, vocab_size=256, d_ff=160,
    num_heads=4, num_kv_heads=2, head_dim=16,
    sliding_window=8, activation="swiglu", dtype="float32",
)
