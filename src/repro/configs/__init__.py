"""Architecture registry: exact published configs + reduced smoke variants.

``get_config(arch, smoke=False)`` returns the ModelConfig (or SNNConfig for
'colibries'). ``ARCHS`` lists the 10 assigned LM-family architectures.
"""
from __future__ import annotations

import importlib
from typing import Any

ARCHS = [
    "h2o-danube-1.8b",
    "glm4-9b",
    "nemotron-4-340b",
    "llama3.2-1b",
    "rwkv6-7b",
    "llama4-scout-17b-a16e",
    "deepseek-moe-16b",
    "zamba2-1.2b",
    "seamless-m4t-medium",
    "qwen2-vl-2b",
]

_MODULES = {
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "glm4-9b": "glm4_9b",
    "nemotron-4-340b": "nemotron_4_340b",
    "llama3.2-1b": "llama3_2_1b",
    "rwkv6-7b": "rwkv6_7b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "zamba2-1.2b": "zamba2_1_2b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "colibries": "colibries",
}


def get_config(arch: str, smoke: bool = False) -> Any:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.SMOKE if smoke else mod.CONFIG
