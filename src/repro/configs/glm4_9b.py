"""glm4-9b [hf:THUDM/glm-4-9b]: dense, RoPE, strong GQA (kv=2).
40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b", family="dense",
    num_layers=40, d_model=4096, vocab_size=151_552, d_ff=13_696,
    num_heads=32, num_kv_heads=2, head_dim=128,
    rope_theta=10_000.0, activation="swiglu",
)

SMOKE = ModelConfig(
    name="glm4-9b-smoke", family="dense",
    num_layers=2, d_model=64, vocab_size=256, d_ff=192,
    num_heads=4, num_kv_heads=1, head_dim=16,
    activation="swiglu", dtype="float32",
)
