"""seamless-m4t-medium [arXiv:2308.11596]: enc-dec multimodal backbone.
12L (x2: encoder+decoder) d_model=1024 16H (MHA kv=16) d_ff=4096
vocab=256206. Audio frontend stubbed: input_specs provides precomputed
1024-d frame embeddings. vocab 256206 % 16 != 0 -> vocab dim left
unsharded by the divisibility fallback (DESIGN.md)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    num_layers=12, d_model=1024, vocab_size=256_206, d_ff=4096,
    num_heads=16, num_kv_heads=16, head_dim=64,
    encoder_layers=12, decoder_layers=12, frontend_dim=1024,
    activation="gelu",
)

SMOKE = ModelConfig(
    name="seamless-smoke", family="encdec",
    num_layers=2, d_model=64, vocab_size=254, d_ff=128,
    num_heads=4, num_kv_heads=4, head_dim=16,
    encoder_layers=2, decoder_layers=2, frontend_dim=32,
    activation="gelu", dtype="float32",
)
