"""llama3.2-1b [hf:meta-llama/Llama-3.2-1B]: small llama3, tied embeddings.
16L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=128256."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b", family="dense",
    num_layers=16, d_model=2048, vocab_size=128_256, d_ff=8192,
    num_heads=32, num_kv_heads=8, head_dim=64,
    rope_theta=500_000.0, activation="swiglu", tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="llama3.2-1b-smoke", family="dense",
    num_layers=2, d_model=64, vocab_size=256, d_ff=160,
    num_heads=4, num_kv_heads=2, head_dim=16,
    activation="swiglu", tie_embeddings=True, dtype="float32",
)
