"""qwen2-vl-2b [arXiv:2409.12191]: VLM backbone with M-RoPE (t/h/w rotary
sections) and dynamic resolution. 28L d_model=1536 12H (GQA kv=2)
d_ff=8960 vocab=151936. Vision tower stubbed: input_specs provides patch
embeddings merged at the sequence head. 12 heads % 16 != 0 -> head_dim
sharding fallback."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b", family="vlm",
    num_layers=28, d_model=1536, vocab_size=151_936, d_ff=8960,
    num_heads=12, num_kv_heads=2, head_dim=128,
    rope_theta=1_000_000.0, activation="swiglu", tie_embeddings=True,
    mrope_sections=(16, 24, 24),
)

SMOKE = ModelConfig(
    name="qwen2-vl-smoke", family="vlm",
    num_layers=2, d_model=64, vocab_size=256, d_ff=160,
    num_heads=4, num_kv_heads=2, head_dim=16,
    mrope_sections=(4, 2, 2), tie_embeddings=True, dtype="float32",
)
