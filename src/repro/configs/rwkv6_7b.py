"""rwkv6-7b "Finch" [arXiv:2404.05892]: attention-free, token-shift,
data-dependent decay. 32L d_model=4096 d_ff=14336 vocab=65536.
O(1) recurrent state => long_500k admissible."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="rwkv6",
    num_layers=32, d_model=4096, vocab_size=65_536, d_ff=14_336,
    rwkv_head_dim=64, rwkv_lora_rank=64, chunk_size=16,
)

SMOKE = ModelConfig(
    name="rwkv6-7b-smoke", family="rwkv6",
    num_layers=2, d_model=64, vocab_size=256, d_ff=160,
    rwkv_head_dim=16, rwkv_lora_rank=8, chunk_size=8, dtype="float32",
)
