"""llama4-scout-17b-a16e [hf:meta-llama/Llama-4-Scout-17B-16E]: MoE 16
routed experts top-1 + 1 shared (Llama-4 style). 48L d_model=5120 40H
(GQA kv=8) d_ff=8192 vocab=202048. Text backbone (early fusion out of
scope per assignment). 40 heads % 16 mesh != 0 -> sharding falls back to
head_dim (see distributed/sharding.py)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe",
    num_layers=48, d_model=5120, vocab_size=202_048, d_ff=8192,
    num_heads=40, num_kv_heads=8, head_dim=128,
    rope_theta=500_000.0, activation="swiglu",
    num_experts=16, top_k=1, num_shared_experts=1, expert_d_ff=8192,
    moe_group_size=256,
)

SMOKE = ModelConfig(
    name="llama4-scout-smoke", family="moe",
    num_layers=2, d_model=64, vocab_size=256, d_ff=128,
    num_heads=4, num_kv_heads=2, head_dim=16,
    num_experts=4, top_k=1, num_shared_experts=1, expert_d_ff=128,
    moe_group_size=8, dtype="float32",
)
