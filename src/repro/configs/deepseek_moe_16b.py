"""deepseek-moe-16b [arXiv:2401.06066]: fine-grained MoE, 2 shared + 64
routed top-6, expert d_ff=1408. 28L d_model=2048 16H (MHA kv=16)
vocab=102400. (Published dense first layer folded into the uniform MoE
stack; FLOP delta < 0.5% -- DESIGN.md.)"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    num_layers=28, d_model=2048, vocab_size=102_400, d_ff=1408,
    num_heads=16, num_kv_heads=16, head_dim=128,
    rope_theta=10_000.0, activation="swiglu",
    num_experts=64, top_k=6, num_shared_experts=2, expert_d_ff=1408,
    moe_group_size=256,
)

SMOKE = ModelConfig(
    name="deepseek-moe-smoke", family="moe",
    num_layers=2, d_model=64, vocab_size=256, d_ff=64,
    num_heads=4, num_kv_heads=4, head_dim=16,
    num_experts=8, top_k=2, num_shared_experts=2, expert_d_ff=64,
    moe_group_size=8, dtype="float32",
)
