"""Per-arch smoke tests (reduced configs, deliverable f) + family-level
consistency: decode==forward, chunked==stepwise recurrences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import ModelConfig, build_model
from repro.kernels.ref import wkv6_ref
from repro.models.rwkv6 import wkv6_chunked
from repro.models.zamba2 import mamba2_chunked, _mamba_step
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update


def _batch_for(cfg, b=2, s=16, rng=None):
    rng = rng or jax.random.PRNGKey(1)
    toks = jax.random.randint(rng, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": toks, "targets": toks}
    if cfg.family == "encdec":
        fd = cfg.frontend_dim or cfg.d_model
        batch["frames"] = jax.random.normal(
            jax.random.fold_in(rng, 1), (b, s, fd))
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            jax.random.fold_in(rng, 2), (b, 4, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    """Instantiate the REDUCED config, run forward + one optimizer step on
    CPU, assert output shapes and absence of NaNs."""
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    logits, aux = model.apply(params, batch)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any()), f"{arch}: NaN logits"

    loss, metrics = model.loss(params, batch)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    opt = adamw_init(params)
    new_params, opt, om = adamw_update(grads, opt, params, AdamWConfig())
    # params actually moved and stayed finite
    moved = any(
        float(jnp.abs(a - b).max()) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)))
    assert moved
    assert np.isfinite(float(om["grad_norm"]))


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_decode_step(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(2, 8)
    tok = jnp.zeros((2, 1), jnp.int32)
    logits, cache2 = model.decode(params, cache, tok)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    assert int(cache2["pos"]) == 1


@pytest.mark.parametrize("arch", ["llama3.2-1b", "rwkv6-7b", "zamba2-1.2b",
                                  "glm4-9b"])
def test_decode_matches_forward(arch):
    """Token-by-token decode reproduces the full-sequence forward logits
    (the serving path is numerically the training path)."""
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, s), 0,
                              cfg.vocab_size)
    full, _ = model.apply(params, {"tokens": toks})
    cache = model.init_cache(b, s)
    outs = []
    for i in range(s):
        lg, cache = model.decode(params, cache, toks[:, i:i + 1])
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-4, atol=2e-4)


def test_wkv6_chunked_matches_reference():
    """Chunked-parallel WKV == stepwise oracle (kernels/ref.py)."""
    t, dk, dv = 32, 8, 8
    key = jax.random.PRNGKey(0)
    r, k, v = (jax.random.normal(jax.random.fold_in(key, i), (t, dk))
               for i in range(3))
    logw = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 3),
                                      (t, dk)) * 0.5)
    u = jax.random.normal(jax.random.fold_in(key, 4), (dk,)) * 0.1
    o_ref, s_ref = wkv6_ref(r, k, v, jnp.exp(logw), u)
    o_chk, s_chk = wkv6_chunked(
        r[None, :, None], k[None, :, None], v[None, :, None],
        logw[None, :, None], u[None], chunk=8)
    np.testing.assert_allclose(np.asarray(o_chk[0, :, 0]),
                               np.asarray(o_ref), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_chk[0, 0]), np.asarray(s_ref),
                               rtol=1e-4, atol=1e-4)


def test_mamba2_chunked_matches_stepwise():
    b, s, h, p, n = 2, 24, 3, 4, 5
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                           (b, s, h)))
    a = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (h,)) * 0.3)
    b_in = jax.random.normal(jax.random.fold_in(key, 3), (b, s, n))
    c_in = jax.random.normal(jax.random.fold_in(key, 4), (b, s, n))
    y_chk, s_chk = mamba2_chunked(x, dt, a, b_in, c_in, chunk=8)
    state = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        y_t, state = _mamba_step(x[:, t], dt[:, t], a, b_in[:, t],
                                 c_in[:, t], state)
        ys.append(y_t)
    y_step = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_step),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_chk), np.asarray(state),
                               rtol=1e-4, atol=1e-4)


def test_sliding_window_attention_masks_far_context():
    """SWA: moving a token OUTSIDE the window does not change logits at the
    final position; moving one INSIDE does."""
    cfg = get_config("h2o-danube-1.8b", smoke=True)  # window = 8
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    s = 24
    toks = jax.random.randint(jax.random.PRNGKey(5), (1, s), 2,
                              cfg.vocab_size)
    base, _ = model.apply(params, {"tokens": toks})
    far = toks.at[0, 0].set((toks[0, 0] + 1) % cfg.vocab_size)
    near = toks.at[0, s - 2].set((toks[0, s - 2] + 1) % cfg.vocab_size)
    out_far, _ = model.apply(params, {"tokens": far})
    out_near, _ = model.apply(params, {"tokens": near})
    np.testing.assert_allclose(np.asarray(out_far[0, -1]),
                               np.asarray(base[0, -1]), rtol=1e-5,
                               atol=1e-5)
    assert float(jnp.abs(out_near[0, -1] - base[0, -1]).max()) > 1e-4


def test_moe_capacity_and_aux_loss():
    cfg = get_config("deepseek-moe-16b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg, b=2, s=16)
    _, aux = model.apply(params, batch)
    # Switch aux loss ~= 1 for uniform routing; must be finite and positive.
    assert 0 < float(aux) < 50


def test_param_counts_match_analytic():
    """Analytic count (roofline MODEL_FLOPS input) within 0.2% of exact
    (exact for dense/moe/vlm; small-bias terms uncounted for rwkv/zamba/
    encdec)."""
    for arch in ARCHS:
        cfg = get_config(arch)
        model = build_model(cfg)
        exact, analytic = model.num_params(), cfg.param_count()
        assert abs(exact - analytic) / exact < 0.002, (arch, exact,
                                                       analytic)
