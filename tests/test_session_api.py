"""Session-handle serving API: StreamHandle lifecycle, legacy-shim
bitwise parity, checkpoint/restore stream migration (fresh-process
round trips at B in {1, 4, 8}, sync and pipelined), the cross-modal
FusionSession, and the one-shot deprecation surface.
"""
import dataclasses
import pickle
import warnings

import jax
import numpy as np
import pytest

from repro.core import (FrameTCNEngine, SNNConfig, TCNConfig, init_snn,
                        init_tcn)
from repro.core import events as ev
from repro.core import frames as fr
from repro.core.pipeline import BatchedClosedLoop, pwm_from_logits
from repro.serving import (FusionSession, StreamCheckpoint, StreamEngine,
                           StreamStats, late_logit_fusion)
from tests.test_stateful_stream import (_assert_matches_oracle,
                                        _uninterrupted_oracle, _windows)


@pytest.fixture(scope="module")
def cfg():
    return SNNConfig(height=32, width=32, time_bins=4, conv1_features=4,
                     conv2_features=8, hidden=32, num_classes=11)


@pytest.fixture(scope="module")
def params(cfg):
    return init_snn(jax.random.PRNGKey(0), cfg)


@pytest.fixture(scope="module")
def tcfg():
    return TCNConfig(height=32, width=32, conv1_features=4,
                     conv2_features=8, hidden=32, num_classes=11)


@pytest.fixture(scope="module")
def tparams(tcfg):
    return init_tcn(jax.random.PRNGKey(1), tcfg)


def _frames(n, seed=0):
    rng = np.random.default_rng(seed)
    return [fr.synthetic_gesture_frames(rng, i % 11, height=32, width=32)
            for i in range(n)]


def _hetero_engine(cfg, params, tcfg, tparams, **kw):
    return StreamEngine(engines=[BatchedClosedLoop(params, cfg),
                                 FrameTCNEngine(tparams, tcfg)], **kw)


# -- handle lifecycle --------------------------------------------------------

def test_open_and_submit_basics(cfg, params):
    eng = StreamEngine(params, cfg, max_streams=2)
    h = eng.open(stateful=True)                 # single lane: no modality
    assert h.modality == "event" and h.stateful and not h.closed
    assert h.stream_id == "event-0"             # auto-generated
    assert eng.open().stream_id == "event-1"
    named = eng.open(stream_id="cam")
    assert eng.handles["cam"] is named
    with pytest.raises(ValueError, match="already open"):
        eng.open(stream_id="cam")
    ws = _windows(2, seed=1)
    assert h.submit(ws[0]) == 0 and h.submit(ws[1]) == 1
    assert h.queued == 2
    out = eng.run()
    assert [(r.stream_id, r.seq) for r in out] == [("event-0", 0),
                                                   ("event-0", 1)]
    assert h.stats.windows == 2 and h.queued == 0
    assert h.close() == 0 and h.closed
    assert h.close() == 0                       # idempotent
    with pytest.raises(ValueError, match="closed"):
        h.submit(ws[0])
    # The id is free again after close: reopening is a brand-new stream.
    assert eng.open(stream_id="event-0").submit(ws[0]) == 0


def test_open_validation(cfg, params, tcfg, tparams):
    from tests.test_slot_policy import StubEngine
    eng = _hetero_engine(cfg, params, tcfg, tparams, max_streams=1)
    with pytest.raises(ValueError, match="modality required"):
        eng.open()
    with pytest.raises(ValueError, match="no engine"):
        eng.open(modality="lidar")
    stub = StreamEngine(engines=[StubEngine()], max_streams=1)
    with pytest.raises(ValueError, match="carried-state"):
        stub.open(stateful=True)
    assert stub.handles == {}                   # nothing registered


def test_handle_default_deadline_feeds_policy(cfg, params):
    """A handle's default deadline is attached to every window it
    submits (overridable per submit) -- visible to deadline policies."""
    eng = StreamEngine(params, cfg, max_streams=1)
    h = eng.open(deadline=7.0)
    ws = _windows(2, seed=2)
    h.submit(ws[0])
    h.submit(ws[1], deadline=1.0)
    lane = eng._lanes["event"]
    assert [q.deadline for q in lane.queues[h.stream_id]] == [7.0, 1.0]
    eng.run()


# -- the deprecation surface -------------------------------------------------

def test_legacy_submit_warns_once_naming_handle_api(cfg, params):
    eng = StreamEngine(params, cfg, max_streams=2)
    ws = _windows(2, seed=3)
    with pytest.warns(DeprecationWarning, match=r"open\(modality"):
        eng.submit("a", ws[0])
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        eng.submit("a", ws[1])                  # one-shot: now silent
    assert not [w for w in rec if w.category is DeprecationWarning]
    eng.run()


def test_stateless_infer_warns_once_naming_replacement(cfg, params):
    loop = BatchedClosedLoop(params, cfg)
    batch = ev.pad_event_windows(_windows(1, seed=4))
    with pytest.warns(DeprecationWarning, match="init_state"):
        loop.infer(batch)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        loop.infer(batch)
        loop.infer(batch, loop.init_state(batch.batch_size))  # modern form
    assert not [w for w in rec if w.category is DeprecationWarning]


def test_handle_api_and_shim_internals_emit_no_deprecation(cfg, params):
    """The full handle-API serving path -- including the engine's
    internal stateless infer calls -- is deprecation-silent; only USER
    calls of the legacy forms warn."""
    eng = StreamEngine(params, cfg, max_streams=2, pipeline_depth=1)
    h = eng.open()                              # stateless lane
    hs = eng.open(stateful=True)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        for w in _windows(2, seed=5):
            h.submit(w)
            hs.submit(w)
        eng.run()
    assert not [w for w in rec if w.category is DeprecationWarning]


# -- legacy shim: bitwise parity against the handle API ----------------------

@pytest.mark.parametrize("pipeline_depth", [0, 1], ids=["sync", "pipelined"])
def test_shim_results_bitwise_identical_to_handle_api(cfg, params,
                                                      pipeline_depth):
    """The acceptance criterion: the id-keyed submit shim must produce
    the exact StreamResult sequence -- order and values -- of the
    equivalent handle-API run, stateless and stateful streams mixed,
    sync and pipelined."""
    streams = {f"cam{s}": _windows(3, seed=10 + s) for s in range(3)}
    stateful_ids = {"cam1"}

    legacy = StreamEngine(params, cfg, max_streams=2,
                          pipeline_depth=pipeline_depth)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        for sid, ws in streams.items():
            for w in ws:
                legacy.submit(sid, w, stateful=sid in stateful_ids)
    ref = legacy.run()

    modern = StreamEngine(params, cfg, max_streams=2,
                          pipeline_depth=pipeline_depth)
    handles = {sid: modern.open(stream_id=sid,
                                stateful=sid in stateful_ids)
               for sid in streams}
    for sid, ws in streams.items():
        for w in ws:
            handles[sid].submit(w)
    got = modern.run()

    assert ([(r.stream_id, r.seq, r.modality) for r in got]
            == [(r.stream_id, r.seq, r.modality) for r in ref])
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(a.result.label_pred,
                                      b.result.label_pred)
        np.testing.assert_array_equal(a.result.pwm, b.result.pwm)
        np.testing.assert_array_equal(a.result.logits, b.result.logits)
        assert a.result.energy_mj == b.result.energy_mj
        assert a.result.latency_ms == b.result.latency_ms


def test_legacy_stateful_latch_still_enforced_through_shim(cfg, params):
    eng = StreamEngine(params, cfg, max_streams=2)
    ws = _windows(2, seed=12)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        eng.submit("a", ws[0], stateful=True)
        with pytest.raises(ValueError, match="latched"):
            eng.submit("a", ws[1], stateful=False)
        assert eng.submit("a", ws[1]) == 1      # None leaves the latch alone
    assert eng.stateful_of("a") is True
    eng.run()


# -- checkpoint / restore ----------------------------------------------------

@pytest.mark.parametrize("pipeline_depth", [0, 1], ids=["sync", "pipelined"])
@pytest.mark.parametrize("b", [1, 4, 8])
def test_checkpoint_restore_roundtrip(cfg, params, b, pipeline_depth):
    """The acceptance criterion: checkpoint every stream mid-scan,
    restore into a FRESH StreamEngine (through a pickle round trip --
    i.e. a fresh process), serve the remaining windows: the full result
    sequence is bitwise identical to the uninterrupted scan."""
    full, cut = 4, 2
    streams = {f"cam{s}": _windows(full, seed=120 + 5 * s + b)
               for s in range(b)}
    eng_a = StreamEngine(params, cfg, max_streams=b,
                         pipeline_depth=pipeline_depth)
    h_a = {sid: eng_a.open(stream_id=sid, stateful=True)
           for sid in streams}
    for sid, ws in streams.items():
        for w in ws[:cut]:
            h_a[sid].submit(w)
    results = eng_a.run()

    blobs = pickle.dumps({sid: h.checkpoint() for sid, h in h_a.items()})
    ckpts = pickle.loads(blobs)                 # "the other process"
    for ck in ckpts.values():
        assert isinstance(ck, StreamCheckpoint) and ck.next_seq == cut
        for leaf in jax.tree_util.tree_leaves(ck.state):
            assert isinstance(leaf, np.ndarray)  # host-resident payload

    eng_b = StreamEngine(params, cfg, max_streams=b,
                         pipeline_depth=pipeline_depth)
    h_b = {sid: eng_b.restore(ckpts[sid]) for sid in streams}
    for sid, ws in streams.items():
        for w in ws[cut:]:
            h_b[sid].submit(w)
    results += eng_b.run()

    assert len(results) == full * b
    ids, per_window = _uninterrupted_oracle(params, cfg, streams)
    _assert_matches_oracle(results, ids, per_window)


def test_checkpoint_of_parked_carry(cfg, params):
    """Two stateful streams over one slot: at checkpoint time one carry
    lives in the slot-major buffer, the other is parked -- both must
    export, migrate, and chain bitwise."""
    streams = {"s0": _windows(4, seed=130), "s1": _windows(4, seed=131)}
    eng_a = StreamEngine(params, cfg, max_streams=1)
    h_a = {sid: eng_a.open(stream_id=sid, stateful=True)
           for sid in streams}
    for sid, ws in streams.items():
        for w in ws[:2]:
            h_a[sid].submit(w)
    results = eng_a.run()
    lane = eng_a._lanes["event"]
    assert lane.parked                           # one carry parked
    ckpts = {sid: h.checkpoint() for sid, h in h_a.items()}
    eng_b = StreamEngine(params, cfg, max_streams=1)
    for sid, ws in streams.items():
        h = eng_b.restore(ckpts[sid])
        for w in ws[2:]:
            h.submit(w)
    results += eng_b.run()
    ids, per_window = _uninterrupted_oracle(params, cfg, streams)
    _assert_matches_oracle(results, ids, per_window)


def test_checkpoint_carries_queued_windows(cfg, params):
    """Still-queued windows ride the checkpoint: migration resubmits
    them under their original sequence numbers."""
    ws = _windows(4, seed=140)
    eng_a = StreamEngine(params, cfg, max_streams=1)
    h = eng_a.open(stream_id="s", stateful=True)
    h.submit(ws[0])
    h.submit(ws[1])
    res_a = eng_a.step()
    assert [r.seq for r in res_a] == [0]         # window 1 still queued
    ck = pickle.loads(pickle.dumps(h.checkpoint()))
    assert ck.next_seq == 2 and len(ck.queued) == 1
    eng_b = StreamEngine(params, cfg, max_streams=1)
    h_b = eng_b.restore(ck)
    assert h_b.queued == 1 and h_b.stats.queued == 1
    h_b.submit(ws[2])
    h_b.submit(ws[3])
    res_b = eng_b.run()
    assert [r.seq for r in res_b] == [1, 2, 3]
    ids, per_window = _uninterrupted_oracle(params, cfg, {"s": ws})
    _assert_matches_oracle(res_a + res_b, ids, per_window)


def test_checkpoint_rejects_inflight_windows(cfg, params):
    eng = StreamEngine(params, cfg, max_streams=1, pipeline_depth=1)
    h = eng.open(stream_id="s", stateful=True)
    h.submit(_windows(1, seed=150)[0])
    eng.step()                                   # dispatched, uncollected
    with pytest.raises(ValueError, match="in-flight"):
        h.checkpoint()
    eng.flush()
    assert h.checkpoint().next_seq == 1


def test_restore_validation(cfg, params):
    ws = _windows(2, seed=160)
    eng = StreamEngine(params, cfg, max_streams=1)
    h = eng.open(stream_id="s", stateful=True)
    h.submit(ws[0])
    eng.run()
    ck = h.checkpoint()
    # Not fresh: the source handle itself has history.
    with pytest.raises(ValueError, match="fresh"):
        h.restore(ck)
    eng_b = StreamEngine(params, cfg, max_streams=1)
    # Statefulness must match the checkpoint.
    with pytest.raises(ValueError, match="stateful"):
        eng_b.open(stream_id="s").restore(ck)
    # engine.restore cleans up its half-opened handle on failure.
    eng_c = StreamEngine(params, cfg, max_streams=1,
                         duration_us=150_000)
    with pytest.raises(ValueError, match="duration_us"):
        eng_c.restore(ck)
    assert eng_c.handles == {}
    # Same id restores cleanly elsewhere; rename works too.
    eng_d = StreamEngine(params, cfg, max_streams=1)
    assert eng_d.restore(ck).stream_id == "s"
    assert eng_d.restore(ck, stream_id="s2").stream_id == "s2"
    # Wrong modality (a frame checkpoint cannot land on an event lane).
    bad = dataclasses.replace(ck, modality="frame")
    with pytest.raises(ValueError, match="no engine"):
        eng_d.restore(bad)


def test_export_import_state_roundtrip(cfg, params):
    """Engine-level primitive: export_state(state, slot) is a host
    (numpy) pytree; import_state splices it back bitwise."""
    loop = BatchedClosedLoop(params, cfg)
    batch = ev.pad_event_windows(_windows(3, seed=170))
    _, state = loop.infer(batch, loop.init_state(batch.batch_size))
    payload = loop.export_state(state, 1)
    assert all(isinstance(v, np.ndarray) for v in payload.values())
    spliced = loop.import_state(loop.init_state(3), 1, payload)
    for name, v in state.items():
        np.testing.assert_array_equal(np.asarray(spliced[name][1]),
                                      np.asarray(v[1]))
        assert not np.asarray(spliced[name][0]).any()  # other rows zero


# -- StreamStats zero-window guards ------------------------------------------

def test_stream_stats_guard_zero_completed_windows(cfg, params):
    st = StreamStats()
    assert st.mean_latency_ms == 0.0
    assert st.realtime_fraction == 0.0
    assert st.mean_power_mw == 0.0
    # Opened-but-never-served stream: same guards through the handle.
    eng = StreamEngine(params, cfg, max_streams=1)
    h = eng.open()
    assert h.stats.mean_latency_ms == 0.0
    assert h.stats.realtime_fraction == 0.0
    assert h.stats.mean_power_mw == 0.0
    # Queued-but-unserved keeps the guards too.
    h.submit(_windows(1, seed=180)[0])
    assert h.stats.windows == 0 and h.stats.mean_power_mw == 0.0
    eng.run()
    assert h.stats.mean_latency_ms > 0 and h.stats.mean_power_mw > 0


# -- FusionSession -----------------------------------------------------------

def test_fusion_session_one_result_per_tick(cfg, params, tcfg, tparams):
    """The acceptance criterion: one fused StreamResult per control
    tick, with combined PWM actuation (late logit fusion) and per-wing
    energy attribution."""
    eng = _hetero_engine(cfg, params, tcfg, tparams,
                         max_streams={"event": 2, "frame": 2})
    sess = FusionSession(eng, stateful=False)
    n = 3
    evs, frs = _windows(n, seed=190), _frames(n, seed=191)
    for k in range(n):
        assert sess.submit(evs[k], frs[k]) == k
    fused = sess.run()
    assert [(r.seq, r.modality) for r in fused] == [
        (k, "fusion") for k in range(n)]
    assert sess.unclaimed == [] and sess.ticks_fused == n

    # Expected fusion from the wings served unfused on twin engines.
    ev_eng = StreamEngine(engines=[BatchedClosedLoop(params, cfg)],
                          max_streams=2)
    fr_eng = StreamEngine(engines=[FrameTCNEngine(tparams, tcfg)],
                          max_streams=2)
    he, hf = ev_eng.open(), fr_eng.open()
    for k in range(n):
        he.submit(evs[k])
        hf.submit(frs[k])
    wing = {("event", r.seq): r.result for r in ev_eng.run()}
    wing.update({("frame", r.seq): r.result for r in fr_eng.run()})

    pwm_jit = jax.jit(pwm_from_logits)   # the session's actuation map
    for r in fused:
        e, f = wing[("event", r.seq)], wing[("frame", r.seq)]
        expected = 0.5 * e.logits + 0.5 * f.logits
        np.testing.assert_array_equal(r.result.logits, expected)
        np.testing.assert_array_equal(
            r.result.pwm, np.asarray(pwm_jit(expected)))
        np.testing.assert_array_equal(r.result.label_pred,
                                      np.argmax(expected, axis=-1))
        assert r.result.energy_mj == e.energy_mj + f.energy_mj
        assert r.result.breakdown["per_wing_energy_mj"] == {
            "event": e.energy_mj, "frame": f.energy_mj}
        assert r.result.latency_ms == max(e.latency_ms, f.latency_ms)
        assert "snn_inference" in r.result.breakdown["event"]["stages"]
        assert "tcn_inference" in r.result.breakdown["frame"]["stages"]


def test_fusion_rule_pluggable_and_event_only_weight(cfg, params, tcfg,
                                                     tparams):
    """weights (1, 0): the fused actuation collapses to the event wing's
    bitwise, proving the rule actually drives the output."""
    eng = _hetero_engine(cfg, params, tcfg, tparams, max_streams=1)
    sess = FusionSession(eng, fusion=late_logit_fusion(1.0, 0.0))
    evs, frs = _windows(2, seed=200), _frames(2, seed=201)
    for k in range(2):
        sess.submit(evs[k], frs[k])
    fused = sess.run()
    ev_eng = StreamEngine(engines=[BatchedClosedLoop(params, cfg)],
                          max_streams=1)
    h = ev_eng.open()
    for w in evs:
        h.submit(w)
    for r, ref in zip(fused, ev_eng.run()):
        np.testing.assert_array_equal(r.result.pwm, ref.result.pwm)
        np.testing.assert_array_equal(r.result.label_pred,
                                      ref.result.label_pred)
        # ... but energy still counts BOTH wings (fusion fuses decisions,
        # not accounting).
        assert r.result.energy_mj > ref.result.energy_mj


def test_fusion_session_leaves_foreign_streams_alone(cfg, params, tcfg,
                                                     tparams):
    eng = _hetero_engine(cfg, params, tcfg, tparams,
                         max_streams={"event": 2, "frame": 1})
    sess = FusionSession(eng)
    solo = eng.open(modality="event", stream_id="solo")
    evs, frs = _windows(2, seed=210), _frames(2, seed=211)
    sess.submit(evs[0], frs[0])
    solo.submit(evs[1])
    fused = sess.run()
    assert [r.stream_id for r in fused] == [sess.session_id]
    assert [r.stream_id for r in sess.unclaimed] == ["solo"]
    assert sess.stats["ticks_fused"] == 1
    assert sess.stats["event"].windows == 1


def test_fusion_session_checkpoint_restore(cfg, params, tcfg, tparams):
    """A whole fusion stream migrates: both wings' carries + the tick
    cursor; post-migration fused ticks are bitwise identical to the
    uninterrupted session."""
    n, cut = 4, 2
    evs, frs = _windows(n, seed=220), _frames(n, seed=221)

    def mk_engine():
        return _hetero_engine(cfg, params, tcfg, tparams, max_streams=1)

    # Uninterrupted oracle session.
    oracle = FusionSession(mk_engine(), session_id="o", stateful=True)
    for k in range(n):
        oracle.submit(evs[k], frs[k])
    ref = oracle.run()

    sess_a = FusionSession(mk_engine(), session_id="m", stateful=True)
    for k in range(cut):
        sess_a.submit(evs[k], frs[k])
    got = sess_a.run()
    ck = pickle.loads(pickle.dumps(sess_a.checkpoint()))
    sess_b = FusionSession.restore(mk_engine(), ck)
    assert sess_b.session_id == "m"
    for k in range(cut, n):
        sess_b.submit(evs[k], frs[k])
    got += sess_b.run()

    assert [r.seq for r in got] == [r.seq for r in ref] == list(range(n))
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(a.result.pwm, b.result.pwm)
        np.testing.assert_array_equal(a.result.logits, b.result.logits)
        assert a.result.energy_mj == b.result.energy_mj


def test_fusion_submit_is_atomic_on_bad_window(cfg, params, tcfg,
                                               tparams):
    """A rejected tick (one bad window) queues NOTHING: the wings stay
    in lockstep and the next good tick pairs correctly."""
    eng = _hetero_engine(cfg, params, tcfg, tparams, max_streams=1)
    sess = FusionSession(eng)
    rng = np.random.default_rng(240)
    bad_frame = fr.synthetic_gesture_frames(rng, 0, height=16, width=16)
    with pytest.raises(ValueError, match="geometry"):
        sess.submit(_windows(1, seed=241)[0], bad_frame)
    assert sess.event.queued == 0 and sess.frame.queued == 0
    assert sess.submit(_windows(1, seed=242)[0],
                       _frames(1, seed=243)[0]) == 0
    assert len(sess.run()) == 1


def test_fusion_restore_rejects_mismatched_rule(cfg, params, tcfg,
                                                tparams):
    """Restoring a custom-rule session without re-supplying the rule
    must raise, not silently fuse with the 0.5/0.5 default."""
    sess = FusionSession(_hetero_engine(cfg, params, tcfg, tparams,
                                        max_streams=1),
                         fusion=late_logit_fusion(0.9, 0.1))
    sess.submit(_windows(1, seed=250)[0], _frames(1, seed=251)[0])
    sess.run()
    ck = sess.checkpoint()
    fresh = _hetero_engine(cfg, params, tcfg, tparams, max_streams=1)
    with pytest.raises(ValueError, match="rules are code"):
        FusionSession.restore(fresh, ck)
    assert FusionSession.restore(
        fresh, ck, fusion=late_logit_fusion(0.9, 0.1)
    ).session_id == sess.session_id


def test_fusion_init_leak_free_on_bad_passed_handle(cfg, params, tcfg,
                                                    tparams):
    """A rejected construction (passed handle of the wrong modality)
    must not leave an auto-opened other-wing stream behind."""
    eng = _hetero_engine(cfg, params, tcfg, tparams, max_streams=1)
    wrong = eng.open(modality="frame", stream_id="not-an-event")
    with pytest.raises(ValueError, match="event_handle"):
        FusionSession(eng, session_id="s", event_handle=wrong)
    assert set(eng.handles) == {"not-an-event"}
    FusionSession(eng, session_id="s")        # same id now constructs


def test_restore_validates_queued_windows(cfg, params, tcfg, tparams):
    """Checkpointed windows an engine cannot serve reject the restore
    up front (validate-before-queue-state), not mid-dispatch."""
    fr_eng = StreamEngine(engines=[FrameTCNEngine(tparams, tcfg)],
                          max_streams=1)
    h = fr_eng.open(stream_id="cam")
    h.submit(_frames(1, seed=260)[0])         # queued, unserved
    ck = h.checkpoint()
    small = TCNConfig(height=16, width=16, conv1_features=4,
                      conv2_features=8, hidden=32, num_classes=11)
    other = StreamEngine(
        engines=[FrameTCNEngine(init_tcn(jax.random.PRNGKey(3), small),
                                small)], max_streams=1)
    with pytest.raises(ValueError, match="geometry"):
        other.restore(ck)
    assert other.handles == {}                # failed restore cleaned up
    # ...and the rejected restore rolled back the duration it latched
    # while validating, leaving the engine exactly as it found it.
    assert other.engines["frame"].duration_us is None
    fr_eng.run()


def test_fusion_restore_cleans_up_on_frame_wing_failure(cfg, params,
                                                        tcfg, tparams):
    """If the frame wing of a session checkpoint cannot restore, the
    already-restored event wing must not be left stranded on the target
    engine."""
    sess = FusionSession(_hetero_engine(cfg, params, tcfg, tparams,
                                        max_streams=1),
                         session_id="m", stateful=True)
    sess.submit(_windows(1, seed=280)[0], _frames(1, seed=281)[0])
    sess.run()
    ck = sess.checkpoint()
    small = TCNConfig(height=16, width=16, conv1_features=4,
                      conv2_features=8, hidden=32, num_classes=11)
    target = StreamEngine(
        engines=[BatchedClosedLoop(params, cfg),
                 FrameTCNEngine(init_tcn(jax.random.PRNGKey(3), small),
                                small, duration_us=150_000)],
        max_streams=1)
    with pytest.raises(ValueError, match="duration_us"):
        FusionSession.restore(target, ck)
    assert target.handles == {}               # nothing stranded
    # A compatible target then restores the same payload cleanly.
    ok = FusionSession.restore(
        _hetero_engine(cfg, params, tcfg, tparams, max_streams=1), ck)
    assert ok.session_id == "m"


def test_checkpoint_migrates_default_deadline(cfg, params):
    """A handle's default deadline survives migration: post-restore
    submits keep the stream's scheduling urgency."""
    eng = StreamEngine(params, cfg, max_streams=1)
    h = eng.open(stream_id="s", deadline=5.0)
    h.submit(_windows(1, seed=270)[0])
    eng.run()
    ck = h.checkpoint()
    assert ck.deadline == 5.0
    eng_b = StreamEngine(params, cfg, max_streams=1)
    h_b = eng_b.restore(ck)
    assert h_b.deadline == 5.0
    h_b.submit(_windows(1, seed=271)[0])
    lane = eng_b._lanes["event"]
    assert [q.deadline for q in lane.queues["s"]] == [5.0]
    eng_b.run()


def test_fusion_desync_detected_before_queueing(cfg, params, tcfg,
                                                tparams):
    eng = _hetero_engine(cfg, params, tcfg, tparams, max_streams=1)
    sess = FusionSession(eng)
    # A rogue submit on one wing's handle desynchronizes the pairing;
    # the next session submit detects it BEFORE queueing anything, so
    # the desync cannot deepen into mispaired ticks.
    sess.event.submit(_windows(1, seed=230)[0])
    with pytest.raises(RuntimeError, match="desynchronized"):
        sess.submit(_windows(1, seed=231)[0], _frames(1, seed=232)[0])
    assert sess.event.queued == 1 and sess.frame.queued == 0
    eng.run()
