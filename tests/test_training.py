"""Training substrate: convergence, checkpoint/restart, fault tolerance,
gradient compression."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import TokenTaskConfig, token_batch
from repro.models import ModelConfig, build_model
from repro.training import (AdamWConfig, Trainer, TrainerConfig,
                            checkpoint as CKPT)
from repro.training.compression import compress_grads, compression_init
from repro.training.optimizer import adamw_init, adamw_update, cosine_schedule


def _tiny_model():
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                      vocab_size=64, d_ff=128, num_heads=4, num_kv_heads=2,
                      dtype="float32")
    return build_model(cfg)


def _trainer(model, tmpdir, total=30, ckpt_every=10, **kw):
    tk = TokenTaskConfig(vocab_size=64, seq_len=16, batch_size=16,
                         task="repeat")
    tc = TrainerConfig(total_steps=total, ckpt_every=ckpt_every,
                       ckpt_dir=str(tmpdir), log_every=1000,
                       opt=AdamWConfig(lr=5e-3, warmup_steps=5,
                                       total_steps=total), **kw)
    return Trainer(model, tc, lambda s: token_batch(tk, s))


def test_loss_decreases(tmp_path):
    model = _tiny_model()
    tr = _trainer(model, tmp_path / "c1", total=40)
    res = tr.run(jax.random.PRNGKey(0))
    losses = [h["loss"] for h in res["history"]]
    assert losses[-1] < losses[0] * 0.5, losses[::10]


def test_checkpoint_roundtrip(tmp_path):
    state = {"a": {"w": jnp.arange(12.0).reshape(3, 4)},
             "b": jnp.ones((5,), jnp.int32)}
    CKPT.save_checkpoint(tmp_path, 7, state, extra={"cursor": 7})
    restored, extra = CKPT.restore_checkpoint(tmp_path, 7, state)
    np.testing.assert_array_equal(np.asarray(restored["a"]["w"]),
                                  np.asarray(state["a"]["w"]))
    assert extra["cursor"] == 7
    assert CKPT.latest_step(tmp_path) == 7


def test_checkpoint_keep_last(tmp_path):
    state = {"x": jnp.zeros((2,))}
    for s in (10, 20, 30, 40):
        CKPT.save_checkpoint(tmp_path, s, state, keep_last=2)
    assert CKPT.list_steps(tmp_path) == [30, 40]


def test_corrupt_checkpoint_falls_back(tmp_path):
    state = {"x": jnp.arange(4.0)}
    CKPT.save_checkpoint(tmp_path, 10, state, keep_last=5)
    CKPT.save_checkpoint(tmp_path, 20, state, keep_last=5)
    # corrupt the newest arrays file
    (tmp_path / "step_00000020" / "arrays.npz").write_bytes(b"garbage")
    out = CKPT.restore_latest(tmp_path, state)
    assert out is not None and out[0] == 10


def test_crash_restart_is_bit_identical(tmp_path):
    """A simulated node failure + restore reproduces the uninterrupted
    loss trajectory exactly (checkpoint captures params+opt+cursor)."""
    model = _tiny_model()
    crashed = {"done": False}

    def hook(step):
        if step == 15 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("simulated node failure")

    tr1 = _trainer(model, tmp_path / "a", total=25, ckpt_every=5)
    res1 = tr1.run_with_restarts(jax.random.PRNGKey(0), failure_hook=hook)
    tr2 = _trainer(model, tmp_path / "b", total=25, ckpt_every=5)
    res2 = tr2.run(jax.random.PRNGKey(0))
    assert res1["history"][-1]["loss"] == pytest.approx(
        res2["history"][-1]["loss"], abs=1e-7)


def test_gradient_compression_error_feedback():
    """Compressed stream + error feedback transmits every coordinate
    eventually: residual of a CONSTANT gradient is fully flushed."""
    rng = np.random.default_rng(0)
    vals = (0.5 + rng.random(64)) * np.sign(rng.normal(size=64))
    g = {"w": jnp.asarray(vals, jnp.float32)}   # |g| in [0.5, 1.5]
    err = compression_init(g)
    sent_total = jnp.zeros((64,))
    for _ in range(60):
        sent, err, _ = compress_grads(g, err, ratio=0.1)
        sent_total = sent_total + sent["w"]
    # Invariant: transmitted + residual == N * g EXACTLY (error feedback
    # conserves gradient mass -- nothing is lost, only delayed).
    total = sent_total + err["w"]
    np.testing.assert_allclose(np.asarray(total), 60 * np.asarray(g["w"]),
                               rtol=1e-4)
    # Every coordinate is eventually transmitted (no starvation), and the
    # cumulative stream tracks the dense one up to the bounded lag of the
    # pending residual.
    ratio = np.asarray(sent_total / (60 * g["w"]))
    assert (np.asarray(sent_total) != 0).all()
    assert ratio.min() > 0.3 and ratio.max() < 1.05


def test_compression_sparsity():
    g = {"w": jnp.asarray(np.random.default_rng(1).normal(size=(1000,)),
                          jnp.float32)}
    sent, err, _ = compress_grads(g, compression_init(g), ratio=0.05)
    nnz = int((sent["w"] != 0).sum())
    assert nnz <= 60  # ~5% of 1000 (+ ties)


def test_training_with_compression_converges(tmp_path):
    model = _tiny_model()
    tr = _trainer(model, tmp_path / "c2", total=40,
                  grad_compression_ratio=0.25)
    res = tr.run(jax.random.PRNGKey(0))
    losses = [h["loss"] for h in res["history"]]
    assert losses[-1] < losses[0] * 0.7


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(cosine_schedule(cfg, jnp.asarray(s)))
           for s in (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert 0.1 < lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.1, rel=1e-3)


def test_straggler_detection():
    model = _tiny_model()
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        tr = _trainer(model, d, total=1)
        for _ in range(20):
            tr._track_stragglers(0.01)
        tr._track_stragglers(0.5)   # 50x median
        assert tr.straggler_steps == 1
