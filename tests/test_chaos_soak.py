"""Chaos soak: seeded fault churn over real engines, bitwise oracles.

The acceptance bar from the fault-tolerance ISSUE, pinned end to end:
under seeded injected faults (step exceptions, NaN poison, lane kills)
every window ever reported successful is bitwise-identical to the
uninterrupted-scan oracle -- sync and pipelined -- with retries,
quarantines, a supervisor restore, and degraded fusion ticks all
actually exercised; and a fault-rate-0 run through the recovery-enabled
engine is bitwise-identical to the pre-PR (no-recovery) engine.

Everything here is deterministic: the injector is seeded and draws in
call order, backoff counts engine steps (not wall time), and the
assertions never read clocks -- so a failure replays exactly.
"""
import jax
import numpy as np
import pytest

from repro.core import SNNConfig, init_snn
from repro.core._api import EngineConfig, FaultConfig, RecoveryConfig
from repro.core.pipeline import BatchedClosedLoop, ClosedLoopResult
from repro.fleet import CheckpointStore, FaultInjector, LaneSupervisor
from repro.serving import FusionSession, StreamEngine

from test_faults import Stub
from test_stateful_stream import (_assert_matches_oracle,
                                  _uninterrupted_oracle, _windows)


@pytest.fixture(scope="module")
def cfg():
    return SNNConfig(height=32, width=32, time_bins=4, conv1_features=4,
                     conv2_features=8, hidden=32, num_classes=11)


@pytest.fixture(scope="module")
def params(cfg):
    return init_snn(jax.random.PRNGKey(0), cfg)


def _soak(params, cfg, config, *, streams, fault=None, max_steps=400):
    """Submit every stream's windows on one engine (faulted when a
    FaultConfig is given) and step until quiescent; returns
    (engine, all results)."""
    if fault is not None:
        inj = FaultInjector(fault)
        wrap = lambda e: inj.wrap(e)
    else:
        wrap = lambda e: e
    eng = StreamEngine(
        engines=[wrap(BatchedClosedLoop.from_config(params, cfg, config))],
        config=config)
    handles = {sid: eng.open(modality="event", stream_id=sid,
                             stateful=True)
               for sid in streams}
    got = []
    for k in range(max(len(ws) for ws in streams.values())):
        for sid, ws in streams.items():
            if k < len(ws):
                handles[sid].submit(ws[k])
        got.extend(eng.step())
    for _ in range(max_steps):
        out = eng.step()
        got.extend(out)
        if not out and not eng.pending() and not eng._inflight:
            break
    got.extend(eng.flush())
    return eng, got


def _streams(n_streams, n_windows, seed=0):
    return {f"s{i}": _windows(n_windows, seed=seed + 31 * i)
            for i in range(n_streams)}


# ----------------------------------------------------------------------
# Stateful churn under step errors: every window survives retries and
# the whole scan stays bitwise, sync and pipelined.
# ----------------------------------------------------------------------

@pytest.mark.parametrize("depth", [0, 2])
def test_soak_stateful_step_errors_bitwise(params, cfg, depth):
    streams = _streams(2, 6, seed=3)
    config = EngineConfig(
        max_streams=2, pipeline_depth=depth,
        recovery=RecoveryConfig(max_retries=4, backoff_steps=1,
                                dead_after=50))
    eng, got = _soak(params, cfg, config, streams=streams,
                     fault=FaultConfig(seed=9, step_error_rate=0.15))
    tel = eng.telemetry("event")
    assert tel.retries >= 1                       # churn actually happened
    assert tel.quarantined == 0                   # seeded: no exhaustion
    ok = [r for r in got if r.ok]
    per_stream = {}
    for r in ok:
        per_stream.setdefault(r.stream_id, []).append(r.seq)
    assert all(sorted(v) == list(range(6)) for v in per_stream.values())
    ids, per_window = _uninterrupted_oracle(params, cfg, streams)
    _assert_matches_oracle(ok, ids, per_window)


# ----------------------------------------------------------------------
# Stateless churn under errors + NaN poison: quarantines fire, and every
# successful window still equals its per-window oracle.
# ----------------------------------------------------------------------

@pytest.mark.parametrize("depth", [0, 2])
def test_soak_stateless_nan_and_errors(params, cfg, depth):
    streams = _streams(2, 8, seed=17)

    def run(fault):
        if fault is not None:
            inj = FaultInjector(fault)
            wrap = inj.wrap
        else:
            wrap = lambda e: e
        config = EngineConfig(
            max_streams=2, pipeline_depth=depth,
            recovery=None if fault is None else RecoveryConfig(
                max_retries=1, backoff_steps=0, dead_after=50))
        eng = StreamEngine(
            engines=[wrap(BatchedClosedLoop.from_config(
                params, cfg, config))],
            config=config)
        hs = {sid: eng.open(modality="event", stream_id=sid)
              for sid in streams}
        got = []
        for k in range(8):
            for sid in streams:
                hs[sid].submit(streams[sid][k])
            got.extend(eng.step())
        for _ in range(200):
            out = eng.step()
            got.extend(out)
            if not out and not eng.pending() and not eng._inflight:
                break
        got.extend(eng.flush())
        return eng, got

    _, clean = run(None)
    baseline = {(r.stream_id, r.seq): r.result for r in clean}
    eng, got = run(FaultConfig(seed=2, step_error_rate=0.1, nan_rate=0.1))
    tel = eng.telemetry("event")
    assert tel.retries >= 1 and tel.quarantined >= 1
    assert len(eng.dead_letters("event")) == tel.quarantined
    ok = [r for r in got if r.ok]
    assert ok                                      # the soak served windows
    for r in ok:                                   # zero divergence
        ref = baseline[(r.stream_id, r.seq)]
        np.testing.assert_array_equal(r.result.label_pred, ref.label_pred)
        np.testing.assert_array_equal(r.result.pwm, ref.pwm)
        np.testing.assert_array_equal(r.result.logits, ref.logits)
    # Quarantined windows emitted exactly one failed row each.
    failed = [r for r in got if r.status == "failed"]
    assert len(failed) == tel.quarantined


# ----------------------------------------------------------------------
# Supervised churn: random step errors PLUS a lane kill; the supervisor
# restores and the whole scan stays bitwise, bounded recovery.
# ----------------------------------------------------------------------

def test_soak_supervised_lane_kill_recovers_bitwise(params, cfg):
    ws = _windows(10, seed=23)
    config = EngineConfig(
        max_streams=1,
        recovery=RecoveryConfig(max_retries=0, backoff_steps=0,
                                dead_after=1, checkpoint_every=3))
    inj = FaultInjector(FaultConfig(seed=5))
    make = lambda: inj.wrap(BatchedClosedLoop.from_config(
        params, cfg, config))
    eng = StreamEngine(engines=[make()], config=config)
    sup = LaneSupervisor(eng, store=CheckpointStore(capacity=4),
                         rebuild=lambda modality: make())
    h = sup.watch(eng.open(modality="event", stateful=True))
    got = []
    recovery_ticks = None
    for k, w in enumerate(ws):
        sup.submit(h.stream_id, w)
        if k == 6:
            inj.kill("event")
        got.extend(sup.tick(eng.step()))
        if k == 7:
            inj.revive("event")
        if recovery_ticks is None and sup.stats["restores"]:
            recovery_ticks = k - 6                # ticks from kill to restore
    for _ in range(10):
        got.extend(sup.tick(eng.step()))
    assert sup.stats["restores"] >= 1
    assert recovery_ticks is not None and recovery_ticks <= 2  # bounded
    ok = [r for r in got if r.ok]
    assert sorted(r.seq for r in ok) == list(range(len(ws)))
    ids, per_window = _uninterrupted_oracle(params, cfg,
                                            {h.stream_id: ws})
    _assert_matches_oracle(ok, ids, per_window)


# ----------------------------------------------------------------------
# Fusion under churn: a killed wing degrades (never stalls) and fused
# ticks resume after the lane is replaced.
# ----------------------------------------------------------------------

def test_soak_fusion_wing_kill_degrades_then_resumes():
    inj = FaultInjector(FaultConfig(seed=1))
    eng = StreamEngine(
        engines=[inj.wrap(Stub("event")), inj.wrap(Stub("frame"))],
        config=EngineConfig(max_streams=1,
                            recovery=RecoveryConfig(max_retries=0,
                                                    backoff_steps=0,
                                                    dead_after=1)))
    sess = FusionSession(eng)
    rows = []
    for t in range(12):
        if t == 4:
            inj.kill("frame")
        if t == 8:
            inj.revive("frame")
            eng.replace_lane_engine("frame", engine=inj.wrap(Stub("frame")))
        sess.submit(t, 100 + t)
        rows.extend(sess.step())
    rows.extend(sess.absorb(eng.flush()) or sess.drain())
    rows.extend(sess.drain())
    # Every tick emitted exactly once, in order, fused or degraded.
    assert [r.seq for r in rows] == list(range(12))
    statuses = [r.status for r in rows]
    assert statuses[:4] == ["ok"] * 4
    assert "degraded" in statuses                 # the wing-down stretch
    assert statuses[-4:] == ["ok"] * 4            # resumed after replace
    assert sess.ticks_degraded >= 1
    assert all(r.result.breakdown["degraded_wing"] == "frame"
               for r in rows if r.status == "degraded")


# ----------------------------------------------------------------------
# Fault-rate zero: the recovery-enabled engine is bitwise the pre-PR
# engine, with zero recovery machinery engaged.
# ----------------------------------------------------------------------

@pytest.mark.parametrize("depth", [0, 2])
def test_fault_rate_zero_is_bitwise_pre_pr(params, cfg, depth):
    streams = _streams(2, 4, seed=41)
    plain_cfg = EngineConfig(max_streams=2, pipeline_depth=depth)
    rec_cfg = EngineConfig(max_streams=2, pipeline_depth=depth,
                           recovery=RecoveryConfig())
    _, plain = _soak(params, cfg, plain_cfg, streams=streams, fault=None)
    eng, guarded = _soak(params, cfg, rec_cfg, streams=streams,
                         fault=FaultConfig(seed=0))   # all rates zero
    assert eng.fault_log == []
    tel = eng.telemetry("event")
    assert tel.retries == 0 and tel.quarantined == 0 and not tel.dead
    assert len(plain) == len(guarded)
    for a, b in zip(plain, guarded):
        assert (a.stream_id, a.seq, a.status) == (b.stream_id, b.seq,
                                                  b.status)
        np.testing.assert_array_equal(a.result.label_pred,
                                      b.result.label_pred)
        np.testing.assert_array_equal(a.result.pwm, b.result.pwm)
        np.testing.assert_array_equal(a.result.logits, b.result.logits)
