"""Slot-axis sharding across a device mesh + the EngineConfig surface.

The tentpole contract: a ``StreamEngine`` built with
``EngineConfig(mesh=make_mesh())`` runs one shard_map'd jit step per lane
with the batch-slot axis partitioned over the mesh's data axis, and its
results are BITWISE identical to the single-device engine -- sync and
pipelined, stateful and stateless, at B in {4, 8}, over 1/2/4 devices --
with zero collectives in the compiled step. Checkpoints cross device
counts: a stream checkpointed on a 4-device engine restores bitwise on a
1-device engine (and back).

Multi-device cases run in subprocesses that set
``--xla_force_host_platform_device_count`` themselves (the in-process
suite must keep seeing the 1 real CPU device -- see conftest.py).

Also here: the EngineConfig construction surface (config == legacy-kwarg
shim bitwise; one-shot kwargs deprecation; mutual exclusion), the
unified ``repro.distributed.make_mesh`` entrypoint, and the
DeadlinePolicy bookkeeping-release regression (close() must drop the
per-stream aging counters via ``policy.forget``).
"""
import os
import pickle
import subprocess
import sys
import textwrap
import warnings

import jax
import numpy as np
import pytest

from repro.core import EngineConfig, SNNConfig, init_snn
from repro.core import events as ev
from repro.core.pipeline import BatchedClosedLoop
from repro.distributed import (make_mesh, slot_axis, slot_pspec,
                               slot_state_pspecs)
from repro.serving import DeadlinePolicy, StreamEngine

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


# Shared subprocess preamble: the small test net, deterministic windows,
# and a serve() that returns every (stream, seq) row's outputs.
_PRELUDE = """
import numpy as np, jax, jax.numpy as jnp
from repro.core import SNNConfig, init_snn, snn_apply
from repro.core import events as ev
from repro.core.pipeline import BatchedClosedLoop, pwm_from_logits
from repro.core._api import EngineConfig
from repro.serving import StreamEngine
from repro.distributed import make_mesh

CFG = SNNConfig(height=32, width=32, time_bins=4, conv1_features=4,
                conv2_features=8, hidden=32, num_classes=11)
PARAMS = init_snn(jax.random.PRNGKey(0), CFG)

def windows(n, seed=0, mean_events=1500):
    rng = np.random.default_rng(seed)
    return [ev.synthetic_gesture_events(rng, i % 11, mean_events=mean_events,
                                        height=32, width=32)
            for i in range(n)]

def streams_of(n_streams, n_windows, seed=0):
    return {f"s{i}": windows(n_windows, seed=seed + i)
            for i in range(n_streams)}

def serve(eng, streams, stateful_ids=()):
    hs = {sid: eng.open(stream_id=sid, stateful=sid in stateful_ids)
          for sid in sorted(streams)}
    n_windows = len(next(iter(streams.values())))
    for k in range(n_windows):
        for sid in sorted(streams):
            hs[sid].submit(streams[sid][k])
    rows = {}
    for r in eng.run():
        rows[(r.stream_id, r.seq)] = (np.asarray(r.result.label_pred),
                                      np.asarray(r.result.pwm),
                                      np.asarray(r.result.logits))
    return rows

def assert_rows_equal(a, b):
    assert set(a) == set(b), (sorted(a), sorted(b))
    for key in a:
        for x, y in zip(a[key], b[key]):
            np.testing.assert_array_equal(x, y, err_msg=str(key))
"""


def _run_sub(body: str, devices: int = 4) -> str:
    """Run ``_PRELUDE + dedent(body)`` under N forced host devices.

    The body is dedented SEPARATELY and concatenated at column 0 (an
    f-string-embedded prelude would defeat textwrap.dedent and silently
    swallow the body into the prelude's last function). Every body must
    end by printing ``OK`` -- asserted here, so a subprocess that exits
    0 without reaching its assertions can never pass vacuously.
    """
    code = _PRELUDE + "\n" + textwrap.dedent(body)
    compile(code, "<sharded-test>", "exec")    # fail fast on bad compose
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout, (out.stdout, out.stderr[-1500:])
    return out.stdout


# ----------------------------------------------------------------------
# Tentpole: sharded serving == single-device serving, bitwise.
# ----------------------------------------------------------------------

@pytest.mark.parametrize("devices", [1, 2, 4])
@pytest.mark.parametrize("slots", [4, 8])
def test_sharded_serving_bitwise_parity(devices, slots):
    """Mesh-sharded StreamEngine == no-mesh StreamEngine, bitwise: sync
    and pipelined, stateful and stateless streams interleaved, more
    streams than slots (so slot parking/reassignment runs sharded)."""
    _run_sub(f"""
        SLOTS = {slots}
        streams = streams_of(SLOTS + 2, 2, seed=11)
        stateful = tuple(sorted(streams))[::2]
        mesh = make_mesh({devices})
        for depth in (0, 1):
            base = StreamEngine(
                PARAMS, CFG, EngineConfig(max_streams=SLOTS,
                                          pipeline_depth=depth))
            shard = StreamEngine(
                PARAMS, CFG, EngineConfig(max_streams=SLOTS,
                                          pipeline_depth=depth,
                                          mesh=mesh))
            assert_rows_equal(serve(base, streams, stateful),
                              serve(shard, streams, stateful))
        print("OK")
    """, devices=devices)


def test_sharded_step_is_collective_free_and_state_sharded():
    """The compiled sharded step contains NO collectives (the shard_map
    step is structurally per-shard), and the carried state it returns is
    slot-sharded over the mesh -- both engine wings."""
    out = _run_sub(f"""
        from repro.core import FrameTCNEngine, TCNConfig, init_tcn
        from repro.core import frames as fr
        mesh = make_mesh(2)
        eng = BatchedClosedLoop.from_config(
            PARAMS, CFG, EngineConfig(duration_us=300000, mesh=mesh))
        ws = windows(4, seed=3)
        batch = eng.prepare(ws, batch_size=4)
        _, state = eng.infer(batch, eng.init_state(4))
        sh = state["fc1"].sharding
        print("SPEC", getattr(sh, "spec", None))

        tcfg = TCNConfig(height=32, width=32, conv1_features=4,
                         conv2_features=8, hidden=32, num_classes=11)
        feng = FrameTCNEngine.from_config(
            init_tcn(jax.random.PRNGKey(1), tcfg), tcfg,
            EngineConfig(duration_us=300000, mesh=mesh))
        rng = np.random.default_rng(5)
        frames = [fr.synthetic_gesture_frames(rng, i % 11, height=32,
                                              width=32) for i in range(4)]
        feng.infer_collect(feng.infer_dispatch(
            feng.prepare(frames, batch_size=4)))

        for wing in (eng, feng):
            for exe in wing._exe.values():
                txt = exe.as_text()
                bad = [l for l in txt.splitlines()
                       if "all-reduce" in l or "all-gather" in l
                       or "all-to-all" in l or "collective-permute" in l]
                assert not bad, bad[:3]
        print("OK")
    """, devices=2)
    assert "OK" in out
    assert "PartitionSpec('data',)" in out


# ----------------------------------------------------------------------
# Satellite 4: the key stateful/session parity suites over 1/2/4 devices.
# ----------------------------------------------------------------------

@pytest.mark.parametrize("devices", [1, 2, 4])
def test_stateful_windows_match_uninterrupted_scan_sharded(devices):
    """W windows served stateful on a SHARDED engine == one
    uninterrupted scan over the concatenated event stream (the PR 4
    contract, now parameterized over the device mesh)."""
    _run_sub(f"""
        W = 3
        ws = windows(W, seed=21)
        # Oracle: one uninterrupted scan over the concatenated stream.
        d = ws[0].duration_us
        vox = ev.voxelize(
            jnp.asarray(np.concatenate([w.x for w in ws])),
            jnp.asarray(np.concatenate([w.y for w in ws])),
            jnp.asarray(np.concatenate(
                [w.t + k * d for k, w in enumerate(ws)])),
            jnp.asarray(np.concatenate([w.p for w in ws])),
            duration_us=d * W, time_bins=CFG.time_bins * W,
            height=CFG.height, width=CFG.width)[None]
        out = snn_apply(PARAMS, vox, CFG, mode="layer_serial")
        eng = StreamEngine(PARAMS, CFG,
                           EngineConfig(max_streams=4,
                                        mesh=make_mesh({devices})))
        h = eng.open(stateful=True)
        for w in ws:
            h.submit(w)
        t = CFG.time_bins
        for r in eng.run():
            logits = out["out_spikes"][:, r.seq * t:(r.seq + 1) * t]
            logits = logits.mean(axis=1) * 10.0
            np.testing.assert_array_equal(
                r.result.label_pred, np.asarray(jnp.argmax(logits, -1)))
            np.testing.assert_array_equal(
                r.result.pwm, np.asarray(pwm_from_logits(logits)))
        print("OK")
    """, devices=devices)


@pytest.mark.parametrize("devices", [1, 2, 4])
def test_checkpoint_restore_parity_sharded(devices):
    """checkpoint() mid-stream on a sharded engine and restore() into a
    FRESH sharded engine: the continuation is bitwise identical to the
    uninterrupted run (same device count; cross-count migration is the
    test below)."""
    _run_sub(f"""
        ws = windows(4, seed=31)
        cfg_s = EngineConfig(max_streams=4, mesh=make_mesh({devices}))
        # Uninterrupted run.
        ref = StreamEngine(PARAMS, CFG, cfg_s)
        h = ref.open(stream_id="s", stateful=True)
        for w in ws:
            h.submit(w)
        want = {{r.seq: np.asarray(r.result.logits) for r in ref.run()}}
        # Interrupted at window 2: checkpoint, migrate, continue.
        a = StreamEngine(PARAMS, CFG, cfg_s)
        ha = a.open(stream_id="s", stateful=True)
        ha.submit(ws[0]); ha.submit(ws[1])
        got = {{r.seq: np.asarray(r.result.logits) for r in a.run()}}
        ckpt = ha.checkpoint()
        b = StreamEngine(PARAMS, CFG, cfg_s)
        hb = b.open(stream_id="s", stateful=True).restore(ckpt)
        hb.submit(ws[2]); hb.submit(ws[3])
        got.update({{r.seq: np.asarray(r.result.logits) for r in b.run()}})
        assert set(got) == set(want)
        for k in want:
            np.testing.assert_array_equal(got[k], want[k], err_msg=str(k))
        print("OK")
    """, devices=devices)


@pytest.mark.parametrize("direction", ["4to1", "1to4"])
def test_checkpoint_migrates_across_device_counts(direction, tmp_path):
    """A checkpoint taken on an N-device sharded engine restores bitwise
    on a 1-device engine (and back): exported carries are host numpy,
    so the mesh layout never leaks into the checkpoint."""
    src_dev, dst_dev = (4, 1) if direction == "4to1" else (1, 4)
    ckpt_file = tmp_path / "ckpt.pkl"
    # Process A (src_dev devices): serve 2 windows, checkpoint, and also
    # record the expected continuation by serving windows 3/4 on an
    # uninterrupted engine.
    _run_sub(f"""
        import pickle
        ws = windows(4, seed=41)
        mesh = make_mesh({src_dev})
        ref = StreamEngine(PARAMS, CFG, EngineConfig(max_streams=4,
                                                     mesh=mesh))
        h = ref.open(stream_id="mig", stateful=True)
        for w in ws:
            h.submit(w)
        want = {{r.seq: np.asarray(r.result.logits) for r in ref.run()}}
        a = StreamEngine(PARAMS, CFG, EngineConfig(max_streams=4,
                                                   mesh=mesh))
        ha = a.open(stream_id="mig", stateful=True)
        ha.submit(ws[0]); ha.submit(ws[1])
        a.run()
        ckpt = ha.checkpoint()
        with open({str(ckpt_file)!r}, "wb") as f:
            pickle.dump((ckpt, {{k: v for k, v in want.items()}}), f)
        print("OK")
    """, devices=src_dev)
    # Process B (dst_dev devices): restore and continue; rows 2/3 must be
    # bitwise equal to process A's uninterrupted run.
    _run_sub(f"""
        import pickle
        with open({str(ckpt_file)!r}, "rb") as f:
            ckpt, want = pickle.load(f)
        ws = windows(4, seed=41)
        eng = StreamEngine(
            PARAMS, CFG,
            EngineConfig(max_streams=4, mesh=make_mesh({dst_dev})))
        h = eng.open(stream_id="mig", stateful=True).restore(ckpt)
        h.submit(ws[2]); h.submit(ws[3])
        got = {{r.seq: np.asarray(r.result.logits) for r in eng.run()}}
        assert set(got) == {{2, 3}}, sorted(got)
        for k in (2, 3):
            np.testing.assert_array_equal(got[k], want[k], err_msg=str(k))
        print("OK")
    """, devices=dst_dev)


def test_sharded_lane_slot_divisibility_enforced():
    """Lane slot counts that do not divide over the mesh fail loudly at
    construction (never a silent single-device fallback)."""
    _run_sub(f"""
        mesh = make_mesh(4)
        try:
            StreamEngine(PARAMS, CFG,
                         EngineConfig(max_streams=6, mesh=mesh))
        except ValueError as e:
            assert "divide" in str(e), e
        else:
            raise AssertionError("indivisible lane accepted")
        eng = BatchedClosedLoop.from_config(
            PARAMS, CFG, EngineConfig(duration_us=300000, mesh=mesh))
        try:
            eng._executable((6, 64, 300000))
        except ValueError as e:
            assert "divide" in str(e), e
        else:
            raise AssertionError("indivisible batch accepted")
        print("OK")
    """, devices=4)


def test_attach_mesh_rules():
    """attach_mesh is idempotent for the same mesh, rejects a second
    different mesh, and rejects attaching after compilation; engines=
    construction threads the serving mesh onto caller engines."""
    _run_sub(f"""
        from jax.sharding import Mesh
        mesh = make_mesh(2)
        other = make_mesh((2,), ("x",))
        eng = BatchedClosedLoop(PARAMS, CFG, duration_us=300000, mesh=mesh)
        eng.attach_mesh(mesh)            # same mesh: no-op
        try:
            eng.attach_mesh(other)
        except ValueError as e:
            assert "different mesh" in str(e), e
        else:
            raise AssertionError("second mesh accepted")
        eng2 = BatchedClosedLoop(PARAMS, CFG, duration_us=300000)
        eng2._exe["poisoned"] = lambda: None
        try:
            eng2.attach_mesh(mesh)
        except RuntimeError as e:
            assert "compiled" in str(e), e
        else:
            raise AssertionError("post-compile attach accepted")
        # engines= threads the mesh (idempotent with a pre-attached one).
        pre = BatchedClosedLoop(PARAMS, CFG, duration_us=300000, mesh=mesh)
        served = StreamEngine(engines=[pre],
                              config=EngineConfig(max_streams=4,
                                                  mesh=mesh))
        assert served.mesh is mesh and pre.mesh is mesh
        conflicted = BatchedClosedLoop(PARAMS, CFG, duration_us=300000,
                                       mesh=other)
        try:
            StreamEngine(engines=[conflicted],
                         config=EngineConfig(max_streams=4, mesh=mesh))
        except ValueError as e:
            assert "different mesh" in str(e), e
        else:
            raise AssertionError("mesh conflict accepted")
        print("OK")
    """, devices=2)


def test_fusion_session_over_sharded_lanes():
    """FusionSession (cross-modal event+frame fusion) over a sharded
    heterogeneous engine == over the single-device engine, bitwise."""
    _run_sub(f"""
        from repro.core import FrameTCNEngine, TCNConfig, init_tcn
        from repro.core import frames as fr
        from repro.serving import FusionSession
        tcfg = TCNConfig(height=32, width=32, conv1_features=4,
                         conv2_features=8, hidden=32, num_classes=11)
        tparams = init_tcn(jax.random.PRNGKey(1), tcfg)
        rng = np.random.default_rng(7)
        evs = windows(2, seed=8)
        frs = [fr.synthetic_gesture_frames(rng, i % 11, height=32,
                                           width=32) for i in range(2)]
        def fused(mesh):
            engines = [BatchedClosedLoop(PARAMS, CFG),
                       FrameTCNEngine(tparams, tcfg)]
            eng = StreamEngine(engines=engines,
                               config=EngineConfig(max_streams=4,
                                                   mesh=mesh))
            fs = FusionSession(eng, stateful=True)
            for e, f in zip(evs, frs):
                fs.submit(e, f)
            return [(r.seq, np.asarray(r.result.logits),
                     np.asarray(r.result.pwm)) for r in fs.run()]
        a = fused(None)
        b = fused(make_mesh(4))
        assert len(a) == len(b) == 2
        for (sa, la, pa), (sb, lb, pb) in zip(a, b):
            assert sa == sb
            np.testing.assert_array_equal(la, lb)
            np.testing.assert_array_equal(pa, pb)
        print("OK")
    """, devices=4)


# ----------------------------------------------------------------------
# Satellite 1: the EngineConfig construction surface (in-process,
# 1 device -- the config semantics are mesh-independent).
# ----------------------------------------------------------------------

@pytest.fixture(scope="module")
def cfg():
    return SNNConfig(height=32, width=32, time_bins=4, conv1_features=4,
                     conv2_features=8, hidden=32, num_classes=11)


@pytest.fixture(scope="module")
def params(cfg):
    return init_snn(jax.random.PRNGKey(0), cfg)


def _windows(n, seed=0):
    rng = np.random.default_rng(seed)
    return [ev.synthetic_gesture_events(rng, i % 11, mean_events=1500,
                                        height=32, width=32)
            for i in range(n)]


def _serve_rows(eng, windows):
    h = eng.open(stateful=True)
    for w in windows:
        h.submit(w)
    return [(r.seq, np.asarray(r.result.logits)) for r in eng.run()]


def test_engine_config_validation():
    with pytest.raises(ValueError, match="pipeline_depth"):
        EngineConfig(pipeline_depth=-1)
    with pytest.raises(ValueError, match="fair_quantum"):
        EngineConfig(policy=DeadlinePolicy(), fair_quantum=2)
    import dataclasses
    with pytest.raises(dataclasses.FrozenInstanceError):
        EngineConfig().pipeline_depth = 3


def test_config_and_legacy_kwargs_mutually_exclusive(params, cfg):
    with pytest.raises(ValueError, match="mutually exclusive"):
        StreamEngine(params, cfg, EngineConfig(), max_streams=4)
    with pytest.raises(TypeError, match="EngineConfig"):
        StreamEngine(params, cfg, {"max_streams": 4})


def test_legacy_kwargs_warn_once_config_is_silent(params, cfg):
    with pytest.warns(DeprecationWarning, match="EngineConfig"):
        StreamEngine(params, cfg, max_streams=2)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        StreamEngine(params, cfg, EngineConfig(max_streams=2))
        StreamEngine(params, cfg)          # bare default: also modern
    assert not [w for w in rec if w.category is DeprecationWarning]


def test_legacy_kwargs_and_config_build_identical_engines(params, cfg):
    """The shim is exactly a respelling: a kwarg-built engine and a
    config-built engine produce bitwise-identical serving rows."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = StreamEngine(params, cfg, max_streams=2, fair_quantum=3,
                              pipeline_depth=1, window_ms=250.0)
    modern = StreamEngine(params, cfg, EngineConfig(
        max_streams=2, fair_quantum=3, pipeline_depth=1, window_ms=250.0))
    assert legacy.config == modern.config
    ws = _windows(3, seed=51)
    for (sa, la), (sb, lb) in zip(_serve_rows(legacy, ws),
                                  _serve_rows(modern, ws)):
        assert sa == sb
        np.testing.assert_array_equal(la, lb)


def test_from_config_forwards_engine_fields(params, cfg):
    config = EngineConfig(duration_us=300000, window_ms=123.0,
                          fuse_fc=True)
    eng = BatchedClosedLoop.from_config(params, cfg, config)
    assert (eng.duration_us, eng.window_ms, eng.fuse_fc, eng.mesh) == \
        (300000, 123.0, True, None)


# ----------------------------------------------------------------------
# Satellite 2: the unified mesh entrypoint.
# ----------------------------------------------------------------------

def test_make_mesh_forms_and_aliases():
    m = make_mesh()                       # all local devices, ("data",)
    assert m.axis_names == ("data",)
    assert m.size == len(jax.devices())   # works at any forced device count
    assert make_mesh(1).axis_names == ("data",)
    assert make_mesh((1,), ("x",)).axis_names == ("x",)
    with pytest.raises(ValueError, match="axes required"):
        make_mesh((1, 1))
    with pytest.raises(ValueError, match="disagree"):
        make_mesh((1,), ("a", "b"))
    with pytest.raises(RuntimeError, match="device_count"):
        make_mesh(64)
    # launch-stack alias resolves to the same constructor
    from repro.launch.mesh import make_mesh_for
    from repro.launch import mesh as launch_mesh
    assert launch_mesh.make_mesh is make_mesh
    assert make_mesh_for((1,), ("data",)).axis_names == ("data",)


def test_slot_axis_and_pspecs():
    from jax.sharding import PartitionSpec as P
    m = make_mesh()
    assert slot_axis(m) == "data"
    assert slot_axis(make_mesh((1,), ("model",))) == "model"
    assert slot_pspec(3, m) == P("data", None, None)
    assert slot_pspec(1, m) == P("data")
    state = {"a": np.zeros((4, 2, 2)), "b": np.zeros((4,))}
    specs = slot_state_pspecs(state, m)
    assert specs == {"a": P("data", None, None), "b": P("data")}


# ----------------------------------------------------------------------
# Satellite 3: DeadlinePolicy bookkeeping is released on close().
# ----------------------------------------------------------------------

class _StubEngine:
    """Minimal protocol engine: instant canned results, no jax."""
    modality = "stub"
    duration_us = 1000

    def validate(self, item):
        pass

    def prepare(self, items, *, batch_size):
        assert len(items) == batch_size
        return items

    def shape_key(self, batch):
        return (len(batch),)

    def infer(self, batch):
        from repro.core.pipeline import ClosedLoopResult
        return [None if it is None else ClosedLoopResult(
            label_pred=np.zeros(1, np.int64), pwm=np.zeros((1, 4)),
            latency_ms=1.0, energy_mj=1.0, breakdown={}, realtime=True,
            sustained_rate_hz=1.0) for it in batch]


def test_close_releases_deadline_policy_bookkeeping():
    """Regression: retiring streams must drop their aging counters via
    ``policy.forget`` -- a serving process that opens and closes many
    deadlined streams must not grow ``DeadlinePolicy._waited``."""
    policy = DeadlinePolicy(aging=1.0)
    eng = StreamEngine(engines=[_StubEngine()],
                       config=EngineConfig(max_streams=1, policy=policy))
    for round_ in range(5):
        handles = [eng.open(stream_id=f"r{round_}s{i}", deadline=float(i))
                   for i in range(3)]
        for h in handles:
            h.submit(object())
        eng.step()                 # 1 slot, 3 streams -> 2 wait + age
        assert policy._waited      # the passed-over streams aged
        # Close with counters still LIVE (streams waiting, windows
        # queued): a sync engine has nothing in flight, so close() drops
        # the queues -- and must drop the aging counters with them.
        for h in handles:
            h.close()
        assert policy._waited == {}, policy._waited
