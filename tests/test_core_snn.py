"""ColibriES core: SNN equivalences, events, tiling, energy model."""
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (KrakenModel, NOMINAL, SNNConfig, init_snn,
                        plan_layer_tiles, plan_network, snn_apply, snn_loss,
                        SNE_NEURON_CAPACITY)
from repro.core import events as ev
from repro.core.pipeline import ClosedLoopPipeline, pwm_from_logits
from repro.kernels import lif_scan


@pytest.fixture(scope="module")
def tiny_cfg():
    return SNNConfig(height=32, width=32, time_bins=8, conv1_features=4,
                     conv2_features=8, hidden=32, num_classes=11)


@pytest.fixture(scope="module")
def tiny_setup(tiny_cfg):
    params = init_snn(jax.random.PRNGKey(0), tiny_cfg)
    rng = np.random.default_rng(0)
    w = ev.synthetic_gesture_events(rng, 3, mean_events=6000,
                                    height=32, width=32)
    vox = ev.voxelize(jnp.asarray(w.x), jnp.asarray(w.y), jnp.asarray(w.t),
                      jnp.asarray(w.p), duration_us=w.duration_us,
                      time_bins=8, height=32, width=32)[None]
    return params, vox, w


# -- execution-order equivalence (SNE layer-serial == STBP time-serial) --

def test_layer_serial_equals_time_serial(tiny_cfg, tiny_setup):
    params, vox, _ = tiny_setup
    out_t = snn_apply(params, vox, tiny_cfg, mode="time_serial")
    out_l = snn_apply(params, vox, tiny_cfg, mode="layer_serial")
    np.testing.assert_array_equal(np.asarray(out_t["out_spikes"]),
                                  np.asarray(out_l["out_spikes"]))


def test_layer_serial_with_pallas_kernel(tiny_cfg, tiny_setup):
    params, vox, _ = tiny_setup
    out_ref = snn_apply(params, vox, tiny_cfg, mode="layer_serial")
    out_k = snn_apply(params, vox, tiny_cfg, mode="layer_serial",
                      lif_scan_fn=lambda c, p: lif_scan(c, p))
    np.testing.assert_array_equal(np.asarray(out_ref["out_spikes"]),
                                  np.asarray(out_k["out_spikes"]))


def test_stbp_gradients_flow_to_all_layers(tiny_cfg, tiny_setup):
    params, vox, _ = tiny_setup
    g = jax.grad(lambda p: snn_loss(p, vox, jnp.array([3]), tiny_cfg)[0]
                 )(params)
    for name in ("conv1", "conv2", "fc1", "fc2"):
        assert float(jnp.abs(g[name]["w"]).max()) > 0, f"dead grad {name}"


def test_full_table2_network_shapes():
    cfg = get_config("colibries")
    assert cfg.flat_dim == 2048          # Table II: FC input 2048
    params = init_snn(jax.random.PRNGKey(0), cfg)
    assert params["conv1"]["w"].shape == (3, 3, 2, 16)
    assert params["conv2"]["w"].shape == (3, 3, 16, 32)
    assert params["fc1"]["w"].shape == (2048, 512)
    assert params["fc2"]["w"].shape == (512, 11)


# -- events --------------------------------------------------------------

@hypothesis.settings(max_examples=20, deadline=None)
@hypothesis.given(n=st.integers(1, 2000), seed=st.integers(0, 2 ** 16),
                  tb=st.integers(1, 16))
def test_voxelize_conserves_events(n, seed, tb):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(0, 32, n), jnp.int32)
    y = jnp.asarray(rng.integers(0, 32, n), jnp.int32)
    t = jnp.asarray(rng.integers(0, 1000, n), jnp.int32)
    p = jnp.asarray(rng.integers(0, 2, n), jnp.int32)
    vox = ev.voxelize(x, y, t, p, duration_us=1000, time_bins=tb,
                      height=32, width=32, binary=False)
    assert vox.shape == (tb, 2, 32, 32)
    assert int(np.asarray(vox).sum()) == n        # count conservation
    voxb = ev.voxelize(x, y, t, p, duration_us=1000, time_bins=tb,
                       height=32, width=32, binary=True)
    assert float(voxb.max()) <= 1.0


def test_voxelize_batch_padding():
    n = 100
    rng = np.random.default_rng(0)
    mk = lambda hi, size: jnp.asarray(rng.integers(0, hi, size), jnp.int32)
    x, y = mk(32, (2, n)), mk(32, (2, n))
    t, p = mk(1000, (2, n)), mk(2, (2, n))
    valid = jnp.asarray(np.arange(n)[None, :] < np.array([[60], [100]]))
    vox = ev.voxelize_batch(x, y, t, p, valid, duration_us=1000,
                            time_bins=4, height=32, width=32, binary=False)
    assert int(np.asarray(vox[0]).sum()) == 60
    assert int(np.asarray(vox[1]).sum()) == 100


# -- tiling (SNE TDM) ------------------------------------------------------

def test_table2_tiling_matches_sne_capacity():
    cfg = get_config("colibries")
    sizes = cfg.spatial_sizes()
    plans = plan_network([("conv1", sizes["conv1"]),
                          ("conv2", sizes["conv2"]),
                          ("fc1", sizes["fc1"]), ("fc2", sizes["fc2"])])
    # conv1: 32*32*16 = 16384 neurons > 8192 -> exactly 2 TDM passes
    assert plans[0].passes == 2
    assert plans[1].passes == 1 and plans[2].passes == 1


@hypothesis.settings(max_examples=30, deadline=None)
@hypothesis.given(h=st.integers(1, 64), w=st.integers(1, 64),
                  c=st.integers(1, 64), cap=st.integers(64, 16384))
def test_property_tiling_covers_volume(h, w, c, cap):
    plan = plan_layer_tiles("x", (h, w, c), cap)
    th, tw, tc = plan.tile
    gh, gw, gc = plan.grid
    assert plan.neurons_per_pass <= cap
    assert gh * th >= h and gw * tw >= w and gc * tc >= c
    assert plan.passes == gh * gw * gc


# -- energy model (Table III) ---------------------------------------------

def test_energy_model_reproduces_table3():
    m = KrakenModel()
    acct = m.closed_loop(events=NOMINAL.events,
                         layer_in_spikes=NOMINAL.layer_in_spikes,
                         layer_fanout=NOMINAL.layer_fanout,
                         layer_passes=NOMINAL.layer_passes)
    assert acct["total_time_ms"] == pytest.approx(164.5, rel=1e-6)
    assert acct["total_energy_mj"] == pytest.approx(7.7, rel=0.01)
    assert acct["p_idle_mw"] == pytest.approx(17.7, rel=1e-6)
    assert acct["p_avg_active_mw"] == pytest.approx(35.6, rel=0.01)
    st = acct["stages"]
    assert st["data_acquisition"]["time_ms"] == pytest.approx(1.5)
    assert st["preprocessing"]["time_ms"] == pytest.approx(131.0)
    assert st["snn_inference"]["time_ms"] == pytest.approx(32.0)


def test_energy_model_monotone_in_workload():
    m = KrakenModel()
    a1 = m.closed_loop(30_000, (30_000, 6_000, 1_500, 400),
                       NOMINAL.layer_fanout, NOMINAL.layer_passes)
    a2 = m.closed_loop(60_000, (60_000, 12_000, 3_000, 800),
                       NOMINAL.layer_fanout, NOMINAL.layer_passes)
    assert a2["total_time_ms"] > a1["total_time_ms"]
    assert a2["total_energy_mj"] > a1["total_energy_mj"]


# -- closed loop -----------------------------------------------------------

def test_closed_loop_pipeline(tiny_cfg):
    params = init_snn(jax.random.PRNGKey(0), tiny_cfg)
    pipe = ClosedLoopPipeline(params, tiny_cfg)
    rng = np.random.default_rng(1)
    w = ev.synthetic_gesture_events(rng, 5, mean_events=5000,
                                    height=32, width=32)
    res = pipe(w)
    assert res.pwm.shape == (1, 4)
    assert (res.pwm >= 0).all() and (res.pwm <= 1).all()
    assert 0 <= res.label_pred[0] < 11
    assert res.latency_ms > 0 and res.energy_mj > 0
    bd = res.breakdown
    total = sum(s["time_ms"] for s in bd["stages"].values())
    assert bd["total_time_ms"] == pytest.approx(total)
    assert res.sustained_rate_hz > 0


def test_pwm_mapping_bounds():
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(4, 11)),
                         jnp.float32)
    pwm = pwm_from_logits(logits)
    assert pwm.shape == (4, 4)
    assert float(pwm.min()) >= 0 and float(pwm.max()) <= 1
