"""The bench-regression gate: absolute floors, runner-independent ratio
fallbacks, and the stateful-cell gating (including the hard
stateful/stateless floor that must hold even against a baseline that
predates stateful_rows)."""
import importlib.util
import json
import os

import pytest

_SPEC = importlib.util.spec_from_file_location(
    "check_regression",
    os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                 "check_regression.py"))
check_regression = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(check_regression)


def _doc(batched=600.0, looped=300.0, stateful=590.0, stateless=600.0,
         fused=230.0, separate=195.0, fusion=None, with_stateful=True,
         with_fusion=True, with_sharded=True, sharded=None,
         with_hetero=True, hetero_mixed=1800.0, hetero_event=900.0,
         hetero_frame=3900.0,
         with_fleet=True, static_miss=0.25, rebal_miss=0.0,
         fleet_rebal=580.0, fleet_static=560.0, migrations=3,
         with_fault=True, fault_clean=24.0, fault_faulted=23.0,
         fault_retries=4, fault_quarantined=0, fault_recovery=4.0):
    doc = {"rows": [{"batch_size": 4,
                     "batched_windows_per_s": batched,
                     "looped_windows_per_s": looped,
                     "speedup": batched / looped}]}
    if with_stateful:
        doc["stateful_rows"] = [{
            "batch_size": 4,
            "stateless_windows_per_s": stateless,
            "stateful_windows_per_s": stateful,
            "stateful_over_stateless": stateful / stateless}]
    if with_fusion:
        fusion = {2: (fused, separate)} if fusion is None else fusion
        doc["fusion_rows"] = [{
            "sessions": s,
            "separate_ticks_per_s": sep,
            "fused_ticks_per_s": fus,
            "fused_over_separate": fus / sep}
            for s, (fus, sep) in sorted(fusion.items())]
    if with_hetero:
        serial = 2.0 / (1.0 / hetero_event + 1.0 / hetero_frame)
        doc["hetero_rows"] = [{
            "slots_per_engine": 4, "windows_per_stream": 8,
            "event_windows_per_s": hetero_event,
            "frame_windows_per_s": hetero_frame,
            "mixed_windows_per_s": hetero_mixed,
            "mixed_over_serial": hetero_mixed / serial}]
    if with_sharded:
        sharded = {1: 600.0, 2: 610.0, 4: 590.0} if sharded is None else sharded
        single = sharded[min(sharded)]
        doc["sharded_rows"] = [{
            "devices": d, "batch_size": 8,
            "windows_per_s": wps,
            "sharded_over_single": wps / single}
            for d, wps in sorted(sharded.items())]
    if with_fleet:
        doc["fleet_rows"] = [{
            "engines": 2, "streams": 4, "windows_per_stream": 6,
            "static_miss_rate": static_miss,
            "rebalanced_miss_rate": rebal_miss,
            "static_windows_per_s": fleet_static,
            "rebalanced_windows_per_s": fleet_rebal,
            "rebalanced_over_static": fleet_rebal / fleet_static,
            "migrations": migrations,
            "migration_ms": 1.5}]
    if with_fault:
        doc["fault_rows"] = [{
            "streams": 2, "windows_per_stream": 8, "fault_rate": 0.05,
            "clean_windows_per_s": fault_clean,
            "faulted_windows_per_s": fault_faulted,
            "faulted_over_clean": fault_faulted / fault_clean,
            "retries": fault_retries,
            "quarantined": fault_quarantined,
            "recovery_ticks_median": fault_recovery}]
    return doc


def _run(tmp_path, base, fresh, extra=()):
    bp, fp = tmp_path / "base.json", tmp_path / "fresh.json"
    bp.write_text(json.dumps(base))
    fp.write_text(json.dumps(fresh))
    return check_regression.main(
        ["--baseline", str(bp), "--fresh", str(fp), *extra])


def test_identical_artifacts_pass(tmp_path):
    assert _run(tmp_path, _doc(), _doc()) == 0


def test_absolute_regression_fails(tmp_path):
    # Throughput halved AND the batched-vs-looped ratio collapsed.
    assert _run(tmp_path, _doc(),
                _doc(batched=300.0, looped=290.0)) == 1


def test_slow_runner_passes_via_ratio_fallback(tmp_path):
    # Uniformly slower machine: absolute floors missed, ratios hold.
    assert _run(tmp_path, _doc(),
                _doc(batched=300.0, looped=150.0,
                     stateful=295.0, stateless=300.0,
                     fused=115.0, separate=97.0,
                     hetero_mixed=900.0, hetero_event=450.0,
                     hetero_frame=1950.0)) == 0


def test_stateful_cell_regression_fails(tmp_path):
    # Stateful throughput collapsed relative to its own stateless cell.
    assert _run(tmp_path, _doc(),
                _doc(stateful=350.0, stateless=600.0)) == 1


def test_missing_fresh_stateful_cell_fails(tmp_path):
    assert _run(tmp_path, _doc(), _doc(with_stateful=False)) == 1


def test_old_baseline_skips_relative_gate_but_keeps_hard_floor(tmp_path):
    """A baseline predating stateful_rows must not disable stateful
    gating entirely: the runner-independent hard floor only needs the
    fresh artifact, so a 30%-cost state carry still fails."""
    old_base = _doc(with_stateful=False)
    assert _run(tmp_path, old_base, _doc()) == 0
    assert _run(tmp_path, old_base,
                _doc(stateful=420.0, stateless=600.0)) == 1


def test_stateful_ratio_floor_is_configurable(tmp_path):
    fresh = _doc(stateful=540.0, stateless=600.0)     # ratio 0.90
    assert _run(tmp_path, _doc(), fresh) == 1
    assert _run(tmp_path, _doc(), fresh,
                extra=("--stateful-ratio-floor", "0.85")) == 0


# -- the cross-modal fusion cell ---------------------------------------------

def test_missing_fresh_fusion_cell_fails(tmp_path):
    assert _run(tmp_path, _doc(), _doc(with_fusion=False)) == 1


def test_old_baseline_without_fusion_warns_and_passes(tmp_path):
    """A baseline predating fusion_rows must not block the transition:
    the fusion gate is skipped with a warning, everything else gates."""
    assert _run(tmp_path, _doc(with_fusion=False), _doc()) == 0
    # ...but a real regression elsewhere still fails.
    assert _run(tmp_path, _doc(with_fusion=False),
                _doc(batched=300.0, looped=290.0)) == 1


def test_fusion_regression_fails(tmp_path):
    # Fused throughput halved AND the fused-vs-separate ratio collapsed
    # (separate side unchanged): the fusion path itself regressed.
    assert _run(tmp_path, _doc(),
                _doc(fused=90.0, separate=195.0)) == 1


def test_fusion_slow_runner_passes_via_ratio(tmp_path):
    # Both fusion cells uniformly slower: ratio holds, gate passes.
    assert _run(tmp_path, _doc(),
                _doc(fused=116.0, separate=98.0)) == 0


def test_fusion_floor_fails_even_against_baseline_ratio(tmp_path):
    """The fused-over-separate floor is fresh-only and absolute: a
    fused cell that merely tracks a weak baseline ratio (here 1.05,
    above the 0.8x-of-baseline fallback) still fails the 1.1 floor --
    fused serving must actually beat the separate wings."""
    assert _run(tmp_path, _doc(),
                _doc(fused=205.0, separate=195.0)) == 1


def test_fusion_floor_exempts_single_session(tmp_path):
    # One session cannot amortize the shared step: S=1 is gated against
    # the baseline but exempt from the >= 1.1 floor.
    rows = {1: (100.0, 99.0), 2: (230.0, 195.0)}
    assert _run(tmp_path, _doc(fusion=rows), _doc(fusion=rows)) == 0
    slow = {1: (100.0, 99.0), 2: (205.0, 195.0)}
    assert _run(tmp_path, _doc(fusion=rows), _doc(fusion=slow)) == 1


def test_fusion_floor_is_configurable(tmp_path):
    fresh = _doc(fused=205.0, separate=195.0)         # ratio 1.05
    assert _run(tmp_path, _doc(), fresh) == 1
    assert _run(tmp_path, _doc(), fresh,
                extra=("--fusion-ratio-floor", "1.0")) == 0


def test_fusion_gates_only_common_session_counts(tmp_path):
    # A fresh sweep wider than the baseline gates the overlap and warns
    # on the new session counts (old baseline predates the sweep).
    assert _run(tmp_path, _doc(fusion={2: (230.0, 195.0)}),
                _doc(fusion={2: (230.0, 195.0),
                             4: (240.0, 195.0)})) == 0


# -- the mixed-fleet hetero cell ----------------------------------------------

def test_missing_fresh_hetero_cell_fails(tmp_path):
    assert _run(tmp_path, _doc(), _doc(with_hetero=False)) == 1


def test_old_baseline_without_hetero_warns_and_passes(tmp_path):
    """A baseline predating hetero_rows must not block the transition:
    the hetero gate is skipped with a warning, everything else gates."""
    assert _run(tmp_path, _doc(with_hetero=False), _doc()) == 0
    assert _run(tmp_path, _doc(with_hetero=False),
                _doc(batched=300.0, looped=290.0)) == 1


def test_hetero_regression_fails(tmp_path):
    # Mixed throughput collapsed while the per-wing cells held: both
    # the absolute floor and the mixed-over-serial ratio miss.
    assert _run(tmp_path, _doc(), _doc(hetero_mixed=700.0)) == 1


def test_hetero_slow_runner_passes_via_ratio(tmp_path):
    # All three hetero cells uniformly slower: the ratio holds.
    assert _run(tmp_path, _doc(),
                _doc(hetero_mixed=900.0, hetero_event=450.0,
                     hetero_frame=1950.0)) == 0


# -- the sharded serving cells ------------------------------------------------

def test_missing_fresh_sharded_cell_fails(tmp_path):
    assert _run(tmp_path, _doc(), _doc(with_sharded=False)) == 1


def test_old_baseline_without_sharded_warns_and_passes(tmp_path):
    """A baseline predating sharded_rows must not block the transition:
    the sharded gate is skipped with a warning, everything else gates."""
    assert _run(tmp_path, _doc(with_sharded=False), _doc()) == 0
    assert _run(tmp_path, _doc(with_sharded=False),
                _doc(batched=300.0, looped=290.0)) == 1


def test_sharded_regression_fails(tmp_path):
    # The D=4 sharded step collapsed while single-device held: its
    # absolute floor AND its sharded-over-single ratio both miss.
    assert _run(tmp_path, _doc(),
                _doc(sharded={1: 600.0, 2: 610.0, 4: 250.0})) == 1


def test_sharded_slow_runner_passes_via_ratio(tmp_path):
    # Every device count uniformly slower: each ratio holds.
    assert _run(tmp_path, _doc(),
                _doc(sharded={1: 300.0, 2: 305.0, 4: 295.0})) == 0


def test_sharded_gates_only_common_device_counts(tmp_path):
    # A fresh run measured at fewer device counts gates the overlap
    # (baseline D=4 absent from fresh is not an error in either order).
    assert _run(tmp_path, _doc(),
                _doc(sharded={1: 600.0, 2: 610.0})) == 0
    assert _run(tmp_path, _doc(sharded={1: 600.0, 2: 610.0}),
                _doc()) == 0


# -- the fleet control-plane cell ---------------------------------------------

def test_missing_fresh_fleet_cell_fails(tmp_path):
    assert _run(tmp_path, _doc(), _doc(with_fleet=False)) == 1


def test_old_baseline_without_fleet_warns_and_passes(tmp_path):
    """A baseline predating fleet_rows must not block the transition:
    the fleet throughput gate is skipped with a warning, but the
    fresh-only miss-rate check still gates (it needs no baseline)."""
    assert _run(tmp_path, _doc(with_fleet=False), _doc()) == 0
    assert _run(tmp_path, _doc(with_fleet=False),
                _doc(static_miss=0.1, rebal_miss=0.3)) == 1


def test_fleet_rebalancer_must_beat_static(tmp_path):
    # Logical-clock miss rates are runner-independent: a rebalanced
    # fleet missing MORE deadlines than static placement always fails.
    assert _run(tmp_path, _doc(),
                _doc(static_miss=0.1, rebal_miss=0.3)) == 1


def test_fleet_without_migrations_is_vacuous_and_fails(tmp_path):
    # A 0-vs-0 miss-rate "win" with no stream ever moved proves nothing
    # about live migration; the cell must record at least one.
    assert _run(tmp_path, _doc(),
                _doc(static_miss=0.0, rebal_miss=0.0, migrations=0)) == 1


def test_fleet_throughput_regression_fails(tmp_path):
    # Rebalanced windows/s halved AND the rebalanced-over-static ratio
    # collapsed: the control plane itself got expensive.
    assert _run(tmp_path, _doc(),
                _doc(fleet_rebal=250.0, fleet_static=560.0)) == 1


def test_fleet_slow_runner_passes_via_ratio(tmp_path):
    # Both fleet cells uniformly slower: the ratio holds, gate passes.
    assert _run(tmp_path, _doc(),
                _doc(fleet_rebal=290.0, fleet_static=280.0)) == 0


# -- the fault-recovery cell ---------------------------------------------------

def test_missing_fresh_fault_cell_fails(tmp_path):
    assert _run(tmp_path, _doc(), _doc(with_fault=False)) == 1


def test_old_baseline_without_fault_warns_and_passes(tmp_path):
    """A baseline predating fault_rows must not block the transition:
    the faulted-throughput gate is skipped with a warning, but the
    fresh-only checks (exercised retries, bounded recovery) still
    gate -- they need no baseline."""
    assert _run(tmp_path, _doc(with_fault=False), _doc()) == 0
    assert _run(tmp_path, _doc(with_fault=False),
                _doc(fault_recovery=20.0)) == 1


def test_fault_cell_without_retries_is_vacuous_and_fails(tmp_path):
    # A clean-vs-faulted "parity" where no fault ever fired proves
    # nothing about the recovery path; the cell must exercise it.
    assert _run(tmp_path, _doc(), _doc(fault_retries=0)) == 1


def test_fault_recovery_ticks_bound_gates(tmp_path):
    # Recovery latency is step-counted (runner-independent): a window
    # that takes 20 engine steps to land after its first retry fails.
    assert _run(tmp_path, _doc(), _doc(fault_recovery=20.0)) == 1
    assert _run(tmp_path, _doc(), _doc(fault_recovery=20.0),
                extra=("--recovery-ticks-max", "24")) == 0


def test_fault_throughput_regression_fails(tmp_path):
    # Faulted throughput collapsed AND the faulted-over-clean ratio
    # collapsed (clean side held): recovery itself got expensive.
    assert _run(tmp_path, _doc(),
                _doc(fault_faulted=8.0, fault_clean=24.0)) == 1


def test_fault_slow_runner_passes_via_ratio(tmp_path):
    # Both fault cells uniformly slower: the ratio holds, gate passes.
    assert _run(tmp_path, _doc(),
                _doc(fault_clean=12.0, fault_faulted=11.5)) == 0
