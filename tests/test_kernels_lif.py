"""LIF-scan Pallas kernel vs the pure-jnp oracle: shape/dtype sweeps,
hypothesis property tests, STBP gradient equivalence."""
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lif import LIFParams, lif_scan_reference
from repro.kernels import lif_scan, lif_scan_ref
from repro.kernels.lif_scan import choose_blocks, lif_scan_pallas

SHAPES = [
    (4, (8,)),            # tiny, sub-lane
    (16, (129,)),         # non-multiple of 128 lanes
    (7, (2, 200)),        # odd T, 2-D neurons
    (16, (1, 32, 32, 16)),  # conv-layer shaped (SNE workload)
    (33, (3, 130)),       # T padding tail + lane padding
    (128, (256,)),        # T chunking path
]


@pytest.mark.parametrize("t,shape", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_matches_oracle(t, shape, dtype):
    cur = jax.random.normal(jax.random.PRNGKey(t), (t, *shape),
                            dtype) * 0.8
    p = LIFParams()
    s_ref, v_ref = lif_scan_ref(cur, p)
    s_k, v_k = lif_scan_pallas(cur, p, interpret=True)
    # spikes are exact {0,1}; membrane bitwise-close (f32 accum in kernel)
    np.testing.assert_array_equal(np.asarray(s_ref, np.float32),
                                  np.asarray(s_k, np.float32))
    np.testing.assert_allclose(np.asarray(v_ref, np.float32),
                               np.asarray(v_k, np.float32),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-6,
                               atol=1e-2 if dtype == jnp.bfloat16 else 1e-6)


def test_kernel_with_initial_state():
    cur = jax.random.normal(jax.random.PRNGKey(0), (9, 3, 50)) * 0.5
    v0 = jax.random.uniform(jax.random.PRNGKey(1), (3, 50))
    p = LIFParams(alpha=0.9, v_th=0.7)
    s_ref, v_ref = lif_scan_ref(cur, p, v0)
    s_k, v_k = lif_scan_pallas(cur, p, v0, interpret=True)
    np.testing.assert_array_equal(np.asarray(s_ref), np.asarray(s_k))
    np.testing.assert_allclose(np.asarray(v_ref), np.asarray(v_k),
                               rtol=1e-6)


def test_explicit_blocks_and_budget():
    cur = jax.random.normal(jax.random.PRNGKey(2), (64, 1024)) * 0.8
    p = LIFParams()
    s_ref, _ = lif_scan_ref(cur, p)
    s_k, _ = lif_scan_pallas(cur, p, block_t=16, block_r=8,
                             interpret=True)
    np.testing.assert_array_equal(np.asarray(s_ref), np.asarray(s_k))


def test_choose_blocks_fits_budget():
    for t, r in [(16, 8), (512, 4096), (100, 7)]:
        bt, br = choose_blocks(t, r, jnp.float32, vmem_budget=1 << 20)
        state = 3 * 4 * br * 128
        per_t = 2 * 4 * br * 128
        assert state + bt * per_t <= (1 << 20)
        assert bt >= 1 and br >= 1


def test_choose_blocks_degenerate_budget_clamps_below_floor():
    """A budget that fits block_r=8 but fewer than 8 timesteps must clamp
    block_t below the preferred floor instead of overcommitting VMEM."""
    state = 3 * 4 * 8 * 128           # block_r=8 state planes
    per_t = 2 * 4 * 8 * 128           # one f32 timestep at block_r=8
    budget = state + 3 * per_t        # room for exactly 3 timesteps
    bt, br = choose_blocks(64, 1024, jnp.float32, vmem_budget=budget)
    assert br == 8
    assert bt == 3                    # clamped, NOT the 8 floor
    assert 3 * 4 * br * 128 + bt * 2 * 4 * br * 128 <= budget


def test_choose_blocks_impossible_budget_raises():
    with pytest.raises(ValueError, match="vmem_budget"):
        choose_blocks(16, 64, jnp.float32, vmem_budget=1024)
    # And the kernel surfaces the same clear error, not a silent overrun.
    cur = jax.random.normal(jax.random.PRNGKey(0), (16, 64 * 128))
    with pytest.raises(ValueError, match="vmem_budget"):
        lif_scan_pallas(cur, LIFParams(), interpret=True, vmem_budget=1024)


# -- stateful streaming: membrane carried across T-chunk boundaries --------

@pytest.mark.parametrize("t,block_t", [(16, 4), (33, 8), (12, 1), (40, 16)])
def test_v0_carried_across_t_chunks(t, block_t):
    """Non-zero v0 (including components above threshold) must produce the
    oracle's trajectory for every T-chunking of the kernel grid -- the
    prerequisite for carrying membrane state across a stream's windows."""
    cur = jax.random.normal(jax.random.PRNGKey(t * 31 + block_t),
                            (t, 3, 130)) * 0.8
    v0 = jax.random.uniform(jax.random.PRNGKey(7), (3, 130)) * 1.6  # > v_th
    p = LIFParams()
    s_ref, v_ref = lif_scan_ref(cur, p, v0)
    s_k, v_k = lif_scan_pallas(cur, p, v0, block_t=block_t, interpret=True)
    np.testing.assert_array_equal(np.asarray(s_ref), np.asarray(s_k))
    np.testing.assert_allclose(np.asarray(v_ref), np.asarray(v_k),
                               rtol=1e-6, atol=1e-6)


def test_window_chaining_equals_uninterrupted_scan():
    """scan(cur[:k]) ++ scan(cur[k:], v0=v_fin) == scan(cur), bitwise, for
    the kernel AND both oracles -- v0 >= v_th carries the implied spike
    state, so reset-to-zero applies across the window boundary."""
    p = LIFParams()
    cur = jax.random.normal(jax.random.PRNGKey(5), (24, 96)) * 1.2
    for scan in (lif_scan_ref,
                 lif_scan_reference,
                 lambda c, pp, v=None: lif_scan_pallas(
                     c, pp, v, interpret=True)):
        s_whole, v_whole = scan(cur, p)
        s_a, v_a = scan(cur[:11], p)
        s_b, v_b = scan(cur[11:], p, v_a)
        np.testing.assert_array_equal(
            np.asarray(s_whole), np.concatenate([np.asarray(s_a),
                                                 np.asarray(s_b)]))
        np.testing.assert_allclose(np.asarray(v_whole), np.asarray(v_b),
                                   rtol=1e-6, atol=1e-6)


def test_reference_matches_kernel_for_above_threshold_v0():
    """core.lif.lif_scan_reference and the kernel agree bitwise even when
    v0 has components >= v_th (the s0-implied-by-v0 contract)."""
    p = LIFParams(alpha=0.9, v_th=0.7)
    cur = jax.random.normal(jax.random.PRNGKey(0), (9, 3, 50)) * 0.5
    v0 = jax.random.uniform(jax.random.PRNGKey(1), (3, 50))  # some >= 0.7
    assert bool((np.asarray(v0) >= 0.7).any())
    s_ref, v_ref = lif_scan_reference(cur, p, v0)
    s_k, v_k = lif_scan_pallas(cur, p, v0, interpret=True)
    np.testing.assert_array_equal(np.asarray(s_ref), np.asarray(s_k))
    np.testing.assert_allclose(np.asarray(v_ref), np.asarray(v_k),
                               rtol=1e-6)


def test_gradients_match_stbp_reference():
    cur = jax.random.normal(jax.random.PRNGKey(3), (12, 3, 40))
    p = LIFParams()

    def loss_k(c):
        s, v = lif_scan(c, p)
        return (s * jnp.arange(40)).sum() + v.sum()

    def loss_r(c):
        s, v = lif_scan_reference(c, p)
        return (s * jnp.arange(40)).sum() + v.sum()

    g_k = jax.grad(loss_k)(cur)
    g_r = jax.grad(loss_r)(cur)
    np.testing.assert_allclose(np.asarray(g_k), np.asarray(g_r), rtol=1e-6)
    assert float(jnp.abs(g_k).max()) > 0  # surrogate grad alive


@hypothesis.settings(max_examples=20, deadline=None)
@hypothesis.given(
    t=st.integers(1, 40),
    n=st.integers(1, 300),
    alpha=st.floats(0.1, 1.0),
    v_th=st.floats(0.2, 2.0),
    seed=st.integers(0, 2 ** 16),
)
def test_property_kernel_equals_oracle(t, n, alpha, v_th, seed):
    cur = jax.random.normal(jax.random.PRNGKey(seed), (t, n)) * 0.9
    p = LIFParams(alpha=alpha, v_th=v_th)
    s_ref, v_ref = lif_scan_ref(cur, p)
    s_k, v_k = lif_scan_pallas(cur, p, interpret=True)
    np.testing.assert_array_equal(np.asarray(s_ref), np.asarray(s_k))
    np.testing.assert_allclose(np.asarray(v_ref), np.asarray(v_k),
                               rtol=1e-5, atol=1e-5)


@hypothesis.settings(max_examples=15, deadline=None)
@hypothesis.given(seed=st.integers(0, 2 ** 16), t=st.integers(1, 30))
def test_property_spikes_binary_and_reset(seed, t):
    """System invariants: spikes in {0,1}; post-spike membrane excludes
    the pre-spike charge (reset-to-zero dynamics)."""
    cur = jax.random.normal(jax.random.PRNGKey(seed), (t, 64)) * 1.5
    p = LIFParams()
    s, v = lif_scan_pallas(cur, p, interpret=True)
    su = np.unique(np.asarray(s))
    assert set(su.tolist()) <= {0.0, 1.0}
    # silent network when inputs stay below threshold
    s2, _ = lif_scan_pallas(jnp.full((t, 64), 0.4 * p.v_th * (1 - p.alpha)),
                            p, interpret=True)
    assert float(jnp.abs(s2).max()) == 0.0
