"""The unified InferenceEngine protocol: conformance of both accelerator
wings, frame preprocessing, the CUTIE TCN numerics, and the frame-wing
Kraken energy accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BatchedClosedLoop, FrameTCNEngine, InferenceEngine,
                        KrakenModel, SNNConfig, TCNConfig, init_snn,
                        init_tcn, pack_tcn, tcn_apply, tcn_layer_macs)
from repro.core import frames as fr
from repro.core.energy import FRAME_DOMAINS, KRAKEN_DOMAINS
from repro.core.ternary import ternarize, unpack2bit


@pytest.fixture(scope="module")
def tcfg():
    return TCNConfig(height=32, width=32, conv1_features=4,
                     conv2_features=8, hidden=32, num_classes=11)


@pytest.fixture(scope="module")
def tparams(tcfg):
    return init_tcn(jax.random.PRNGKey(1), tcfg)


@pytest.fixture(scope="module")
def frame_engine(tcfg, tparams):
    return FrameTCNEngine(tparams, tcfg)


def _frames(n, seed=0, h=32, w=32):
    rng = np.random.default_rng(seed)
    return [fr.synthetic_gesture_frames(rng, i % 11, height=h, width=w)
            for i in range(n)]


# -- protocol conformance ----------------------------------------------------

def test_both_wings_satisfy_protocol(tcfg, tparams):
    scfg = SNNConfig(height=32, width=32, time_bins=8, conv1_features=4,
                     conv2_features=8, hidden=32, num_classes=11)
    ev_eng = BatchedClosedLoop(init_snn(jax.random.PRNGKey(0), scfg), scfg)
    fr_eng = FrameTCNEngine(tparams, tcfg)
    assert isinstance(ev_eng, InferenceEngine)
    assert isinstance(fr_eng, InferenceEngine)
    assert ev_eng.modality == "event" and fr_eng.modality == "frame"
    # duration latches on first validate, then enforces.
    f = _frames(1)[0]
    fr_eng2 = FrameTCNEngine(tparams, tcfg)
    assert fr_eng2.duration_us is None
    fr_eng2.validate(f)
    assert fr_eng2.duration_us == f.duration_us
    bad = fr.FrameWindow(pixels=f.pixels, duration_us=f.duration_us // 2)
    with pytest.raises(ValueError):
        fr_eng2.validate(bad)


def test_frame_engine_rejects_wrong_geometry(frame_engine):
    bad = _frames(1, h=16, w=16)[0]
    with pytest.raises(ValueError):
        frame_engine.validate(bad)


def test_event_engine_pinned_duration():
    scfg = SNNConfig(height=32, width=32, time_bins=8, conv1_features=4,
                     conv2_features=8, hidden=32, num_classes=11)
    eng = BatchedClosedLoop(init_snn(jax.random.PRNGKey(0), scfg), scfg,
                            duration_us=150_000)
    from repro.core import events as ev
    rng = np.random.default_rng(3)
    w = ev.synthetic_gesture_events(rng, 0, mean_events=1500,
                                    height=32, width=32)  # 300 ms window
    with pytest.raises(ValueError):
        eng.validate(w)


# -- frame preprocessing -----------------------------------------------------

def test_pad_frame_windows_shapes_and_slots():
    fs = _frames(2, seed=6)
    batch = fr.pad_frame_windows([fs[0], None, fs[1]], batch_size=4)
    assert batch.batch_size == 4
    assert batch.pixels.shape == (4, 32, 32, 1)
    assert list(batch.occupied) == [True, False, True, False]
    assert batch.num_pixels[0] == 32 * 32 and batch.num_pixels[1] == 0
    assert batch.labels[2] == fs[1].label and batch.labels[1] == -1
    assert not batch.pixels[1].any()
    with pytest.raises(ValueError):
        fr.pad_frame_windows(fs, batch_size=1)        # too many frames
    with pytest.raises(ValueError):
        fr.pad_frame_windows([None, None])            # no duration known
    mixed = fr.FrameWindow(pixels=fs[0].pixels,
                           duration_us=fs[0].duration_us // 3)
    with pytest.raises(ValueError):
        fr.pad_frame_windows([fs[0], mixed])          # mixed periods
    other = _frames(1, h=16, w=16)[0]
    with pytest.raises(ValueError):
        fr.pad_frame_windows([fs[0], other])          # mixed geometry


def test_normalize_frames_range():
    px = jnp.asarray([[0.0, 127.5, 255.0]])
    out = np.asarray(fr.normalize_frames(px))
    np.testing.assert_allclose(out, [[-1.0, 0.0, 1.0]], atol=1e-6)


def test_synthetic_frames_are_class_dependent():
    a = _frames(1, seed=1)[0]
    rng = np.random.default_rng(1)
    b = fr.synthetic_gesture_frames(rng, 5, height=32, width=32)
    assert a.pixels.shape == (32, 32) and a.pixels.dtype == np.uint8
    assert not np.array_equal(a.pixels, b.pixels)


# -- the CUTIE TCN -----------------------------------------------------------

def test_pack_tcn_fc1_roundtrip(tcfg, tparams):
    packed = pack_tcn(tparams)
    q, scale = ternarize(tparams["fc1"]["w"], axis=-1)
    unpacked = unpack2bit(packed["fc1"]["packed"].T).T
    np.testing.assert_array_equal(np.asarray(unpacked), np.asarray(q))
    np.testing.assert_allclose(np.asarray(packed["fc1"]["scale"]),
                               np.asarray(scale).reshape(-1), rtol=1e-6)


def test_tcn_apply_kernel_matches_dense_reference(tcfg, tparams):
    """The Pallas ternary-matmul fc1 must agree with the dense dequantized
    matmul to f32 tolerance."""
    packed = pack_tcn(tparams)
    batch = fr.pad_frame_windows(_frames(3, seed=9))
    x = fr.normalize_frames(jnp.asarray(batch.pixels))
    out = tcn_apply(packed, x, tcfg)
    assert out["logits"].shape == (3, tcfg.num_classes)

    # Dense reference: replace the packed fc1 with q * scale.
    q, scale = ternarize(tparams["fc1"]["w"], axis=-1)
    from repro.core.tcn import (_avg_pool, _ternarize_act, _ternary_conv)
    x0 = _avg_pool(x, tcfg.pool0)
    s1 = _ternarize_act(_ternary_conv(x0, packed["conv1"]),
                        tcfg.act_threshold)
    s2 = _ternarize_act(_ternary_conv(_avg_pool(s1, 2), packed["conv2"]),
                        tcfg.act_threshold)
    flat = _avg_pool(s2, 2).reshape(3, -1)
    h_ref = flat @ (q.astype(jnp.float32) * scale)
    s3 = _ternarize_act(h_ref, tcfg.act_threshold)
    logits_ref = s3 @ packed["fc2"]["w"]
    np.testing.assert_allclose(np.asarray(out["logits"]),
                               np.asarray(logits_ref), rtol=1e-5, atol=1e-5)


def test_tcn_activity_is_per_stream_and_bounded(tcfg, tparams):
    packed = pack_tcn(tparams)
    batch = fr.pad_frame_windows(_frames(4, seed=11))
    out = tcn_apply(packed, fr.normalize_frames(jnp.asarray(batch.pixels)),
                    tcfg)
    for name, dens in out["activity_per_stream"].items():
        dens = np.asarray(dens)
        assert dens.shape == (4,)
        assert ((dens >= 0) & (dens <= 1)).all(), name


def test_tcn_layer_macs_positive(tcfg):
    macs = tcn_layer_macs(tcfg)
    assert len(macs) == 4 and all(m > 0 for m in macs)


# -- FrameTCNEngine ----------------------------------------------------------

def test_frame_engine_empty_slots_do_not_change_results(frame_engine):
    """Per-slot results are independent of what the other slots hold
    (same fixed batch size, as the streaming engine always uses)."""
    fs = _frames(2, seed=30)
    dense = frame_engine.infer_frames([fs[0], fs[1], None, None],
                                      batch_size=4)
    sparse = frame_engine.infer_frames([fs[0], None, fs[1], None],
                                       batch_size=4)
    assert dense[2] is None and sparse[1] is None and sparse[3] is None
    for ref, got in zip([dense[0], dense[1]], [sparse[0], sparse[2]]):
        np.testing.assert_array_equal(ref.label_pred, got.label_pred)
        np.testing.assert_array_equal(ref.pwm, got.pwm)
        assert ref.energy_mj == got.energy_mj
        assert ref.latency_ms == got.latency_ms


def test_frame_engine_warmup_3tuple_requires_latched_duration(tcfg,
                                                              tparams):
    """A 3-tuple (b, h, w) shape key borrows the engine's latched
    duration_us; warming an UNLATCHED engine with one must raise a
    clear latch-first error instead of silently caching an executable
    under a (b, h, w, None) key no served batch ever hits."""
    eng = FrameTCNEngine(tparams, tcfg)
    assert eng.duration_us is None
    with pytest.raises(ValueError, match="latch duration_us first"):
        eng.warmup([(2, 32, 32)])
    assert eng.compiled_shape_keys() == set()    # nothing cached
    # A full 4-tuple key needs no latch...
    eng.warmup([(2, 32, 32, 300_000)])
    assert eng.compiled_shape_keys() == {(2, 32, 32, 300_000)}
    # ...and once the duration IS latched, 3-tuples resolve against it.
    eng2 = FrameTCNEngine(tparams, tcfg, duration_us=300_000)
    eng2.warmup([(2, 32, 32)])
    assert eng2.compiled_shape_keys() == {(2, 32, 32, 300_000)}
    # Geometry and arity validation unchanged.
    with pytest.raises(ValueError, match="geometry"):
        eng2.warmup([(2, 16, 16)])
    with pytest.raises(ValueError, match="shape key"):
        eng2.warmup([(2, 32)])


def test_frame_engine_export_import_state_trivially_empty(frame_engine):
    """The feedforward wing satisfies the checkpoint contract with the
    empty pytree: export -> import round-trips {} unchanged."""
    state = frame_engine.init_state(2)
    payload = frame_engine.export_state(state, 0)
    assert payload == {}
    assert frame_engine.import_state(state, 0, payload) == {}


def test_frame_engine_result_contract(frame_engine):
    res = frame_engine.infer_frames(_frames(1, seed=40))[0]
    assert res.pwm.shape == (1, 4)
    assert (res.pwm >= 0).all() and (res.pwm <= 1).all()
    stages = res.breakdown["stages"]
    assert set(stages) == {"data_acquisition", "preprocessing",
                           "tcn_inference"}
    assert stages["tcn_inference"]["domain"] == "cutie"
    assert res.latency_ms == pytest.approx(
        sum(s["time_ms"] for s in stages.values()))
    assert 0.0 <= res.breakdown["cutie_activity"] <= 1.0
    assert res.energy_mj > 0 and res.sustained_rate_hz > 0


# -- frame-wing energy model -------------------------------------------------

def test_frame_loop_accounting_consistent():
    model = KrakenModel()
    out = model.frame_loop(128.0 * 128.0, 2_381_312.0, activity=0.5)
    assert out["total_time_ms"] == pytest.approx(
        sum(s["time_ms"] for s in out["stages"].values()))
    assert out["total_energy_mj"] == pytest.approx(
        out["active_energy_mj"] + out["idle_energy_mj"])
    # Nominal workload reproduces the calibration targets.
    nf = model.nominal_frame
    assert out["stages"]["data_acquisition"]["time_ms"] == pytest.approx(
        nf.t_acq_ms)
    assert out["stages"]["preprocessing"]["time_ms"] == pytest.approx(
        nf.t_pre_ms)
    assert out["stages"]["tcn_inference"]["time_ms"] == pytest.approx(
        nf.t_cutie_ms)


def test_frame_loop_energy_monotone_in_activity():
    model = KrakenModel()
    es = [model.frame_loop(1e4, 1e6, activity=a)["total_energy_mj"]
          for a in (0.0, 0.5, 1.0)]
    assert es[0] < es[1] < es[2]
    # Activity clamps to [0, 1].
    lo = model.frame_loop(1e4, 1e6, activity=-3.0)
    hi = model.frame_loop(1e4, 1e6, activity=7.0)
    assert lo["cutie_activity"] == 0.0 and hi["cutie_activity"] == 1.0


def test_cutie_domain_does_not_leak_into_event_accounting():
    """Adding the frame wing must not perturb the event wing's Table III
    calibration: the event domain set stays exactly {fc, cluster, sne}."""
    assert set(KRAKEN_DOMAINS) == {"fc", "cluster", "sne"}
    assert set(FRAME_DOMAINS) == {"fc", "cluster", "cutie"}
