"""SlotPolicy semantics: the default FairQuantumPolicy must reproduce the
PR 1 fairness-quantum scheduler exactly (order-for-order), and the
DeadlinePolicy must prefer urgent work without ever starving a stream.

Uses a stub InferenceEngine so scheduling is tested in isolation from any
accelerator numerics -- which also demonstrates that third-party engines
plug into StreamEngine through the protocol alone.
"""
from collections import deque

import numpy as np
import pytest

from repro.core.pipeline import ClosedLoopResult
from repro.serving import DeadlinePolicy, FairQuantumPolicy, StreamEngine
from repro.serving.stream import SlotPolicy


class StubEngine:
    """Minimal InferenceEngine: items are opaque tokens, results canned."""

    modality = "stub"

    def __init__(self):
        self.duration_us = None
        self.infer_calls = 0

    def validate(self, item):
        pass

    def prepare(self, items, *, batch_size):
        assert len(items) == batch_size
        return items

    def shape_key(self, batch):
        return (len(batch),)

    def infer(self, batch):
        self.infer_calls += 1
        return [None if it is None else ClosedLoopResult(
            label_pred=np.zeros(1, np.int64), pwm=np.zeros((1, 4)),
            latency_ms=1.0, energy_mj=1.0, breakdown={}, realtime=True,
            sustained_rate_hz=1.0) for it in batch]


def _stub_engine(max_streams, policy=None, fair_quantum=None):
    return StreamEngine(engines=[StubEngine()], max_streams=max_streams,
                        policy=policy, fair_quantum=fair_quantum)


# -- PR 1 reference scheduler ------------------------------------------------

class _PR1Reference:
    """Literal re-implementation of PR 1's StreamEngine scheduling (slot
    pinning, fairness-quantum rotation, refill-without-stall), serving
    abstract tokens. The order of (stream, seq) completions is the spec
    the default policy must match exactly."""

    _FREE = object()

    def __init__(self, max_streams, fair_quantum):
        self.max_streams = max_streams
        self.fair_quantum = fair_quantum
        self.queues = {}
        self.seq = {}
        self.slots = [self._FREE] * max_streams
        self.slot_runs = [0] * max_streams
        self.waiting = deque()

    def submit(self, sid):
        if sid not in self.queues:
            self.queues[sid] = deque()
            self.seq[sid] = 0
        self.queues[sid].append(self.seq[sid])
        self.seq[sid] += 1
        if sid not in self.slots and sid not in self.waiting:
            self.waiting.append(sid)

    def _assign_slots(self):
        contended = any(self.queues[s] for s in self.waiting)
        for i, sid in enumerate(self.slots):
            if sid is self._FREE:
                continue
            if not self.queues[sid]:
                self.slots[i] = self._FREE
                self.slot_runs[i] = 0
            elif contended and self.slot_runs[i] >= self.fair_quantum:
                self.waiting.append(sid)
                self.slots[i] = self._FREE
                self.slot_runs[i] = 0
        for i, sid in enumerate(self.slots):
            if sid is self._FREE:
                while self.waiting:
                    cand = self.waiting.popleft()
                    if self.queues[cand]:
                        self.slots[i] = cand
                        self.slot_runs[i] = 0
                        break
                if self.slots[i] is self._FREE:
                    break

    def step(self):
        self._assign_slots()
        out = []
        for i, sid in enumerate(self.slots):
            if sid is self._FREE or not self.queues[sid]:
                continue
            out.append((sid, self.queues[sid].popleft()))
            self.slot_runs[i] += 1
        return out

    def pending(self):
        return sum(len(q) for q in self.queues.values())


def _random_script(rng, n_streams, rounds):
    """A reproducible interleaved submit/step script: each round submits a
    random multiset of windows, then steps once."""
    return [[int(s) for s in rng.integers(0, n_streams,
                                          size=rng.integers(0, 4))]
            for _ in range(rounds)]


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("max_streams,fair_quantum", [(1, 1), (2, 2),
                                                      (2, 4), (3, 2)])
def test_default_policy_matches_pr1_exactly(seed, max_streams, fair_quantum):
    """Under arbitrary interleaved submission, the engine with the default
    policy completes (stream, seq) pairs in exactly the PR 1 order."""
    rng = np.random.default_rng(seed)
    script = _random_script(rng, n_streams=5, rounds=30)

    eng = _stub_engine(max_streams, fair_quantum=fair_quantum)
    ref = _PR1Reference(max_streams, fair_quantum)
    got_order, ref_order = [], []
    for round_submits in script:
        for s in round_submits:
            eng.submit(f"s{s}", object())
            ref.submit(f"s{s}")
        got_order.extend((r.stream_id, r.seq) for r in eng.step())
        ref_order.extend(ref.step())
    # Drain both.
    while eng.pending():
        got_order.extend((r.stream_id, r.seq) for r in eng.step())
    while ref.pending():
        ref_order.extend(ref.step())
    assert got_order == ref_order


def test_default_policy_is_fair_quantum_instance():
    eng = _stub_engine(2, fair_quantum=3)
    assert isinstance(eng.policy, FairQuantumPolicy)
    assert not isinstance(eng.policy, DeadlinePolicy)
    assert eng.policy.fair_quantum == 3


# -- DeadlinePolicy ----------------------------------------------------------

def test_deadline_policy_serves_urgent_first():
    """With one slot and all streams waiting, the earliest deadline wins
    regardless of arrival order."""
    eng = _stub_engine(1, policy=DeadlinePolicy())
    eng.submit("slack", object(), deadline=900.0)
    eng.submit("late", object(), deadline=300.0)
    eng.submit("urgent", object(), deadline=10.0)
    order = [r.stream_id for r in eng.run()]
    assert order == ["urgent", "late", "slack"]


def test_deadline_none_sorts_after_finite():
    eng = _stub_engine(1, policy=DeadlinePolicy())
    eng.submit("undated", object())                  # deadline=None
    eng.submit("dated", object(), deadline=1e9)
    assert [r.stream_id for r in eng.run()] == ["dated", "undated"]


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_deadline_policy_never_starves(seed):
    """Adversarial load: urgent streams resubmit tiny deadlines every
    step, an undeadlined stream just waits. The wait bound guarantees the
    slack stream is served within max_wait + quantum steps -- and keeps
    being served with bounded gaps forever."""
    policy = DeadlinePolicy(fair_quantum=2, max_wait=8)
    eng = _stub_engine(1, policy=policy)
    rng = np.random.default_rng(seed)
    eng.submit("slack", object())                    # no deadline: most slack
    served_slack_steps = []
    for step_i in range(120):
        for u in range(2):
            # keep the urgent queues non-empty with ever-earlier urgency
            if rng.random() < 0.9:
                eng.submit(f"urgent{u}", object(),
                           deadline=float(rng.integers(0, 10)))
        if not eng.pending():
            continue
        for r in eng.step():
            if r.stream_id == "slack":
                served_slack_steps.append(step_i)
                eng.submit("slack", object())        # go wait again
    assert served_slack_steps, "slack stream was starved"
    gaps = np.diff([0] + served_slack_steps)
    bound = (policy.max_wait + policy.fair_quantum + 2) * 2
    assert gaps.max() <= bound, (served_slack_steps, gaps)
    # And urgent streams were not locked out either.
    assert all(eng.stream_stats[f"urgent{u}"].windows > 10
               for u in range(2))


def test_deadline_policy_drops_drained_waiting_entries():
    """Ephemeral streams must not accumulate in the waiting line or the
    aging table after they drain (memory/scan-cost leak)."""
    policy = DeadlinePolicy()
    eng = _stub_engine(1, policy=policy)
    for k in range(50):
        eng.submit(f"ephemeral{k}", object(), deadline=float(k))
    eng.run()
    lane = eng._lanes["stub"]
    eng.submit("fresh", object())
    eng.run()
    assert len(lane.waiting) == 0
    assert len(policy._waited) == 0


def test_deadline_aging_counts_rounds_not_slot_fills():
    """With many free slots per round, a passed-over stream ages by ONE
    per round, so the max_wait hard bound does not fire early."""
    policy = DeadlinePolicy(max_wait=16)
    eng = _stub_engine(4, policy=policy)
    # 5 streams over 4 slots: exactly one waits each round.
    for s in range(5):
        for _ in range(3):
            eng.submit(f"s{s}", object(), deadline=float(s))
    eng.step()
    waited = [v for v in policy._waited.values()]
    assert waited and max(waited) == 1      # one round -> aged once


def test_deadline_bookkeeping_survives_resize():
    """A lane resize (the fleet autoscaler's move) must not disturb the
    policy's aging/anti-starvation bookkeeping: waiting streams keep
    their counters, evicted streams rejoin the line and age normally,
    and retire() still forgets them (the PR 6 forget regression,
    extended to cover resize)."""
    policy = DeadlinePolicy(max_wait=16)
    eng = _stub_engine(2, policy=policy)
    for sid, dl in (("a", 1.0), ("b", 2.0), ("aged", 9.0)):
        for _ in range(4):
            eng.submit(sid, object(), deadline=dl)
    eng.step()                          # a,b slotted; "aged" aged once
    assert policy._waited["aged"] == 1
    evicted = eng.resize_lane(slots=1)
    assert evicted == ["b"]
    # The shrink touched no policy state: the counter survived.
    assert policy._waited["aged"] == 1
    eng.step()                          # "aged" and evicted "b" both wait
    assert policy._waited["aged"] == 2
    assert policy._waited["b"] == 1
    eng.resize_lane(slots=4)            # grow: counters still intact
    assert policy._waited["aged"] == 2
    # Retiring the evicted stream still releases its bookkeeping.
    eng.retire("b")
    assert "b" not in policy._waited
    eng.run()
    assert not policy._waited


def test_deadline_aged_stream_wins_slot_freed_by_grow():
    """Growing a lane serves the passed-over stream immediately: its
    aging counter is consumed by winning the new slot, exactly as if the
    slot had been freed by rotation."""
    policy = DeadlinePolicy()
    eng = _stub_engine(1, policy=policy)
    for _ in range(2):
        eng.submit("hog", object(), deadline=0.0)
    eng.submit("aged", object(), deadline=5.0)
    eng.step()
    assert policy._waited["aged"] == 1
    eng.resize_lane(slots=2)
    served = {r.stream_id for r in eng.step()}
    assert served == {"hog", "aged"}
    assert "aged" not in policy._waited


def test_deadline_max_wait_bound_holds_across_resizes():
    """The hard anti-starvation bound keeps counting across slot-count
    changes: an undeadlined stream aged past max_wait is served next
    even though every resize reshuffled the slots around it."""
    policy = DeadlinePolicy(fair_quantum=2, max_wait=4)
    eng = _stub_engine(1, policy=policy)
    eng.submit("slack", object())               # no deadline
    served_slack = False
    for step_i in range(30):
        eng.submit("urgent", object(), deadline=0.0)
        if step_i in (3, 7):                    # churn the capacity
            eng.resize_lane(slots=2)
        elif step_i in (5, 9):
            eng.resize_lane(slots=1)
        for r in eng.step():
            if r.stream_id == "slack":
                served_slack = True
        if served_slack:
            break
    assert served_slack, "resize churn starved the undeadlined stream"


def test_fair_quantum_and_policy_mutually_exclusive():
    with pytest.raises(ValueError):
        _stub_engine(1, policy=DeadlinePolicy(), fair_quantum=2)


def test_max_streams_mapping_rejects_unknown_modality():
    with pytest.raises(ValueError):
        StreamEngine(engines=[StubEngine()],
                     max_streams={"stub": 2, "frames": 2})


def test_compiled_shapes_requires_modality_when_plural():
    class Stub2(StubEngine):
        modality = "stub2"

    eng = StreamEngine(engines=[StubEngine(), Stub2()], max_streams=1)
    with pytest.raises(ValueError):
        eng.compiled_shapes()
    with pytest.raises(ValueError):
        eng.compiled_shapes("nope")
    assert eng.compiled_shapes("stub") == set()


def test_step_is_retry_safe_across_lanes():
    """If a later lane's engine raises, NO lane's windows are consumed --
    the heterogeneous step can be retried without losing results."""

    class FailingEngine(StubEngine):
        modality = "flaky"

        def __init__(self):
            super().__init__()
            self.fail_next = False

        def infer(self, batch):
            if self.fail_next:
                self.fail_next = False
                raise RuntimeError("transient device error")
            return super().infer(batch)

    ok, flaky = StubEngine(), FailingEngine()
    eng = StreamEngine(engines=[ok, flaky], max_streams=1)
    eng.submit("a", object(), modality="stub")
    eng.submit("b", object(), modality="flaky")
    flaky.fail_next = True
    with pytest.raises(RuntimeError):
        eng.step()
    # Nothing consumed, stats untouched, both windows still queued.
    assert eng.pending() == 2
    assert eng.stats["windows"] == 0 and eng.stats["steps"] == 0
    assert eng.stream_stats["a"].windows == 0
    assert eng.stream_stats["a"].queued == 1
    # Retry serves both.
    out = eng.step()
    assert {(r.stream_id, r.seq) for r in out} == {("a", 0), ("b", 0)}
    assert eng.pending() == 0


def test_deadline_policy_validates_args():
    with pytest.raises(ValueError):
        DeadlinePolicy(aging=-1.0)
    with pytest.raises(ValueError):
        DeadlinePolicy(max_wait=0)
    with pytest.raises(ValueError):
        FairQuantumPolicy(fair_quantum=0)


def test_custom_policy_pluggable():
    """Any SlotPolicy subclass drops in: a strict round-robin that
    re-queues the stream after every single window."""

    class RoundRobin(FairQuantumPolicy):
        def __init__(self):
            super().__init__(fair_quantum=1)

    eng = _stub_engine(1, policy=RoundRobin())
    for k in range(2):
        for s in range(3):
            eng.submit(f"s{s}", object())
    order = [r.stream_id for r in eng.run()]
    assert order == ["s0", "s1", "s2", "s0", "s1", "s2"]
    assert isinstance(eng.policy, SlotPolicy)
