"""Ternary-matmul Pallas kernel vs oracle + packing roundtrip properties."""
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ternary import pack2bit, ternarize, ternary_ste, unpack2bit
from repro.kernels import pack_ternary_weights, ternary_matmul_ref
from repro.kernels.ternary_matmul import ternary_matmul_pallas

SHAPES = [(8, 128, 256), (5, 64, 32), (129, 512, 1000), (1, 256, 512),
          (64, 260, 130)]


@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_matches_oracle(m, k, n, dtype):
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (m, k), dtype)
    wp, sc = pack_ternary_weights(w)
    y_ref = ternary_matmul_ref(x, wp, sc)
    y_k = ternary_matmul_pallas(x, wp, sc, interpret=True)
    # f32: accumulation-order noise only; bf16: dequant rounding.
    rtol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(y_ref, np.float32),
                               np.asarray(y_k, np.float32),
                               rtol=rtol, atol=rtol)


def test_explicit_blocks():
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 384))
    x = jax.random.normal(jax.random.PRNGKey(2), (32, 256))
    wp, sc = pack_ternary_weights(w)
    y_ref = ternary_matmul_ref(x, wp, sc)
    y_k = ternary_matmul_pallas(x, wp, sc, block_m=16, block_n=128,
                                block_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_k),
                               rtol=1e-4, atol=1e-4)


def test_pack_unpack_roundtrip():
    q = jnp.array([[-1, 0, 1, 1], [0, 0, -1, 1]], jnp.int8)
    packed = pack2bit(q)
    assert packed.shape == (2, 1) and packed.dtype == jnp.uint8
    np.testing.assert_array_equal(np.asarray(unpack2bit(packed)),
                                  np.asarray(q))


@hypothesis.settings(max_examples=25, deadline=None)
@hypothesis.given(
    rows=st.integers(1, 8), cols4=st.integers(1, 16),
    seed=st.integers(0, 2 ** 16))
def test_property_roundtrip(rows, cols4, seed):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.integers(-1, 2, size=(rows, cols4 * 4)), jnp.int8)
    np.testing.assert_array_equal(np.asarray(unpack2bit(pack2bit(q))),
                                  np.asarray(q))


@hypothesis.settings(max_examples=20, deadline=None)
@hypothesis.given(k4=st.integers(2, 32), n=st.integers(1, 64),
                  m=st.integers(1, 16), seed=st.integers(0, 2 ** 16))
def test_property_kernel_equals_oracle(k4, n, m, seed):
    k = 4 * k4
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (k, n))
    x = jax.random.normal(jax.random.fold_in(key, 1), (m, k))
    wp, sc = pack_ternary_weights(w)
    y_ref = ternary_matmul_ref(x, wp, sc)
    y_k = ternary_matmul_pallas(x, wp, sc, interpret=True)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_k),
                               rtol=1e-4, atol=1e-4)


def test_ternarize_values_and_scale():
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 32)) * 2.0
    q, scale = ternarize(w)
    assert set(np.unique(np.asarray(q)).tolist()) <= {-1, 0, 1}
    assert float(scale.min()) > 0
    # sign preserved wherever a weight survives
    qn = np.asarray(q)
    wn = np.asarray(w)
    nz = qn != 0
    assert (np.sign(wn[nz]) == qn[nz]).all()


def test_ste_gradient_is_identity():
    w = jax.random.normal(jax.random.PRNGKey(0), (16, 16))
    g = jax.grad(lambda w: (ternary_ste(w) * 3.0).sum())(w)
    np.testing.assert_allclose(np.asarray(g), 3.0 * np.ones((16, 16)),
                               rtol=1e-6)


def test_quantization_error_bounded():
    """Ternary fake-quant keeps relative Frobenius error moderate for
    gaussian weights (the TWN operating regime CUTIE assumes)."""
    w = jax.random.normal(jax.random.PRNGKey(5), (512, 512))
    q, scale = ternarize(w)
    wq = np.asarray(q, np.float32) * np.asarray(scale)
    rel = np.linalg.norm(wq - np.asarray(w)) / np.linalg.norm(np.asarray(w))
    assert rel < 0.75
