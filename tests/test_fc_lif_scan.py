"""Fused synapse+LIF kernel (fc_lif_scan): oracle equality across shapes,
chunkings and carried state; bitwise parity of the fuse_fc serving path
against the unfused layer_serial path at B in {1, 4, 8}; STBP gradients;
VMEM block selection."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SNNConfig, init_snn, snn_apply
from repro.core.lif import LIFParams, lif_scan_reference
from repro.kernels import fc_lif_scan, fc_lif_scan_batched
from repro.kernels.fc_lif_scan import (LANES, choose_fc_blocks,
                                       fc_lif_scan_pallas)


def _spikes(key, shape, density=0.25):
    return (jax.random.uniform(jax.random.PRNGKey(key), shape)
            < density).astype(jnp.float32)


def _w(key, k, n, gain=2.0):
    return (jax.random.normal(jax.random.PRNGKey(key), (k, n))
            * gain / np.sqrt(k)).astype(jnp.float32)


SHAPES = [
    (16, 1, 64, 32),      # single stream
    (8, 4, 32, 11),       # fc2-shaped (test config), N < LANES
    (7, 3, 130, 29),      # nothing aligned
    (16, 8, 256, 140),    # batched, N needs lane padding
    (40, 2, 96, 200),     # T chunking path
]


@pytest.mark.parametrize("t,b,k,n", SHAPES)
def test_kernel_matches_matmul_plus_scan_oracle(t, b, k, n):
    s = _spikes(t * 7 + b, (t, b, k))
    w = _w(1, k, n)
    p = LIFParams()
    ref_s, ref_v = lif_scan_reference(jnp.matmul(s, w), p)
    out_s, out_v = fc_lif_scan_pallas(s, w, p, interpret=True)
    np.testing.assert_array_equal(np.asarray(ref_s), np.asarray(out_s))
    np.testing.assert_array_equal(np.asarray(ref_v), np.asarray(out_v))


@pytest.mark.parametrize("block_t", [1, 4, 8, 33])
def test_kernel_chunking_and_carried_state(block_t):
    """Every T-chunking gives the oracle trajectory, with a non-zero v0
    that includes above-threshold components (stateful streaming)."""
    t, b, k, n = 33, 4, 96, 40
    s = _spikes(9, (t, b, k), density=0.3)
    w = _w(2, k, n, gain=1.0)
    v0 = jax.random.uniform(jax.random.PRNGKey(3), (b, n)) * 1.4
    p = LIFParams()
    ref_s, ref_v = lif_scan_reference(jnp.matmul(s, w), p, v0)
    out_s, out_v = fc_lif_scan_pallas(s, w, p, v0, block_t=block_t,
                                      interpret=True)
    np.testing.assert_array_equal(np.asarray(ref_s), np.asarray(out_s))
    np.testing.assert_array_equal(np.asarray(ref_v), np.asarray(out_v))


def test_two_dim_spikes_and_batched_wrapper():
    p = LIFParams()
    s2 = _spikes(5, (12, 64), density=0.3)
    w = _w(6, 64, 20, gain=1.5)
    r_s, r_v = lif_scan_reference(s2 @ w, p)
    o_s, o_v = fc_lif_scan(s2, w, p)
    np.testing.assert_array_equal(np.asarray(r_s), np.asarray(o_s))
    np.testing.assert_array_equal(np.asarray(r_v), np.asarray(o_v))

    sb = _spikes(7, (3, 10, 64), density=0.3)     # (B, T, K) stream-major
    ob, vb = fc_lif_scan_batched(sb, w, p)
    for i in range(3):
        ri_s, ri_v = lif_scan_reference(sb[i] @ w, p)
        np.testing.assert_array_equal(np.asarray(ri_s), np.asarray(ob[i]))
        np.testing.assert_array_equal(np.asarray(ri_v), np.asarray(vb[i]))


def test_window_chaining_via_v_final():
    """Kernel chaining across windows (v0 = previous v_final) equals the
    uninterrupted fused scan, bitwise."""
    t, b, k, n = 24, 2, 64, 48
    s = _spikes(11, (t, b, k), density=0.35)
    w = _w(4, k, n)
    p = LIFParams()
    s_whole, v_whole = fc_lif_scan_pallas(s, w, p, interpret=True)
    s_a, v_a = fc_lif_scan_pallas(s[:10], w, p, interpret=True)
    s_b, v_b = fc_lif_scan_pallas(s[10:], w, p, v_a, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(s_whole),
        np.concatenate([np.asarray(s_a), np.asarray(s_b)]))
    np.testing.assert_array_equal(np.asarray(v_whole), np.asarray(v_b))


def test_gradients_match_stbp_reference():
    t, b, k, n = 10, 2, 48, 24
    s = _spikes(13, (t, b, k), density=0.3)
    w = _w(8, k, n)
    p = LIFParams()

    def loss_k(w_):
        out, v = fc_lif_scan(s, w_, p)
        return (out * jnp.arange(n)).sum() + v.sum()

    def loss_r(w_):
        out, v = lif_scan_reference(jnp.matmul(s, w_), p)
        return (out * jnp.arange(n)).sum() + v.sum()

    g_k = jax.grad(loss_k)(w)
    g_r = jax.grad(loss_r)(w)
    np.testing.assert_allclose(np.asarray(g_k), np.asarray(g_r), rtol=1e-6)
    assert float(jnp.abs(g_k).max()) > 0


def test_choose_fc_blocks_fits_and_raises():
    # Full-model fc1 panel at B=8 fits the default budget with block_t
    # covering the whole Table II scan.
    bt, bn = choose_fc_blocks(16, 8, 2048, 512, jnp.float32)
    assert bt == 16 and bn % LANES == 0
    # Tight budget: block_n shrinks to one lane-row before block_t drops.
    bt2, bn2 = choose_fc_blocks(16, 8, 2048, 512, jnp.float32,
                                vmem_budget=2 * 1024 * 1024)
    w_bytes = 4 * 2048 * bn2
    state = 2 * 4 * 8 * bn2
    per_t = 8 * (2048 * 4 + bn2 * 8)
    assert w_bytes + state + bt2 * per_t <= 2 * 1024 * 1024
    with pytest.raises(ValueError, match="vmem_budget"):
        choose_fc_blocks(16, 8, 2048, 512, jnp.float32, vmem_budget=1 << 16)


def test_shape_validation():
    p = LIFParams()
    with pytest.raises(ValueError, match="weights K"):
        fc_lif_scan_pallas(_spikes(0, (4, 2, 8)), _w(0, 16, 4), p,
                           interpret=True)
    with pytest.raises(ValueError):
        fc_lif_scan_pallas(_spikes(0, (4, 2, 2, 8)), _w(0, 8, 4), p,
                           interpret=True)
    with pytest.raises(ValueError, match="B, T, K"):
        fc_lif_scan_batched(_spikes(0, (4, 8)), _w(0, 8, 4), p)


# -- the serving hot path: fuse_fc bitwise parity ---------------------------

@pytest.fixture(scope="module")
def cfg():
    return SNNConfig(height=32, width=32, time_bins=8, conv1_features=4,
                     conv2_features=8, hidden=32, num_classes=11)


@pytest.fixture(scope="module")
def params(cfg):
    return init_snn(jax.random.PRNGKey(0), cfg)


@pytest.mark.parametrize("b", [1, 4, 8])
def test_fuse_fc_bitwise_parity(cfg, params, b):
    """snn_apply(fuse_fc=True) must be bitwise identical to the unfused
    layer_serial path -- spikes, membrane placeholder, and every per-
    stream firing rate -- at B in {1, 4, 8}, jit'd and eager."""
    vox = (jax.random.uniform(jax.random.PRNGKey(b),
                              (b, cfg.time_bins, 2, 32, 32))
           < 0.1).astype(jnp.float32)
    base = snn_apply(params, vox, cfg, mode="layer_serial")
    fused = snn_apply(params, vox, cfg, mode="layer_serial", fuse_fc=True)
    jit_fused = jax.jit(
        lambda p, v: snn_apply(p, v, cfg, mode="layer_serial",
                               fuse_fc=True))(params, vox)
    for got in (fused, jit_fused):
        np.testing.assert_array_equal(np.asarray(base["out_spikes"]),
                                      np.asarray(got["out_spikes"]))
        for k in base["firing_rates_per_stream"]:
            np.testing.assert_array_equal(
                np.asarray(base["firing_rates_per_stream"][k]),
                np.asarray(got["firing_rates_per_stream"][k]))


def test_fuse_fc_requires_layer_serial(cfg, params):
    vox = jnp.zeros((1, cfg.time_bins, 2, 32, 32))
    with pytest.raises(ValueError, match="layer_serial"):
        snn_apply(params, vox, cfg, mode="time_serial", fuse_fc=True)
