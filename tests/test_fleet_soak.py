"""The fleet soak: adversarial many-streams churn across two engines.

The acceptance artifact for the fleet control plane. One hot engine (2
slots, 4 deadlined persistent streams + ephemeral churn) and one cold
engine (4 slots, nearly idle) serve the same workload twice under a
shared deterministic logical clock:

  * **static** -- streams stay where they were opened; the hot engine's
    backlog makes deadlines slip.
  * **rebalanced** -- a :class:`~repro.fleet.rebalance.FleetRebalancer`
    ticks every round, live-migrating deep-queue streams hot-to-cold
    through the checkpoint store (draining the hot lane mid-pipeline
    when windows are in flight).

Asserted, sync and pipelined:

  * the rebalanced fleet's deadline-miss rate is strictly lower than the
    static fleet's (and migrations actually happened -- no vacuous win),
  * every persistent (live-migrated, stateful) stream's served windows
    are bitwise-identical to one uninterrupted scan -- pre-migration
    rows from the hot engine, drain-displaced rows, and post-migration
    rows from the cold engine all line up with the oracle.

Determinism: both engines' ``deadline_clock`` is the driver's logical
tick, scheduling is deterministic, and the load score reads only
queue depth and the (clock-driven) miss horizon -- so the soak never
depends on wall time.
"""
import jax
import numpy as np
import pytest

from repro.core import SNNConfig, init_snn
from repro.core._api import EngineConfig, FleetConfig
from repro.fleet import CheckpointStore, FleetRebalancer
from repro.serving import DeadlinePolicy, StreamEngine

from test_stateful_stream import (_assert_matches_oracle,
                                  _uninterrupted_oracle, _windows)

N_PERSISTENT = 4
N_WINDOWS = 6


@pytest.fixture(scope="module")
def cfg():
    return SNNConfig(height=32, width=32, time_bins=4, conv1_features=4,
                     conv2_features=8, hidden=32, num_classes=11)


@pytest.fixture(scope="module")
def params(cfg):
    return init_snn(jax.random.PRNGKey(0), cfg)


@pytest.fixture(scope="module")
def persistent(cfg):
    return {f"p{i}": _windows(N_WINDOWS, seed=80 + i)
            for i in range(N_PERSISTENT)}


@pytest.fixture(scope="module")
def oracle(cfg, params, persistent):
    return _uninterrupted_oracle(params, cfg, persistent)


def _run_soak(params, cfg, persistent, *, depth, rebalance):
    """Serve the soak workload; returns (per-stream rows, fleet
    deadline-miss rate, migration count)."""
    policy = lambda: DeadlinePolicy(fair_quantum=2)       # noqa: E731
    hot = StreamEngine(params, cfg, EngineConfig(
        max_streams=2, pipeline_depth=depth, policy=policy()))
    cold = StreamEngine(params, cfg, EngineConfig(
        max_streams=4, pipeline_depth=depth, policy=policy()))
    tick = [0]
    for eng in (hot, cold):
        eng.deadline_clock = lambda: float(tick[0])

    # All persistent streams land on the hot engine with ALL windows
    # queued up front (the forced imbalance) and per-window deadlines
    # sized for ~one window per tick -- feasible once load spreads,
    # hopeless behind a 2-slot backlog.
    handles = {}
    for sid in sorted(persistent):
        h = hot.open(stream_id=sid, stateful=True)
        for k, w in enumerate(persistent[sid]):
            h.submit(w, deadline=3.0 + 1.2 * k)
        handles[sid] = h

    reb = FleetRebalancer(
        {"hot": hot, "cold": cold}, store=CheckpointStore(),
        config=FleetConfig(imbalance=1.0, cooldown=1, miss_weight=10.0),
    ) if rebalance else None

    churn_pool = _windows(4, seed=99)
    rows, ephemerals, n_eph = [], {}, 0
    rounds = 0
    while (hot.pending() or cold.pending()
           or hot.in_flight or cold.in_flight or ephemerals):
        rounds += 1
        assert rounds < 300, "soak failed to drain"
        rows.extend(hot.step())
        rows.extend(cold.step())
        tick[0] += 1
        # Churn: every other round opens a one-window ephemeral stream
        # on each engine (mixed deadlines: hot gets slack windows, cold
        # gets tight ones); ephemerals close as soon as they complete.
        if rounds % 2 == 1 and rounds < 20:
            for eng, slack in ((hot, 50.0), (cold, 2.0)):
                eph = eng.open(stream_id=f"e{n_eph}")
                eph.submit(churn_pool[n_eph % len(churn_pool)],
                           deadline=tick[0] + slack)
                ephemerals[f"e{n_eph}"] = eph
                n_eph += 1
        done = [sid for sid, h in ephemerals.items()
                if any(r.stream_id == sid for r in rows)]
        for sid in done:
            ephemerals.pop(sid).close()
        if reb is not None:
            report = reb.observe()
            rows.extend(report.displaced)
    # Fleet-wide deadline accounting, summed across engines (a migrated
    # stream accrues on both) and including the churn.
    dated = missed = 0
    for eng in (hot, cold):
        for st in eng.stream_stats.values():
            dated += st.deadline_windows
            missed += st.deadline_missed
    migrations = len(reb.migrations) if reb is not None else 0
    return rows, missed / dated, migrations


@pytest.mark.parametrize("depth", [0, 1], ids=["sync", "pipelined"])
def test_soak_rebalancer_beats_static_and_stays_bitwise(
        params, cfg, persistent, oracle, depth):
    ids, per_window = oracle
    static_rows, static_miss, n0 = _run_soak(
        params, cfg, persistent, depth=depth, rebalance=False)
    rebal_rows, rebal_miss, n_migrations = _run_soak(
        params, cfg, persistent, depth=depth, rebalance=True)
    assert n0 == 0
    # The win is real: streams actually moved, and the moved fleet
    # misses fewer deadlines than the static assignment.
    assert n_migrations >= 1
    assert rebal_miss < static_miss, (rebal_miss, static_miss)
    # Bitwise: every persistent stream's full window sequence -- served
    # across two engines with live mid-pipeline migrations -- equals
    # the uninterrupted single-engine scan. The static fleet is held to
    # the same bar (sanity for the harness itself).
    for rows in (static_rows, rebal_rows):
        mine = [r for r in rows if r.stream_id in persistent]
        assert len(mine) == N_PERSISTENT * N_WINDOWS
        seen = {(r.stream_id, r.seq) for r in mine}
        assert len(seen) == len(mine), "duplicate (stream, seq) rows"
        _assert_matches_oracle(mine, ids, per_window)
