"""Batched streaming closed loop: batched-vs-single bitwise parity, the
batched voxelizer/LIF kernel, and StreamEngine scheduling semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SNNConfig, init_snn, snn_apply
from repro.core import events as ev
from repro.core.lif import LIFParams, lif_scan_reference
from repro.core.pipeline import BatchedClosedLoop, ClosedLoopPipeline
from repro.kernels import lif_scan_batched
from repro.kernels.lif_scan import lif_scan_pallas, lif_scan_pallas_batched
from repro.serving import StreamEngine


@pytest.fixture(scope="module")
def cfg():
    return SNNConfig(height=32, width=32, time_bins=8, conv1_features=4,
                     conv2_features=8, hidden=32, num_classes=11)


@pytest.fixture(scope="module")
def params(cfg):
    return init_snn(jax.random.PRNGKey(0), cfg)


def _windows(n, seed=0, base_events=2500, step_events=900):
    """n windows with deliberately ragged event counts."""
    rng = np.random.default_rng(seed)
    return [ev.synthetic_gesture_events(rng, i % 11,
                                        mean_events=base_events
                                        + step_events * i,
                                        height=32, width=32)
            for i in range(n)]


def _assert_same_breakdown(a, b):
    """Energy breakdowns must agree exactly (float ==, not approx)."""
    assert a.keys() == b.keys()
    for k, va in a.items():
        vb = b[k]
        if isinstance(va, dict):
            _assert_same_breakdown(va, vb)
        else:
            assert va == vb, (k, va, vb)


# -- batched voxelization --------------------------------------------------

def test_voxelize_batch_bitwise_matches_single():
    ws = _windows(3, seed=5)
    batch = ev.pad_event_windows(ws)
    vox_b = ev.voxelize_batch(
        jnp.asarray(batch.x), jnp.asarray(batch.y), jnp.asarray(batch.t),
        jnp.asarray(batch.p), jnp.asarray(batch.valid),
        duration_us=batch.duration_us, time_bins=8, height=32, width=32)
    for i, w in enumerate(ws):
        vox_1 = ev.voxelize(
            jnp.asarray(w.x), jnp.asarray(w.y), jnp.asarray(w.t),
            jnp.asarray(w.p), duration_us=w.duration_us, time_bins=8,
            height=32, width=32)
        np.testing.assert_array_equal(np.asarray(vox_b[i]),
                                      np.asarray(vox_1))


def test_voxelize_batch_drops_out_of_range_like_single():
    """A malformed coordinate (linear index >= num_voxels) must be dropped,
    not leaked into the next stream's voxel region."""
    h = w = 8
    tb = 2
    mk = lambda vals: jnp.asarray(np.asarray(vals, np.int32))
    # slot 0: one valid event + one event at y == height (out of range);
    # slot 1: one valid event.
    x = mk([[1, 0], [2, 0]])
    y = mk([[1, h], [2, 0]])
    t = mk([[0, 999], [0, 0]])
    p = mk([[0, 1], [0, 0]])
    valid = jnp.asarray([[True, True], [True, False]])
    vb = ev.voxelize_batch(x, y, t, p, valid, duration_us=1000,
                           time_bins=tb, height=h, width=w, binary=False)
    # stream isolation: slot 1 holds exactly its own single event
    assert float(np.asarray(vb[1]).sum()) == 1.0
    # and slot 0's out-of-range event is dropped, same as single-window
    v0 = ev.voxelize(x[0], y[0], t[0], p[0], duration_us=1000, time_bins=tb,
                     height=h, width=w, binary=False)
    np.testing.assert_array_equal(np.asarray(vb[0]), np.asarray(v0))
    assert float(np.asarray(vb[0]).sum()) == 1.0


def test_pad_event_windows_shapes_and_slots():
    ws = _windows(2, seed=6)
    batch = ev.pad_event_windows([ws[0], None, ws[1]], batch_size=4,
                                 max_events=1 << 14)
    assert batch.batch_size == 4 and batch.max_events == 1 << 14
    assert batch.num_events[1] == 0 and batch.num_events[3] == 0
    assert not batch.valid[1].any()
    assert batch.valid[0].sum() == ws[0].num_events
    assert batch.labels[2] == ws[1].label
    with pytest.raises(ValueError):
        ev.pad_event_windows(ws, max_events=10)   # would truncate
    with pytest.raises(ValueError):
        ev.pad_event_windows([None, None])        # no duration known


# -- batched LIF kernel ----------------------------------------------------

def test_lif_scan_pallas_batched_matches_per_stream():
    b, t, shape = 3, 9, (2, 70)   # 70 -> lane padding per stream
    cur = jax.random.normal(jax.random.PRNGKey(1), (b, t, *shape)) * 0.8
    p = LIFParams()
    s_b, v_b = lif_scan_pallas_batched(cur, p, interpret=True)
    assert s_b.shape == (b, t, *shape) and v_b.shape == (b, *shape)
    for i in range(b):
        s_1, v_1 = lif_scan_pallas(cur[i], p, interpret=True)
        np.testing.assert_array_equal(np.asarray(s_b[i]), np.asarray(s_1))
        np.testing.assert_array_equal(np.asarray(v_b[i]), np.asarray(v_1))


def test_lif_scan_batched_gradients_match_reference():
    cur = jax.random.normal(jax.random.PRNGKey(2), (2, 6, 40))
    p = LIFParams()

    def loss_k(c):
        s, v = lif_scan_batched(c, p)
        return (s * jnp.arange(40)).sum() + v.sum()

    def loss_r(c):
        ref = jax.vmap(lambda cc: lif_scan_reference(cc, p))
        s, v = ref(c)
        return (s * jnp.arange(40)).sum() + v.sum()

    np.testing.assert_allclose(np.asarray(jax.grad(loss_k)(cur)),
                               np.asarray(jax.grad(loss_r)(cur)), rtol=1e-6)


# -- per-stream firing rates -----------------------------------------------

@pytest.mark.parametrize("mode", ["time_serial", "layer_serial"])
def test_per_stream_rates_consistent_with_scalars(cfg, params, mode):
    ws = _windows(3, seed=7)
    batch = ev.pad_event_windows(ws)
    vox = ev.voxelize_batch(
        jnp.asarray(batch.x), jnp.asarray(batch.y), jnp.asarray(batch.t),
        jnp.asarray(batch.p), jnp.asarray(batch.valid),
        duration_us=batch.duration_us, time_bins=cfg.time_bins,
        height=cfg.height, width=cfg.width)
    out = snn_apply(params, vox, cfg, mode=mode)
    for name, per_stream in out["firing_rates_per_stream"].items():
        assert per_stream.shape == (3,)
        np.testing.assert_allclose(float(per_stream.mean()),
                                   float(out["firing_rates"][name]),
                                   rtol=1e-6)


# -- batched-vs-single closed-loop parity ----------------------------------

@pytest.mark.parametrize("b", [1, 4, 7])
def test_batched_loop_bitwise_parity(cfg, params, b):
    """BatchedClosedLoop over ragged windows == looping ClosedLoopPipeline:
    bitwise-identical label_pred, pwm, and energy breakdowns."""
    ws = _windows(b, seed=10 + b)
    pipe = ClosedLoopPipeline(params, cfg)
    looped = [pipe(w) for w in ws]
    batched = BatchedClosedLoop(params, cfg).infer_windows(ws)
    for ref, got in zip(looped, batched):
        np.testing.assert_array_equal(ref.label_pred, got.label_pred)
        np.testing.assert_array_equal(ref.pwm, got.pwm)
        assert ref.latency_ms == got.latency_ms
        assert ref.energy_mj == got.energy_mj
        assert ref.realtime == got.realtime
        assert ref.sustained_rate_hz == got.sustained_rate_hz
        _assert_same_breakdown(ref.breakdown, got.breakdown)


def test_batched_loop_parity_with_pallas_kernel(cfg, params):
    """Parity also holds when the SNE Pallas kernel drives the scan.

    ``lif_scan_fn`` is the engine's scan hook; since the stateful-
    streaming refactor the engine threads carried state through it, so
    it must accept the ``(currents, params, v0)`` signature --
    ``ops.lif_scan`` already does."""
    from repro.kernels import lif_scan
    ws = _windows(3, seed=21)
    fn = lif_scan
    pipe = ClosedLoopPipeline(params, cfg, lif_scan_fn=fn)
    looped = [pipe(w) for w in ws]
    batched = BatchedClosedLoop(params, cfg, lif_scan_fn=fn).infer_windows(ws)
    for ref, got in zip(looped, batched):
        np.testing.assert_array_equal(ref.label_pred, got.label_pred)
        np.testing.assert_array_equal(ref.pwm, got.pwm)
        assert ref.energy_mj == got.energy_mj


def test_empty_slots_do_not_change_results(cfg, params):
    """A partially filled batch (empty slots) yields the same per-stream
    results as a dense batch of the same windows."""
    ws = _windows(2, seed=30)
    loop = BatchedClosedLoop(params, cfg)
    dense = loop.infer(ev.pad_event_windows(ws, max_events=1 << 13))
    sparse = loop.infer(ev.pad_event_windows(
        [ws[0], None, ws[1], None], max_events=1 << 13))
    assert sparse[1] is None and sparse[3] is None
    for ref, got in zip(dense, [sparse[0], sparse[2]]):
        np.testing.assert_array_equal(ref.label_pred, got.label_pred)
        np.testing.assert_array_equal(ref.pwm, got.pwm)
        assert ref.energy_mj == got.energy_mj


# -- StreamEngine ----------------------------------------------------------

def test_stream_engine_parity_and_order(cfg, params):
    """5 streams over 2 slots: every window served exactly once, in
    per-stream submission order, with results bitwise equal to the
    single-window pipeline."""
    eng = StreamEngine(params, cfg, max_streams=2)
    submitted = {}
    rngs = np.random.default_rng(40)
    for s in range(5):
        submitted[s] = []
        for k in range(2):
            w = ev.synthetic_gesture_events(
                rngs, (s + k) % 11, mean_events=2000 + 500 * s,
                height=32, width=32)
            eng.submit(f"cam{s}", w)
            submitted[s].append(w)
    results = eng.run()
    assert len(results) == 10
    assert eng.pending() == 0
    pipe = ClosedLoopPipeline(params, cfg)
    seen = {}
    for r in results:
        s = int(r.stream_id[3:])
        assert r.seq == seen.get(s, 0)       # in-order per stream
        seen[s] = r.seq + 1
        ref = pipe(submitted[s][r.seq])
        np.testing.assert_array_equal(ref.label_pred, r.result.label_pred)
        np.testing.assert_array_equal(ref.pwm, r.result.pwm)
        assert ref.energy_mj == r.result.energy_mj
        _assert_same_breakdown(ref.breakdown, r.result.breakdown)
    # slots were shared: 10 windows over 2 slots needs >= 5 steps
    assert eng.stats["steps"] >= 5
    assert 0 < eng.mean_occupancy <= 2


def test_stream_engine_stats_and_refill(cfg, params):
    eng = StreamEngine(params, cfg, max_streams=4)
    rng = np.random.default_rng(50)
    w0 = ev.synthetic_gesture_events(rng, 1, mean_events=2000,
                                     height=32, width=32)
    eng.submit("a", w0)
    assert eng.step() and eng.step() == []    # drained after one step
    # a drained stream that comes back gets rescheduled (refill)
    w1 = ev.synthetic_gesture_events(rng, 2, mean_events=2000,
                                     height=32, width=32)
    eng.submit("a", w1)
    out = eng.run()
    assert [r.seq for r in out] == [1]
    st = eng.stream_stats["a"]
    assert st.windows == 2 and st.queued == 0
    assert st.energy_mj > 0 and st.mean_latency_ms > 0
    assert 0 <= st.realtime_fraction <= 1
    assert st.mean_power_mw > 0


def test_zero_event_window_is_not_an_empty_slot(cfg, params):
    """A real window from a quiet sensor (zero events) still produces a
    result everywhere; only window=None slots yield None."""
    quiet = ev.EventWindow(
        x=np.zeros(0, np.int32), y=np.zeros(0, np.int32),
        t=np.zeros(0, np.int32), p=np.zeros(0, np.int32),
        duration_us=300_000, label=-1)
    pipe = ClosedLoopPipeline(params, cfg)
    res = pipe(quiet)
    assert res is not None
    assert res.pwm.shape == (1, 4)
    assert res.breakdown["stages"]["data_acquisition"]["time_ms"] == 0.0
    eng = StreamEngine(params, cfg, max_streams=2)
    eng.submit("quiet", quiet)
    out = eng.run()
    assert len(out) == 1
    np.testing.assert_array_equal(out[0].result.pwm, res.pwm)
    assert out[0].result.energy_mj == res.energy_mj


def test_infer_windows_all_none(cfg, params):
    loop = BatchedClosedLoop(params, cfg)
    out = loop.infer_windows([None, None], duration_us=300_000)
    assert out == [None, None]


def test_stream_engine_fairness_no_starvation(cfg, params):
    """More live streams than slots with deep queues: the fairness quantum
    rotates pins, so the slotless stream is served before the pinned
    streams drain completely."""
    eng = StreamEngine(params, cfg, max_streams=2, fair_quantum=2)
    rng = np.random.default_rng(70)
    for s in range(3):
        for k in range(6):
            eng.submit(s, ev.synthetic_gesture_events(
                rng, (s + k) % 11, mean_events=1500, height=32, width=32))
    results = eng.run()
    assert len(results) == 18
    order = [(r.stream_id, r.seq) for r in results]
    first_s2 = order.index((2, 0))
    last_s0 = order.index((0, 5))
    assert first_s2 < last_s0, order   # stream 2 not starved until s0 drains


def test_stream_engine_rejects_bad_slot_count(cfg, params):
    with pytest.raises(ValueError):
        StreamEngine(params, cfg, max_streams=0)
    with pytest.raises(ValueError):
        StreamEngine(params, cfg, max_streams=2, fair_quantum=0)


def test_stream_engine_rejects_mixed_durations(cfg, params):
    eng = StreamEngine(params, cfg, max_streams=2)
    rng = np.random.default_rng(60)
    eng.submit("a", ev.synthetic_gesture_events(rng, 0, mean_events=1500,
                                                height=32, width=32))
    bad = ev.synthetic_gesture_events(rng, 0, mean_events=1500,
                                      duration_us=150_000,
                                      height=32, width=32)
    with pytest.raises(ValueError):
        eng.submit("b", bad)


def test_stream_engine_duration_us_ctor_arg(cfg, params):
    """The bin width can be pinned at construction: submits are validated
    against it from the very first window (no latch-by-accident)."""
    eng = StreamEngine(params, cfg, max_streams=2, duration_us=150_000)
    rng = np.random.default_rng(61)
    w300 = ev.synthetic_gesture_events(rng, 0, mean_events=1500,
                                       height=32, width=32)  # 300 ms
    with pytest.raises(ValueError):
        eng.submit("a", w300)
    assert eng.pending() == 0           # rejected submit left no state
    assert "a" not in eng.stream_stats
    w150 = ev.synthetic_gesture_events(rng, 0, mean_events=1500,
                                       duration_us=150_000,
                                       height=32, width=32)
    eng.submit("a", w150)
    assert len(eng.run()) == 1


def test_stream_result_seq_is_submission_seq(cfg, params):
    """StreamResult.seq must be the sequence number submit() returned --
    not re-derived from completion counts -- and a rejected submit must
    not burn a sequence number."""
    eng = StreamEngine(params, cfg, max_streams=2)
    rng = np.random.default_rng(62)
    mk = lambda lbl, dur=300_000: ev.synthetic_gesture_events(
        rng, lbl, mean_events=1500, duration_us=dur, height=32, width=32)
    returned = {}
    returned[("a", 0)] = eng.submit("a", mk(0))
    returned[("b", 0)] = eng.submit("b", mk(1))
    # A rejected submit in the middle: wrong bin width.
    with pytest.raises(ValueError):
        eng.submit("a", mk(2, dur=150_000))
    returned[("a", 1)] = eng.submit("a", mk(3))
    assert returned == {("a", 0): 0, ("b", 0): 0, ("a", 1): 1}
    got = {(r.stream_id, r.seq) for r in eng.run()}
    assert got == set(returned)
    # The next submit continues the per-stream numbering contiguously.
    assert eng.submit("a", mk(4)) == 2


@pytest.mark.parametrize("slots", [1, 4, 7])
def test_stream_engine_parity_across_slot_counts(cfg, params, slots):
    """Redesigned engine-agnostic StreamEngine: event results stay bitwise
    identical to the single-window ClosedLoopPipeline at B in {1, 4, 7}."""
    eng = StreamEngine(params, cfg, max_streams=slots)
    rng = np.random.default_rng(80 + slots)
    windows = {}
    for s in range(slots):
        w = ev.synthetic_gesture_events(rng, s % 11,
                                        mean_events=2000 + 700 * s,
                                        height=32, width=32)
        eng.submit(f"cam{s}", w)
        windows[f"cam{s}"] = w
    results = eng.run()
    assert len(results) == slots
    pipe = ClosedLoopPipeline(params, cfg)
    for r in results:
        assert r.modality == "event"
        ref = pipe(windows[r.stream_id])
        np.testing.assert_array_equal(ref.label_pred, r.result.label_pred)
        np.testing.assert_array_equal(ref.pwm, r.result.pwm)
        assert ref.energy_mj == r.result.energy_mj
        assert ref.latency_ms == r.result.latency_ms
        _assert_same_breakdown(ref.breakdown, r.result.breakdown)


# -- heterogeneous (event + frame) serving ----------------------------------

def test_mixed_modality_step_serves_both_engines(cfg, params):
    """One step() serves event and frame streams together -- one jit'd
    call per engine -- with per-stream Kraken breakdowns from each wing,
    and the event results still bitwise-match the single-window loop."""
    from repro.core import FrameTCNEngine, TCNConfig, init_tcn
    from repro.core import frames as fr
    from repro.core.pipeline import BatchedClosedLoop

    tcfg = TCNConfig(height=32, width=32, conv1_features=4,
                     conv2_features=8, hidden=32, num_classes=11)
    ev_eng = BatchedClosedLoop(params, cfg)
    fr_eng = FrameTCNEngine(init_tcn(jax.random.PRNGKey(2), tcfg), tcfg)
    eng = StreamEngine(engines=[ev_eng, fr_eng],
                       max_streams={"event": 2, "frame": 2})
    rng = np.random.default_rng(90)
    w = {s: ev.synthetic_gesture_events(rng, s, mean_events=1800,
                                        height=32, width=32)
         for s in range(2)}
    f = {s: fr.synthetic_gesture_frames(rng, s, height=32, width=32)
         for s in range(2)}
    for s in range(2):
        eng.submit(f"dvs{s}", w[s], modality="event")
        eng.submit(f"cam{s}", f[s], modality="frame")

    out = eng.step()
    assert {(r.stream_id, r.modality) for r in out} == {
        ("dvs0", "event"), ("dvs1", "event"),
        ("cam0", "frame"), ("cam1", "frame")}
    assert eng.pending() == 0
    by_id = {r.stream_id: r.result for r in out}
    # Per-engine Kraken accounting: SNE wing vs CUTIE wing stage sets.
    assert "snn_inference" in by_id["dvs0"].breakdown["stages"]
    assert "tcn_inference" in by_id["cam0"].breakdown["stages"]
    assert by_id["cam0"].breakdown["stages"]["tcn_inference"]["domain"] \
        == "cutie"
    # Event wing unchanged by riding next to a frame engine.
    pipe = ClosedLoopPipeline(params, cfg)
    for s in range(2):
        ref = pipe(w[s])
        np.testing.assert_array_equal(ref.pwm, by_id[f"dvs{s}"].pwm)
        assert ref.energy_mj == by_id[f"dvs{s}"].energy_mj
    # Per-stream stats accumulated for both modalities.
    assert eng.stream_stats["cam0"].energy_mj > 0
    assert eng.stream_stats["dvs0"].energy_mj > 0
    # A stream cannot switch modality.
    with pytest.raises(ValueError):
        eng.submit("dvs0", f[0], modality="frame")
    # New streams need an explicit modality when engines are plural.
    with pytest.raises(ValueError):
        eng.submit("new", w[0])


def test_engines_and_params_mutually_exclusive(cfg, params):
    from repro.core.pipeline import BatchedClosedLoop
    with pytest.raises(ValueError):
        StreamEngine(params, cfg, engines=[BatchedClosedLoop(params, cfg)])
    with pytest.raises(ValueError):
        StreamEngine()
    with pytest.raises(ValueError):
        StreamEngine(engines=[BatchedClosedLoop(params, cfg),
                              BatchedClosedLoop(params, cfg)])  # dup modality


# -- pipelined step + warmup ------------------------------------------------

def _submit_all(eng, streams=3, per_stream=4, seed=60):
    rng = np.random.default_rng(seed)
    for s in range(streams):
        for k in range(per_stream):
            eng.submit(f"cam{s}", ev.synthetic_gesture_events(
                rng, (s + k) % 11, mean_events=1500 + 400 * k,
                height=32, width=32))
    return streams * per_stream


@pytest.mark.parametrize("depth", [1, 2])
@pytest.mark.parametrize("fuse_fc", [False, True])
def test_pipelined_run_bitwise_matches_sync(cfg, params, depth, fuse_fc):
    """Any pipeline depth (and the fused fc path) must reproduce the
    synchronous engine's StreamResult sequence exactly -- same
    (stream, seq) order, bitwise-equal results."""
    sync = StreamEngine(params, cfg, max_streams=3)
    n = _submit_all(sync)
    ref = sync.run()

    eng = StreamEngine(params, cfg, max_streams=3, pipeline_depth=depth,
                       fuse_fc=fuse_fc)
    _submit_all(eng)
    got = eng.run()
    assert eng.in_flight == 0 and eng.pending() == 0
    assert len(got) == n
    assert ([(r.stream_id, r.seq) for r in got]
            == [(r.stream_id, r.seq) for r in ref])
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(a.result.label_pred,
                                      b.result.label_pred)
        np.testing.assert_array_equal(a.result.pwm, b.result.pwm)
        assert a.result.energy_mj == b.result.energy_mj


def test_pipelined_step_returns_one_step_late(cfg, params):
    eng = StreamEngine(params, cfg, max_streams=3, pipeline_depth=1)
    _submit_all(eng, streams=3, per_stream=2)
    assert eng.step() == []           # pipeline filling
    assert eng.in_flight == 1
    out = eng.step()                  # step 1's results, step 2 in flight
    assert {r.stream_id for r in out} == {"cam0", "cam1", "cam2"}
    assert all(r.seq == 0 for r in out)
    tail = eng.flush()                # drain without dispatching
    assert all(r.seq == 1 for r in tail) and len(tail) == 3
    assert eng.in_flight == 0 and eng.pending() == 0
    # Stats agree with what was actually served.
    assert eng.stats["windows"] == 6


def test_pipelined_step_drains_when_queues_empty(cfg, params):
    """A step() with no queued work but in-flight batches must make
    progress (collect one step) rather than spin."""
    eng = StreamEngine(params, cfg, max_streams=2, pipeline_depth=2)
    _submit_all(eng, streams=1, per_stream=2)
    assert eng.step() == [] and eng.step() == []   # both windows in flight
    assert eng.pending() == 0 and eng.in_flight == 2
    first = eng.step()                 # no dispatch -> drain oldest
    assert [r.seq for r in first] == [0]
    second = eng.step()
    assert [r.seq for r in second] == [1]
    assert eng.step() == [] and eng.in_flight == 0


def test_pipelined_stub_engine_without_async_split():
    """Engines that only implement the base protocol still work under
    pipelining (served synchronously, one step late)."""
    from tests.test_slot_policy import StubEngine
    eng = StreamEngine(engines=[StubEngine()], max_streams=2,
                       pipeline_depth=1)
    eng.submit("a", object())
    eng.submit("b", object())
    assert eng.step() == []
    out = eng.run()
    assert {(r.stream_id, r.seq) for r in out} == {("a", 0), ("b", 0)}


def test_warmup_precompiles_shape_buckets(cfg, params):
    eng = StreamEngine(params, cfg, max_streams=4, duration_us=300_000)
    loop = eng.loop
    assert loop.compiled_shape_keys() == set()
    eng.warmup([(4, 2048, 300_000), (4, 4096, 300_000)])
    assert loop.compiled_shape_keys() == {(4, 2048, 300_000),
                                          (4, 4096, 300_000)}
    # Serving a warmed bucket adds no new executable.
    rng = np.random.default_rng(70)
    eng.submit("a", ev.synthetic_gesture_events(rng, 0, mean_events=1800,
                                                height=32, width=32))
    eng.run()
    assert loop.compiled_shape_keys() == {(4, 2048, 300_000),
                                          (4, 4096, 300_000)}
    assert eng.compiled_shapes() == {(4, 2048, 300_000)}


def test_warmup_validation(cfg, params):
    eng = StreamEngine(params, cfg, max_streams=2)
    # No latched duration yet: a 2-tuple key cannot be resolved.
    with pytest.raises(ValueError, match="duration"):
        eng.warmup([(2, 2048)])
    eng2 = StreamEngine(params, cfg, max_streams=2, duration_us=300_000)
    eng2.warmup([(2, 2048)])          # 2-tuple uses the pinned duration
    assert eng2.loop.compiled_shape_keys() == {(2, 2048, 300_000)}
    from tests.test_slot_policy import StubEngine
    stub = StreamEngine(engines=[StubEngine()], max_streams=1)
    with pytest.raises(ValueError, match="warmup"):
        stub.warmup([(1,)])


def test_fuse_fc_with_engines_form_rejected(cfg, params):
    with pytest.raises(ValueError, match="fuse_fc"):
        StreamEngine(engines=[BatchedClosedLoop(params, cfg)], fuse_fc=True)


def test_pipeline_depth_validation(cfg, params):
    with pytest.raises(ValueError, match="pipeline_depth"):
        StreamEngine(params, cfg, pipeline_depth=-1)
