"""Serving: generate loop + ternary serving quantization (CUTIE at scale)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig, build_model
from repro.models.layers import dense
from repro.kernels import pack_ternary_weights, ternary_matmul
from repro.serving import ServeConfig, generate, quantize_for_serving


def _model(vocab=64):
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=256,
                      vocab_size=vocab, d_ff=512, num_heads=4,
                      num_kv_heads=2, dtype="float32")
    return build_model(cfg)


def test_generate_runs_and_shapes():
    model = _model()
    params = model.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (3, 5), 0, 64)
    toks, stats = generate(model, params,prompts,
                           ServeConfig(max_new_tokens=6))
    assert toks.shape == (3, 6)
    assert stats.tokens_generated == 18
    assert stats.tokens_per_s > 0


def test_quantize_for_serving_stats_and_8x():
    model = _model()
    params = model.init(jax.random.PRNGKey(0))
    qparams, stats = quantize_for_serving(params)
    assert stats["quantized"] > 0
    # quantized leaves shrink ~8x; embedding stays fp.
    assert isinstance(qparams["embed"], jnp.ndarray)
    mlp = qparams["layers"]["mlp"]["w_up"]
    assert "packed" in mlp and mlp["packed"].dtype == jnp.uint8
    orig = params["layers"]["mlp"]["w_up"]
    assert mlp["packed"].size * 8 == orig.size * 2  # 2bit vs f32... packed bytes
    # overall compression on the quantized subset ~8x for f32 weights
    # (bytes_before includes kept leaves; just sanity check direction)
    assert stats["bytes_after"] < stats["bytes_before"]


def test_quantized_model_still_generates():
    model = _model()
    params = model.init(jax.random.PRNGKey(0))
    qparams, _ = quantize_for_serving(params)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0, 64)
    toks, _ = generate(model, qparams, prompts,
                       ServeConfig(max_new_tokens=4))
    assert toks.shape == (2, 4)


def test_dense_dispatch_matches_pallas_kernel():
    """layers.dense() jnp dequant path == Pallas ternary kernel numerics."""
    w = jax.random.normal(jax.random.PRNGKey(0), (256, 512))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 256))
    packed, scale = pack_ternary_weights(w)
    y_jnp = dense(x, {"packed": packed, "scale": scale})
    y_pallas = ternary_matmul(x, packed, scale)
    np.testing.assert_allclose(np.asarray(y_jnp), np.asarray(y_pallas),
                               rtol=1e-4, atol=1e-4)


def test_quantized_logits_close_to_dense():
    """Ternary serving is an approximation: top-1 agreement on random
    inputs should be high for a *trained-like* scale regime. Here we just
    bound the logit perturbation on an untrained net."""
    model = _model()
    params = model.init(jax.random.PRNGKey(0))
    qparams, _ = quantize_for_serving(params)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0, 64)
    lg_f, _ = model.apply(params, {"tokens": toks})
    lg_q, _ = model.apply(qparams, {"tokens": toks})
    assert np.isfinite(np.asarray(lg_q)).all()
    # same order of magnitude (ternary keeps per-channel scale)
    assert float(jnp.abs(lg_q).mean()) < 10 * float(jnp.abs(lg_f).mean()) + 1
