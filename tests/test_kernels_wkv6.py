"""WKV-6 scan Pallas kernel vs the stepwise and chunked oracles."""
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ref import wkv6_ref
from repro.kernels.wkv6_scan import wkv6_scan_pallas
from repro.models.rwkv6 import wkv6_chunked


def _inputs(b, t, h, hd, seed=0, dtype=jnp.float32):
    key = jax.random.PRNGKey(seed)
    r, k, v = (jax.random.normal(jax.random.fold_in(key, i),
                                 (b, t, h, hd), dtype) for i in range(3))
    logw = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 3),
                                      (b, t, h, hd)) * 0.5)
    logw = jnp.maximum(logw, -4.0).astype(dtype)
    u = (jax.random.normal(jax.random.fold_in(key, 4), (h, hd)) * 0.1
         ).astype(dtype)
    return r, k, v, logw, u


@pytest.mark.parametrize("b,t,h,hd", [(1, 8, 1, 64), (2, 24, 3, 64),
                                      (2, 17, 2, 64), (1, 40, 5, 64)])
def test_kernel_matches_chunked(b, t, h, hd):
    r, k, v, logw, u = _inputs(b, t, h, hd)
    o_k, s_k = wkv6_scan_pallas(r, k, v, logw, u, interpret=True)
    o_c, s_c = wkv6_chunked(r, k, v, logw, u,
                            chunk=min(8, t) if t % 8 == 0 else 1)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_c),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_c),
                               rtol=2e-4, atol=2e-4)


def test_kernel_matches_stepwise_ref_per_head():
    r, k, v, logw, u = _inputs(2, 16, 3, 64, seed=5)
    o_k, s_k = wkv6_scan_pallas(r, k, v, logw, u, interpret=True)
    for bi in range(2):
        for hi in range(3):
            o_ref, s_ref = wkv6_ref(r[bi, :, hi], k[bi, :, hi],
                                    v[bi, :, hi],
                                    jnp.exp(logw[bi, :, hi]), u[hi])
            np.testing.assert_allclose(np.asarray(o_k[bi, :, hi]),
                                       np.asarray(o_ref),
                                       rtol=2e-4, atol=2e-4)
            np.testing.assert_allclose(np.asarray(s_k[bi, hi]),
                                       np.asarray(s_ref),
                                       rtol=2e-4, atol=2e-4)


def test_block_t_chunking_path():
    r, k, v, logw, u = _inputs(1, 33, 2, 64, seed=7)
    o_a, s_a = wkv6_scan_pallas(r, k, v, logw, u, block_t=8,
                                interpret=True)
    o_b, s_b = wkv6_scan_pallas(r, k, v, logw, u, block_t=33,
                                interpret=True)
    np.testing.assert_allclose(np.asarray(o_a), np.asarray(o_b),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_a), np.asarray(s_b),
                               rtol=1e-5, atol=1e-5)


@hypothesis.settings(max_examples=10, deadline=None)
@hypothesis.given(t=st.integers(1, 24), h=st.integers(1, 3),
                  seed=st.integers(0, 1000))
def test_property_kernel_equals_chunked(t, h, seed):
    r, k, v, logw, u = _inputs(1, t, h, 64, seed=seed)
    o_k, s_k = wkv6_scan_pallas(r, k, v, logw, u, interpret=True)
    o_c, s_c = wkv6_chunked(r, k, v, logw, u, chunk=1)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_c),
                               rtol=3e-4, atol=3e-4)
