"""Shared test fixtures.

NOTE: no XLA_FLAGS here on purpose -- unit tests must see the 1 real CPU
device. Multi-device tests spawn subprocesses that set
--xla_force_host_platform_device_count themselves (test_distributed.py).
"""
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
