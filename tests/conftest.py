"""Shared test fixtures.

NOTE: no XLA_FLAGS here on purpose -- unit tests must see the 1 real CPU
device. Multi-device tests spawn subprocesses that set
--xla_force_host_platform_device_count themselves (test_distributed.py).

If ``hypothesis`` is not installed (it is a ``[test]`` extra, not a
runtime dependency), a minimal stand-in module is registered so that
test modules importing it still *collect* cleanly; every ``@given``
property test then skips with a clear reason instead of erroring the
whole session.
"""
import os
import sys
import types

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:
    import hypothesis  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def _given(*_a, **_k):
        def deco(fn):
            # Deliberately no functools.wraps: the stand-in must NOT expose
            # the strategy parameters, or pytest would treat them as
            # fixtures. Zero-arg skipper + copied name/doc only.
            def skipper():
                pytest.skip("hypothesis not installed (pip install -e "
                            "'.[test]'); property test skipped")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def _settings(*_a, **_k):
        return lambda fn: fn

    class _Strategy:
        """Placeholder: accepted by the stub ``given``, never drawn from."""
        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.assume = lambda *a, **k: True
    _hyp.note = lambda *a, **k: None
    # Any other hypothesis name (HealthCheck, example, ...) resolves to a
    # benign placeholder so collection can never hard-fail on the stub.
    _hyp.__getattr__ = lambda name: _Strategy()
    _st = types.ModuleType("hypothesis.strategies")
    _st.__getattr__ = lambda name: _Strategy()
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st

import jax  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
