"""Distribution: sharding-rule resolution + an in-process mini dry-run on
8 fake devices (subprocess so the device-count flag doesn't leak)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from jax.sharding import PartitionSpec as P

ROOT = os.path.join(os.path.dirname(__file__), "..")
SRC = os.path.join(ROOT, "src")


def _run_sub(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# -- rule resolution (pure, no devices needed) ----------------------------

def test_resolve_spec_fallbacks():
    out = _run_sub("""
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_mesh_for
        from repro.distributed.sharding import resolve_spec
        mesh = make_mesh_for((2, 4), ("data", "model"))
        # heads divisible -> heads on model
        s = resolve_spec((64, 8, 16), ("embed", "heads", "head_dim"), mesh)
        print("A", s)
        # heads NOT divisible (e.g. llama4's 40%16) -> fallback to head_dim
        s = resolve_spec((64, 10, 16), ("embed", "heads", "head_dim"), mesh)
        print("B", s)
        # nothing divisible -> unsharded dims
        s = resolve_spec((63, 9, 15), ("embed", "heads", "head_dim"), mesh)
        print("C", s)
        # vocab not divisible (seamless 256206-like) -> unsharded vocab
        s = resolve_spec((254, 64), ("vocab", "embed"), mesh)
        print("D", s)
    """)
    lines = dict(l.split(" ", 1) for l in out.strip().splitlines())
    assert lines["A"] == "PartitionSpec('data', 'model', None)"
    assert lines["B"] == "PartitionSpec('data', None, 'model')"
    assert lines["C"] == "PartitionSpec(None, None, None)"
    assert lines["D"] == "PartitionSpec(None, 'data')"


def test_param_specs_cover_all_archs():
    out = _run_sub("""
        import jax
        from repro.configs import ARCHS, get_config
        from repro.models import build_model
        from repro.launch.mesh import make_mesh_for
        from repro.distributed.sharding import param_pspecs
        mesh = make_mesh_for((2, 4), ("data", "model"))
        for a in ARCHS:
            cfg = get_config(a, smoke=True)
            defs = build_model(cfg).defs()
            specs = param_pspecs(defs, mesh)
            n = len(jax.tree.leaves(specs,
                    is_leaf=lambda x: hasattr(x, "_normalized_spec_for_aval")))
            print(a, "ok")
    """)
    assert out.count("ok") == 10


def test_mini_dryrun_dense_and_rwkv():
    """lower+compile a train and a decode cell on a (2,4) mesh with smoke
    configs -- the full-size version of this is launch/dryrun.py."""
    out = _run_sub("""
        import dataclasses, jax, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.configs.shapes import ShapeSpec
        from repro.launch.mesh import make_mesh_for
        from repro.launch.dryrun import lower_cell, analyze
        import repro.launch.dryrun as DR

        mesh = make_mesh_for((2, 4), ("data", "model"))
        for arch, shape in [("llama3.2-1b",
                             ShapeSpec("t", "train", 64, 8)),
                            ("rwkv6-7b",
                             ShapeSpec("d", "decode", 64, 8)),
                            ("deepseek-moe-16b",
                             ShapeSpec("t", "train", 64, 8))]:
            cfg = get_config(arch, smoke=True)
            with mesh:
                compiled, _ = lower_cell(cfg, shape, mesh)
            rec = analyze(compiled)
            assert rec["flops"] > 0
            print(arch, "compiled flops", rec["flops"] > 0)
    """)
    assert out.count("compiled flops True") == 3


def test_batch_spec_prefers_pod_data():
    out = _run_sub("""
        from repro.launch.mesh import make_mesh_for
        from repro.distributed.sharding import _batch_dim_spec
        mesh3 = make_mesh_for((2, 2, 2), ("pod", "data", "model"))
        print("A", _batch_dim_spec(mesh3, 8))
        print("B", _batch_dim_spec(mesh3, 2))
        print("C", _batch_dim_spec(mesh3, 1))
    """)
    lines = dict(l.split(" ", 1) for l in out.strip().splitlines())
    assert lines["A"] == "('pod', 'data')"
    assert lines["B"] == "('pod',)"
    assert lines["C"] == "None"
