"""Stateful streaming: per-stream carried state (LIF membranes) threaded
through the whole serving stack.

The contract under test, at every layer:

  * ``snn_apply`` -- running T steps in W chained chunks (feeding each
    chunk the previous chunk's ``state``) is bitwise identical to one
    uninterrupted T-step run, in every execution mode (time_serial,
    layer_serial, fused fc, Pallas kernel).
  * ``BatchedClosedLoop`` -- ``init_state`` / ``infer(batch, state)``
    expose that chain per batch slot; the zero state reproduces the
    stateless call bitwise.
  * ``StreamEngine`` -- a stream served in W windows with
    ``stateful=True`` equals the single uninterrupted scan, at
    B in {1, 4, 8}, sync and pipelined, kernel and reference paths;
    state follows the STREAM through slot reassignment (not the slot
    index), slots are zeroed on admission (dirty-slot regression), and
    ``reset_state`` / ``retire`` drop a carry on demand.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SNNConfig, init_snn, snn_apply, snn_init_state
from repro.core import events as ev
from repro.core.pipeline import (BatchedClosedLoop, ClosedLoopPipeline,
                                 pwm_from_logits)
from repro.kernels import lif_scan
from repro.serving import DeadlinePolicy, StreamEngine


@pytest.fixture(scope="module")
def cfg():
    return SNNConfig(height=32, width=32, time_bins=4, conv1_features=4,
                     conv2_features=8, hidden=32, num_classes=11)


@pytest.fixture(scope="module")
def params(cfg):
    return init_snn(jax.random.PRNGKey(0), cfg)


def _windows(n, seed=0, mean_events=1500):
    rng = np.random.default_rng(seed)
    return [ev.synthetic_gesture_events(rng, i % 11, mean_events=mean_events,
                                        height=32, width=32)
            for i in range(n)]


def _vox_stream(windows, cfg):
    """Voxelize a window sequence as ONE uninterrupted event stream:
    concatenated events, W * time_bins bins -- bitwise the concatenation
    of the per-window grids (same bin width)."""
    d = windows[0].duration_us
    x = np.concatenate([w.x for w in windows])
    y = np.concatenate([w.y for w in windows])
    t = np.concatenate([w.t + k * d for k, w in enumerate(windows)])
    p = np.concatenate([w.p for w in windows])
    return ev.voxelize(
        jnp.asarray(x), jnp.asarray(y), jnp.asarray(t), jnp.asarray(p),
        duration_us=d * len(windows), time_bins=cfg.time_bins * len(windows),
        height=cfg.height, width=cfg.width)


def _readout(spikes_bt):
    """The engine's readout on a (B, T', classes) spike train slice."""
    logits = spikes_bt.mean(axis=1) * 10.0
    return (np.asarray(jnp.argmax(logits, -1)),
            np.asarray(pwm_from_logits(logits)))


def _uninterrupted_oracle(params, cfg, streams):
    """Per-(stream, window) readouts sliced from ONE uninterrupted scan
    over each stream's whole event sequence."""
    ids = sorted(streams)
    vox = jnp.stack([_vox_stream(streams[sid], cfg) for sid in ids])
    out = snn_apply(params, vox, cfg, mode="layer_serial")
    per_window = {}
    w = cfg.time_bins
    for k in range(next(iter(streams.values())).__len__()):
        per_window[k] = _readout(out["out_spikes"][:, k * w:(k + 1) * w])
    return ids, per_window


def _assert_matches_oracle(results, ids, per_window):
    for r in results:
        b = ids.index(r.stream_id)
        preds, pwm = per_window[r.seq]
        np.testing.assert_array_equal(r.result.label_pred, preds[b:b + 1])
        np.testing.assert_array_equal(r.result.pwm, pwm[b:b + 1])


# -- snn_apply: the chaining contract in every mode --------------------------

@pytest.mark.parametrize("mode,kw", [
    ("time_serial", {}),
    ("layer_serial", {}),
    ("layer_serial", {"fuse_fc": True}),
    ("layer_serial", {"lif_scan_fn": lif_scan}),
], ids=["time_serial", "layer_serial", "fused_fc", "pallas_kernel"])
def test_snn_apply_chaining_matches_uninterrupted(cfg, params, mode, kw):
    """W chained chunks == one uninterrupted scan: spikes bitwise, final
    state bitwise, in every execution order."""
    b, t = 3, 8
    vox = (jax.random.uniform(jax.random.PRNGKey(1), (b, t, 2, 32, 32))
           < 0.05).astype(jnp.float32)
    full = snn_apply(params, vox, cfg, mode=mode, **kw)
    state = snn_init_state(cfg, b)
    chunks = []
    for lo, hi in ((0, 3), (3, 5), (5, 8)):
        out = snn_apply(params, vox[:, lo:hi], cfg, mode=mode,
                        state=state, **kw)
        state = out["state"]
        chunks.append(out["out_spikes"])
    np.testing.assert_array_equal(
        np.asarray(jnp.concatenate(chunks, axis=1)),
        np.asarray(full["out_spikes"]))
    for name, v in full["state"].items():
        assert state[name].shape == (b, *v.shape[1:])
        np.testing.assert_array_equal(np.asarray(state[name]),
                                      np.asarray(v))


def test_snn_apply_zero_state_equals_stateless(cfg, params):
    """snn_init_state is the cold-start condition: explicit zero state
    reproduces the stateless call bitwise (the property that lets one
    executable serve both paths)."""
    vox = (jax.random.uniform(jax.random.PRNGKey(2), (2, 4, 2, 32, 32))
           < 0.05).astype(jnp.float32)
    for mode in ("time_serial", "layer_serial"):
        a = snn_apply(params, vox, cfg, mode=mode)
        z = snn_apply(params, vox, cfg, mode=mode,
                      state=snn_init_state(cfg, 2))
        np.testing.assert_array_equal(np.asarray(a["out_spikes"]),
                                      np.asarray(z["out_spikes"]))


# -- BatchedClosedLoop: the engine-level state API ---------------------------

def test_batched_loop_stateful_infer_contract(cfg, params):
    ws = _windows(3, seed=3)
    loop = BatchedClosedLoop(params, cfg)
    batch = ev.pad_event_windows(ws)
    state = loop.init_state(batch.batch_size)
    assert set(state) == {"conv1", "conv2", "fc1", "fc2"}
    assert all(v.shape[0] == batch.batch_size for v in state.values())
    # Zero state == stateless, bitwise, and new_state is slot-major.
    stateless = loop.infer(batch)
    results, new_state = loop.infer(batch, state)
    for a, b in zip(stateless, results):
        np.testing.assert_array_equal(a.pwm, b.pwm)
        assert a.energy_mj == b.energy_mj
    assert all(new_state[k].shape == state[k].shape for k in state)
    # The carried membrane is live (some slot moved off zero).
    assert any(float(jnp.abs(v).sum()) > 0 for v in new_state.values())


def test_batched_loop_window_chaining(cfg, params):
    """infer(batch, state) chained over W windows == the uninterrupted
    scan, per batch slot."""
    streams = {s: _windows(3, seed=10 + s) for s in range(2)}
    ids, per_window = _uninterrupted_oracle(params, cfg, streams)
    loop = BatchedClosedLoop(params, cfg)
    state = loop.init_state(2)
    for k in range(3):
        batch = ev.pad_event_windows([streams[sid][k] for sid in ids])
        results, state = loop.infer(batch, state)
        preds, pwm = per_window[k]
        for b, res in enumerate(results):
            np.testing.assert_array_equal(res.label_pred, preds[b:b + 1])
            np.testing.assert_array_equal(res.pwm, pwm[b:b + 1])


# -- StreamEngine: W-window stateful serving == uninterrupted scan -----------

@pytest.mark.parametrize("pipeline_depth", [0, 1], ids=["sync", "pipelined"])
@pytest.mark.parametrize("path", ["reference", "kernel"])
@pytest.mark.parametrize("b", [1, 4, 8])
def test_stream_engine_stateful_chaining(cfg, params, b, path,
                                         pipeline_depth):
    """The acceptance criterion: a stream served in W windows with state
    carry equals the single uninterrupted scan -- B in {1, 4, 8}, sync
    and pipelined, kernel (Pallas lif_scan + fused fc) and reference
    paths. The oracle is one reference scan; the kernel path passing it
    re-pins the kernels' bitwise contract end to end."""
    streams = {f"cam{s}": _windows(2, seed=20 + 7 * s + b)
               for s in range(b)}
    kernel_kw = ({"lif_scan_fn": lif_scan, "fuse_fc": True}
                 if path == "kernel" else {})
    eng = StreamEngine(params, cfg, max_streams=b,
                       pipeline_depth=pipeline_depth, **kernel_kw)
    for sid, ws in streams.items():
        for w in ws:
            eng.submit(sid, w, stateful=True)
    results = eng.run()
    assert len(results) == 2 * b
    assert eng.in_flight == 0 and eng.pending() == 0
    ids, per_window = _uninterrupted_oracle(params, cfg, streams)
    _assert_matches_oracle(results, ids, per_window)


def test_stateful_and_stateless_streams_coexist(cfg, params):
    """Mixed batch: the stateful stream chains while its stateless
    neighbours stay bitwise equal to fresh single-window runs -- slot
    state never leaks sideways."""
    chained = _windows(3, seed=30)
    fresh = _windows(3, seed=31)
    eng = StreamEngine(params, cfg, max_streams=2)
    for k in range(3):
        eng.submit("carry", chained[k], stateful=True)
        eng.submit("amnesiac", fresh[k])
    results = eng.run()
    ids, per_window = _uninterrupted_oracle(params, cfg,
                                            {"carry": chained})
    _assert_matches_oracle([r for r in results if r.stream_id == "carry"],
                           ids, per_window)
    pipe = ClosedLoopPipeline(params, cfg)
    for r in results:
        if r.stream_id != "amnesiac":
            continue
        ref = pipe(fresh[r.seq])
        np.testing.assert_array_equal(r.result.pwm, ref.pwm)
        assert r.result.energy_mj == ref.energy_mj


# -- slot hygiene: dirty slots, reset, retire --------------------------------

def test_dirty_slot_is_zeroed_for_new_stream(cfg, params):
    """Slot-retirement leak surface: after a stateful stream drains (or
    is retired), a NEW stream admitted into the same slot -- whose state
    row still physically holds the old membrane -- must be bitwise
    identical to a fresh B=1 run. Checked for a stateless and a stateful
    newcomer, and after an explicit retire()."""
    hot = _windows(2, seed=40, mean_events=2500)
    eng = StreamEngine(params, cfg, max_streams=1)   # one slot: always dirty
    for w in hot:
        eng.submit("hot", w, stateful=True)
    eng.run()

    pipe = ClosedLoopPipeline(params, cfg)
    w_a, w_b, w_c = _windows(3, seed=41)
    eng.submit("newcomer", w_a)                      # stateless admit
    r = eng.run()[0]
    ref = pipe(w_a)
    np.testing.assert_array_equal(r.result.pwm, ref.pwm)
    assert r.result.energy_mj == ref.energy_mj

    eng.submit("newcomer2", w_b, stateful=True)      # stateful cold start
    r = eng.run()[0]
    ref = pipe(w_b)
    np.testing.assert_array_equal(r.result.pwm, ref.pwm)

    assert eng.retire("newcomer2") == 0              # retire drops the carry
    eng.submit("newcomer3", w_c, stateful=True)
    r = eng.run()[0]
    ref = pipe(w_c)
    np.testing.assert_array_equal(r.result.pwm, ref.pwm)


def test_reset_state_is_a_gesture_boundary(cfg, params):
    """reset_state() zeroes a live stream's carry: the next window runs
    from cold start, as if the stream were newly admitted."""
    ws = _windows(3, seed=50)
    pipe = ClosedLoopPipeline(params, cfg)
    eng = StreamEngine(params, cfg, max_streams=2)
    eng.submit("s", ws[0], stateful=True)
    eng.run()
    eng.reset_state("s")
    eng.submit("s", ws[1])
    r = eng.run()[0]
    ref = pipe(ws[1])                                # == fresh run
    np.testing.assert_array_equal(r.result.pwm, ref.pwm)
    with pytest.raises(ValueError, match="not stateful"):
        eng.submit("plain", ws[2])
        eng.reset_state("plain")
    with pytest.raises(KeyError):
        eng.reset_state("nobody")


def test_retire_frees_stream_and_validates(cfg, params):
    ws = _windows(2, seed=60)
    eng = StreamEngine(params, cfg, max_streams=2)
    eng.submit("x", ws[0], stateful=True)
    eng.submit("x", ws[1])
    eng.run()
    assert eng.retire("x") == 0
    with pytest.raises(KeyError):
        eng.retire("x")                              # id is gone
    # Same id re-admitted: a brand-new stream, seq restarts at 0.
    assert eng.submit("x", ws[0], stateful=True) == 0
    assert eng.run()[0].seq == 0
    # Retiring with queued windows discards and reports them.
    eng.submit("y", ws[0])
    eng.submit("y", ws[1])
    assert eng.retire("y") == 2
    assert eng.pending() == 0
    # Retiring with windows in flight discards exactly that stream's
    # dispatched records (counted in the total); nothing is emitted for
    # them at the later collect.
    eng2 = StreamEngine(params, cfg, max_streams=1, pipeline_depth=1)
    eng2.submit("z", ws[0], stateful=True)
    eng2.step()                                      # dispatched, uncollected
    assert eng2.retire("z") == 1
    assert eng2.flush() == []


# -- state follows the stream, not the slot ----------------------------------

class _RecordingDeadline(DeadlinePolicy):
    """DeadlinePolicy that records each round's slot assignment."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.history = []

    def assign(self, lane):
        super().assign(lane)
        self.history.append(list(lane.slots))


def test_state_follows_stream_across_deadline_reorder(cfg, params):
    """Under DeadlinePolicy a stateful stream gets rotated out by urgent
    traffic and re-admitted -- often into a DIFFERENT slot index. Its
    carry must follow the stream, not the slot: the chained results still
    equal the uninterrupted scan."""
    carry = _windows(4, seed=70)
    u0 = _windows(4, seed=80)
    u1 = _windows(3, seed=81)
    policy = _RecordingDeadline(fair_quantum=1, aging=0.0, max_wait=2)
    eng = StreamEngine(params, cfg, max_streams=2, policy=policy)
    # Phase 1: carry (slack deadline) is outranked by urgent0, so EDF
    # puts urgent0 in slot 0 and carry in slot 1; with nobody waiting
    # there is no rotation, and carry chains two windows in slot 1.
    for k, w in enumerate(carry):
        eng.submit("carry", w, deadline=1000.0 + k, stateful=True)
    for w in u0:
        eng.submit("urgent0", w, deadline=0.0)
    results = eng.step() + eng.step()
    # Phase 2: a second urgent stream starts waiting -> every round is
    # contended, carry is rotated out (fair_quantum=1), passed over by
    # EDF, and finally re-admitted via the max_wait anti-starvation
    # bound -- into slot 0, while an urgent stream still cycles slot 1.
    for w in u1:
        eng.submit("urgent1", w, deadline=0.0)
    results += eng.run()
    assert len(results) == 11
    # The reorder actually happened: "carry" held >= 2 distinct slots.
    slots_held = {i for rnd in policy.history
                  for i, sid in enumerate(rnd) if sid == "carry"}
    assert len(slots_held) >= 2, policy.history
    ids, per_window = _uninterrupted_oracle(params, cfg, {"carry": carry})
    _assert_matches_oracle([r for r in results if r.stream_id == "carry"],
                           ids, per_window)


# -- protocol uniformity ------------------------------------------------------

def test_stateful_submit_validation(cfg, params):
    from tests.test_slot_policy import StubEngine
    stub = StreamEngine(engines=[StubEngine()], max_streams=2)
    with pytest.raises(ValueError, match="carried-state"):
        stub.submit("a", object(), stateful=True)
    assert stub.pending() == 0                       # nothing latched
    eng = StreamEngine(params, cfg, max_streams=2)
    ws = _windows(2, seed=90)
    eng.submit("a", ws[0], stateful=True)
    with pytest.raises(ValueError, match="latched"):
        eng.submit("a", ws[1], stateful=False)
    assert eng.stateful_of("a") is True
    eng.submit("b", ws[1])
    assert eng.stateful_of("b") is False
    # A rejected stateful toggle burns no sequence number.
    assert eng.submit("a", ws[1]) == 1


def test_legacy_two_arg_scan_fn_rejected_at_construction(cfg, params):
    """The engine threads v0 through lif_scan_fn; a pre-stateful
    two-argument callable must be rejected at construction with a clear
    message, not with an opaque TypeError mid-trace."""
    with pytest.raises(ValueError, match="lif_scan_fn"):
        BatchedClosedLoop(params, cfg, lif_scan_fn=lambda c, p: None)
    with pytest.raises(ValueError, match="lif_scan_fn"):
        ClosedLoopPipeline(params, cfg, lif_scan_fn=lambda c, p: None)
    # Three-positional callables (and v0-defaulted ones) are fine.
    BatchedClosedLoop(params, cfg, lif_scan_fn=lif_scan)
    BatchedClosedLoop(params, cfg,
                      lif_scan_fn=lambda c, p, v0=None: lif_scan(c, p, v0))


def test_retire_forgets_policy_bookkeeping(cfg, params):
    """retire() must clear a policy's per-stream bookkeeping through the
    duck-typed forget hook, so a reused id starts with fresh aging."""
    policy = DeadlinePolicy(max_wait=16)
    eng = StreamEngine(params, cfg, max_streams=1, policy=policy)
    ws = _windows(2, seed=96)
    eng.submit("hog", ws[0], deadline=0.0)
    eng.submit("aged", ws[1], deadline=5.0)
    eng.step()                          # "aged" passed over: counter > 0
    assert policy._waited.get("aged", 0) > 0
    eng.retire("aged")
    assert "aged" not in policy._waited
    eng.run()


class _StatefulStub:
    """StubEngine + init_state: a stateful-capable engine WITHOUT the
    async dispatch/collect split."""

    modality = "stub"

    def __init__(self):
        self.duration_us = None
        self.infer_calls = 0

    def validate(self, item):
        pass

    def prepare(self, items, *, batch_size):
        return items

    def shape_key(self, batch):
        return (len(batch),)

    def init_state(self, batch_size):
        return {"v": jnp.zeros((batch_size,))}

    def infer(self, batch, state=None):
        from repro.core.pipeline import ClosedLoopResult
        self.infer_calls += 1
        results = [None if it is None else ClosedLoopResult(
            label_pred=np.zeros(1, np.int64), pwm=np.zeros((1, 4)),
            latency_ms=1.0, energy_mj=1.0, breakdown={}, realtime=True,
            sustained_rate_hz=1.0) for it in batch]
        if state is None:
            return results
        return results, {"v": state["v"] + 1.0}


def test_splitless_engine_keeps_pipelined_deferral_when_stateless(cfg):
    """A stateful-capable engine without the async split: stateless
    pipelined serving keeps the deferred-infer fallback (infer runs at
    collect), while stateful streams force infer at dispatch order so
    the carry chains correctly."""
    stub = _StatefulStub()
    eng = StreamEngine(engines=[stub], max_streams=1, pipeline_depth=1)
    eng.submit("a", object())                       # stateless
    assert eng.step() == [] and stub.infer_calls == 0   # deferred
    assert len(eng.flush()) == 1 and stub.infer_calls == 1

    stub2 = _StatefulStub()
    eng2 = StreamEngine(engines=[stub2], max_streams=1, pipeline_depth=1)
    eng2.submit("a", object(), stateful=True)
    assert eng2.step() == [] and stub2.infer_calls == 1  # eager at dispatch
    lane = eng2._lanes["stub"]
    assert float(lane.state["v"][0]) == 1.0              # carry advanced
    eng2.submit("a", object())
    assert len(eng2.step()) == 1
    eng2.flush()
    assert float(lane.state["v"][0]) == 2.0              # chained


def test_frame_engine_state_is_trivially_empty(cfg, params):
    """The CUTIE wing is feedforward: init_state is the empty pytree and
    a stateful frame stream behaves exactly like a stateless one -- the
    protocol stays uniform across wings."""
    from repro.core import FrameTCNEngine, TCNConfig, init_tcn
    from repro.core import frames as fr
    tcfg = TCNConfig(height=32, width=32, conv1_features=4,
                     conv2_features=8, hidden=32, num_classes=11)
    fr_eng = FrameTCNEngine(init_tcn(jax.random.PRNGKey(2), tcfg), tcfg)
    assert fr_eng.init_state(4) == {}
    rng = np.random.default_rng(7)
    frames = [fr.synthetic_gesture_frames(rng, k, height=32, width=32)
              for k in range(2)]
    stateless = fr_eng.infer_frames(frames)
    eng = StreamEngine(engines=[FrameTCNEngine(
        init_tcn(jax.random.PRNGKey(2), tcfg), tcfg)], max_streams=2)
    for f in frames:
        eng.submit("cam", f, stateful=True)
    out = eng.run()
    for r in out:
        np.testing.assert_array_equal(r.result.pwm,
                                      stateless[r.seq].pwm)
        assert r.result.energy_mj == stateless[r.seq].energy_mj


def test_pipelined_state_stays_on_device(cfg, params):
    """Pipelined serving chains membranes dispatch-to-dispatch as device
    arrays (jax futures): the lane's carried state is never a host
    (numpy) buffer."""
    eng = StreamEngine(params, cfg, max_streams=2, pipeline_depth=1)
    ws = _windows(4, seed=95)
    for k, w in enumerate(ws):
        eng.submit("s", w, stateful=True)
    eng.step()
    eng.step()          # two dispatches in flight / chained
    lane = eng._lanes["event"]
    assert lane.state is not None
    for leaf in jax.tree_util.tree_leaves(lane.state):
        assert isinstance(leaf, jax.Array)
    eng.flush()
