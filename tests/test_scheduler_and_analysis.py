"""Serving scheduler + HLO collective parser + annotation helpers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import (collective_bytes, parse_shape_bytes,
                                       _group_size)
from repro.models import ModelConfig, build_model
from repro.serving.scheduler import BatchScheduler, Request
from repro.distributed.annotate import (constrain, execution_mode,
                                        get_execution_mode, unshard_fsdp)


def test_parse_shape_bytes():
    assert parse_shape_bytes("bf16[8,128]") == 8 * 128 * 2
    assert parse_shape_bytes("f32[100]") == 400
    assert parse_shape_bytes("(bf16[4], f32[2,2])") == 8 + 16
    assert parse_shape_bytes("pred[16]") == 16
    assert parse_shape_bytes("u8[1024,64]") == 1024 * 64


def test_group_size_formats():
    assert _group_size("replica_groups={{0,1,2,3}}") == 4
    assert _group_size("replica_groups=[32,16]<=[512]") == 16
    assert _group_size("no groups here") == 1


def test_collective_bytes_ring_factors():
    hlo = """
  %ag = f32[64,128]{1,0} all-gather(f32[4,128] %x), replica_groups={{0,1,2,3}}, dimensions={0}
  %ar = bf16[32]{0} all-reduce(bf16[32] %y), replica_groups=[2,4]<=[8], to_apply=%sum
  %cp = f32[16]{0} collective-permute(f32[16] %z), source_target_pairs={{0,1}}, replica_groups={{0,1}}
"""
    out = collective_bytes(hlo)
    ag = 64 * 128 * 4 * 3 / 4
    ar = 2 * 32 * 2 * 3 / 4
    assert out["bytes_by_kind"]["all-gather"] == pytest.approx(ag)
    assert out["bytes_by_kind"]["all-reduce"] == pytest.approx(ar)
    assert out["count_by_kind"]["collective-permute"] == 1


def test_execution_mode_context():
    assert get_execution_mode() == "train"
    with execution_mode("serve"):
        assert get_execution_mode() == "serve"
        w = jnp.zeros((8, 8))
        assert unshard_fsdp(w, (None, "model")) is w   # no-op in serve
    assert get_execution_mode() == "train"


def test_constrain_noop_off_mesh():
    x = jnp.ones((4, 4))
    y = constrain(x, ("batch", None))
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_scheduler_serves_all_requests():
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                      vocab_size=64, d_ff=128, num_heads=4, num_kv_heads=2,
                      dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [Request(id=i,
                    prompt=rng.integers(2, 64, size=rng.integers(2, 6)),
                    max_new_tokens=int(rng.integers(2, 6)))
            for i in range(7)]
    sched = BatchScheduler(model, params, max_batch=3, cache_len=16)
    done = sched.run(reqs)
    assert len(done) == 7
    for r in done:
        assert r.done and len(r.output) == r.max_new_tokens
    assert sched.stats["batches"] == 3       # ceil(7/3)
    assert sched.stats["tokens"] == sum(r.max_new_tokens for r in reqs)


def test_scheduler_batch_consistency_vs_single():
    """A request served alone == the same request served in a batch
    (padding slots must not leak into real slots)."""
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                      vocab_size=64, d_ff=128, num_heads=4, num_kv_heads=2,
                      dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = np.array([5, 9, 11], np.int64)
    r_solo = Request(id=0, prompt=prompt, max_new_tokens=5)
    BatchScheduler(model, params, max_batch=1, cache_len=16).run([r_solo])
    r_b = Request(id=1, prompt=prompt, max_new_tokens=5)
    other = Request(id=2, prompt=np.array([30, 31], np.int64),
                    max_new_tokens=5)
    BatchScheduler(model, params, max_batch=2, cache_len=16).run(
        [r_b, other])
    assert r_solo.output == r_b.output
