"""End-to-end system behaviour: STBP gesture training + the closed loop
reproducing the paper's workflow (deliverable b/c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SNNConfig, init_snn, snn_loss
from repro.core.pipeline import ClosedLoopPipeline
from repro.data import dvs_gesture_batch
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update


@pytest.fixture(scope="module")
def cfg():
    return SNNConfig(height=32, width=32, time_bins=8, conv1_features=4,
                     conv2_features=8, hidden=32, num_classes=4)


def test_stbp_training_learns_gestures(cfg):
    """Train the reduced Table-II SCNN on synthetic gestures: loss must
    drop decisively and train accuracy must beat chance by 2x."""
    params = init_snn(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    ocfg = AdamWConfig(lr=2e-3, warmup_steps=5, total_steps=60,
                       weight_decay=0.0)

    @jax.jit
    def step(params, opt, vox, labels):
        (loss, aux), g = jax.value_and_grad(
            lambda p: snn_loss(p, vox, labels, cfg), has_aux=True)(params)
        params, opt, _ = adamw_update(g, opt, params, ocfg)
        return params, opt, loss, aux["accuracy"]

    losses, accs = [], []
    for s in range(60):
        b = dvs_gesture_batch(8, s, height=32, width=32, time_bins=8,
                              mean_events=4000, num_classes=4)
        params, opt, loss, acc = step(params, opt, b.vox, b.labels)
        losses.append(float(loss))
        accs.append(float(acc))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) * 0.9
    assert np.mean(accs[-10:]) > 0.5        # chance = 0.25


def test_closed_loop_realtime_budget(cfg):
    """The scaled pipeline must meet the paper's real-time criterion
    (latency <= window) for nominal-rate workloads."""
    params = init_snn(jax.random.PRNGKey(0), cfg)
    pipe = ClosedLoopPipeline(params, cfg)
    rng = np.random.default_rng(3)
    from repro.core import events as ev
    w = ev.synthetic_gesture_events(rng, 1, mean_events=8000,
                                    height=32, width=32)
    res = pipe(w)
    assert res.realtime
    assert res.breakdown["total_energy_mj"] < 7.7  # smaller net < paper's
