"""Fleet control plane: telemetry snapshots, live lane resize, lane-drain
migration, the checkpoint store, and the autoscaler/rebalancer policies.

The mechanism contracts live in the serving layer and are pinned here
with stub engines (scheduling isolated from numerics) plus real-engine
bitwise checks: a resize or live migration must never change a served
stream's results vs an uninterrupted scan. The policy layer
(``repro.fleet``) is tested purely through the public engine surface --
``telemetry()`` / ``resize_lane()`` / ``drain_lane()`` / handles.
"""
import jax
import numpy as np
import pytest

from repro.core import SNNConfig, init_snn
from repro.core._api import EngineConfig, FleetConfig
from repro.core.pipeline import ClosedLoopResult
from repro.fleet import (CheckpointStore, FleetRebalancer, LaneAutoscaler,
                         checkpoint_live, migrate_stream)
from repro.serving import StreamEngine
from repro.serving.session import StreamCheckpoint
from repro.serving.stream import StreamStats

from test_stateful_stream import (_assert_matches_oracle,
                                  _uninterrupted_oracle, _windows)


class StubEngine:
    """Minimal InferenceEngine: items are opaque tokens, results canned."""

    modality = "stub"

    def __init__(self):
        self.duration_us = None
        self.infer_calls = 0

    def validate(self, item):
        pass

    def prepare(self, items, *, batch_size):
        assert len(items) == batch_size
        return items

    def shape_key(self, batch):
        return (len(batch),)

    def infer(self, batch):
        self.infer_calls += 1
        return [None if it is None else ClosedLoopResult(
            label_pred=np.zeros(1, np.int64), pwm=np.zeros((1, 4)),
            latency_ms=1.0, energy_mj=1.0, breakdown={}, realtime=True,
            sustained_rate_hz=1.0) for it in batch]


class WarmStub(StubEngine):
    """StubEngine + the AOT warmup surface, recording every warm call."""

    def __init__(self):
        super().__init__()
        self.warmed = []
        self._compiled = set()

    def warmup(self, shape_keys):
        self.warmed.append(tuple(shape_keys))
        self._compiled.update(shape_keys)

    def compiled_shape_keys(self):
        return set(self._compiled)

    def infer(self, batch):
        self._compiled.add((len(batch),))
        return super().infer(batch)


def _stub_engine(slots, *, engine=None, **cfg_kw):
    return StreamEngine(engines=[engine or StubEngine()],
                        config=EngineConfig(max_streams=slots, **cfg_kw))


def _ckpt(stream_id="s", **kw):
    return StreamCheckpoint(stream_id=stream_id, modality="stub",
                            stateful=False, next_seq=0, duration_us=None,
                            state=None, **kw)


# ----------------------------------------------------------------------
# StreamStats.snapshot(): the frozen telemetry view (satellite).
# ----------------------------------------------------------------------

def test_stats_snapshot_derived_rates():
    st = StreamStats(horizon=8)
    st.windows, st.queued = 3, 7
    st.note_completion(10.0, 3, None)
    st.note_completion(11.0, 1, True)
    st.note_completion(12.0, 2, False)
    snap = st.snapshot()
    assert snap.windows == 3 and snap.queued == 7
    assert snap.horizon_windows == 3
    # 2 completions spanning 2 s of wall time.
    assert snap.windows_per_s == pytest.approx(1.0)
    # Nearest-rank p95 of depths [1, 2, 3].
    assert snap.queue_depth_p95 == 3.0
    # One miss out of two dated completions (undated ones don't count).
    assert snap.horizon_deadline_windows == 2 and snap.horizon_missed == 1
    assert snap.deadline_miss_rate == pytest.approx(0.5)
    assert snap.deadline_windows == 2 and snap.deadline_missed == 1
    # Frozen: a control plane reading a snapshot can never corrupt stats.
    import dataclasses
    with pytest.raises(dataclasses.FrozenInstanceError):
        snap.windows = 99


def test_stats_snapshot_empty_and_horizon_eviction():
    st = StreamStats(horizon=2)
    snap = st.snapshot()
    assert snap.windows_per_s == 0.0 and snap.queue_depth_p95 == 0.0
    assert snap.deadline_miss_rate == 0.0
    # Lifetime counters keep counting; the sliding window forgets.
    st.note_completion(1.0, 9, True)
    st.note_completion(2.0, 1, False)
    st.note_completion(3.0, 1, False)    # evicts the miss at t=1.0
    snap = st.snapshot()
    assert snap.deadline_missed == 1                 # lifetime
    assert snap.horizon_missed == 0                  # horizon forgot it
    assert snap.deadline_miss_rate == 0.0
    assert snap.queue_depth_p95 == 1.0


def test_deadline_miss_telemetry_uses_engine_clock():
    """A finite deadline is an instant on engine.deadline_clock; the
    collect-side comparison feeds per-stream and lane miss rates."""
    eng = _stub_engine(2)
    eng.deadline_clock = lambda: 100.0
    missed = eng.open(stream_id="missed")
    met = eng.open(stream_id="met")
    undated = eng.open(stream_id="undated")
    missed.submit(object(), deadline=50.0)    # already past: miss
    met.submit(object(), deadline=200.0)      # still ahead: met
    undated.submit(object())                  # no deadline: not counted
    eng.run()
    assert missed.stats.snapshot().deadline_miss_rate == 1.0
    assert met.stats.snapshot().deadline_miss_rate == 0.0
    assert undated.stats.snapshot().horizon_deadline_windows == 0
    lane = eng.telemetry()
    assert lane.deadline_miss_rate == pytest.approx(0.5)
    assert lane.windows == 3


# ----------------------------------------------------------------------
# LaneTelemetry: the lane-level control-plane view.
# ----------------------------------------------------------------------

def test_lane_telemetry_counts():
    eng = _stub_engine(2)
    handles = [eng.open(stream_id=f"s{i}") for i in range(3)]
    for h in handles:
        for _ in range(2):
            h.submit(object())
    t = eng.telemetry()
    assert t.modality == "stub" and t.slots == 2
    assert t.queued == 6 and t.backlog_per_slot == 3.0
    assert t.occupied == 0 and t.waiting == 3     # nothing stepped yet
    assert set(t.streams) == {"s0", "s1", "s2"}
    eng.step()
    t = eng.telemetry()
    assert t.occupied == 2 and t.occupancy == 1.0
    assert t.queued == 4
    eng.run()
    t = eng.telemetry()
    assert t.queued == 0 and t.windows == 6


def test_telemetry_counts_in_flight_and_requires_modality_when_plural():
    class Stub2(StubEngine):
        modality = "stub2"

    eng = StreamEngine(engines=[StubEngine(), Stub2()],
                       config=EngineConfig(max_streams=1,
                                           pipeline_depth=1))
    eng.open("stub", stream_id="a").submit(object())
    eng.open("stub2", stream_id="b").submit(object())
    eng.step()                  # dispatched, not yet collected
    with pytest.raises(ValueError, match="modality required"):
        eng.telemetry()
    assert eng.telemetry("stub").in_flight == 1
    assert eng.telemetry("stub2").in_flight == 1
    eng.flush()
    assert eng.telemetry("stub").in_flight == 0


# ----------------------------------------------------------------------
# resize_lane: live slot-count changes.
# ----------------------------------------------------------------------

def test_resize_grow_and_shrink_semantics():
    eng = _stub_engine(4)
    handles = {s: eng.open(stream_id=s) for s in "abcd"}
    for h in handles.values():
        for _ in range(3):
            h.submit(object())
    out = eng.step()                         # a..d each hold a slot
    assert eng.resize_lane(slots=4) == []    # no-op resize
    evicted = eng.resize_lane(slots=2)
    assert evicted == ["c", "d"]             # slot order past the cut
    lane = eng._lanes["stub"]
    assert len(lane.slots) == 2 and lane.slots == ["a", "b"]
    # Evicted streams rejoin the FRONT of the line in slot order: they
    # were being served and outrank never-slotted arrivals.
    eng.open(stream_id="e").submit(object())
    assert list(lane.waiting)[:2] == ["c", "d"]
    evicted = eng.resize_lane(slots=5)
    assert evicted == [] and len(lane.slots) == 5
    out.extend(eng.run())
    # Nothing lost across either resize: every submitted window served.
    assert len(out) == 13
    with pytest.raises(ValueError, match=">= 1"):
        eng.resize_lane(slots=0)


def test_resize_prewarms_new_batch_size_through_aot_cache():
    stub = WarmStub()
    eng = _stub_engine(2, engine=stub)
    eng.open(stream_id="a").submit(object())
    eng.run()                                # compiles (2,)
    eng.resize_lane(slots=4)
    assert stub.warmed == [((4,),)]          # re-keyed old count only
    eng.resize_lane(slots=2, warm=False)
    assert stub.warmed == [((4,),)]          # warm=False skips
    eng.resize_lane(slots=4)
    assert stub.warmed == [((4,),)]          # already compiled: no call


def test_resize_safe_with_other_steps_in_flight():
    """Pipelined: results dispatched before a resize collect correctly
    after it (collection is positional into the dispatched batch)."""
    eng = _stub_engine(2, pipeline_depth=2)
    handles = [eng.open(stream_id=f"s{i}") for i in range(2)]
    for h in handles:
        for _ in range(4):
            h.submit(object())
    eng.step()
    eng.step()                               # two steps in flight
    eng.resize_lane(slots=4)
    out = eng.run()
    got = sorted((r.stream_id, r.seq) for r in out)
    assert got == sorted((f"s{i}", k) for i in range(2) for k in range(4))


# ----------------------------------------------------------------------
# drain_lane: the live-migration primitive.
# ----------------------------------------------------------------------

def test_drain_lane_collects_one_lane_only():
    class Stub2(StubEngine):
        modality = "stub2"

    eng = StreamEngine(engines=[StubEngine(), Stub2()],
                       config=EngineConfig(max_streams=1,
                                           pipeline_depth=2))
    a = eng.open("stub", stream_id="a")
    b = eng.open("stub2", stream_id="b")
    for _ in range(2):
        a.submit(object())
        b.submit(object())
    eng.step()
    eng.step()                       # two steps, each with both lanes
    drained = eng.drain_lane("stub")
    assert [(r.stream_id, r.seq) for r in drained] == [("a", 0), ("a", 1)]
    # The other lane's dispatched work stays in flight, in order.
    assert eng.in_flight == 2
    rest = eng.flush()
    assert [(r.stream_id, r.seq) for r in rest] == [("b", 0), ("b", 1)]


def test_checkpoint_live_where_plain_checkpoint_refuses():
    eng = _stub_engine(1, pipeline_depth=1)
    h = eng.open(stream_id="s")
    for _ in range(3):
        h.submit(object())
    eng.step()                       # one window in flight
    with pytest.raises(ValueError, match="in-flight"):
        h.checkpoint()
    ckpt, displaced = checkpoint_live(h)
    assert [r.seq for r in displaced] == [0]
    assert ckpt.next_seq == 3 and [q[1] for q in ckpt.queued] == [1, 2]


# ----------------------------------------------------------------------
# CheckpointStore (satellite: round-trips + single-use restore).
# ----------------------------------------------------------------------

def test_store_put_get_delete_round_trip():
    store = CheckpointStore()
    ckpt = _ckpt(queued=(("window-0", 0, None),))
    cid = store.put(ckpt)
    assert cid in store and len(store) == 1 and store.ids() == [cid]
    got = store.get(ckpt_id=cid)
    assert got == ckpt and got is not ckpt          # a fresh copy
    assert store.get(cid) is not got                # every get is fresh
    assert store.delete(cid) is True
    assert store.delete(cid) is False and cid not in store
    with pytest.raises(KeyError):
        store.get(cid)
    # Explicit ids work; reuse of a live id is rejected.
    assert store.put(_ckpt(), ckpt_id="mine") == "mine"
    with pytest.raises(ValueError, match="already used"):
        store.put(_ckpt(), ckpt_id="mine")


def test_store_proves_serializability_at_put():
    store = CheckpointStore()
    with pytest.raises(Exception):
        store.put(_ckpt(queued=((lambda: None, 0, None),)))
    assert len(store) == 0


def test_store_rejects_double_restore():
    src, dst1, dst2 = _stub_engine(1), _stub_engine(1), _stub_engine(1)
    h = src.open(stream_id="s")
    for _ in range(2):
        h.submit(object())
    src.step()
    store = CheckpointStore()
    cid = store.put(h.checkpoint())
    new = store.restore_into(dst1, cid)
    assert new.stream_id == "s" and new.queued == 1
    assert [r.seq for r in dst1.run()] == [1]
    # The id is consumed: a second restore would fork the stream.
    with pytest.raises(ValueError, match="single-use"):
        store.restore_into(dst2, cid)
    with pytest.raises(ValueError, match="single-use"):
        store.get(cid)
    with pytest.raises(ValueError, match="already used"):
        store.put(_ckpt(), ckpt_id=cid)


def test_store_failed_restore_keeps_checkpoint():
    src, dst = _stub_engine(1), _stub_engine(1)
    h = src.open(stream_id="s")
    h.submit(object())
    store = CheckpointStore()
    cid = store.put(h.checkpoint())
    dst.open(stream_id="s")          # occupy the id on the target
    with pytest.raises(ValueError):
        store.restore_into(dst, cid)
    assert cid in store              # not consumed by the failure
    got = store.restore_into(dst, cid, stream_id="s2")
    assert got.stream_id == "s2" and cid not in store


# ----------------------------------------------------------------------
# LaneAutoscaler.
# ----------------------------------------------------------------------

def test_autoscaler_grows_on_sustained_backlog_only():
    eng = _stub_engine(2)
    asc = LaneAutoscaler(eng, config=FleetConfig(
        grow_backlog=2.0, grow_patience=2, max_slots=8))
    for i in range(2):
        h = eng.open(stream_id=f"s{i}")
        for _ in range(3):
            h.submit(object())
    assert asc.observe().action == "hold"    # first over-threshold tick
    decision = asc.observe()                 # sustained: grow fires
    assert decision.action == "grow"
    assert (decision.old_slots, decision.new_slots) == (2, 4)
    assert eng.telemetry().slots == 4
    assert asc.decisions == [decision]


def test_autoscaler_blip_resets_patience():
    eng = _stub_engine(2)
    asc = LaneAutoscaler(eng, config=FleetConfig(grow_backlog=2.0,
                                                 grow_patience=2))
    h = eng.open(stream_id="s")
    for _ in range(4):
        h.submit(object())
    assert asc.observe().action == "hold"    # backlogged once
    eng.run()                                # backlog clears: a blip
    assert asc.observe().action == "hold"
    for _ in range(4):
        h.submit(object())
    assert asc.observe().action == "hold"    # streak restarted at 1
    assert asc.observe().action == "grow"


def test_autoscaler_shrinks_on_idle_and_respects_bounds():
    eng = _stub_engine(8)
    asc = LaneAutoscaler(eng, config=FleetConfig(
        shrink_patience=2, min_slots=2, max_slots=8))
    assert asc.observe().action == "hold"
    assert asc.observe().action == "shrink"
    assert eng.telemetry().slots == 4
    asc.observe()
    assert asc.observe().new_slots == 2
    # Floor: min_slots holds no matter how long the lane idles.
    for _ in range(6):
        decision = asc.observe()
    assert decision.action == "hold" and eng.telemetry().slots == 2
    # And a busy lane is never "idle", whatever its occupancy.
    h = eng.open(stream_id="s")
    h.submit(object())
    asc._shrink_streak = 99
    assert asc.observe().action == "hold"


# ----------------------------------------------------------------------
# migrate_stream + FleetRebalancer (stub level).
# ----------------------------------------------------------------------

def test_migrate_stream_moves_queue_and_displaced_results():
    src = _stub_engine(1, pipeline_depth=1)
    dst = _stub_engine(1)
    h = src.open(stream_id="mig")
    other = src.open(stream_id="other")
    for _ in range(3):
        h.submit(object())
        other.submit(object())
    src.step()                       # one step in flight (mig slotted)
    store = CheckpointStore()
    record = migrate_stream(h, dst, store=store)
    assert record.stream_id == "mig" and record.ckpt_id is not None
    assert record.migration_ms > 0.0
    assert h.closed and dst.has_stream("mig")
    # The drain's early results belong to the caller.
    assert {r.stream_id for r in record.displaced} == {"mig"}
    # Remaining windows continue on the target with their seq numbers.
    served = [r.seq for r in dst.run() if r.stream_id == "mig"]
    displaced = [r.seq for r in record.displaced]
    assert sorted(displaced + served) == [0, 1, 2]
    # The source keeps serving its other streams.
    assert [r.stream_id for r in src.run()] == ["other"] * 3


def test_rebalancer_moves_hot_to_cold_with_hysteresis():
    hot, cold = _stub_engine(1), _stub_engine(4)
    streams = [hot.open(stream_id=f"h{i}") for i in range(3)]
    for h in streams:
        for _ in range(4):
            h.submit(object())
    reb = FleetRebalancer(
        {"hot": hot, "cold": cold},
        config=FleetConfig(imbalance=1.0, cooldown=2, miss_weight=0.0))
    report = reb.observe()
    assert report.migrated
    [record] = report.moved
    assert record.stream_id.startswith("h")
    assert cold.has_stream(record.stream_id)
    assert not hot.has_stream(record.stream_id)
    assert report.loads["hot"] > report.loads["cold"]
    # Cooldown: the next ticks hold even though the gap persists.
    assert not reb.observe().migrated
    assert not reb.observe().migrated
    assert reb.observe().migrated        # cooldown elapsed
    assert len(reb.migrations) == 2


def test_rebalancer_dead_band_prevents_thrash():
    a, b = _stub_engine(2), _stub_engine(2)
    ha = a.open(stream_id="a")
    ha.submit(object())
    reb = FleetRebalancer({"a": a, "b": b},
                          config=FleetConfig(imbalance=1.0))
    report = reb.observe()               # gap 0.5 <= dead-band 1.0
    assert not report.migrated and "balanced" in report.reason
    assert len(reb.migrations) == 0
    with pytest.raises(ValueError, match=">= 2 engines"):
        FleetRebalancer({"a": a})


# ----------------------------------------------------------------------
# Real-engine bitwise contracts: resize and live migration never change
# a stream's served windows vs an uninterrupted scan.
# ----------------------------------------------------------------------

@pytest.fixture(scope="module")
def cfg():
    return SNNConfig(height=32, width=32, time_bins=4, conv1_features=4,
                     conv2_features=8, hidden=32, num_classes=11)


@pytest.fixture(scope="module")
def params(cfg):
    return init_snn(jax.random.PRNGKey(0), cfg)


@pytest.mark.parametrize("depth", [0, 1], ids=["sync", "pipelined"])
def test_resize_mid_stream_is_bitwise(cfg, params, depth):
    """Grow then shrink a lane mid-serve: every stateful stream's windows
    stay bitwise-identical to one uninterrupted scan (the carry is
    parked across the resize, evicted streams resume correctly)."""
    streams = {f"s{i}": _windows(4, seed=60 + i) for i in range(3)}
    ids, per_window = _uninterrupted_oracle(params, cfg, streams)
    eng = StreamEngine(params, cfg, EngineConfig(max_streams=2,
                                                 pipeline_depth=depth))
    handles = {sid: eng.open(stream_id=sid, stateful=True)
               for sid in sorted(streams)}
    for k in range(4):
        for sid in sorted(streams):
            handles[sid].submit(streams[sid][k])
    out = [*eng.step(), *eng.step()]
    eng.resize_lane(slots=4)          # grow mid-serve
    out.extend(eng.step())
    evicted = eng.resize_lane(slots=2)    # shrink: evicts live streams
    assert isinstance(evicted, list)
    out.extend(eng.run())
    assert len(out) == 12
    _assert_matches_oracle(out, ids, per_window)


@pytest.mark.parametrize("depth", [0, 1], ids=["sync", "pipelined"])
def test_live_migration_is_bitwise(cfg, params, depth):
    """migrate_stream mid-serve (windows in flight when pipelined): the
    stream's windows across source + target engines equal one
    uninterrupted scan, and the store round-trip is the transport."""
    streams = {"mig": _windows(4, seed=70), "stay": _windows(4, seed=71)}
    ids, per_window = _uninterrupted_oracle(params, cfg, streams)
    src = StreamEngine(params, cfg, EngineConfig(max_streams=2,
                                                 pipeline_depth=depth))
    dst = StreamEngine(params, cfg, EngineConfig(max_streams=2))
    handles = {sid: src.open(stream_id=sid, stateful=True)
               for sid in sorted(streams)}
    for k in range(4):
        for sid in sorted(streams):
            handles[sid].submit(streams[sid][k])
    out = [*src.step(), *src.step()]
    record = migrate_stream(handles["mig"], dst, store=CheckpointStore())
    out.extend(record.displaced)
    out.extend(src.run())
    out.extend(dst.run())
    assert len(out) == 8
    assert {r.seq for r in out if r.stream_id == "mig"} == {0, 1, 2, 3}
    _assert_matches_oracle(out, ids, per_window)


def test_store_restore_across_device_counts(tmp_path):
    """A store written on a 1-device engine restores on a 2-device
    sharded engine bitwise (satellite: different-device-count restore
    goes through the store, and the consumed id stays rejected)."""
    from test_sharded_engine import _run_sub
    store_file = tmp_path / "store.pkl"
    _run_sub(f"""
        import pickle
        from repro.fleet import CheckpointStore
        ws = windows(4, seed=81)
        ref = StreamEngine(PARAMS, CFG, EngineConfig(max_streams=2))
        h = ref.open(stream_id="mig", stateful=True)
        for w in ws:
            h.submit(w)
        want = {{r.seq: np.asarray(r.result.logits) for r in ref.run()}}
        src = StreamEngine(PARAMS, CFG, EngineConfig(max_streams=2))
        hs = src.open(stream_id="mig", stateful=True)
        hs.submit(ws[0]); hs.submit(ws[1])
        src.run()
        store = CheckpointStore()
        cid = store.put(hs.checkpoint())
        with open({str(store_file)!r}, "wb") as f:
            pickle.dump((store, cid, want), f)
        print("OK")
    """, devices=1)
    _run_sub(f"""
        import pickle
        with open({str(store_file)!r}, "rb") as f:
            store, cid, want = pickle.load(f)
        ws = windows(4, seed=81)
        eng = StreamEngine(
            PARAMS, CFG,
            EngineConfig(max_streams=2, mesh=make_mesh(2)))
        h = store.restore_into(eng, cid)
        h.submit(ws[2]); h.submit(ws[3])
        got = {{r.seq: np.asarray(r.result.logits) for r in eng.run()}}
        assert set(got) == {{2, 3}}, sorted(got)
        for k in (2, 3):
            np.testing.assert_array_equal(got[k], want[k], err_msg=str(k))
        try:
            store.restore_into(eng, cid)
        except ValueError as e:
            assert "single-use" in str(e), e
        else:
            raise AssertionError("double restore accepted")
        print("OK")
    """, devices=2)
