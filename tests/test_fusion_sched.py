"""Fusion co-scheduling + the cross-wing megastep, pinned under
adversarial load.

The two contracts of the fused fast path:

  * SCHEDULING may move (paired wings pulled into the same engine step;
    both wings dispatched through one fused jit'd call) but RESULTS may
    not: every fused tick stays bitwise-identical to serving the wings
    on separate single-wing engines -- at B in {1, 4, 8}, sync and
    pipelined, stateless and stateful (carried LIF membranes), under
    DeadlinePolicy reorder, and across a PR 8-style wing fault.
  * The co-scheduler's effect is observable: ``paired_tick_rate`` in
    ``StreamStats.snapshot()`` / ``LaneTelemetry`` reports the fraction
    of fusion ticks whose two wing windows shared one engine step.
"""
import jax
import numpy as np
import pytest

from repro.core import (EngineConfig, FrameTCNEngine, SNNConfig, TCNConfig,
                        init_snn, init_tcn)
from repro.core import frames as fr
from repro.core._api import RecoveryConfig
from repro.core.pipeline import BatchedClosedLoop
from repro.fleet import FaultInjector
from repro.serving import (DeadlinePolicy, FusionSession, StreamEngine,
                           late_logit_fusion)

from tests.test_stateful_stream import _windows

TICKS = 3


@pytest.fixture(scope="module")
def cfg():
    return SNNConfig(height=32, width=32, time_bins=4, conv1_features=4,
                     conv2_features=8, hidden=32, num_classes=11)


@pytest.fixture(scope="module")
def params(cfg):
    return init_snn(jax.random.PRNGKey(0), cfg)


@pytest.fixture(scope="module")
def tcfg():
    return TCNConfig(height=32, width=32, conv1_features=4,
                     conv2_features=8, hidden=32, num_classes=11)


@pytest.fixture(scope="module")
def tparams(tcfg):
    return init_tcn(jax.random.PRNGKey(1), tcfg)


def _frames(n, seed=0):
    rng = np.random.default_rng(seed)
    return [fr.synthetic_gesture_frames(rng, i % 11, height=32, width=32)
            for i in range(n)]


def _tick_data(sessions, ticks=TICKS):
    return [(_windows(ticks, seed=10 + i), _frames(ticks, seed=20 + i))
            for i in range(sessions)]


def _run_fused(params, cfg, tparams, tcfg, data, *, stateful=False,
               **cfg_kw):
    """Serve ``data`` through FusionSessions on one engine; returns
    ({session_id: [ticks in seq order]}, engine)."""
    sessions = len(data)
    eng = StreamEngine(
        engines=[BatchedClosedLoop(params, cfg),
                 FrameTCNEngine(tparams, tcfg)],
        config=EngineConfig(max_streams=sessions, **cfg_kw))
    sess = [FusionSession(eng, session_id=f"s{i}", stateful=stateful)
            for i in range(sessions)]
    n_ticks = len(data[0][0])
    for t in range(n_ticks):
        for s, (evs, frs) in zip(sess, data):
            s.submit(evs[t], frs[t])
    out = {s.session_id: [] for s in sess}
    done, guard = 0, 0
    while done < sessions * n_ticks:
        rows = eng.step()
        guard += 1
        assert guard < 50 * sessions * n_ticks
        for s in sess:
            rows = s.absorb(rows)
            got = s.drain()
            out[s.session_id].extend(got)
            done += len(got)
    for sid in out:
        out[sid].sort(key=lambda r: r.seq)
    return out, eng


def _run_separate(params, cfg, tparams, tcfg, data, *, stateful=False):
    """The decoupled oracle: each session's wings on their own
    single-wing sync engines; returns {sid: (event_rows, frame_rows)}."""
    outs = {}
    for i, (evs, frs) in enumerate(data):
        e1 = StreamEngine(engines=[BatchedClosedLoop(params, cfg)],
                          config=EngineConfig(max_streams=1))
        e2 = StreamEngine(engines=[FrameTCNEngine(tparams, tcfg)],
                          config=EngineConfig(max_streams=1))
        h_e = e1.open(modality="event", stateful=stateful)
        h_f = e2.open(modality="frame", stateful=stateful)
        res_e, res_f = [], []
        for t in range(len(evs)):
            h_e.submit(evs[t])
            res_e += e1.run()
            h_f.submit(frs[t])
            res_f += e2.run()
        outs[f"s{i}"] = (res_e, res_f)
    return outs


def _assert_ticks_match(ticks, res_e, res_f, ctx):
    """Every fused tick bitwise-identical to fusing the separate-wing
    rows (same rule, same inputs => byte-equal logits and pwm)."""
    rule = late_logit_fusion()
    assert len(ticks) == len(res_e) == len(res_f), ctx
    for tk, re_, rf_ in zip(ticks, res_e, res_f):
        assert tk.status == "ok", (ctx, tk.status, tk.error)
        want = np.asarray(rule(re_.result, rf_.result))
        assert np.array_equal(np.asarray(tk.result.logits), want), \
            (ctx, tk.seq)


# -- bitwise parity under the fused fast path --------------------------------

@pytest.mark.parametrize("sessions", [1, 4, 8])
@pytest.mark.parametrize("depth", [0, 1])
@pytest.mark.parametrize("stateful", [False, True])
def test_fused_bitwise_vs_separate(params, cfg, tparams, tcfg, sessions,
                                   depth, stateful):
    data = _tick_data(sessions)
    fused, eng = _run_fused(params, cfg, tparams, tcfg, data,
                            stateful=stateful, megastep=True,
                            pipeline_depth=depth)
    sep = _run_separate(params, cfg, tparams, tcfg, data,
                        stateful=stateful)
    for sid, ticks in fused.items():
        _assert_ticks_match(ticks, *sep[sid],
                            (sessions, depth, stateful, sid))
    # Co-scheduling kept every tick's wings in one engine step.
    for m in ("event", "frame"):
        assert eng.telemetry(m).paired_tick_rate == 1.0


def test_megastep_off_is_bitwise_identical_to_megastep_on(
        params, cfg, tparams, tcfg):
    """The megastep is a pure dispatch fusion: same engine, same
    sessions, megastep on vs off -- byte-equal fused logits."""
    data = _tick_data(2)
    on, _ = _run_fused(params, cfg, tparams, tcfg, data, stateful=True,
                       megastep=True, pipeline_depth=1)
    off, _ = _run_fused(params, cfg, tparams, tcfg, data, stateful=True,
                        megastep=False, pipeline_depth=1)
    for sid in on:
        for a, b in zip(on[sid], off[sid]):
            assert np.array_equal(np.asarray(a.result.logits),
                                  np.asarray(b.result.logits))


def test_deadline_policy_reorder_keeps_pairing_and_parity(
        params, cfg, tparams, tcfg):
    """Contended lanes under DeadlinePolicy (EDF reorder, fewer slots
    than sessions): the co-scheduler still lands both wings of every
    tick in one step and results stay bitwise."""
    sessions, slots = 4, 2
    data = _tick_data(sessions)
    eng = StreamEngine(
        engines=[BatchedClosedLoop(params, cfg),
                 FrameTCNEngine(tparams, tcfg)],
        config=EngineConfig(max_streams=slots, policy=DeadlinePolicy(),
                            megastep=True))
    sess = [FusionSession(eng, session_id=f"s{i}",
                          deadline=float(sessions - i))
            for i in range(sessions)]
    for t in range(TICKS):
        for s, (evs, frs) in zip(sess, data):
            s.submit(evs[t], frs[t])
    out = {s.session_id: [] for s in sess}
    done, guard = 0, 0
    while done < sessions * TICKS:
        rows = eng.step()
        guard += 1
        assert guard < 200
        for s in sess:
            rows = s.absorb(rows)
            got = s.drain()
            out[s.session_id].extend(got)
            done += len(got)
    sep = _run_separate(params, cfg, tparams, tcfg, data)
    for sid, ticks in out.items():
        ticks.sort(key=lambda r: r.seq)
        _assert_ticks_match(ticks, *sep[sid], ("deadline", sid))
    for m in ("event", "frame"):
        assert eng.telemetry(m).paired_tick_rate == 1.0


def test_wing_fault_degrades_but_survivor_stays_coscheduled(
        params, cfg, tparams, tcfg):
    """A PR 8-style wing fault under the megastep: the frame wing is
    killed mid-flight; ticks degrade to the surviving event wing, whose
    results stay bitwise vs separate serving -- the fused call falls
    back to per-lane dispatch so the fault localizes to the bad wing."""
    data = _tick_data(1, ticks=6)
    inj = FaultInjector()
    eng = StreamEngine(
        engines=[inj.wrap(BatchedClosedLoop(params, cfg)),
                 inj.wrap(FrameTCNEngine(tparams, tcfg))],
        config=EngineConfig(max_streams=1, megastep=True,
                            recovery=RecoveryConfig(max_retries=0,
                                                    backoff_steps=0,
                                                    dead_after=2)))
    sess = FusionSession(eng, session_id="s0")
    evs, frs = data[0]
    rows = []
    for t in range(3):                         # healthy fused ticks
        sess.submit(evs[t], frs[t])
        rows.extend(sess.step())
    inj.kill("frame")
    for t in range(3, 6):                      # degraded ticks
        sess.submit(evs[t], frs[t])
    guard = 0
    while len(rows) < 6:
        rows.extend(sess.step())
        guard += 1
        assert guard < 40
    rows.sort(key=lambda r: r.seq)
    assert [r.status for r in rows] == ["ok"] * 3 + ["degraded"] * 3
    assert all(r.result.breakdown["degraded_wing"] == "frame"
               for r in rows[3:])
    # The surviving event wing's windows are bitwise vs separate.
    sep_e, _ = _run_separate(params, cfg, tparams, tcfg,
                             [data[0]])["s0"]
    for r, want in zip(rows[3:], sep_e[3:]):
        assert np.array_equal(np.asarray(r.result.logits),
                              np.asarray(want.result.logits))
    assert sess.ticks_degraded == 3
    assert eng.telemetry("frame").dead


# -- opt-in surface ----------------------------------------------------------

def test_megastep_mesh_is_rejected_cleanly():
    with pytest.raises(ValueError, match="single-device"):
        EngineConfig(megastep=True, mesh=object())


def test_megastep_needs_both_wings(params, cfg):
    with pytest.raises(ValueError, match="event and one frame"):
        StreamEngine(engines=[BatchedClosedLoop(params, cfg)],
                     config=EngineConfig(max_streams=1, megastep=True))


def test_megastep_needs_capable_engines(params, cfg):
    from tests.test_slot_policy import StubEngine
    ev_stub = StubEngine()
    ev_stub.modality = "event"
    fr_stub = StubEngine()
    fr_stub.modality = "frame"
    with pytest.raises(ValueError, match="megastep"):
        StreamEngine(engines=[ev_stub, fr_stub],
                     config=EngineConfig(max_streams=1, megastep=True))


def test_megastep_warmup_precompiles(params, cfg, tparams, tcfg):
    def mk():
        return StreamEngine(
            engines=[BatchedClosedLoop(params, cfg),
                     FrameTCNEngine(tparams, tcfg)],
            config=EngineConfig(max_streams=1, megastep=True))

    (evs, frs), = _tick_data(1, ticks=1)
    # Discover the workload's fused shape key by serving it once...
    probe = mk()
    s0 = FusionSession(probe, session_id="s0")
    s0.submit(evs[0], frs[0])
    [r] = s0.run()
    assert r.status == "ok"
    [key] = probe.compiled_megastep_keys()
    # ...then AOT-warm a fresh engine with it: serving hits the cache
    # (no new entry) and a non-megastep engine refuses the warmup.
    eng = mk()
    assert eng.compiled_megastep_keys() == set()
    eng.warmup_megastep([key])
    assert eng.compiled_megastep_keys() == {key}
    s1 = FusionSession(eng, session_id="s0")
    s1.submit(evs[0], frs[0])
    [r] = s1.run()
    assert r.status == "ok"
    assert eng.compiled_megastep_keys() == {key}
    plain = StreamEngine(
        engines=[BatchedClosedLoop(params, cfg),
                 FrameTCNEngine(tparams, tcfg)],
        config=EngineConfig(max_streams=1))
    with pytest.raises(ValueError, match="megastep"):
        plain.warmup_megastep([key])


# -- paired_tick_rate observability ------------------------------------------

def test_paired_tick_rate_in_snapshot_and_telemetry(params, cfg, tparams,
                                                    tcfg):
    data = _tick_data(2)
    _, eng = _run_fused(params, cfg, tparams, tcfg, data, megastep=False)
    for sid in ("s0:event", "s0:frame", "s1:event", "s1:frame"):
        snap = eng.stream_stats[sid].snapshot()
        assert snap.fusion_ticks == TICKS
        assert snap.fusion_ticks_paired == TICKS
        assert snap.paired_tick_rate == 1.0
    for m in ("event", "frame"):
        assert eng.telemetry(m).paired_tick_rate == 1.0


def test_unpaired_streams_report_unit_rate(params, cfg):
    """Plain streams never tick the fusion counters; the rate degrades
    to the no-signal default 1.0 rather than 0/0."""
    eng = StreamEngine(params, cfg, EngineConfig(max_streams=1))
    h = eng.open()
    h.submit(_windows(1, seed=3)[0])
    eng.run()
    snap = eng.stream_stats[h.stream_id].snapshot()
    assert snap.fusion_ticks == 0 and snap.paired_tick_rate == 1.0
    assert eng.telemetry().paired_tick_rate == 1.0
