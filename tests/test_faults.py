"""Fault injection + recovery: the failure semantics, pinned one path
at a time.

Layers under test:

  * ``FaultInjector``/``FaultyEngine`` -- seeded determinism, scripted
    faults, kill/revive, protocol transparency.
  * ``StreamEngine`` recovery -- sync retry with backoff, retry
    exhaustion -> quarantine (dead letter + failed row + live stream),
    NaN quarantine with carry rollback (the chained-scan contract),
    lane death -> fail-fast -> ``replace_lane_engine``.
  * Satellites -- pipelined ``infer_collect`` pop-or-restore (recovery
    OFF: the pre-existing desync bug), ``close()`` idempotency and
    close-during-in-flight, ``CheckpointStore`` LRU eviction.
  * ``LaneSupervisor`` -- journal/checkpoint/restore/replay, bitwise
    vs the uninterrupted oracle, dedupe of replayed successes.
  * ``FusionSession`` -- single-wing degraded ticks and wing health.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import SNNConfig, init_snn
from repro.core._api import EngineConfig, FaultConfig, RecoveryConfig
from repro.core.pipeline import BatchedClosedLoop, ClosedLoopResult
from repro.fleet import (CheckpointStore, FaultInjector, InjectedFault,
                         LaneSupervisor)
from repro.serving import FusionSession, StreamEngine

from test_stateful_stream import (_assert_matches_oracle,
                                  _uninterrupted_oracle, _windows)


@pytest.fixture(scope="module")
def cfg():
    return SNNConfig(height=32, width=32, time_bins=4, conv1_features=4,
                     conv2_features=8, hidden=32, num_classes=11)


@pytest.fixture(scope="module")
def params(cfg):
    return init_snn(jax.random.PRNGKey(0), cfg)


class Stub:
    """Minimal sync engine; each item is an int token, logits encode it
    so results are checkable and per-window deterministic."""

    def __init__(self, modality="stub"):
        self.modality = modality
        self.duration_us = None
        self.infer_calls = 0

    def validate(self, item):
        pass

    def prepare(self, items, *, batch_size):
        return items

    def shape_key(self, batch):
        return (len(batch),)

    def _result(self, it):
        logits = np.full((1, 4), float(it), np.float32)
        return ClosedLoopResult(
            label_pred=np.zeros(1, np.int64), pwm=np.zeros((1, 4)),
            latency_ms=1.0, energy_mj=1.0, breakdown={}, realtime=True,
            sustained_rate_hz=1.0, logits=logits)

    def infer(self, batch):
        self.infer_calls += 1
        return [None if it is None else self._result(it) for it in batch]


class SplitStub(Stub):
    """Stub + the async dispatch/collect split; ``fail_collects`` makes
    the next N ``infer_collect`` calls raise (raw, not injector-driven:
    the pop-or-restore satellite predates the recovery layer)."""

    def __init__(self, modality="stub"):
        super().__init__(modality)
        self.fail_collects = 0
        self.collect_calls = 0

    def infer_dispatch(self, batch):
        return list(batch)

    def infer_collect(self, pending):
        self.collect_calls += 1
        if self.fail_collects > 0:
            self.fail_collects -= 1
            raise RuntimeError("device fell over")
        return [None if it is None else self._result(it) for it in pending]


def _engine(slots=2, *, stub=None, recovery=None, **cfg_kw):
    return StreamEngine(
        engines=[stub or Stub()],
        config=EngineConfig(max_streams=slots, recovery=recovery, **cfg_kw))


# ----------------------------------------------------------------------
# FaultInjector: determinism, scripting, transparency.
# ----------------------------------------------------------------------

def _drive(seed, n=40):
    """One fixed call sequence against a seeded injector; returns which
    calls faulted and how."""
    inj = FaultInjector(FaultConfig(seed=seed, step_error_rate=0.2,
                                    nan_rate=0.2))
    eng = inj.wrap(Stub())
    trace = []
    for i in range(n):
        try:
            res = eng.infer([i])
            trace.append("nan" if not np.all(np.isfinite(res[0].logits))
                         else "ok")
        except InjectedFault:
            trace.append("err")
    return trace, dict(inj.counters)


def test_injector_is_deterministic_per_seed():
    t1, c1 = _drive(seed=3)
    t2, c2 = _drive(seed=3)
    assert t1 == t2 and c1 == c2
    assert c1["errors"] > 0 and c1["nans"] > 0   # both modes exercised
    t3, _ = _drive(seed=4)
    assert t3 != t1                               # seed actually matters


def test_scripted_faults_and_kill_revive():
    inj = FaultInjector(FaultConfig(seed=0))      # all rates 0
    stub = Stub()
    eng = inj.wrap(stub)
    assert np.isfinite(eng.infer([7])[0].logits).all()
    inj.fail_next(kind="error")
    with pytest.raises(InjectedFault):
        eng.infer([7])
    inj.fail_next(kind="nan")
    assert not np.isfinite(eng.infer([7])[0].logits).any()
    inj.kill("stub")
    with pytest.raises(InjectedFault, match="killed"):
        eng.infer([7])
    assert stub.infer_calls == 2                  # kill never reaches inner
    inj.revive("stub")
    assert np.isfinite(eng.infer([7])[0].logits).all()
    # Scripted faults for another modality don't fire here.
    inj.fail_next("frame", kind="error")
    eng.infer([7])
    assert inj._scripted                          # still queued


def test_proxy_is_transparent():
    inj = FaultInjector()
    plain, split = inj.wrap(Stub()), inj.wrap(SplitStub())
    # Capability probe: the split surfaces only when the inner has it.
    assert getattr(plain, "infer_dispatch", None) is None
    assert split.infer_collect(split.infer_dispatch([3]))[0] is not None
    # Attribute writes land on the inner engine (duration latching).
    plain.duration_us = 1000
    assert plain.inner.duration_us == 1000


# ----------------------------------------------------------------------
# Engine recovery: retry, quarantine, rollback, lane death.
# ----------------------------------------------------------------------

def test_sync_retry_recovers_the_window():
    inj = FaultInjector()
    stub = Stub()
    eng = _engine(stub=inj.wrap(stub),
                  recovery=RecoveryConfig(max_retries=2, backoff_steps=1))
    h = eng.open(modality="stub")
    h.submit(5)
    inj.fail_next(kind="error")
    assert eng.step() == []                       # failed step: no result
    assert eng.step() == []                       # backoff step: lane idle
    [r] = eng.step()                              # retried and served
    assert r.ok and r.seq == 0
    assert np.unique(r.result.logits).item() == 5.0
    assert eng.telemetry("stub").retries == 1
    assert h.stats.snapshot().retries == 1
    assert [f["kind"] for f in eng.fault_log] == ["retry"]


def test_retry_exhaustion_quarantines_but_stream_survives():
    inj = FaultInjector()
    eng = _engine(stub=inj.wrap(Stub()),
                  recovery=RecoveryConfig(max_retries=1, backoff_steps=0,
                                          dead_after=10))
    h = eng.open(modality="stub")
    h.submit(5)
    inj.fail_next(kind="error", count=2)          # initial try + 1 retry
    assert eng.step() == []
    [r] = eng.step()
    assert r.status == "failed" and r.result is None and not r.ok
    [dl] = eng.dead_letters("stub")
    assert dl.item == 5 and dl.seq == 0 and dl.stream_id == h.stream_id
    assert eng.telemetry("stub").quarantined == 1
    # The stream is alive: the next window is served normally.
    h.submit(6)
    [r2] = eng.step()
    assert r2.ok and r2.seq == 1


def test_nan_output_quarantines_immediately():
    inj = FaultInjector()
    eng = _engine(stub=inj.wrap(Stub()), recovery=RecoveryConfig())
    h = eng.open(modality="stub")
    h.submit(3)
    inj.fail_next(kind="nan")
    [r] = eng.step()
    assert r.status == "failed" and "non-finite" in r.error
    assert eng.telemetry("stub").retries == 0     # no retry: deterministic
    h.submit(4)
    [r2] = eng.step()
    assert r2.ok


def test_quarantine_rolls_carry_back_to_pre_window_value(params, cfg):
    """w0 ok, w1 NaN-poisoned (failed), w2 ok: the surviving scan must
    equal the uninterrupted chained scan of [w0, w2] -- the quarantined
    window leaves no trace in the carry."""
    ws = _windows(3, seed=11)
    config = EngineConfig(max_streams=1, recovery=RecoveryConfig())
    inj = FaultInjector()
    inner = BatchedClosedLoop.from_config(params, cfg, config)
    eng = StreamEngine(engines=[inj.wrap(inner)], config=config)
    h = eng.open(modality="event", stateful=True)
    h.submit(ws[0])
    [r0] = eng.step()
    h.submit(ws[1])
    inj.fail_next(kind="nan")
    [r1] = eng.step()
    assert r1.status == "failed"
    h.submit(ws[2])
    [r2] = eng.step()
    assert r2.ok
    ids, per_window = _uninterrupted_oracle(params, cfg,
                                            {h.stream_id: [ws[0], ws[2]]})
    _assert_matches_oracle(
        [r0, dataclasses.replace(r2, seq=1)], ids, per_window)


def test_lane_death_fail_fast_then_replace(params=None, cfg=None):
    inj = FaultInjector()
    stub = Stub()
    eng = _engine(stub=inj.wrap(stub),
                  recovery=RecoveryConfig(max_retries=0, backoff_steps=0,
                                          dead_after=2))
    h = eng.open(modality="stub")
    inj.kill("stub")
    for i in range(2):                            # two failed lane steps
        h.submit(i)
        [r] = eng.step()
        assert r.status == "failed"
    assert eng.telemetry("stub").dead
    calls = stub.infer_calls
    h.submit(9)
    [r] = eng.step()                              # fail-fast: no engine call
    assert r.status == "failed" and stub.infer_calls == calls
    assert any(f["kind"] == "lane_dead" for f in eng.fault_log)
    # Install a fresh engine: the lane serves again (kill() tracked the
    # old proxy; the replacement is clean).
    inj.revive("stub")
    eng.replace_lane_engine("stub", engine=Stub())
    assert not eng.telemetry("stub").dead
    h.submit(10)
    [r] = eng.step()
    assert r.ok and np.unique(r.result.logits).item() == 10.0


def test_pipelined_retry_keeps_carry_intact(params, cfg):
    """A failed pipelined collect requeues its windows and re-dispatches
    with the rolled-back carry: every successful window still equals the
    uninterrupted scan."""
    ws = _windows(4, seed=5)
    config = EngineConfig(max_streams=1, pipeline_depth=2,
                          recovery=RecoveryConfig(max_retries=2,
                                                  backoff_steps=0))
    inj = FaultInjector()
    inner = BatchedClosedLoop.from_config(params, cfg, config)
    eng = StreamEngine(engines=[inj.wrap(inner)], config=config)
    h = eng.open(modality="event", stateful=True)
    for w in ws:
        h.submit(w)
    inj.fail_next(kind="error")                   # first collect fails
    got = []
    for _ in range(16):
        got.extend(eng.step())
    got.extend(eng.flush())
    ok = [r for r in got if r.ok]
    assert len(ok) == len(ws)                     # every window recovered
    assert eng.telemetry("event").retries >= 1
    ids, per_window = _uninterrupted_oracle(params, cfg, {h.stream_id: ws})
    _assert_matches_oracle(ok, ids, per_window)


# ----------------------------------------------------------------------
# Satellite: pipelined infer_collect pop-or-restore (recovery OFF).
# ----------------------------------------------------------------------

def test_collect_exception_leaves_inflight_consistent():
    """Regression: with no recovery configured, an ``infer_collect``
    exception must leave exactly the uncollected suffix in flight --
    retrying the step collects every window exactly once."""
    stub = SplitStub()
    eng = _engine(slots=2, stub=stub, pipeline_depth=1)
    h = eng.open(modality="stub")
    h.submit(1)
    assert eng.step() == []                       # dispatched, depth 1
    h.submit(2)
    stub.fail_collects = 1
    with pytest.raises(RuntimeError, match="fell over"):
        eng.step()
    # The failed record is still in flight (not lost, not duplicated).
    assert len(eng._inflight) == 2
    out = []
    for _ in range(4):
        out.extend(eng.step())
    out.extend(eng.flush())
    assert sorted(r.seq for r in out) == [0, 1]
    assert all(r.ok for r in out)


# ----------------------------------------------------------------------
# Satellite: close() idempotency and close-during-in-flight.
# ----------------------------------------------------------------------

def test_close_is_idempotent():
    eng = _engine()
    h = eng.open(modality="stub")
    h.submit(1)
    assert h.close() == 1
    assert h.close() == 0                         # double close: no-op
    assert h.closed


def test_close_with_inflight_drains_own_records_only():
    eng = _engine(slots=2, stub=Stub(), pipeline_depth=2)
    a = eng.open(modality="stub", stream_id="a")
    b = eng.open(modality="stub", stream_id="b")
    for i in range(2):
        a.submit(10 + i)
        b.submit(20 + i)
    eng.step()
    eng.step()                                    # both steps in flight
    assert a.close() == 2                         # both a-windows in flight
    out = eng.flush()
    # The lane-mate's windows all land; nothing is emitted for "a".
    assert sorted((r.stream_id, r.seq) for r in out) == [("b", 0), ("b", 1)]
    assert [np.unique(r.result.logits).item() for r in out] == [20.0, 21.0]


# ----------------------------------------------------------------------
# Satellite: CheckpointStore capacity bound + LRU eviction.
# ----------------------------------------------------------------------

def test_store_lru_eviction_and_stats():
    store = CheckpointStore(capacity=2)
    i1, i2 = store.put({"n": 1}), store.put({"n": 2})
    assert store.get(i1) == {"n": 1}              # refreshes i1's recency
    i3 = store.put({"n": 3})                      # evicts i2 (LRU), not i1
    assert store.stats["evicted"] == 1
    assert i2 not in store and i1 in store and i3 in store
    with pytest.raises(KeyError):
        store.get(i2)
    # Consumed blobs free capacity without counting as evictions.
    store.consume(i1)
    store.put({"n": 4})
    assert store.stats["evicted"] == 1
    with pytest.raises(ValueError):
        CheckpointStore(capacity=0)


# ----------------------------------------------------------------------
# LaneSupervisor: checkpoint/restore/replay, bitwise.
# ----------------------------------------------------------------------

def test_supervisor_restores_bitwise_after_lane_death(params, cfg):
    ws = _windows(8, seed=7)
    config = EngineConfig(
        max_streams=1,
        recovery=RecoveryConfig(max_retries=0, backoff_steps=0,
                                dead_after=1, checkpoint_every=2))
    inj = FaultInjector()
    make = lambda: inj.wrap(BatchedClosedLoop.from_config(
        params, cfg, config))
    eng = StreamEngine(engines=[make()], config=config)
    sup = LaneSupervisor(eng, store=CheckpointStore(capacity=4),
                         rebuild=lambda modality: make())
    h = sup.watch(eng.open(modality="event", stateful=True))
    sid = h.stream_id
    got = []
    for k, w in enumerate(ws):
        sup.submit(sid, w)
        if k == 4:
            inj.kill("event")                     # lane dies mid-flight
        got.extend(sup.tick(eng.step()))
        if k == 5:
            inj.revive("event")                   # rebuilds come up clean
    for _ in range(8):                            # drain the replay
        got.extend(sup.tick(eng.step()))
    assert sup.stats["restores"] >= 1
    assert sup.stats["checkpoints"] >= 1
    assert sup.stats["replayed"] >= 1
    ok = [r for r in got if r.ok]
    # Every window eventually succeeded, each (sid, seq) exactly once.
    assert sorted(r.seq for r in ok) == list(range(len(ws)))
    ids, per_window = _uninterrupted_oracle(params, cfg, {sid: ws})
    _assert_matches_oracle(ok, ids, per_window)


def test_supervisor_raises_on_evicted_checkpoint():
    eng = _engine(stub=Stub(), recovery=RecoveryConfig(checkpoint_every=1))
    store = CheckpointStore(capacity=1)
    sup = LaneSupervisor(eng, store=store, rebuild=lambda m: Stub())
    h = sup.watch(eng.open(modality="stub"))
    sup.tick(eng.step())                          # checkpoint lands
    store.put({"squatter": True})                 # evicts the checkpoint
    eng._lanes["stub"].dead = True                # simulate lane death
    with pytest.raises(RuntimeError, match="evicted"):
        sup.recover("stub")


# ----------------------------------------------------------------------
# FusionSession: degraded single-wing ticks + wing health.
# ----------------------------------------------------------------------

def test_fusion_degrades_to_surviving_wing():
    inj = FaultInjector()
    event, frame = Stub("event"), Stub("frame")
    eng = StreamEngine(
        engines=[inj.wrap(event), inj.wrap(frame)],
        config=EngineConfig(max_streams=1,
                            recovery=RecoveryConfig(max_retries=0,
                                                    backoff_steps=0,
                                                    dead_after=2)))
    sess = FusionSession(eng)
    sess.submit(1, 101)
    [r] = sess.step()
    assert r.status == "ok" and r.modality == "fusion"
    assert sess.ticks_fused == 1
    inj.kill("frame")                             # frame wing goes down
    degraded = []
    for t in range(3):
        sess.submit(2 + t, 102 + t)
        degraded.extend(sess.step())
    assert len(degraded) == 3
    assert all(r.status == "degraded" for r in degraded)
    # The surviving wing's result carries the tick, flagged.
    d = degraded[0]
    assert np.unique(d.result.logits).item() == 2.0      # event wing's
    assert d.result.breakdown["degraded_wing"] == "frame"
    assert sess.ticks_degraded == 3
    assert sess.wing_failures == {"event": 0, "frame": 3}
    health = sess.wing_health()
    assert health["frame"]["dead"] and not health["event"]["dead"]
    assert health["frame"]["failures_seen"] == 3
    # Both wings down: the tick fails outright but still emits in order.
    inj.kill("event")
    sess.submit(5, 105)
    sess.submit(6, 106)
    rows = []
    for _ in range(3):
        rows.extend(sess.step())
    assert [r.status for r in rows] == ["failed", "failed"]
    assert [r.seq for r in rows] == [4, 5]
    assert sess.ticks_failed == 2
