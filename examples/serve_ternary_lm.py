"""Serve an LM with CUTIE-style ternary weights (the paper's technique
carried to the LM serving path).

Trains a small llama-family model briefly on the synthetic copy task,
quantizes the GEMM weights to packed 2-bit ternary, and serves batched
requests from both variants, reporting the weight-byte compression and
agreement.

Run:  PYTHONPATH=src python examples/serve_ternary_lm.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import TokenTaskConfig, token_batch
from repro.models import build_model
from repro.serving import ServeConfig, generate, quantize_for_serving
from repro.training import AdamWConfig, Trainer, TrainerConfig


def main():
    # llama3.2-family reduced config, widened to make quantization bite.
    cfg = dataclasses.replace(get_config("llama3.2-1b", smoke=True),
                              d_model=256, d_ff=512, num_heads=8,
                              num_kv_heads=4, head_dim=32)
    model = build_model(cfg)

    tk = TokenTaskConfig(vocab_size=cfg.vocab_size, seq_len=32,
                         batch_size=16, task="repeat")
    tr = Trainer(model, TrainerConfig(
        total_steps=60, ckpt_every=1000, log_every=20,
        ckpt_dir="checkpoints/serve_example",
        opt=AdamWConfig(lr=5e-3, warmup_steps=5, total_steps=60)),
        lambda s: token_batch(tk, s))
    print("training the base model on the copy task...")
    res = tr.run(jax.random.PRNGKey(0))
    params = res["state"]["params"]

    qparams, stats = quantize_for_serving(params)
    print(f"\nternary serving quantization: {stats['quantized']} tensors "
          f"packed, {stats['kept']} kept fp")
    print(f"  weight bytes {stats['bytes_before'] / 1e6:.1f} MB -> "
          f"{stats['bytes_after'] / 1e6:.1f} MB "
          f"({stats['bytes_before'] / stats['bytes_after']:.2f}x)")

    prompts = token_batch(tk, 999)["tokens"][:4, :8]
    sc = ServeConfig(max_new_tokens=12)
    toks_f, st_f = generate(model, params, prompts, sc)
    toks_q, st_q = generate(model, qparams, prompts, sc)
    agree = float((toks_f == toks_q).mean())
    print(f"\nfull-precision serve: {st_f.tokens_per_s:.1f} tok/s (host)")
    print(f"ternary serve:        {st_q.tokens_per_s:.1f} tok/s (host)")
    print(f"greedy token agreement: {agree:.2f}")
    print("full:    ", toks_f[0].tolist())
    print("ternary: ", toks_q[0].tolist())


if __name__ == "__main__":
    main()
