"""Quickstart: the ColibriES pipeline in ~40 lines.

Builds the paper's Table II spiking CNN (reduced), voxelizes a synthetic
DVS gesture window, runs event->label->PWM through the closed loop with
the fused LIF Pallas kernel, and prints the modelled Kraken latency/energy
next to the paper's Table III.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.core import SNNConfig, init_snn
from repro.core import events as ev
from repro.core.pipeline import ClosedLoopPipeline
from repro.kernels import lif_scan


def main():
    # Reduced Table-II-family SCNN (full config: get_config("colibries")).
    cfg = get_config("colibries", smoke=True)
    params = init_snn(jax.random.PRNGKey(0), cfg)

    # One 300 ms DVS event window (synthetic gesture, class 7).
    rng = np.random.default_rng(0)
    window = ev.synthetic_gesture_events(
        rng, label=7, mean_events=6000,
        height=cfg.height, width=cfg.width)
    print(f"window: {window.num_events} events over "
          f"{window.duration_us / 1000:.0f} ms")

    # Closed loop: acquire -> preprocess -> SNE inference -> PWM.
    pipe = ClosedLoopPipeline(params, cfg,
                              lif_scan_fn=lif_scan)
    res = pipe(window)

    print(f"predicted class: {res.label_pred[0]}  (true: {window.label})")
    print(f"PWM duty cycles: {np.round(res.pwm[0], 3)}")
    print(f"modelled latency: {res.latency_ms:.2f} ms "
          f"(paper, full net: 164.5 ms)")
    print(f"modelled energy:  {res.energy_mj:.3f} mJ "
          f"(paper, full net: 7.7 mJ)")
    print(f"real-time at 300 ms windows: {res.realtime}; "
          f"sustained {res.sustained_rate_hz:.2f} Hz")
    for name, st in res.breakdown["stages"].items():
        print(f"  {name:18s} {st['time_ms']:8.2f} ms  "
              f"{st['active_energy_mj']:6.3f} mJ  [{st['domain']}]")


if __name__ == "__main__":
    main()
