"""Fault-tolerant closed-loop control: degraded fusion + supervised
lane recovery, checked bitwise against the uninterrupted oracle.

ColibriUAV makes the ColibriES loop flight-critical: a corrupted or
missed inference is a control fault. This demo drives the stack's whole
fault-tolerance story on the headline two-wing scenario, in two acts:

  **Act 1 -- a wing dies mid-flight.** A seeded
  :class:`~repro.fleet.faults.FaultInjector` kills the frame wing (CUTIE)
  partway through a fused flight. The engine's recovery layer fail-fasts
  the dead lane; the :class:`~repro.serving.session.FusionSession` emits
  single-wing DEGRADED ticks on the surviving event wing (SNE) instead
  of stalling, until a fresh frame engine is installed
  (``replace_lane_engine``) and full fusion resumes. Every tick fused
  after the recovery is bitwise-identical to the uninterrupted run --
  the event wing's LIF carry never flinched.

  **Act 2 -- the stateful lane itself dies.** A
  :class:`~repro.fleet.supervisor.LaneSupervisor` journals every
  submission and auto-checkpoints the stream into a bounded
  :class:`~repro.fleet.store.CheckpointStore`. The injector kills the
  event lane mid-scan; the supervisor rebuilds it, restores the last
  checkpoint, and replays the journal -- and EVERY window, including the
  ones that failed while the lane was down, lands bitwise-identical to
  the uninterrupted scan.

Run:  PYTHONPATH=src python examples/fault_tolerant_control.py
"""
import jax
import numpy as np

from repro.configs.colibries import SMOKE, TCN_SMOKE
from repro.core import FrameTCNEngine, init_snn, init_tcn
from repro.core import events as ev
from repro.core import frames as fr
from repro.core._api import EngineConfig, FaultConfig, RecoveryConfig
from repro.core.pipeline import BatchedClosedLoop
from repro.fleet import CheckpointStore, FaultInjector, LaneSupervisor
from repro.serving import FusionSession, StreamEngine

TICKS = 8
KILL_AT = 3      # the frame wing dies dispatching this tick
REVIVE_AT = 6    # ...and a fresh engine is installed here

RECOVERY = RecoveryConfig(max_retries=0, backoff_steps=0, dead_after=1,
                          checkpoint_every=2)


def sensor_head(rng, k):
    label = k % SMOKE.num_classes
    return (ev.synthetic_gesture_events(rng, label, mean_events=4000,
                                        height=SMOKE.height,
                                        width=SMOKE.width),
            fr.synthetic_gesture_frames(rng, label, height=TCN_SMOKE.height,
                                        width=TCN_SMOKE.width))


def assert_bitwise(a, b):
    np.testing.assert_array_equal(a.label_pred, b.label_pred)
    np.testing.assert_array_equal(a.pwm, b.pwm)
    np.testing.assert_array_equal(a.logits, b.logits)


def act1_degraded_fusion(snn_params, tcn_params, ticks):
    print("== Act 1: frame wing dies mid-flight, fusion degrades ==")

    def make_session(inj):
        wrap = inj.wrap if inj else (lambda e: e)
        eng = StreamEngine(
            engines=[wrap(BatchedClosedLoop(snn_params, SMOKE)),
                     wrap(FrameTCNEngine(tcn_params, TCN_SMOKE))],
            config=EngineConfig(max_streams={"event": 1, "frame": 1},
                                recovery=RECOVERY))
        return eng, FusionSession(eng, session_id="uav0", stateful=True)

    # The oracle: the same flight with no faults.
    _, clean = make_session(None)
    for ev_w, fr_w in ticks:
        clean.submit(ev_w, fr_w)
    oracle = {r.seq: r.result for r in clean.run()}

    inj = FaultInjector(FaultConfig(seed=3))
    eng, sess = make_session(inj)
    rows = []
    for k, (ev_w, fr_w) in enumerate(ticks):
        if k == KILL_AT:
            inj.kill("frame")
            print(f"  tick {k}: frame wing KILLED")
        if k == REVIVE_AT:
            inj.revive("frame")
            eng.replace_lane_engine("frame", engine=inj.wrap(
                FrameTCNEngine(tcn_params, TCN_SMOKE)))
            print(f"  tick {k}: fresh frame engine installed")
        sess.submit(ev_w, fr_w)
        rows.extend(sess.step())
    sess.absorb(eng.flush())
    rows.extend(sess.drain())

    for r in rows:
        mark = {"ok": "fused", "degraded": "DEGRADED"}[r.status]
        extra = (f" (wing down: {r.result.breakdown['degraded_wing']})"
                 if r.status == "degraded" else "")
        print(f"  tick {r.seq}: {mark}  pred={int(r.result.label_pred[0])}"
              f"{extra}")
    assert [r.seq for r in rows] == list(range(TICKS))
    n_deg = sum(r.status == "degraded" for r in rows)
    assert n_deg == REVIVE_AT - KILL_AT, "wing-down stretch must degrade"
    # Bitwise: every FUSED tick -- before the kill and after the
    # recovery -- equals the uninterrupted flight (the event carry
    # never reset); degraded ticks equal the oracle's event wing.
    for r in rows:
        if r.status == "ok":
            assert_bitwise(r.result, oracle[r.seq])
    health = sess.wing_health()
    print(f"  {sess.ticks_fused} fused + {sess.ticks_degraded} degraded "
          f"ticks; frame wing failures seen: "
          f"{health['frame']['failures_seen']}")
    print("  bitwise: every fused tick == uninterrupted oracle  [OK]\n")


def act2_supervised_recovery(snn_params, ticks):
    print("== Act 2: stateful event lane dies, supervisor recovers ==")
    windows = [ev_w for ev_w, _ in ticks]
    config = EngineConfig(max_streams=1, recovery=RECOVERY)

    # The oracle: the same stateful scan with no faults.
    clean = StreamEngine(
        engines=[BatchedClosedLoop(snn_params, SMOKE)], config=config)
    ch = clean.open(modality="event", stream_id="imu", stateful=True)
    for w in windows:
        ch.submit(w)
    oracle = {r.seq: r.result for r in clean.run()}

    inj = FaultInjector(FaultConfig(seed=3))
    make = lambda: inj.wrap(BatchedClosedLoop(snn_params, SMOKE))
    eng = StreamEngine(engines=[make()], config=config)
    sup = LaneSupervisor(eng, store=CheckpointStore(capacity=4),
                         rebuild=lambda modality: make())
    sup.watch(eng.open(modality="event", stream_id="imu", stateful=True))
    got = []
    for k, w in enumerate(windows):
        if k == KILL_AT:
            inj.kill("event")
            print(f"  window {k}: event lane KILLED")
        if k == REVIVE_AT:
            inj.revive("event")
            print(f"  window {k}: injector revived (next rebuild sticks)")
        sup.submit("imu", w)
        got.extend(sup.tick(eng.step()))
    for _ in range(12):
        got.extend(sup.tick(eng.step()))

    ok = sorted((r for r in got if r.ok), key=lambda r: r.seq)
    failed = [r for r in got if not r.ok]
    assert [r.seq for r in ok] == list(range(TICKS)), \
        "every window must eventually succeed"
    for r in ok:
        assert_bitwise(r.result, oracle[r.seq])
    print(f"  {len(ok)}/{TICKS} windows served ok ({len(failed)} transient "
          f"failures while the lane was down); supervisor: "
          f"{sup.stats['restores']} restores, "
          f"{sup.stats['checkpoints']} checkpoints, "
          f"{sup.stats['replayed']} journal replays")
    print("  bitwise: every successful window == uninterrupted scan  [OK]")


def main():
    snn_params = init_snn(jax.random.PRNGKey(0), SMOKE)
    tcn_params = init_tcn(jax.random.PRNGKey(1), TCN_SMOKE)
    ticks = [sensor_head(np.random.default_rng(7), k)
             for k in range(TICKS)]
    act1_degraded_fusion(snn_params, tcn_params, ticks)
    act2_supervised_recovery(snn_params, ticks)


if __name__ == "__main__":
    main()
