"""Cross-modal fusion control: one sensor head, both Kraken wings, one
actuation decision per control tick -- plus live stream migration.

The ColibriES headline scenario (as deployed on ColibriUAV): a combined
DVS + frame sensor head feeds the SNE (spiking CNN, event wing) and
CUTIE (ternary CNN, frame wing) in parallel; their classifier outputs
are fused late -- a convex combination of the two wings' logits -- into
a single PWM actuation per tick, with per-wing Kraken latency/energy
attribution.

Three session-API capabilities on display:

  * FusionSession -- one event handle + one frame handle bound into a
    single logical stream; each step still runs ONE jit'd call per
    engine lane, the session pairs the wings' results back up by tick.
  * checkpoint/restore -- mid-flight the whole (stateful) fusion stream
    is checkpointed into a host-serializable payload and restored into
    a BRAND-NEW StreamEngine, where the remaining ticks continue
    bitwise-identical to the uninterrupted run: stream migration
    between engine processes.
  * the fused fast path -- co-scheduled fusion ticks plus the
    cross-wing megastep (``EngineConfig(megastep=True)``) against the
    same workload on two decoupled single-wing engines: the demo times
    both and EXITS NONZERO if fused serving is slower, so the CI smoke
    job enforces the perf claim, not just the semantics.

Run:  PYTHONPATH=src python examples/fusion_control.py
"""
import pickle
import time

import jax
import numpy as np

from repro.configs.colibries import SMOKE, TCN_SMOKE
from repro.core import EngineConfig, FrameTCNEngine, init_snn, init_tcn
from repro.core import events as ev
from repro.core import frames as fr
from repro.core.pipeline import BatchedClosedLoop
from repro.serving import FusionSession, StreamEngine, late_logit_fusion

TICKS = 6
CUT = 3          # migrate the stream after this many ticks
HEADS = 2        # sensor heads in the timed fused-vs-separate race
REPEATS = 3


def make_engine(snn_params, tcn_params):
    """One StreamEngine serving both Kraken wings (fresh 'process')."""
    return StreamEngine(
        engines=[BatchedClosedLoop(snn_params, SMOKE),
                 FrameTCNEngine(tcn_params, TCN_SMOKE)],
        config=EngineConfig(max_streams={"event": 1, "frame": 1}),
    )


def sensor_head(rng, k):
    """One control tick's paired windows from the combined head."""
    label = k % SMOKE.num_classes
    return (ev.synthetic_gesture_events(rng, label, mean_events=4000,
                                        height=SMOKE.height,
                                        width=SMOKE.width),
            fr.synthetic_gesture_frames(rng, label, height=TCN_SMOKE.height,
                                        width=TCN_SMOKE.width))


def main():
    snn_params = init_snn(jax.random.PRNGKey(0), SMOKE)
    tcn_params = init_tcn(jax.random.PRNGKey(1), TCN_SMOKE)
    ticks = [sensor_head(np.random.default_rng(7), k)
             for k in range(TICKS)]

    # -- fused serving: one decision per tick ---------------------------
    session = FusionSession(make_engine(snn_params, tcn_params),
                            session_id="uav0", stateful=True,
                            fusion=late_logit_fusion(0.6, 0.4))
    for ev_w, fr_w in ticks:
        session.submit(ev_w, fr_w)
    fused = session.run()

    print("tick  pred  pwm[0..3]              mJ_event  mJ_frame  "
          "lat_ms  realtime")
    for r in fused:
        bd = r.result.breakdown
        pwm = "  ".join(f"{d:.3f}" for d in r.result.pwm[0])
        print(f"{r.seq:4d}  {int(r.result.label_pred[0]):4d}  {pwm}  "
              f"{bd['per_wing_energy_mj']['event']:8.3f}  "
              f"{bd['per_wing_energy_mj']['frame']:8.3f}  "
              f"{r.result.latency_ms:6.1f}  {r.result.realtime!s:>8}")
    st = session.stats
    print(f"\n{st['ticks_fused']} fused ticks "
          f"({st['event'].windows} event + {st['frame'].windows} frame "
          f"windows); rule = {session.fusion.name}; "
          f"wing energy split {st['event'].energy_mj:.2f} / "
          f"{st['frame'].energy_mj:.2f} mJ")

    # -- stream migration: checkpoint -> fresh engine -> restore --------
    part_a = FusionSession(make_engine(snn_params, tcn_params),
                           session_id="uav0", stateful=True,
                           fusion=late_logit_fusion(0.6, 0.4))
    for ev_w, fr_w in ticks[:CUT]:
        part_a.submit(ev_w, fr_w)
    migrated = part_a.run()

    blob = pickle.dumps(part_a.checkpoint())     # host-serializable
    part_b = FusionSession.restore(make_engine(snn_params, tcn_params),
                                   pickle.loads(blob),
                                   fusion=late_logit_fusion(0.6, 0.4))
    for ev_w, fr_w in ticks[CUT:]:
        part_b.submit(ev_w, fr_w)
    migrated += part_b.run()

    same = all(
        np.array_equal(a.result.pwm, b.result.pwm)
        and a.result.energy_mj == b.result.energy_mj
        for a, b in zip(fused, migrated))
    print(f"\nmigrated at tick {CUT} through a {len(blob)}-byte "
          f"checkpoint into a fresh engine: "
          f"{'bitwise-identical to the uninterrupted run' if same else 'MISMATCH'}")

    # -- the perf claim, enforced: fused must beat separate wings -------
    ratio = fused_vs_separate(snn_params, tcn_params)
    print(f"\nfused-vs-separate tick ratio over {HEADS} heads: "
          f"{ratio:.2f}x "
          f"({'fused serving is faster' if ratio >= 1.0 else 'FUSED IS SLOWER'})")
    if not (same and ratio >= 1.0):
        raise SystemExit(1)


def fused_vs_separate(snn_params, tcn_params):
    """Median fused/separate ticks-per-second over REPEATS interleaved
    passes: HEADS FusionSessions on one co-scheduled megastep engine vs
    the same windows through decoupled event-only + frame-only engines."""
    heads = {h: [sensor_head(np.random.default_rng(40 + h), k)
                 for k in range(TICKS)] for h in range(HEADS)}

    eng = StreamEngine(
        engines=[BatchedClosedLoop(snn_params, SMOKE),
                 FrameTCNEngine(tcn_params, TCN_SMOKE)],
        config=EngineConfig(max_streams=HEADS, megastep=True,
                            pipeline_depth=1))
    sess = {h: FusionSession(eng, session_id=f"head{h}")
            for h in range(HEADS)}

    def fused_pass():
        for h, tks in heads.items():
            for ev_w, fr_w in tks:
                sess[h].submit(ev_w, fr_w)
        t0 = time.perf_counter()
        rows = eng.run()
        n = 0
        for s in sess.values():
            rows = s.absorb(rows)
            n += len(s.drain())
        assert n == HEADS * TICKS and not rows
        return n / (time.perf_counter() - t0)

    ev_eng = StreamEngine(engines=[BatchedClosedLoop(snn_params, SMOKE)],
                          config=EngineConfig(max_streams=HEADS))
    fr_eng = StreamEngine(engines=[FrameTCNEngine(tcn_params, TCN_SMOKE)],
                          config=EngineConfig(max_streams=HEADS))
    ev_h = {h: ev_eng.open(stream_id=f"dvs{h}") for h in range(HEADS)}
    fr_h = {h: fr_eng.open(stream_id=f"cam{h}") for h in range(HEADS)}

    def separate_pass():
        for h, tks in heads.items():
            for ev_w, fr_w in tks:
                ev_h[h].submit(ev_w)
                fr_h[h].submit(fr_w)
        t0 = time.perf_counter()
        n = len(ev_eng.run()) + len(fr_eng.run())
        assert n == 2 * HEADS * TICKS
        return (n // 2) / (time.perf_counter() - t0)

    fused_pass(), separate_pass()            # warm-up: compile both sides
    fused, separate = [], []
    for _ in range(REPEATS):
        fused.append(fused_pass())
        separate.append(separate_pass())
    return float(np.median(fused) / np.median(separate))


if __name__ == "__main__":
    main()
