"""Heterogeneous closed-loop control: event cameras AND frame cameras,
one engine-agnostic StreamEngine, both Kraken accelerator wings per step.

ColibriES's pitch is heterogeneity: DVS events route to the SNE (spiking
CNN), frames route to CUTIE (ternary CNN), over one shared FC + cluster
front end. This demo serves a mixed sensor fleet: each step() makes one
jit'd call per engine -- the event batch through the voxelize+SNN loop,
the frame batch through the normalize+TCN loop -- and every stream gets
its own wing-specific Kraken latency/energy breakdown. Urgent control
loops can ride the deadline-aware slot policy.

Run:  PYTHONPATH=src python examples/hetero_control.py
"""
import time

import jax
import numpy as np

from repro.configs.colibries import SMOKE, TCN_SMOKE
from repro.core import FrameTCNEngine, init_snn, init_tcn
from repro.core import events as ev
from repro.core import frames as fr
from repro.core.pipeline import BatchedClosedLoop
from repro.serving import DeadlinePolicy, StreamEngine

EVENT_STREAMS = 3
FRAME_STREAMS = 3
SLOTS = {"event": 2, "frame": 2}
WINDOWS_PER_STREAM = 4


def main():
    scfg, tcfg = SMOKE, TCN_SMOKE
    snn_params = init_snn(jax.random.PRNGKey(0), scfg)
    tcn_params = init_tcn(jax.random.PRNGKey(1), tcfg)
    rng = np.random.default_rng(7)

    engine = StreamEngine(
        engines=[BatchedClosedLoop(snn_params, scfg),
                 FrameTCNEngine(tcn_params, tcfg)],
        max_streams=SLOTS,
        policy=DeadlinePolicy(fair_quantum=2),
    )

    # A mixed fleet: DVS sensors (urgent flight loops, tight deadlines)
    # and frame cameras (slack monitoring loops). One handle per sensor:
    # modality is latched at open, deadlines ride each submit.
    handles = {f"dvs{s}": engine.open(modality="event",
                                      stream_id=f"dvs{s}")
               for s in range(EVENT_STREAMS)}
    handles.update({f"cam{s}": engine.open(modality="frame",
                                           stream_id=f"cam{s}")
                    for s in range(FRAME_STREAMS)})

    def submit_round(k):
        for s in range(EVENT_STREAMS):
            handles[f"dvs{s}"].submit(
                ev.synthetic_gesture_events(
                    rng, (s + k) % scfg.num_classes, mean_events=4000,
                    height=scfg.height, width=scfg.width),
                deadline=float(10 * k + s))
        for s in range(FRAME_STREAMS):
            handles[f"cam{s}"].submit(
                fr.synthetic_gesture_frames(
                    rng, (s + k) % tcfg.num_classes,
                    height=tcfg.height, width=tcfg.width),
                deadline=float(10 * k + 100 + s))

    submit_round(0)             # warm-up: compiles both engines' shapes
    engine.run()
    warm_windows = engine.stats["windows"]
    warm_steps = engine.stats["steps"]
    warm = {sid: (st.windows, st.energy_mj, st.latency_ms_sum)
            for sid, st in engine.stream_stats.items()}

    for k in range(WINDOWS_PER_STREAM):
        submit_round(k + 1)
    t0 = time.perf_counter()
    results = engine.run()
    wall = time.perf_counter() - t0

    steps = engine.stats["steps"] - warm_steps
    served = engine.stats["windows"] - warm_windows
    n_event = sum(r.modality == "event" for r in results)
    n_frame = sum(r.modality == "frame" for r in results)
    print(f"{served} windows ({n_event} event + {n_frame} frame) over "
          f"{sum(SLOTS.values())} slots in {steps} steps -> "
          f"{served / wall:.0f} windows/s; one jit'd call per engine "
          f"per step\n")

    print("stream  wing   windows  mean_lat_ms  energy_mJ  engine_stage")
    for sid in sorted(engine.stream_stats):
        st = engine.stream_stats[sid]
        w0, e0, l0 = warm[sid]
        n = st.windows - w0
        wing = engine.modality_of(sid)
        stage = "snn_inference" if wing == "event" else "tcn_inference"
        print(f"{sid:6s}  {wing:5s}  {n:7d}  "
              f"{(st.latency_ms_sum - l0) / n:11.2f}  "
              f"{st.energy_mj - e0:9.3f}  {stage}")

    last = {r.stream_id: r.result for r in results}
    dvs, cam = last["dvs0"].breakdown, last["cam0"].breakdown
    print("\nper-window Kraken breakdowns (last window of each wing):")
    for name, bd in (("dvs0", dvs), ("cam0", cam)):
        stages = ", ".join(f"{s}={v['time_ms']:.2f}ms"
                           for s, v in bd["stages"].items())
        print(f"  {name}: {stages}; total {bd['total_energy_mj']:.3f} mJ")
    print(f"\ncompiled shapes: event={engine.compiled_shapes('event')} "
          f"frame={engine.compiled_shapes('frame')}")


if __name__ == "__main__":
    main()
