"""Fleet control plane: autoscaling, live migration, and rebalancing.

One Kraken SoC closes one loop; a fleet serves thousands. This demo
drives the whole ``repro.fleet`` control plane over two event-wing
engine instances with a deliberately skewed load:

  * a **hot** engine (2 slots) opens four deadlined stateful streams
    with all their windows queued up front, plus ephemeral churn,
  * a **cold** engine (4 slots) sits nearly idle,
  * a :class:`~repro.fleet.autoscale.LaneAutoscaler` watches the hot
    lane's backlog telemetry and grows its slot count (recompile
    amortized through the AOT warmup cache),
  * a :class:`~repro.fleet.rebalance.FleetRebalancer` live-migrates
    deep-queue streams hot-to-cold through the checkpoint store, and
  * every migrated stream's results are checked bitwise against an
    uninterrupted single-engine run of the same windows.

Deadline misses are measured on a shared logical clock (one tick per
scheduling round), so the printout is deterministic.

Run:  PYTHONPATH=src python examples/fleet_control.py
"""
import jax
import numpy as np

from repro.configs.colibries import SMOKE
from repro.core import init_snn
from repro.core import events as ev
from repro.core._api import EngineConfig, FleetConfig
from repro.fleet import CheckpointStore, FleetRebalancer, LaneAutoscaler
from repro.serving import DeadlinePolicy, StreamEngine

N_STREAMS = 4
N_WINDOWS = 5


def windows_for(sid, n=N_WINDOWS):
    rng = np.random.default_rng(100 + int(sid[1:]))
    return [ev.synthetic_gesture_events(rng, k % SMOKE.num_classes,
                                        mean_events=3000,
                                        height=SMOKE.height,
                                        width=SMOKE.width)
            for k in range(n)]


def make_engine(params, slots):
    return StreamEngine(params, SMOKE, EngineConfig(
        max_streams=slots, policy=DeadlinePolicy(fair_quantum=2)))


def serve_fleet(params, streams, *, control):
    hot, cold = make_engine(params, 2), make_engine(params, 4)
    tick = [0]
    for eng in (hot, cold):
        eng.deadline_clock = lambda: float(tick[0])
    for sid in sorted(streams):
        h = hot.open(stream_id=sid, stateful=True)
        for k, w in enumerate(streams[sid]):
            h.submit(w, deadline=2.0 + 1.0 * k)
    scaler = reb = None
    if control:
        scaler = LaneAutoscaler(hot, config=FleetConfig(
            grow_backlog=3.0, grow_patience=2, max_slots=4))
        reb = FleetRebalancer(
            {"hot": hot, "cold": cold}, store=CheckpointStore(),
            config=FleetConfig(imbalance=1.0, cooldown=1))

    rows = []
    while hot.pending() or cold.pending():
        rows.extend(hot.step())
        rows.extend(cold.step())
        tick[0] += 1
        if scaler is not None:
            decision = scaler.observe()
            if decision.resized:
                print(f"  tick {tick[0]:2d}: autoscaler {decision.action} "
                      f"hot lane {decision.old_slots}->"
                      f"{decision.new_slots} ({decision.reason})")
        if reb is not None:
            report = reb.observe()
            rows.extend(report.displaced)
            for rec in report.moved:
                print(f"  tick {tick[0]:2d}: migrated {rec.stream_id!r} "
                      f"hot->cold in {rec.migration_ms:.1f} ms "
                      f"({len(rec.displaced)} displaced results)")
    dated = missed = 0
    for eng in (hot, cold):
        for st in eng.stream_stats.values():
            dated += st.deadline_windows
            missed += st.deadline_missed
    return rows, missed / dated


def main():
    params = init_snn(jax.random.PRNGKey(0), SMOKE)
    streams = {f"s{i}": windows_for(f"s{i}") for i in range(N_STREAMS)}

    # The oracle: each stream served alone, uninterrupted.
    oracle = {}
    for sid in sorted(streams):
        eng = make_engine(params, 2)
        h = eng.open(stream_id=sid, stateful=True)
        for w in streams[sid]:
            h.submit(w)
        for r in eng.run():
            oracle[(sid, r.seq)] = np.asarray(r.result.pwm)

    print("static fleet (no control plane):")
    _, static_miss = serve_fleet(params, streams, control=False)
    print(f"  deadline-miss rate: {static_miss:.1%}\n")

    print("controlled fleet (autoscaler + rebalancer):")
    rows, rebal_miss = serve_fleet(params, streams, control=True)
    print(f"  deadline-miss rate: {rebal_miss:.1%}")

    same = all(np.array_equal(np.asarray(r.result.pwm),
                              oracle[(r.stream_id, r.seq)])
               for r in rows)
    print(f"\nmiss rate {static_miss:.1%} -> {rebal_miss:.1%}; "
          f"migrated streams "
          f"{'bitwise-identical to uninterrupted runs' if same else 'MISMATCH'}")
    if not (same and rebal_miss <= static_miss):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
