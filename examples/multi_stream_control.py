"""Multi-stream closed-loop control: many DVS sensors, one batched engine.

The ColibriUAV scenario scaled up: S independent event cameras (e.g. a
swarm of platforms, or several sensors on one platform) each produce 300 ms
windows; the StreamEngine serves them over a fixed number of batch slots,
so every engine step runs ONE jit'd closed-loop inference for a whole
batch of streams. Per-stream Kraken energy/latency accounting is identical
to running each window alone through ClosedLoopPipeline.

Run:  PYTHONPATH=src python examples/multi_stream_control.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import init_snn
from repro.core import events as ev
from repro.core.pipeline import ClosedLoopPipeline
from repro.serving import StreamEngine

NUM_STREAMS = 6          # sensors
SLOTS = 4                # engine batch slots (< NUM_STREAMS: slots rotate)
WINDOWS_PER_STREAM = 5


def main():
    cfg = get_config("colibries", smoke=True)
    params = init_snn(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)

    # Each sensor performs its own gesture sequence.
    workload = {
        f"cam{s}": [ev.synthetic_gesture_events(
            rng, (s + k) % cfg.num_classes, mean_events=5000,
            height=cfg.height, width=cfg.width)
            for k in range(WINDOWS_PER_STREAM)]
        for s in range(NUM_STREAMS)
    }

    engine = StreamEngine(params, cfg, max_streams=SLOTS)
    # Warm-up round: compiles the (SLOTS, max_events) closed-loop call.
    for sid, windows in workload.items():
        engine.submit(sid, windows[0])
    engine.run()
    warm = {sid: (st.windows, st.energy_mj, st.latency_ms_sum,
                  st.realtime_windows)
            for sid, st in engine.stream_stats.items()}
    warm_steps = engine.stats["steps"]
    warm_windows = engine.stats["windows"]

    for sid, windows in workload.items():
        for w in windows:
            engine.submit(sid, w)
    t0 = time.perf_counter()
    results = engine.run()
    wall = time.perf_counter() - t0

    steps = engine.stats["steps"] - warm_steps
    occupancy = (engine.stats["windows"] - warm_windows) / steps
    print(f"{len(results)} windows from {NUM_STREAMS} streams over "
          f"{SLOTS} slots in {steps} steps "
          f"(mean occupancy {occupancy:.2f}) -> "
          f"{len(results) / wall:.0f} windows/s\n")

    print("stream  windows  mean_lat_ms  energy_mJ  mW_busy  realtime")
    for sid in sorted(engine.stream_stats):
        st = engine.stream_stats[sid]
        w0, e0, l0, r0 = warm[sid]      # exclude the warm-up round
        n = st.windows - w0
        lat = st.latency_ms_sum - l0
        energy = st.energy_mj - e0
        rt = (st.realtime_windows - r0) / n
        print(f"{sid:6s}  {n:7d}  {lat / n:11.2f}  {energy:9.3f}  "
              f"{energy / (lat * 1e-3):7.1f}  {rt:8.0%}")

    # Looped baseline for comparison (same windows, one at a time).
    pipe = ClosedLoopPipeline(params, cfg)
    flat = [w for ws in workload.values() for w in ws]
    for w in flat[:3]:
        pipe(w)              # compile
    t0 = time.perf_counter()
    for w in flat:
        pipe(w)
    wall_loop = time.perf_counter() - t0
    print(f"\nlooped single-window baseline: "
          f"{len(flat) / wall_loop:.0f} windows/s "
          f"(batched speedup {wall_loop / wall:.2f}x)")


if __name__ == "__main__":
    main()
