"""Multi-stream closed-loop control: many DVS sensors, one batched engine.

The ColibriUAV scenario scaled up: S independent event cameras (e.g. a
swarm of platforms, or several sensors on one platform) each produce 300 ms
windows; the StreamEngine serves them over a fixed number of batch slots,
so every engine step runs ONE jit'd closed-loop inference for a whole
batch of streams. Per-stream Kraken energy/latency accounting is identical
to running each window alone through ClosedLoopPipeline.

Streams are driven through the session-handle API: ``engine.open(...)``
returns a StreamHandle owning the stream's lifecycle (submit /
reset_state / checkpoint / close); ``engine.run()`` stays the completion
surface.

One stream ("tracker") is long-lived and STATEFUL: opened with
``stateful=True``, its LIF membranes carry across window boundaries --
the paper's continuous closed-loop regime -- while its neighbours stay
stateless. To make the carry visible, tracker and its stateless twin
receive the IDENTICAL event window every time: the twin's firing rates
are constant (each window starts from rest), the tracker's drift as the
carried membrane integrates evidence across windows.

Run:  PYTHONPATH=src python examples/multi_stream_control.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import EngineConfig, init_snn
from repro.core import events as ev
from repro.core.pipeline import ClosedLoopPipeline
from repro.distributed import make_mesh
from repro.serving import StreamEngine

NUM_STREAMS = 6          # sensors
SLOTS = 4                # engine batch slots (< NUM_STREAMS: slots rotate)
WINDOWS_PER_STREAM = 5


def main():
    cfg = get_config("colibries", smoke=True)
    params = init_snn(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)

    # Each sensor performs its own gesture sequence.
    workload = {
        f"cam{s}": [ev.synthetic_gesture_events(
            rng, (s + k) % cfg.num_classes, mean_events=5000,
            height=cfg.height, width=cfg.width)
            for k in range(WINDOWS_PER_STREAM)]
        for s in range(NUM_STREAMS)
    }

    # One EngineConfig is the whole construction surface; mesh=make_mesh()
    # shards the slot axis over every local device (a 1-device mesh -- the
    # CPU default -- is served bitwise-identically to no mesh at all).
    engine = StreamEngine(params, cfg,
                          EngineConfig(max_streams=SLOTS, mesh=make_mesh()))
    # One handle per sensor: the session API latches modality (implicit
    # here -- single engine) and statefulness at open.
    handles = {sid: engine.open(stream_id=sid) for sid in workload}
    # Warm-up round: compiles the (SLOTS, max_events) closed-loop call.
    for sid, windows in workload.items():
        handles[sid].submit(windows[0])
    engine.run()
    warm = {sid: (st.windows, st.energy_mj, st.latency_ms_sum,
                  st.realtime_windows)
            for sid, st in engine.stream_stats.items()}
    warm_steps = engine.stats["steps"]
    warm_windows = engine.stats["windows"]

    for sid, windows in workload.items():
        for w in windows:
            handles[sid].submit(w)
    t0 = time.perf_counter()
    results = engine.run()
    wall = time.perf_counter() - t0

    steps = engine.stats["steps"] - warm_steps
    occupancy = (engine.stats["windows"] - warm_windows) / steps
    print(f"{len(results)} windows from {NUM_STREAMS} streams over "
          f"{SLOTS} slots in {steps} steps "
          f"(mean occupancy {occupancy:.2f}) -> "
          f"{len(results) / wall:.0f} windows/s\n")

    print("stream  windows  mean_lat_ms  energy_mJ  mW_busy  realtime")
    for sid in sorted(engine.stream_stats):
        st = engine.stream_stats[sid]
        w0, e0, l0, r0 = warm[sid]      # exclude the warm-up round
        n = st.windows - w0
        lat = st.latency_ms_sum - l0
        energy = st.energy_mj - e0
        rt = (st.realtime_windows - r0) / n
        print(f"{sid:6s}  {n:7d}  {lat / n:11.2f}  {energy:9.3f}  "
              f"{energy / (lat * 1e-3):7.1f}  {rt:8.0%}")

    # -- stateful streaming: a long-lived stream whose membrane carries --
    # Same engine, same slots: "tracker" opts into carried state at
    # open, its "twin" does not; both see the identical window each time.
    tracker = engine.open(stream_id="tracker", stateful=True)
    twin = engine.open(stream_id="twin")
    repeated = ev.synthetic_gesture_events(
        rng, 3, mean_events=5000, height=cfg.height, width=cfg.width)
    for _ in range(WINDOWS_PER_STREAM):
        tracker.submit(repeated)
        twin.submit(repeated)
    drift = {"tracker": {}, "twin": {}}
    for r in engine.run():
        if r.stream_id in drift:
            drift[r.stream_id][r.seq] = r.result.breakdown["firing_rates"]

    print("\nstateful stream vs stateless twin (identical input window "
          "every time):\nwindow   twin fc1 rate   tracker fc1 rate   "
          "tracker drift vs window 0")
    base = drift["tracker"][0]["fc1"]
    for k in sorted(drift["tracker"]):
        tw, tr = drift["twin"][k]["fc1"], drift["tracker"][k]["fc1"]
        print(f"{k:6d}  {tw:14.4f}  {tr:17.4f}  {tr - base:+24.4f}")
    print("twin rates are constant (amnesiac windows); tracker rates "
          "move because\nits LIF membranes carry across windows "
          "(reset_state() would re-zero them).")

    # Looped baseline for comparison (same windows, one at a time).
    pipe = ClosedLoopPipeline(params, cfg)
    flat = [w for ws in workload.values() for w in ws]
    for w in flat[:3]:
        pipe(w)              # compile
    t0 = time.perf_counter()
    for w in flat:
        pipe(w)
    wall_loop = time.perf_counter() - t0
    print(f"\nlooped single-window baseline: "
          f"{len(flat) / wall_loop:.0f} windows/s "
          f"(batched speedup {wall_loop / wall:.2f}x)")


if __name__ == "__main__":
    main()
