"""End-to-end driver: STBP-train the paper's DVS-Gesture SCNN.

Reproduces the paper's training setup (Sec. III: STBP per Wu et al. 2018,
LIF dynamics matched to SNE) on synthetic DVS-Gesture-like event streams,
with the production trainer (checkpoint/restart, straggler tracking).
Defaults train the full 128x128 Table II network for a few hundred steps;
--smoke runs the reduced config for CI-speed validation.

Run:  PYTHONPATH=src python examples/train_dvs_gesture.py [--smoke]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import init_snn, snn_loss
from repro.core.pipeline import ClosedLoopPipeline
from repro.data import dvs_gesture_batch
from repro.training import checkpoint as CKPT
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="checkpoints/dvs_gesture")
    args = ap.parse_args()

    cfg = get_config("colibries", smoke=args.smoke)
    steps = args.steps or (40 if args.smoke else 300)
    batch = args.batch or (8 if args.smoke else 16)
    mean_events = 4000 if args.smoke else 60_000

    params = init_snn(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=steps,
                       weight_decay=1e-4)

    @jax.jit
    def step_fn(params, opt, vox, labels):
        (loss, aux), g = jax.value_and_grad(
            lambda p: snn_loss(p, vox, labels, cfg), has_aux=True)(params)
        params, opt, om = adamw_update(g, opt, params, ocfg)
        return params, opt, loss, aux["accuracy"], aux["firing_rates"]

    # resume if a checkpoint exists (fault tolerance)
    start = 0
    restored = CKPT.restore_latest(args.ckpt_dir,
                                   {"params": params, "opt": opt})
    if restored:
        start, state, extra = restored
        params, opt = state["params"], state["opt"]
        print(f"resumed from step {start}")

    accs = []
    for s in range(start, steps):
        b = dvs_gesture_batch(batch, s, height=cfg.height,
                              width=cfg.width, time_bins=cfg.time_bins,
                              mean_events=mean_events,
                              num_classes=cfg.num_classes)
        t0 = time.perf_counter()
        params, opt, loss, acc, rates = step_fn(params, opt, b.vox,
                                                b.labels)
        accs.append(float(acc))
        if (s + 1) % 10 == 0:
            r = {k: f"{float(v):.3f}" for k, v in rates.items()}
            print(f"step {s + 1:4d}  loss {float(loss):.4f}  "
                  f"acc {np.mean(accs[-10:]):.3f}  "
                  f"({(time.perf_counter() - t0) * 1e3:.0f} ms)  rates {r}")
        if (s + 1) % 50 == 0 or s + 1 == steps:
            CKPT.save_checkpoint(args.ckpt_dir, s + 1,
                                 {"params": params, "opt": opt})

    # Closed-loop evaluation with the trained net
    pipe = ClosedLoopPipeline(params, cfg)
    rng = np.random.default_rng(123)
    correct = 0
    n_eval = 20
    from repro.core import events as ev
    for i in range(n_eval):
        lab = int(rng.integers(0, cfg.num_classes))
        w = ev.synthetic_gesture_events(rng, lab, mean_events=mean_events,
                                        height=cfg.height, width=cfg.width,
                                        num_classes=cfg.num_classes)
        res = pipe(w)
        correct += int(res.label_pred[0]) == lab
    print(f"\nclosed-loop eval: {correct}/{n_eval} correct "
          f"(chance {1 / cfg.num_classes:.2f}); "
          f"latency {res.latency_ms:.1f} ms, energy {res.energy_mj:.2f} mJ,"
          f" realtime={res.realtime}")


if __name__ == "__main__":
    main()
