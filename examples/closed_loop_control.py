"""Closed-loop control demo: gesture -> setpoint tracking at 3.3 Hz.

Simulates the paper's target application (UAV-style closed-loop control):
a stream of 300 ms DVS windows drives the SNN classifier, whose PWM
outputs steer a toy first-order plant toward per-gesture setpoints. The
run reports control latency, per-window energy, and plant tracking error
-- the end-to-end story of Fig. 1 in the paper.

Run:  PYTHONPATH=src python examples/closed_loop_control.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.core import init_snn
from repro.core import events as ev
from repro.core.pipeline import ClosedLoopPipeline

PLANT_TAU = 0.8          # first-order plant time constant (windows)


def main():
    cfg = get_config("colibries", smoke=True)
    params = init_snn(jax.random.PRNGKey(0), cfg)
    pipe = ClosedLoopPipeline(params, cfg)
    rng = np.random.default_rng(7)

    # Gesture sequence the "pilot" performs; each class maps to a target
    # actuation vector via the same mixing matrix as pwm_from_logits.
    gestures = [1, 1, 4, 4, 4, 9, 9, 2, 2, 2]
    state = np.full(4, 0.5)
    total_energy = 0.0
    latencies, errors = [], []

    print("window  gesture  pred  latency_ms  energy_mJ  plant_state")
    for i, g in enumerate(gestures):
        w = ev.synthetic_gesture_events(rng, g, mean_events=5000,
                                        height=cfg.height,
                                        width=cfg.width)
        res = pipe(w)
        # first-order plant follows the PWM setpoint
        target = res.pwm[0]
        state = state + (target - state) * (1 - np.exp(-1 / PLANT_TAU))
        total_energy += res.energy_mj
        latencies.append(res.latency_ms)
        errors.append(float(np.abs(target - state).mean()))
        print(f"{i:6d}  {g:7d}  {int(res.label_pred[0]):4d}  "
              f"{res.latency_ms:10.2f}  {res.energy_mj:9.3f}  "
              f"{np.round(state, 3)}")

    print(f"\nmean control latency: {np.mean(latencies):.2f} ms "
          f"(paper full-scale: 164.5 ms)")
    avg_mw = total_energy / len(gestures) * 3.33   # mJ/window * windows/s
    print(f"energy for {len(gestures)} windows: {total_energy:.2f} mJ "
          f"(avg {avg_mw:.2f} mW; a 2 Wh battery sustains "
          f"{2000 / avg_mw:.0f} h of continuous 3.33 Hz control)")
    print(f"mean tracking error: {np.mean(errors):.3f}")


if __name__ == "__main__":
    main()
