"""Ablations over the paper's SNN design axes (beyond-paper analysis).

Sweeps (a) temporal resolution T, (b) surrogate width, (c) membrane leak
alpha on the reduced DVS-gesture task and reports end-of-training loss /
accuracy plus the modelled SNE latency (synops scale with T and firing
rate, so the energy model couples accuracy to milliwatts -- the trade the
paper's platform is built around).
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.core import SNNConfig, init_snn, snn_loss
from repro.core.lif import LIFParams
from repro.data import dvs_gesture_batch
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update


def _train(cfg: SNNConfig, steps: int = 25, batch: int = 8, seed: int = 0):
    params = init_snn(jax.random.PRNGKey(seed), cfg)
    opt = adamw_init(params)
    ocfg = AdamWConfig(lr=2e-3, warmup_steps=5, total_steps=steps,
                       weight_decay=0.0)

    @jax.jit
    def step(params, opt, vox, labels):
        (loss, aux), g = jax.value_and_grad(
            lambda p: snn_loss(p, vox, labels, cfg), has_aux=True)(params)
        params, opt, _ = adamw_update(g, opt, params, ocfg)
        return params, opt, loss, aux["accuracy"], aux["firing_rates"]

    losses, accs, rate = [], [], 0.0
    for s in range(steps):
        b = dvs_gesture_batch(batch, s, height=cfg.height, width=cfg.width,
                              time_bins=cfg.time_bins, mean_events=4000,
                              num_classes=cfg.num_classes)
        params, opt, loss, acc, rates = step(params, opt, b.vox, b.labels)
        losses.append(float(loss))
        accs.append(float(acc))
        rate = float(rates["conv1"])
    return np.mean(losses[-5:]), np.mean(accs[-5:]), rate


def main():
    base = SNNConfig(height=32, width=32, time_bins=8, conv1_features=4,
                     conv2_features=8, hidden=32, num_classes=4)
    print("ablation,setting,loss,acc,conv1_rate,rel_snn_latency")
    for t in (4, 8, 16):
        cfg = dataclasses.replace(base, time_bins=t)
        l, a, r = _train(cfg)
        # SNE latency ~ synops ~ T * rate (per energy model scaling law)
        print(f"time_bins,{t},{l:.3f},{a:.3f},{r:.3f},{t * r / (8 * 0.15):.2f}")
    for w in (1.0, 2.0, 4.0):
        cfg = dataclasses.replace(
            base, lif=dataclasses.replace(base.lif, surrogate_width=w))
        l, a, r = _train(cfg)
        print(f"surrogate_width,{w},{l:.3f},{a:.3f},{r:.3f},"
              f"{8 * r / (8 * 0.15):.2f}")
    for alpha in (0.5, 0.875, 1.0):
        cfg = dataclasses.replace(
            base, lif=dataclasses.replace(base.lif, alpha=alpha))
        l, a, r = _train(cfg)
        print(f"alpha,{alpha},{l:.3f},{a:.3f},{r:.3f},"
              f"{8 * r / (8 * 0.15):.2f}")


if __name__ == "__main__":
    main()
