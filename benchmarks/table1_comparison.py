"""Paper Table I: ColibriES vs neuromorphic-platform prior work.

Reproduces the ColibriES column from our modelled pipeline (power during
inference, idle power, energy/inference normalized to 6 inf/s as in the
paper's note d) and prints the published comparison rows for context.
"""
from __future__ import annotations

import numpy as np

from repro.core import KRAKEN_DOMAINS, KrakenModel, NOMINAL

# Published rows (platform, app, accuracy %, P_inf mW, P_idle mW, E_inf mJ)
PRIOR = [
    ("Loihi [7]", "KWS", 95.9, 110.0, 29.2, 0.371),
    ("TrueNorth [8]", "KWS", 92.9, 26.5, 21.2, 29.2),
    ("Loihi [9]", "GR", 96.0, 141.9, 29.2, 5.9),
    ("Loihi [10]", "GR", 90.5, float("nan"), 29.2, float("nan")),
    ("TrueNorth [11]", "GR", 90.6, 133.7, 101.6, 29.8),
]
PAPER_COLIBRIES = ("Kraken/SNE (paper)", "GR", 83.0, 35.6, 17.7, 7.7)


def colibries_row():
    m = KrakenModel()
    acct = m.closed_loop(events=NOMINAL.events,
                         layer_in_spikes=NOMINAL.layer_in_spikes,
                         layer_fanout=NOMINAL.layer_fanout,
                         layer_passes=NOMINAL.layer_passes)
    # Energy normalized to 6 inf/s (paper note d): one inference per
    # 1/6 s; idle power covers the gap between latency and period.
    period_ms = 1000.0 / 6.0
    idle_gap_ms = max(period_ms - acct["total_time_ms"], 0.0)
    e_norm = acct["total_energy_mj"] + acct["p_idle_mw"] * idle_gap_ms * 1e-3
    return ("Kraken/SNE (ours)", "GR", 83.0,
            acct["p_avg_active_mw"], acct["p_idle_mw"], e_norm)


def main():
    print("platform, app, accuracy_pct, P_inf_mW, P_idle_mW, E_inf_mJ")
    for row in PRIOR + [PAPER_COLIBRIES, colibries_row()]:
        name, app, acc, p, pi, e = row
        print(f"{name}, {app}, {acc}, {p:.1f}, {pi:.1f}, {e:.3f}")
    ours = colibries_row()
    ref = PAPER_COLIBRIES
    print(f"# model vs paper: P {ours[3] / ref[3]:.3f}x, "
          f"Pidle {ours[4] / ref[4]:.3f}x")


if __name__ == "__main__":
    main()
