"""Roofline analysis from the dry-run artifacts (deliverable g).

Reads results/dryrun/*.json and derives, per (arch x shape) on the
single-pod mesh:

    compute term    = FLOPs_per_device / 197e12        (bf16 peak, v5e)
    memory term     = bytes_per_device / 819e9          (HBM bw)
    collective term = coll_bytes_per_device / 50e9      (ICI link bw)

cost_analysis() is per-device post-SPMD (verified empirically) so no
division by chip count is applied. XLA counts scan bodies ONCE, so
full-depth costs use the affine depth model from the unrolled L1/L2
variants:  per_unit = L2 - L1,  base = L1 - per_unit,
total = base + units * per_unit  (exact for homogeneous stacks).

MODEL_FLOPS (global): train 6*N*tokens, prefill 2*N*tokens, decode
2*N*new_tokens; N = active params for MoE. The ratio MODEL_FLOPS /
HLO_FLOPs measures how much compiled compute is "useful" (remat and
dispatch overheads push it below 1; f32 logits etc.).
"""
from __future__ import annotations

import json
import pathlib
import sys
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link

RESULTS = pathlib.Path("results/dryrun")


def _affine(rec: dict, field, coll=False) -> Optional[float]:
    """total(L) = base + units * (L2 - L1); clamped at L1 lower bound."""
    if "L1" not in rec or "L2" not in rec:
        return None
    get = ((lambda r: r.get("collectives", {}).get("total_bytes", 0.0))
           if coll else (lambda r: r.get(field, 0.0)))
    l1, l2 = get(rec["L1"]), get(rec["L2"])
    per_unit = max(l2 - l1, 0.0)
    base = max(l1 - per_unit, 0.0)
    return base + rec["depth_units"] * per_unit


def model_flops_global(rec: dict) -> float:
    n = rec["active_param_count"]
    b, s = rec["global_batch"], rec["seq_len"]
    if rec["kind"] == "train":
        return 6.0 * n * b * s
    if rec["kind"] == "prefill":
        return 2.0 * n * b * s
    return 2.0 * n * b          # decode: one new token per sequence


def analyze_record(rec: dict) -> Optional[dict]:
    if rec.get("status") != "ok":
        return None
    flops = _affine(rec, "flops")
    byts = _affine(rec, "bytes_accessed")
    coll = _affine(rec, None, coll=True)
    if flops is None:
        flops = rec["full"]["flops"]
        byts = rec["full"]["bytes_accessed"]
        coll = rec["full"].get("collectives", {}).get("total_bytes", 0.0)
    terms = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": byts / HBM_BW,
        "collective_s": coll / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    bound_s = terms[dominant]
    mf = model_flops_global(rec) / rec["num_devices"]   # per-device
    useful = mf / flops if flops else 0.0
    # roofline fraction: useful compute time / achievable step time
    # (the step cannot beat its dominant term).
    frac = (mf / PEAK_FLOPS) / bound_s if bound_s else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "kind": rec["kind"],
        "flops_dev": flops, "bytes_dev": byts, "coll_dev": coll,
        **terms,
        "dominant": dominant.replace("_s", ""),
        "model_flops_dev": mf,
        "useful_ratio": useful,
        "roofline_fraction": frac,
        "temp_gb": rec["full"].get("memory", {}).get("temp_bytes", 0) / 1e9,
        "arg_gb": rec["full"].get("memory", {}).get("argument_bytes",
                                                    0) / 1e9,
    }


_MOVE_HINTS = {
    "compute": ("compute-bound: cut non-useful FLOPs (remat policy, f32 "
                "logit softmax, dispatch einsums) or raise MXU util"),
    "memory": ("memory-bound: shrink HBM traffic -- fuse scans, bf16/"
               "ternary weights (CUTIE path), larger per-step tiles"),
    "collective": ("collective-bound: reshard to cut all-gathers "
                   "(FSDP prefetch overlap, TP-local attention), or "
                   "overlap collectives with compute"),
}


def load_all(mesh: str = "pod16x16") -> List[dict]:
    rows = []
    for f in sorted(RESULTS.glob(f"*__{mesh}.json")):
        rec = json.loads(f.read_text())
        row = analyze_record(rec)
        if row:
            rows.append(row)
    return rows


def to_markdown(rows: List[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | "
           "dominant | MODEL/HLO | roofline frac | hint |\n"
           "|---|---|---|---|---|---|---|---|---|")
    out = [hdr]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.2f} | "
            f"{_MOVE_HINTS[r['dominant']][:40]}... |")
    return "\n".join(out)


def main():
    rows = load_all()
    print(f"{'arch':24s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s}"
          f" {'coll_s':>10s} {'dominant':>10s} {'useful':>7s} {'frac':>6s}")
    for r in rows:
        print(f"{r['arch']:24s} {r['shape']:12s} {r['compute_s']:10.3e} "
              f"{r['memory_s']:10.3e} {r['collective_s']:10.3e} "
              f"{r['dominant']:>10s} {r['useful_ratio']:7.2f} "
              f"{r['roofline_fraction']:6.2f}")
    out = pathlib.Path("results/roofline.json")
    out.write_text(json.dumps(rows, indent=1))
    print(f"\nwrote {out} ({len(rows)} cells)")


if __name__ == "__main__":
    main()
