"""Kernel micro-benchmarks (CPU wall time of interpret/jnp paths + the
structural VMEM/bandwidth accounting that motivates each kernel on TPU).

On this CPU container wall-clock numbers only sanity-check the harness;
the meaningful output is the bytes model: lif_scan's state-traffic saving
and ternary_matmul's 8x weight-byte reduction, both derived from shapes.

``stream_rows`` additionally measures closed-loop throughput (windows/s)
of the batched StreamEngine (fused fc kernels + pipelined step) against
the looped single-window pipeline at several batch sizes, and writes a
``BENCH_stream.json`` artifact; ``stateful_rows`` adds the stateful-vs-
stateless serving cell (carried LIF membranes on vs off, same engine) to
the same artifact; ``fusion_rows`` adds the cross-modal fusion cell
(FusionSession serving paired event+frame ticks through one engine vs
the two wings on separate engines); ``fleet_rows`` adds the fleet
control-plane cell (deadline-miss rate of a skewed two-engine fleet
with vs without the telemetry-driven rebalancer, plus live-migration
cost in ms); ``fault_rows`` adds the fault-tolerance cell (stateful
throughput at injected fault-rate 0 vs 5%, retry/quarantine counters,
median recovery cost in engine steps). ``hetero_rows`` measures the two
accelerator wings through the unified engine protocol -- event-SNN vs
frame-TCN throughput, alone and mixed in one engine -- and writes
``BENCH_hetero.json``.

Methodology (all rows): one dedicated warmup pass (compile + first
touch), then the median of 5 timed samples, each sample closed with
``jax.block_until_ready`` so async dispatch cannot leak device time out
of (or into) a sample. Medians make the committed artifacts stable
enough to gate on (see ``benchmarks/check_regression.py``).
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (EngineConfig, FrameTCNEngine, SNNConfig, TCNConfig,
                        init_snn, init_tcn)
from repro.core import events as ev
from repro.core import frames as fr
from repro.core.lif import LIFParams
from repro.core.pipeline import BatchedClosedLoop, ClosedLoopPipeline
from repro.fleet import CheckpointStore, FleetConfig, FleetRebalancer
from repro.kernels import (fc_lif_scan, lif_scan, lif_scan_ref,
                           pack_ternary_weights, ternary_matmul,
                           ternary_matmul_ref)
from repro.serving import DeadlinePolicy, FusionSession, StreamEngine

REPEATS = 5


def _time(fn, *args, iters=REPEATS):
    """Median-of-``iters`` wall time in us: one warmup call (compile +
    first touch), then every sample individually device-complete."""
    jax.block_until_ready(fn(*args))
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append(time.perf_counter() - t0)
    return float(np.median(samples)) * 1e6


def _median_throughput(measure, repeats=REPEATS):
    """Median windows/s over ``repeats`` full measurement passes."""
    return float(np.median([measure() for _ in range(repeats)]))


def lif_rows():
    p = LIFParams()
    rows = []
    for (t, n) in [(16, 32 * 32 * 16), (16, 2048), (32, 8 * 8 * 32)]:
        cur = jax.random.normal(jax.random.PRNGKey(0), (t, n)) * 0.8
        us_ref = _time(jax.jit(lambda c: lif_scan_ref(c, p)[0]), cur)
        us_k = _time(jax.jit(lambda c: lif_scan(c, p)[0]), cur)
        # HBM traffic model: reference scan writes/reads V (f32) every
        # step; fused kernel keeps V in VMEM.
        bytes_ref = t * n * (4 + 4 + 2 * 4)       # I read, S write, V rw
        bytes_fused = t * n * (4 + 4)             # I read, S write
        rows.append((f"lif_scan_T{t}_N{n}", us_k,
                     f"ref_us={us_ref:.0f};state_traffic_saving="
                     f"{bytes_ref / bytes_fused:.2f}x"))
    return rows


def ternary_rows():
    rows = []
    for (m, k, n) in [(1, 2048, 8192), (16, 4096, 4096)]:
        w = jax.random.normal(jax.random.PRNGKey(1), (k, n))
        x = jax.random.normal(jax.random.PRNGKey(2), (m, k))
        wp, sc = pack_ternary_weights(w)
        us_ref = _time(jax.jit(ternary_matmul_ref), x, wp, sc)
        us_k = _time(ternary_matmul, x, wp, sc)
        w_bytes_bf16 = k * n * 2
        w_bytes_packed = (k // 4) * n + n * 4
        rows.append((f"ternary_mm_{m}x{k}x{n}", us_k,
                     f"ref_us={us_ref:.0f};weight_bytes="
                     f"{w_bytes_bf16 / w_bytes_packed:.2f}x_smaller"))
    return rows


def fc_fusion_rows():
    """The fused synapse+LIF fc path vs the unfused matmul + LIF-scan
    path, at the full Table II fc shapes. Wall time is CPU-interpret
    noise; the structural win is the eliminated current round-trip:
    unfused writes + re-reads the (T, B, N) f32 current tensor in HBM,
    fused consumes currents in-VMEM the step they are produced."""
    p = LIFParams()
    rows = []
    for (t, b, k, n) in [(16, 8, 2048, 512), (16, 8, 512, 11)]:
        s = (jax.random.uniform(jax.random.PRNGKey(0), (t, b, k))
             < 0.2).astype(jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), (k, n)) / np.sqrt(k)
        us_unfused = _time(
            jax.jit(lambda s, w: lif_scan(jnp.matmul(s, w), p)[0]), s, w)
        us_fused = _time(
            jax.jit(lambda s, w: fc_lif_scan(s, w, p)[0]), s, w)
        current_bytes = 2 * t * b * n * 4          # write + read back
        rows.append((f"fc_lif_fused_T{t}B{b}_{k}x{n}", us_fused,
                     f"unfused_us={us_unfused:.0f};hbm_current_traffic_"
                     f"eliminated={current_bytes / 1e6:.2f}MB"))
    return rows


def stream_rows(batch_sizes=(1, 2, 4, 8), windows_per_stream=16,
                repeats=REPEATS, out_json="BENCH_stream.json",
                fuse_fc=True, pipeline_depth=1):
    """Closed-loop throughput: looped single-window pipeline vs the batched
    StreamEngine at several batch sizes (B streams, fixed slots).

    The batched engine runs this PR's serving hot path: fused synapse+LIF
    fc kernels (``fuse_fc``) and the pipelined step (``pipeline_depth``).
    Each (b, side) cell gets a full warmup pass (compiles every shape
    bucket) up front; the ``repeats`` timed passes are then INTERLEAVED
    round-robin across every cell -- machine-speed drift over the bench's
    wall time lands evenly on all rows instead of skewing late rows
    against early ones -- and each cell reports its median."""
    cfg = SNNConfig(height=32, width=32, time_bins=8, conv1_features=4,
                    conv2_features=8, hidden=32, num_classes=11)
    params = init_snn(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    max_b = max(batch_sizes)
    windows = {
        s: [ev.synthetic_gesture_events(rng, (s + k) % 11, mean_events=3000,
                                        height=32, width=32)
            for k in range(windows_per_stream)]
        for s in range(max_b)
    }

    def looped_cell(b):
        pipe = ClosedLoopPipeline(params, cfg)
        work = [w for s in range(b) for w in windows[s]]
        for w in work:          # warm-up: compile every event bucket
            pipe(w)

        def measure():
            t0 = time.perf_counter()
            for w in work:
                pipe(w)
            return len(work) / (time.perf_counter() - t0)

        return measure

    def batched_cell(b):
        eng = StreamEngine(params, cfg, EngineConfig(
            max_streams=b, fuse_fc=fuse_fc,
            pipeline_depth=pipeline_depth))
        handles = {s: eng.open(stream_id=s) for s in range(b)}

        def submit_all():
            for s in range(b):
                for w in windows[s]:
                    handles[s].submit(w)

        submit_all()            # warm-up: compile the (B, bucket) shapes
        eng.run()

        def measure():
            submit_all()
            t0 = time.perf_counter()
            n = len(eng.run())
            return n / (time.perf_counter() - t0)

        return measure

    cells = {b: (looped_cell(b), batched_cell(b)) for b in batch_sizes}
    samples = {b: ([], []) for b in batch_sizes}
    for _ in range(repeats):
        for b in batch_sizes:
            looped, batched = cells[b]
            samples[b][0].append(looped())
            samples[b][1].append(batched())

    rows, artifact = [], []
    for b in batch_sizes:
        wps_loop = float(np.median(samples[b][0]))
        wps_batch = float(np.median(samples[b][1]))
        speedup = wps_batch / wps_loop
        rows.append((f"stream_closed_loop_B{b}", 1e6 / wps_batch,
                     f"batched_wps={wps_batch:.1f};looped_wps="
                     f"{wps_loop:.1f};speedup={speedup:.2f}x"))
        artifact.append({"batch_size": b,
                         "windows_per_stream": windows_per_stream,
                         "looped_windows_per_s": wps_loop,
                         "batched_windows_per_s": wps_batch,
                         "speedup": speedup})
    if out_json:
        with open(out_json, "w") as f:
            json.dump({"benchmark": "stream_closed_loop",
                       "config": "SNNConfig(32x32, T=8, reduced)",
                       "methodology": {
                           "warmup": "one full pass per (batch, side) cell",
                           "timing": f"median of {repeats} passes, "
                                     "interleaved round-robin across "
                                     "cells",
                           "batched_engine": {
                               "fuse_fc": fuse_fc,
                               "pipeline_depth": pipeline_depth,
                           },
                       },
                       "rows": artifact}, f, indent=2)
    return rows


def stateful_rows(batch_sizes=(1, 4, 8), windows_per_stream=16,
                  repeats=REPEATS, out_json="BENCH_stream.json",
                  fuse_fc=True, pipeline_depth=1):
    """Stateful vs stateless serving throughput (windows/s) at several
    batch sizes: the same StreamEngine hot path (fused fc, pipelined
    step), with every stream either carrying its LIF membranes across
    windows (``stateful=True``) or resetting per window (the default).

    The state plumbing is designed to be free on the hot path -- a lane
    with no stateful streams is served through the legacy stateless call
    forms untouched, and a stable stateful assignment takes the identity
    fast path -- so the ratio
    (stateful / stateless) should sit at ~1.0; the regression gate
    (``benchmarks/check_regression.py``) holds it above 0.95. Results
    are appended to the ``stream_rows`` artifact under
    ``stateful_rows``.
    """
    cfg = SNNConfig(height=32, width=32, time_bins=8, conv1_features=4,
                    conv2_features=8, hidden=32, num_classes=11)
    params = init_snn(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    max_b = max(batch_sizes)
    windows = {
        s: [ev.synthetic_gesture_events(rng, (s + k) % 11, mean_events=3000,
                                        height=32, width=32)
            for k in range(windows_per_stream)]
        for s in range(max_b)
    }

    def cell(b, stateful):
        eng = StreamEngine(params, cfg, EngineConfig(
            max_streams=b, fuse_fc=fuse_fc,
            pipeline_depth=pipeline_depth))
        handles = {s: eng.open(stream_id=s, stateful=stateful)
                   for s in range(b)}

        def submit_all():
            for s in range(b):
                for w in windows[s]:
                    handles[s].submit(w)

        submit_all()            # warm-up: compile the (B, bucket) shapes
        eng.run()

        def measure():
            submit_all()
            t0 = time.perf_counter()
            n = len(eng.run())
            return n / (time.perf_counter() - t0)

        return measure

    cells = {b: (cell(b, False), cell(b, True)) for b in batch_sizes}
    samples = {b: ([], []) for b in batch_sizes}
    for _ in range(repeats):
        for b in batch_sizes:
            stateless, stateful = cells[b]
            samples[b][0].append(stateless())
            samples[b][1].append(stateful())

    rows, artifact = [], []
    for b in batch_sizes:
        wps_less = float(np.median(samples[b][0]))
        wps_full = float(np.median(samples[b][1]))
        ratio = wps_full / wps_less
        rows.append((f"stream_stateful_B{b}", 1e6 / wps_full,
                     f"stateful_wps={wps_full:.1f};stateless_wps="
                     f"{wps_less:.1f};ratio={ratio:.3f}"))
        artifact.append({"batch_size": b,
                         "windows_per_stream": windows_per_stream,
                         "stateless_windows_per_s": wps_less,
                         "stateful_windows_per_s": wps_full,
                         "stateful_over_stateless": ratio})
    if out_json:
        try:
            with open(out_json) as f:
                doc = json.load(f)
        except FileNotFoundError:
            doc = {"benchmark": "stream_closed_loop"}
        doc["stateful_rows"] = artifact
        with open(out_json, "w") as f:
            json.dump(doc, f, indent=2)
    return rows


def fusion_rows(session_counts=(1, 2, 4), ticks_per_session=8,
                repeats=REPEATS, out_json="BENCH_stream.json"):
    """Cross-modal fusion throughput: fused event+frame streams (one
    FusionSession per sensor head, both wings in ONE StreamEngine with
    co-scheduled ticks, the cross-wing megastep, and depth-1 pipelined
    dispatch -- one fused jit'd call per step) vs the same workload with
    the two wings served SEPARATELY (an event-only and a frame-only
    engine run back to back, the pre-fusion serving shape). A tick = one
    event window + one frame window + the late-logit fuse; the ratio
    (fused / separate) is what the fusion fast path buys over decoupled
    wings and is gated by ``check_regression`` both against the baseline
    and with a runner-independent fresh-only floor (>= 1.1 at >= 2
    sessions). Swept over session counts; appended to the
    ``stream_rows`` artifact under ``fusion_rows``."""
    scfg = SNNConfig(height=32, width=32, time_bins=8, conv1_features=4,
                     conv2_features=8, hidden=32, num_classes=11)
    tcfg = TCNConfig(height=32, width=32, conv1_features=4,
                     conv2_features=8, hidden=32, num_classes=11)
    snn_params = init_snn(jax.random.PRNGKey(0), scfg)
    tcn_params = init_tcn(jax.random.PRNGKey(1), tcfg)
    rows, artifact = [], []
    for sessions in session_counts:
        rng = np.random.default_rng(0)
        ticks = {s: [(ev.synthetic_gesture_events(rng, (s + k) % 11,
                                                  mean_events=3000,
                                                  height=32, width=32),
                      fr.synthetic_gesture_frames(rng, (s + k) % 11,
                                                  height=32, width=32))
                     for k in range(ticks_per_session)]
                 for s in range(sessions)}
        n_ticks = sessions * ticks_per_session

        def fused_cell():
            eng = StreamEngine(
                engines=[BatchedClosedLoop(snn_params, scfg),
                         FrameTCNEngine(tcn_params, tcfg)],
                config=EngineConfig(max_streams=sessions, megastep=True,
                                    pipeline_depth=1))
            sess = {s: FusionSession(eng, session_id=f"head{s}")
                    for s in range(sessions)}

            def submit_all():
                for s in range(sessions):
                    for ev_w, fr_w in ticks[s]:
                        sess[s].submit(ev_w, fr_w)

            def drain_all():
                # One engine drain; rows routed across the sharing
                # sessions (each absorb() keeps its own rows, hands the
                # rest on).
                rows_ = eng.run()
                n = 0
                for s in sess.values():
                    rows_ = s.absorb(rows_)
                    n += len(s.drain())
                assert not rows_
                return n

            submit_all()        # warm-up: compile the fused megastep
            drain_all()

            def measure():
                submit_all()
                t0 = time.perf_counter()
                n = drain_all()
                assert n == n_ticks
                return n / (time.perf_counter() - t0)

            return measure

        def separate_cell():
            ev_eng = StreamEngine(
                engines=[BatchedClosedLoop(snn_params, scfg)],
                config=EngineConfig(max_streams=sessions))
            fr_eng = StreamEngine(
                engines=[FrameTCNEngine(tcn_params, tcfg)],
                config=EngineConfig(max_streams=sessions))
            ev_h = {s: ev_eng.open(stream_id=f"dvs{s}")
                    for s in range(sessions)}
            fr_h = {s: fr_eng.open(stream_id=f"cam{s}")
                    for s in range(sessions)}

            def submit_all():
                for s in range(sessions):
                    for ev_w, fr_w in ticks[s]:
                        ev_h[s].submit(ev_w)
                        fr_h[s].submit(fr_w)

            submit_all()        # warm-up
            ev_eng.run()
            fr_eng.run()

            def measure():
                submit_all()
                t0 = time.perf_counter()
                n = len(ev_eng.run())
                n_f = len(fr_eng.run())
                assert n == n_f == n_ticks
                return n / (time.perf_counter() - t0)

            return measure

        cells = (fused_cell(), separate_cell())
        samples = ([], [])
        for _ in range(repeats):
            samples[0].append(cells[0]())
            samples[1].append(cells[1]())

        tps_fused = float(np.median(samples[0]))
        tps_sep = float(np.median(samples[1]))
        ratio = tps_fused / tps_sep
        rows.append((f"stream_fusion_S{sessions}", 1e6 / tps_fused,
                     f"fused_tps={tps_fused:.1f};"
                     f"separate_tps={tps_sep:.1f};ratio={ratio:.3f}"))
        artifact.append({"sessions": sessions,
                         "ticks_per_session": ticks_per_session,
                         "separate_ticks_per_s": tps_sep,
                         "fused_ticks_per_s": tps_fused,
                         "fused_over_separate": ratio})
    if out_json:
        try:
            with open(out_json) as f:
                doc = json.load(f)
        except FileNotFoundError:
            doc = {"benchmark": "stream_closed_loop"}
        doc["fusion_rows"] = artifact
        with open(out_json, "w") as f:
            json.dump(doc, f, indent=2)
    return rows


def hetero_rows(slots=4, windows_per_stream=8,
                out_json="BENCH_hetero.json",
                stream_json="BENCH_stream.json"):
    """Unified-engine throughput: the event-SNN wing vs the frame-TCN wing
    (each alone on its own StreamEngine), and both mixed in one engine
    (one jit'd call per wing per step). ``mixed_over_serial`` compares
    the mixed engine against serving the same two-wing workload serially
    (the harmonic mean of the per-wing rates); it is folded into the
    ``BENCH_stream.json`` artifact as a ``hetero_rows`` cell so
    ``check_regression`` gates the mixed-fleet path."""
    scfg = SNNConfig(height=32, width=32, time_bins=8, conv1_features=4,
                     conv2_features=8, hidden=32, num_classes=11)
    tcfg = TCNConfig(height=32, width=32, conv1_features=4,
                     conv2_features=8, hidden=32, num_classes=11)
    snn_params = init_snn(jax.random.PRNGKey(0), scfg)
    tcn_params = init_tcn(jax.random.PRNGKey(1), tcfg)
    rng = np.random.default_rng(0)
    events = {s: [ev.synthetic_gesture_events(rng, (s + k) % 11,
                                              mean_events=3000,
                                              height=32, width=32)
                  for k in range(windows_per_stream)]
              for s in range(slots)}
    frames_ = {s: [fr.synthetic_gesture_frames(rng, (s + k) % 11,
                                               height=32, width=32)
                   for k in range(windows_per_stream)]
               for s in range(slots)}

    def run(engine_sets, submits):
        eng = StreamEngine(engines=engine_sets,
                           config=EngineConfig(max_streams=slots))
        handles = {sid: eng.open(modality=modality, stream_id=sid)
                   for sid, modality, _ in submits}

        def submit_all():
            for sid, _, ws in submits:
                for w in ws:
                    handles[sid].submit(w)

        submit_all()                          # warm-up: compile
        eng.run()

        def measure():
            submit_all()
            t0 = time.perf_counter()
            n = len(eng.run())
            return n / (time.perf_counter() - t0)

        return _median_throughput(measure)

    mk_event = lambda: BatchedClosedLoop(snn_params, scfg)
    mk_frame = lambda: FrameTCNEngine(tcn_params, tcfg)
    ev_subs = [(f"dvs{s}", "event", events[s]) for s in range(slots)]
    fr_subs = [(f"cam{s}", "frame", frames_[s]) for s in range(slots)]

    wps_event = run([mk_event()], ev_subs)
    wps_frame = run([mk_frame()], fr_subs)
    wps_mixed = run([mk_event(), mk_frame()], ev_subs + fr_subs)
    # Serving the mixed workload serially (all-event then all-frame)
    # moves windows at the harmonic mean of the per-wing rates; the
    # mixed engine should beat it by stepping both wings per round.
    wps_serial = 2.0 / (1.0 / wps_event + 1.0 / wps_frame)
    mixed_over_serial = wps_mixed / wps_serial

    rows = [
        ("hetero_event_snn", 1e6 / wps_event, f"wps={wps_event:.1f}"),
        ("hetero_frame_tcn", 1e6 / wps_frame, f"wps={wps_frame:.1f}"),
        ("hetero_mixed", 1e6 / wps_mixed,
         f"wps={wps_mixed:.1f};both_engines_per_step;"
         f"mixed_over_serial={mixed_over_serial:.3f}"),
    ]
    cell = {"slots_per_engine": slots,
            "windows_per_stream": windows_per_stream,
            "event_windows_per_s": wps_event,
            "frame_windows_per_s": wps_frame,
            "mixed_windows_per_s": wps_mixed,
            "mixed_over_serial": mixed_over_serial}
    if out_json:
        with open(out_json, "w") as f:
            json.dump({"benchmark": "hetero_engines", **cell}, f, indent=2)
    if stream_json:
        try:
            with open(stream_json) as f:
                doc = json.load(f)
        except FileNotFoundError:
            doc = {"benchmark": "stream_closed_loop"}
        doc["hetero_rows"] = [cell]
        with open(stream_json, "w") as f:
            json.dump(doc, f, indent=2)
    return rows


def fleet_rows(streams=4, windows_per_stream=6, repeats=REPEATS,
               out_json="BENCH_stream.json"):
    """Fleet control-plane cell: a deliberately skewed two-engine fleet
    (a hot 2-slot engine opens every deadlined stateful stream with all
    windows queued up front; a cold 4-slot engine idles) served twice
    under a shared logical clock -- static placement vs a
    ``FleetRebalancer`` live-migrating deep-queue streams hot-to-cold
    through the checkpoint store.

    Deadline-miss rates are measured on the logical clock (one tick per
    scheduling round), so they are DETERMINISTIC -- the regression gate
    checks ``rebalanced_miss_rate <= static_miss_rate`` on the fresh
    artifact alone. Wall-clock metrics (fleet windows/s and per-migration
    cost in ms) follow the usual methodology: one warmup pass per side,
    then ``repeats`` interleaved timed passes, medians reported; the
    rebalanced-over-static throughput ratio is the runner-independent
    fallback. Appended to the ``stream_rows`` artifact under
    ``fleet_rows``."""
    cfg = SNNConfig(height=32, width=32, time_bins=8, conv1_features=4,
                    conv2_features=8, hidden=32, num_classes=11)
    params = init_snn(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    windows = {
        f"p{s}": [ev.synthetic_gesture_events(rng, (s + k) % 11,
                                              mean_events=3000,
                                              height=32, width=32)
                  for k in range(windows_per_stream)]
        for s in range(streams)
    }
    n_total = streams * windows_per_stream

    def serve(rebalance):
        hot = StreamEngine(params, cfg, EngineConfig(
            max_streams=2, policy=DeadlinePolicy(fair_quantum=2)))
        cold = StreamEngine(params, cfg, EngineConfig(
            max_streams=4, policy=DeadlinePolicy(fair_quantum=2)))
        tick = [0]
        for e in (hot, cold):
            e.deadline_clock = lambda: float(tick[0])
        for sid in sorted(windows):
            h = hot.open(stream_id=sid, stateful=True)
            for k, w in enumerate(windows[sid]):
                h.submit(w, deadline=2.0 + 1.0 * k)
        reb = FleetRebalancer(
            {"hot": hot, "cold": cold}, store=CheckpointStore(),
            config=FleetConfig(imbalance=1.0, cooldown=1),
        ) if rebalance else None
        n = 0
        t0 = time.perf_counter()
        while hot.pending() or cold.pending():
            n += len(hot.step())
            n += len(cold.step())
            tick[0] += 1
            if reb is not None:
                n += len(reb.observe().displaced)
        wall = time.perf_counter() - t0
        assert n == n_total
        dated = missed = 0
        for e in (hot, cold):
            for st in e.stream_stats.values():
                dated += st.deadline_windows
                missed += st.deadline_missed
        mig_ms = [m.migration_ms for m in reb.migrations] if reb else []
        return n / wall, missed / dated, mig_ms

    serve(False)                 # warm-up: compile the hot lane's shapes
    serve(True)                  # warm-up: compile the cold lane's too
    s_static, s_rebal, mig_ms = [], [], []
    static_miss = rebal_miss = 0.0
    n_migrations = 0
    for _ in range(repeats):
        wps, static_miss, _ = serve(False)
        s_static.append(wps)
        wps, rebal_miss, ms = serve(True)
        s_rebal.append(wps)
        n_migrations = len(ms)
        mig_ms.extend(ms)

    wps_static = float(np.median(s_static))
    wps_rebal = float(np.median(s_rebal))
    ratio = wps_rebal / wps_static
    m_ms = float(np.median(mig_ms)) if mig_ms else 0.0
    rows = [(f"fleet_rebalance_S{streams}", 1e6 / wps_rebal,
             f"static_miss={static_miss:.3f};"
             f"rebalanced_miss={rebal_miss:.3f};"
             f"migration_ms={m_ms:.2f};migrations={n_migrations}")]
    artifact = [{"engines": 2, "streams": streams,
                 "windows_per_stream": windows_per_stream,
                 "static_miss_rate": static_miss,
                 "rebalanced_miss_rate": rebal_miss,
                 "static_windows_per_s": wps_static,
                 "rebalanced_windows_per_s": wps_rebal,
                 "rebalanced_over_static": ratio,
                 "migrations": n_migrations,
                 "migration_ms": m_ms}]
    if out_json:
        try:
            with open(out_json) as f:
                doc = json.load(f)
        except FileNotFoundError:
            doc = {"benchmark": "stream_closed_loop"}
        doc["fleet_rows"] = artifact
        with open(out_json, "w") as f:
            json.dump(doc, f, indent=2)
    return rows


# Self-contained child program for one sharded_rows cell: serve the
# standard stream workload on a mesh over every forced host device and
# print the measured windows/s as JSON. Runs in a SUBPROCESS because
# device count is fixed at jax init by XLA_FLAGS.
_SHARDED_CELL = """
import json, time
import numpy as np, jax
from repro.core import EngineConfig, SNNConfig, init_snn
from repro.core import events as ev
from repro.serving import StreamEngine
from repro.distributed import make_mesh

devices, slots, wps_count, repeats = {devices}, {slots}, {wpstream}, {repeats}
cfg = SNNConfig(height=32, width=32, time_bins=8, conv1_features=4,
                conv2_features=8, hidden=32, num_classes=11)
params = init_snn(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)
windows = {{s: [ev.synthetic_gesture_events(rng, (s + k) % 11,
                                            mean_events=3000,
                                            height=32, width=32)
                for k in range(wps_count)]
            for s in range(slots)}}
mesh = make_mesh(devices) if devices else None
eng = StreamEngine(params, cfg, EngineConfig(
    max_streams=slots, fuse_fc=True, pipeline_depth=1, mesh=mesh))
handles = {{s: eng.open(stream_id=s, stateful=True)
            for s in range(slots)}}

def submit_all():
    for s in range(slots):
        for w in windows[s]:
            handles[s].submit(w)

submit_all()
eng.run()                               # warm-up: compile
samples = []
for _ in range(repeats):
    submit_all()
    t0 = time.perf_counter()
    n = len(eng.run())
    samples.append(n / (time.perf_counter() - t0))
print(json.dumps({{"devices": devices,
                   "windows_per_s": float(np.median(samples))}}))
"""


def sharded_rows(device_counts=(1, 2, 4), slots=8, windows_per_stream=8,
                 repeats=REPEATS, out_json="BENCH_stream.json"):
    """Sharded serving throughput (windows/s) vs device count at B=8.

    Each cell is a fresh subprocess forcing ``device_counts[i]`` host
    devices (``XLA_FLAGS=--xla_force_host_platform_device_count``) and
    serving the standard stateful pipelined workload with the slot axis
    sharded over ``make_mesh(d)``; ``devices=1`` is the baseline mesh.

    CAVEAT (recorded in the artifact): forced host devices time-slice
    ONE physical CPU, so windows/s does not scale with d here -- the
    cell measures the sharded step's overhead (it must stay within tol
    of single-device), while real slot-axis scaling needs real devices.
    The regression gate holds each ``sharded_over_single`` ratio.
    """
    import subprocess
    import sys

    results = {}
    for d in device_counts:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={d}"
        code = _SHARDED_CELL.format(devices=d, slots=slots,
                                    wpstream=windows_per_stream,
                                    repeats=repeats)
        out = subprocess.run([sys.executable, "-c", code],
                             capture_output=True, text=True, env=env,
                             timeout=900)
        if out.returncode != 0:
            raise RuntimeError(
                f"sharded cell d={d} failed:\n{out.stderr[-2000:]}")
        results[d] = json.loads(out.stdout.strip().splitlines()[-1])

    base_wps = results[min(device_counts)]["windows_per_s"]
    rows, artifact = [], []
    for d in device_counts:
        wps = results[d]["windows_per_s"]
        ratio = wps / base_wps
        rows.append((f"stream_sharded_D{d}", 1e6 / wps,
                     f"wps={wps:.1f};sharded_over_single={ratio:.3f};"
                     f"forced_host_devices"))
        artifact.append({"devices": d, "batch_size": slots,
                         "windows_per_stream": windows_per_stream,
                         "windows_per_s": wps,
                         "sharded_over_single": ratio})
    if out_json:
        try:
            with open(out_json) as f:
                doc = json.load(f)
        except FileNotFoundError:
            doc = {"benchmark": "stream_closed_loop"}
        doc["sharded_rows"] = artifact
        doc["sharded_caveat"] = (
            "forced host devices share one physical CPU: windows/s "
            "measures sharded-step overhead, not slot-axis scaling")
        with open(out_json, "w") as f:
            json.dump(doc, f, indent=2)
    return rows


def fault_rows(streams=2, windows_per_stream=8, fault_rate=0.05,
               repeats=REPEATS, out_json="BENCH_stream.json"):
    """Fault-tolerance cell: stateful serving throughput with the
    recovery layer on, at injected fault-rate 0 vs ``fault_rate``
    (seeded step errors through a :class:`~repro.fleet.faults.
    FaultInjector`), plus the median recovery cost in engine steps.

    The fault schedule is seeded and drawn in call order, and backoff
    counts logical engine steps, so the retry/quarantine counters and
    recovery-tick metrics are DETERMINISTIC on any runner -- the
    regression gate enforces them on the fresh artifact alone (>=1
    retry at 5%%, zero recovery events at 0%%). Wall-clock throughput
    follows the usual methodology (warmup, medians of ``repeats``),
    with the faulted-over-clean ratio as the runner-independent
    fallback. Appended to the ``stream_rows`` artifact under
    ``fault_rows``."""
    from repro.core._api import FaultConfig, RecoveryConfig
    from repro.fleet import FaultInjector

    cfg = SNNConfig(height=32, width=32, time_bins=8, conv1_features=4,
                    conv2_features=8, hidden=32, num_classes=11)
    params = init_snn(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    windows = {
        s: [ev.synthetic_gesture_events(rng, (s + k) % 11,
                                        mean_events=3000,
                                        height=32, width=32)
            for k in range(windows_per_stream)]
        for s in range(streams)
    }
    n_total = streams * windows_per_stream
    config = EngineConfig(
        max_streams=streams,
        recovery=RecoveryConfig(max_retries=4, backoff_steps=1,
                                dead_after=100))

    def serve(rate):
        inj = FaultInjector(FaultConfig(seed=7, step_error_rate=rate))
        eng = StreamEngine(
            engines=[inj.wrap(BatchedClosedLoop.from_config(
                params, cfg, config))],
            config=config)
        handles = {s: eng.open(modality="event", stream_id=s,
                               stateful=True)
                   for s in range(streams)}
        for s in range(streams):
            for w in windows[s]:
                handles[s].submit(w)
        # landed[(sid, seq)] = engine step at which the result emitted;
        # with the fault_log's per-failure steps this yields the
        # recovery cost of every retried window in logical steps.
        landed, step = {}, 0
        t0 = time.perf_counter()
        while eng.pending() or eng._inflight:
            step += 1
            for r in eng.step():
                if r.ok:
                    landed[(r.stream_id, r.seq)] = step
        wall = time.perf_counter() - t0
        assert len(landed) == n_total       # no quarantine at this seed
        first_fail = {}
        for f in eng.fault_log:
            if f["kind"] == "retry":
                first_fail.setdefault((f["stream"], f["seq"]), f["step"])
        recovery = [landed[k] - s for k, s in first_fail.items()]
        tel = eng.telemetry("event")
        return (n_total / wall, tel.retries, tel.quarantined,
                float(np.median(recovery)) if recovery else 0.0)

    serve(0.0)                       # warm-up: compile
    s_clean, s_fault = [], []
    retries = quarantined = 0
    recovery_ticks = 0.0
    for _ in range(repeats):
        wps, r0, q0, _ = serve(0.0)
        s_clean.append(wps)
        assert r0 == 0 and q0 == 0   # rate 0 engages no recovery
        wps, retries, quarantined, recovery_ticks = serve(fault_rate)
        s_fault.append(wps)

    wps_clean = float(np.median(s_clean))
    wps_fault = float(np.median(s_fault))
    ratio = wps_fault / wps_clean
    rows = [(f"fault_recovery_r{fault_rate:g}", 1e6 / wps_fault,
             f"clean_wps={wps_clean:.1f};faulted_wps={wps_fault:.1f};"
             f"retries={retries};recovery_ticks={recovery_ticks:.1f}")]
    artifact = [{"streams": streams,
                 "windows_per_stream": windows_per_stream,
                 "fault_rate": fault_rate,
                 "clean_windows_per_s": wps_clean,
                 "faulted_windows_per_s": wps_fault,
                 "faulted_over_clean": ratio,
                 "retries": int(retries),
                 "quarantined": int(quarantined),
                 "recovery_ticks_median": recovery_ticks}]
    if out_json:
        try:
            with open(out_json) as f:
                doc = json.load(f)
        except FileNotFoundError:
            doc = {"benchmark": "stream_closed_loop"}
        doc["fault_rows"] = artifact
        with open(out_json, "w") as f:
            json.dump(doc, f, indent=2)
    return rows


def main():
    for name, us, derived in (lif_rows() + ternary_rows() + fc_fusion_rows()
                              + stream_rows() + stateful_rows()
                              + fusion_rows() + fleet_rows()
                              + hetero_rows() + sharded_rows()
                              + fault_rows()):
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
