"""Kernel micro-benchmarks (CPU wall time of interpret/jnp paths + the
structural VMEM/bandwidth accounting that motivates each kernel on TPU).

On this CPU container wall-clock numbers only sanity-check the harness;
the meaningful output is the bytes model: lif_scan's state-traffic saving
and ternary_matmul's 8x weight-byte reduction, both derived from shapes.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lif import LIFParams
from repro.kernels import (lif_scan, lif_scan_ref, pack_ternary_weights,
                           ternary_matmul, ternary_matmul_ref)


def _time(fn, *args, iters=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def lif_rows():
    p = LIFParams()
    rows = []
    for (t, n) in [(16, 32 * 32 * 16), (16, 2048), (32, 8 * 8 * 32)]:
        cur = jax.random.normal(jax.random.PRNGKey(0), (t, n)) * 0.8
        us_ref = _time(jax.jit(lambda c: lif_scan_ref(c, p)[0]), cur)
        us_k = _time(jax.jit(lambda c: lif_scan(c, p)[0]), cur)
        # HBM traffic model: reference scan writes/reads V (f32) every
        # step; fused kernel keeps V in VMEM.
        bytes_ref = t * n * (4 + 4 + 2 * 4)       # I read, S write, V rw
        bytes_fused = t * n * (4 + 4)             # I read, S write
        rows.append((f"lif_scan_T{t}_N{n}", us_k,
                     f"ref_us={us_ref:.0f};state_traffic_saving="
                     f"{bytes_ref / bytes_fused:.2f}x"))
    return rows


def ternary_rows():
    rows = []
    for (m, k, n) in [(1, 2048, 8192), (16, 4096, 4096)]:
        w = jax.random.normal(jax.random.PRNGKey(1), (k, n))
        x = jax.random.normal(jax.random.PRNGKey(2), (m, k))
        wp, sc = pack_ternary_weights(w)
        us_ref = _time(jax.jit(ternary_matmul_ref), x, wp, sc)
        us_k = _time(ternary_matmul, x, wp, sc)
        w_bytes_bf16 = k * n * 2
        w_bytes_packed = (k // 4) * n + n * 4
        rows.append((f"ternary_mm_{m}x{k}x{n}", us_k,
                     f"ref_us={us_ref:.0f};weight_bytes="
                     f"{w_bytes_bf16 / w_bytes_packed:.2f}x_smaller"))
    return rows


def main():
    for name, us, derived in lif_rows() + ternary_rows():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
