"""Benchmark driver: one function per paper table + kernel + roofline.

Prints ``name,us_per_call,derived`` CSV sections. Roofline rows are read
from the dry-run artifacts when present (run ``python -m
repro.launch.dryrun`` first for the full 33-cell table).
"""
from __future__ import annotations

import time


def _section(title):
    print(f"\n## {title}")


def main() -> None:
    t0 = time.perf_counter()

    _section("table3_breakdown (paper Table III)")
    from benchmarks import table3_breakdown
    table3_breakdown.main()

    _section("table1_comparison (paper Table I)")
    from benchmarks import table1_comparison
    table1_comparison.main()

    _section("kernel_bench (SNE lif_scan + CUTIE ternary_matmul)")
    from benchmarks import kernel_bench
    kernel_bench.main()

    _section("roofline (from dry-run artifacts)")
    from benchmarks import roofline
    try:
        rows = roofline.load_all()
        if not rows:
            print("no dry-run artifacts found; run "
                  "`PYTHONPATH=src python -m repro.launch.dryrun`")
        for r in rows:
            print(f"{r['arch']}__{r['shape']},0,"
                  f"dominant={r['dominant']};frac="
                  f"{r['roofline_fraction']:.3f};useful="
                  f"{r['useful_ratio']:.2f}")
    except Exception as e:
        print(f"roofline unavailable: {e}")

    print(f"\n# benchmarks done in {time.perf_counter() - t0:.1f}s")


if __name__ == "__main__":
    main()
