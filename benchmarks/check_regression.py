"""Bench-regression gate: compare a fresh ``BENCH_stream.json`` against
the committed ``BENCH_baseline.json``.

Fails (exit 1) when the batched closed-loop throughput at the gated
batch size drops below ``tolerance`` x the committed baseline value.
Wall-clock numbers move with the runner, so two escape hatches keep the
gate honest about *code* regressions rather than machine speed:

  * the tolerance is deliberately loose (default 0.8x; per-row
    medians-of-5 with interleaved sampling keep the artifacts stable);
  * when the absolute floor is missed, the *batched-vs-looped speedup*
    ratio -- runner-independent, since a slower machine slows both
    sides -- is checked against the same tolerance; a uniformly slower
    runner passes with a warning, a genuine relative regression fails.

Usage (CI runs exactly this, after ``benchmarks.kernel_bench``):

    PYTHONPATH=src python -m benchmarks.check_regression
"""
from __future__ import annotations

import argparse
import json
import sys


def _row(doc: dict, batch_size: int) -> dict:
    for row in doc.get("rows", []):
        if row.get("batch_size") == batch_size:
            return row
    raise SystemExit(
        f"no batch_size={batch_size} row in {sorted(r.get('batch_size') for r in doc.get('rows', []))}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default="BENCH_baseline.json",
                    help="committed baseline artifact")
    ap.add_argument("--fresh", default="BENCH_stream.json",
                    help="freshly generated artifact to check")
    ap.add_argument("--batch-size", type=int, default=4,
                    help="gated batch size row")
    ap.add_argument("--tolerance", type=float, default=0.8,
                    help="fresh must be >= tolerance * baseline")
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        base = _row(json.load(f), args.batch_size)
    with open(args.fresh) as f:
        fresh = _row(json.load(f), args.batch_size)

    base_wps = float(base["batched_windows_per_s"])
    fresh_wps = float(fresh["batched_windows_per_s"])
    base_ratio = float(base["speedup"])
    fresh_ratio = float(fresh["speedup"])
    floor = args.tolerance * base_wps
    ratio_floor = args.tolerance * base_ratio
    print(f"batched windows/s @ B={args.batch_size}: "
          f"baseline={base_wps:.1f}  fresh={fresh_wps:.1f}  "
          f"floor={floor:.1f} ({args.tolerance:.2f}x)")
    print(f"batched-vs-looped speedup: baseline={base_ratio:.2f}x  "
          f"fresh={fresh_ratio:.2f}x  floor={ratio_floor:.2f}x")

    if fresh_wps >= floor:
        print("OK: no batched-throughput regression")
        return 0
    if fresh_ratio >= ratio_floor:
        print(f"WARN: absolute throughput below floor ({fresh_wps:.1f} < "
              f"{floor:.1f} windows/s) but the runner-independent "
              f"batched-vs-looped speedup holds ({fresh_ratio:.2f}x >= "
              f"{ratio_floor:.2f}x) -- slower machine, not a code "
              f"regression")
        return 0
    print(f"FAIL: fresh {fresh_wps:.1f} < floor {floor:.1f} windows/s "
          f"AND speedup {fresh_ratio:.2f}x < {ratio_floor:.2f}x -- "
          f"batched path regressed")
    return 1


if __name__ == "__main__":
    sys.exit(main())
