"""Bench-regression gate: compare a fresh ``BENCH_stream.json`` against
the committed ``BENCH_baseline.json``.

Fails (exit 1) when the batched closed-loop throughput at the gated
batch size drops below ``tolerance`` x the committed baseline value.
Wall-clock numbers move with the runner, so two escape hatches keep the
gate honest about *code* regressions rather than machine speed:

  * the tolerance is deliberately loose (default 0.8x; per-row
    medians-of-5 with interleaved sampling keep the artifacts stable);
  * when the absolute floor is missed, the *batched-vs-looped speedup*
    ratio -- runner-independent, since a slower machine slows both
    sides -- is checked against the same tolerance; a uniformly slower
    runner passes with a warning, a genuine relative regression fails.

The ``stateful_rows`` cell is gated the same way: absolute stateful
windows/s against the baseline, with the runner-independent
stateful-vs-stateless ratio as the fallback -- plus a hard floor on that
ratio itself (``--stateful-ratio-floor``, default 0.95): carried state
must cost less than 5% of stateless throughput on ANY runner, since both
sides of the ratio run on the same machine.

The ``fusion_rows`` cells (cross-modal FusionSession ticks/s, one row
per session count) follow the same pattern per row: absolute fused
ticks/s against the baseline, with the runner-independent
fused-vs-separate ratio (one co-scheduled megastep engine serving both
wings vs two single-wing engines, same machine) as the fallback -- PLUS
a hard fresh-only floor on that ratio itself (``--fusion-ratio-floor``,
default 1.1) at >= 2 sessions: fused serving must actually beat the
separate wings on ANY runner, since both sides run on the same machine.

The ``hetero_rows`` cell (mixed event+frame engine vs the per-wing
engines) is gated on absolute mixed windows/s against the baseline,
with the runner-independent mixed-over-serial ratio (the mixed engine
vs the harmonic mean of the two wings, same machine) as the fallback.

The ``sharded_rows`` cells (slot-axis-sharded serving at each device
count) are gated per device count: absolute windows/s against the
baseline with the runner-independent sharded-vs-single-device ratio as
the fallback -- forced host devices time-slice one CPU, so the ratio
measures sharded-step *overhead* (it must not collapse), not scaling.

The ``fault_rows`` cell (recovery-enabled serving at injected fault-rate
0 vs 5%) splits the same way as ``fleet_rows``: the retry/quarantine
counters come off a seeded injector drawing in call order, so they are
DETERMINISTIC on any runner and enforced on the FRESH artifact alone
(at least one retry at 5%, zero recovery events at 0%, bounded median
recovery ticks); the wall-clock side -- faulted windows/s -- is gated
against the baseline with the runner-independent faulted-over-clean
ratio as the fallback. A fresh run missing the cell FAILS.

The ``fleet_rows`` cell (static vs rebalanced two-engine fleet) splits
in two. Its deadline-miss rates are measured on a logical clock, so
``rebalanced_miss_rate <= static_miss_rate`` (with at least one real
migration) is enforced on the FRESH artifact alone, on any runner. The
wall-clock side -- fleet windows/s under rebalancing -- is gated against
the baseline with the runner-independent rebalanced-over-static
throughput ratio as the fallback.

Usage (CI runs exactly this, after ``benchmarks.kernel_bench``):

    PYTHONPATH=src python -m benchmarks.check_regression
"""
from __future__ import annotations

import argparse
import json
import sys


def _row(doc: dict, batch_size: int, key: str = "rows") -> dict:
    for row in doc.get(key, []):
        if row.get("batch_size") == batch_size:
            return row
    raise SystemExit(
        f"no batch_size={batch_size} row in {key}="
        f"{sorted(r.get('batch_size') for r in doc.get(key, []))}")


def _gate(name: str, base_abs: float, fresh_abs: float,
          base_ratio: float, fresh_ratio: float, ratio_name: str,
          tolerance: float) -> bool:
    """Absolute floor with runner-independent ratio fallback; returns
    True when the cell passes."""
    floor = tolerance * base_abs
    ratio_floor = tolerance * base_ratio
    print(f"{name}: baseline={base_abs:.1f}  fresh={fresh_abs:.1f}  "
          f"floor={floor:.1f} ({tolerance:.2f}x)")
    print(f"{ratio_name}: baseline={base_ratio:.2f}x  "
          f"fresh={fresh_ratio:.2f}x  floor={ratio_floor:.2f}x")
    if fresh_abs >= floor:
        print(f"OK: no {name} regression")
        return True
    if fresh_ratio >= ratio_floor:
        print(f"WARN: {name} below floor ({fresh_abs:.1f} < {floor:.1f}) "
              f"but the runner-independent {ratio_name} holds "
              f"({fresh_ratio:.2f}x >= {ratio_floor:.2f}x) -- slower "
              f"machine, not a code regression")
        return True
    print(f"FAIL: {name} {fresh_abs:.1f} < floor {floor:.1f} AND "
          f"{ratio_name} {fresh_ratio:.2f}x < {ratio_floor:.2f}x -- "
          f"regressed")
    return False


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default="BENCH_baseline.json",
                    help="committed baseline artifact")
    ap.add_argument("--fresh", default="BENCH_stream.json",
                    help="freshly generated artifact to check")
    ap.add_argument("--batch-size", type=int, default=4,
                    help="gated batch size row")
    ap.add_argument("--tolerance", type=float, default=0.8,
                    help="fresh must be >= tolerance * baseline")
    ap.add_argument("--stateful-ratio-floor", type=float, default=0.95,
                    help="hard floor on fresh stateful/stateless "
                         "throughput (runner-independent)")
    ap.add_argument("--recovery-ticks-max", type=float, default=8.0,
                    help="bound on the fault cell's median recovery "
                         "cost in engine steps (deterministic)")
    ap.add_argument("--fusion-ratio-floor", type=float, default=1.1,
                    help="hard floor on fresh fused/separate ticks-per-s "
                         "at >= 2 sessions (runner-independent)")
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        base_doc = json.load(f)
    with open(args.fresh) as f:
        fresh_doc = json.load(f)

    base = _row(base_doc, args.batch_size)
    fresh = _row(fresh_doc, args.batch_size)
    ok = _gate(
        f"batched windows/s @ B={args.batch_size}",
        float(base["batched_windows_per_s"]),
        float(fresh["batched_windows_per_s"]),
        float(base["speedup"]), float(fresh["speedup"]),
        "batched-vs-looped speedup", args.tolerance)

    # The stateful serving cell. A fresh run missing it is a harness
    # regression and fails. The baseline-relative gate needs the cell in
    # both artifacts (a baseline predating stateful_rows only warns, so
    # the gate stays usable across the artifact transition) -- but the
    # hard runner-independent ratio floor needs only the FRESH run
    # (both sides of the ratio came off the same machine), so it is
    # enforced unconditionally.
    if "stateful_rows" not in fresh_doc:
        print("FAIL: fresh artifact has no stateful_rows cell")
        ok = False
    else:
        sfresh = _row(fresh_doc, args.batch_size, key="stateful_rows")
        fresh_ratio = float(sfresh["stateful_over_stateless"])
        if "stateful_rows" not in base_doc:
            print("WARN: baseline has no stateful_rows cell (predates "
                  "stateful streaming); skipping the baseline-relative "
                  "gate -- refresh the baseline")
        else:
            sbase = _row(base_doc, args.batch_size, key="stateful_rows")
            ok &= _gate(
                f"stateful windows/s @ B={args.batch_size}",
                float(sbase["stateful_windows_per_s"]),
                float(sfresh["stateful_windows_per_s"]),
                float(sbase["stateful_over_stateless"]), fresh_ratio,
                "stateful-vs-stateless ratio", args.tolerance)
        if fresh_ratio < args.stateful_ratio_floor:
            print(f"FAIL: stateful serving costs too much on this very "
                  f"runner: stateful/stateless {fresh_ratio:.3f} < "
                  f"{args.stateful_ratio_floor:.2f}")
            ok = False
        else:
            print(f"OK: stateful/stateless {fresh_ratio:.3f} >= "
                  f"{args.stateful_ratio_floor:.2f} (state carry is "
                  f"effectively free)")

    # The cross-modal fusion cells, one row per session count: a fresh
    # run missing them is a harness regression; a baseline predating
    # fusion_rows (or a swept session count) only warns (artifact
    # transition). The baseline-relative gate runs per session count
    # present in both artifacts -- but the hard fused-over-separate
    # floor needs only the FRESH run (both sides of the ratio came off
    # the same machine), so it is enforced unconditionally at >= 2
    # sessions (a single session cannot amortize the shared step).
    if "fusion_rows" not in fresh_doc:
        print("FAIL: fresh artifact has no fusion_rows cell")
        ok = False
    else:
        fresh_by_s = {int(r["sessions"]): r
                      for r in fresh_doc["fusion_rows"]}
        base_by_s = {int(r["sessions"]): r
                     for r in base_doc.get("fusion_rows", [])}
        if not base_by_s:
            print("WARN: baseline has no fusion_rows cell (predates "
                  "fusion serving); skipping the fusion gate -- "
                  "refresh the baseline")
        for s in sorted(fresh_by_s):
            ffresh = fresh_by_s[s]
            fresh_ratio = float(ffresh["fused_over_separate"])
            fbase = base_by_s.get(s)
            if fbase is None and base_by_s:
                print(f"WARN: baseline has no fusion_rows entry at "
                      f"S={s} (predates the session sweep); skipping "
                      f"its baseline-relative gate -- refresh the "
                      f"baseline")
            elif fbase is not None:
                ok &= _gate(
                    f"fused ticks/s @ S={s}",
                    float(fbase["fused_ticks_per_s"]),
                    float(ffresh["fused_ticks_per_s"]),
                    float(fbase["fused_over_separate"]), fresh_ratio,
                    "fused-vs-separate ratio", args.tolerance)
            if s < 2:
                continue
            if fresh_ratio < args.fusion_ratio_floor:
                print(f"FAIL: fused serving does not beat separate "
                      f"wings on this very runner at S={s}: "
                      f"fused/separate {fresh_ratio:.3f} < "
                      f"{args.fusion_ratio_floor:.2f}")
                ok = False
            else:
                print(f"OK: fused/separate {fresh_ratio:.3f} >= "
                      f"{args.fusion_ratio_floor:.2f} @ S={s} "
                      f"(co-scheduled megastep pays for itself)")

    # The mixed-fleet hetero cell: same transition policy (missing
    # fresh FAIL, missing baseline WARN); absolute mixed windows/s
    # against the baseline with the runner-independent
    # mixed-over-serial ratio (mixed engine vs the harmonic mean of
    # the two wings, both sides off the same machine) as the fallback.
    if "hetero_rows" not in fresh_doc:
        print("FAIL: fresh artifact has no hetero_rows cell")
        ok = False
    elif "hetero_rows" not in base_doc:
        print("WARN: baseline has no hetero_rows cell (predates the "
              "mixed-fleet gate); skipping the hetero gate -- refresh "
              "the baseline")
    else:
        hbase = base_doc["hetero_rows"][0]
        hfresh = fresh_doc["hetero_rows"][0]
        ok &= _gate(
            "hetero mixed windows/s",
            float(hbase["mixed_windows_per_s"]),
            float(hfresh["mixed_windows_per_s"]),
            float(hbase["mixed_over_serial"]),
            float(hfresh["mixed_over_serial"]),
            "mixed-over-serial ratio", args.tolerance)

    # The sharded serving cells: one row per forced-host-device count,
    # keyed on "devices" rather than "batch_size". Same transition
    # policy as the other cells (missing fresh FAIL, missing baseline
    # WARN); each device count present in both artifacts is gated on
    # absolute windows/s with the sharded-over-single ratio (both sides
    # off the same machine) as the runner-independent fallback.
    if "sharded_rows" not in fresh_doc:
        print("FAIL: fresh artifact has no sharded_rows cell")
        ok = False
    elif "sharded_rows" not in base_doc:
        print("WARN: baseline has no sharded_rows cell (predates "
              "slot-axis sharding); skipping the sharded gate -- "
              "refresh the baseline")
    else:
        base_by_d = {r["devices"]: r for r in base_doc["sharded_rows"]}
        fresh_by_d = {r["devices"]: r for r in fresh_doc["sharded_rows"]}
        for d in sorted(set(base_by_d) & set(fresh_by_d)):
            ok &= _gate(
                f"sharded windows/s @ D={d}",
                float(base_by_d[d]["windows_per_s"]),
                float(fresh_by_d[d]["windows_per_s"]),
                float(base_by_d[d]["sharded_over_single"]),
                float(fresh_by_d[d]["sharded_over_single"]),
                "sharded-over-single ratio", args.tolerance)

    # The fleet control-plane cell: same transition policy (missing
    # fresh FAIL, missing baseline WARN). The miss rates are measured
    # on a logical clock (deterministic on any runner), so the
    # rebalancer-beats-static check needs only the FRESH run and is
    # enforced unconditionally; the throughput gate is
    # baseline-relative with the rebalanced-over-static ratio (both
    # sides off the same machine) as the runner-independent fallback.
    if "fleet_rows" not in fresh_doc:
        print("FAIL: fresh artifact has no fleet_rows cell")
        ok = False
    else:
        lfresh = fresh_doc["fleet_rows"][0]
        s_miss = float(lfresh["static_miss_rate"])
        r_miss = float(lfresh["rebalanced_miss_rate"])
        if int(lfresh.get("migrations", 0)) < 1:
            print("FAIL: fleet cell recorded no migrations -- the "
                  "rebalancer never moved a stream (vacuous cell)")
            ok = False
        elif r_miss > s_miss:
            print(f"FAIL: rebalanced fleet misses MORE deadlines than "
                  f"static placement ({r_miss:.3f} > {s_miss:.3f})")
            ok = False
        else:
            print(f"OK: rebalanced miss rate {r_miss:.3f} <= static "
                  f"{s_miss:.3f} (live migration cost "
                  f"{float(lfresh['migration_ms']):.2f} ms)")
        if "fleet_rows" not in base_doc:
            print("WARN: baseline has no fleet_rows cell (predates the "
                  "fleet control plane); skipping the fleet throughput "
                  "gate -- refresh the baseline")
        else:
            lbase = base_doc["fleet_rows"][0]
            ok &= _gate(
                "fleet rebalanced windows/s",
                float(lbase["rebalanced_windows_per_s"]),
                float(lfresh["rebalanced_windows_per_s"]),
                float(lbase["rebalanced_over_static"]),
                float(lfresh["rebalanced_over_static"]),
                "rebalanced-over-static ratio", args.tolerance)

    # The fault-tolerance cell: same transition policy (missing fresh
    # FAIL, missing baseline WARN). The recovery counters are seeded
    # and step-counted (deterministic on any runner), so the
    # faults-were-exercised checks need only the FRESH run and are
    # enforced unconditionally; the throughput gate is
    # baseline-relative with the faulted-over-clean ratio (both sides
    # off the same machine) as the runner-independent fallback.
    if "fault_rows" not in fresh_doc:
        print("FAIL: fresh artifact has no fault_rows cell")
        ok = False
    else:
        tfresh = fresh_doc["fault_rows"][0]
        rate = float(tfresh["fault_rate"])
        retries = int(tfresh["retries"])
        rec_ticks = float(tfresh["recovery_ticks_median"])
        if retries < 1:
            print(f"FAIL: fault cell at rate {rate:g} recorded no "
                  f"retries -- the injector never engaged the recovery "
                  f"layer (vacuous cell)")
            ok = False
        elif rec_ticks > args.recovery_ticks_max:
            print(f"FAIL: median recovery cost {rec_ticks:.1f} engine "
                  f"steps > bound {args.recovery_ticks_max:.1f} -- "
                  f"retried windows take too long to land")
            ok = False
        else:
            print(f"OK: fault cell exercised {retries} retries at rate "
                  f"{rate:g}, median recovery {rec_ticks:.1f} steps "
                  f"(<= {args.recovery_ticks_max:.1f})")
        if "fault_rows" not in base_doc:
            print("WARN: baseline has no fault_rows cell (predates the "
                  "fault-tolerance layer); skipping the fault "
                  "throughput gate -- refresh the baseline")
        else:
            tbase = base_doc["fault_rows"][0]
            ok &= _gate(
                f"faulted windows/s @ rate={rate:g}",
                float(tbase["faulted_windows_per_s"]),
                float(tfresh["faulted_windows_per_s"]),
                float(tbase["faulted_over_clean"]),
                float(tfresh["faulted_over_clean"]),
                "faulted-over-clean ratio", args.tolerance)

    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
