"""Paper Table III: end-to-end closed-loop latency/energy breakdown.

Runs the actual pipeline (synthetic DVS window at the nominal event rate
-> voxelize -> Table II SCNN inference via the fused LIF path -> PWM) and
prints the per-stage time/power/energy table next to the paper's measured
values. The workload drivers (events, spike counts, TDM passes) come from
the simulation; the power/latency constants are the calibrated Kraken
model (core/energy.py).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import init_snn, NOMINAL, KrakenModel
from repro.core import events as ev
from repro.core.pipeline import ClosedLoopPipeline
from repro.kernels import lif_scan

PAPER = {
    "data_acquisition": (1.5, 0.006),
    "preprocessing": (131.0, 4.6),
    "snn_inference": (32.0, 1.4),
    "total": (164.5, 7.7),
}


def run(n_windows: int = 3, seed: int = 0):
    cfg = get_config("colibries")
    params = init_snn(jax.random.PRNGKey(seed), cfg)
    pipe = ClosedLoopPipeline(params, cfg,
                              lif_scan_fn=lif_scan)
    rng = np.random.default_rng(seed)
    rows = []
    t_wall = time.perf_counter()
    for i in range(n_windows):
        w = ev.synthetic_gesture_events(
            rng, int(rng.integers(0, 11)),
            mean_events=int(NOMINAL.events))
        res = pipe(w)
        rows.append(res)
    wall = time.perf_counter() - t_wall

    # aggregate modelled numbers across windows
    def stage(name, field):
        return float(np.mean([r.breakdown["stages"][name][field]
                              for r in rows]))

    out = []
    for name in ("data_acquisition", "preprocessing", "snn_inference"):
        t = stage(name, "time_ms")
        e = stage(name, "active_energy_mj")
        pt, pe = PAPER[name]
        out.append((name, t, e, pt, pe))
    tot_t = float(np.mean([r.latency_ms for r in rows]))
    tot_e = float(np.mean([r.energy_mj for r in rows]))
    out.append(("total", tot_t, tot_e, *PAPER["total"]))
    return out, rows, wall


def main():
    out, rows, wall = run()
    print("stage, model_time_ms, model_energy_mj, paper_time_ms, "
          "paper_energy_mj, ratio_t, ratio_e")
    for name, t, e, pt, pe in out:
        print(f"{name}, {t:.2f}, {e:.3f}, {pt}, {pe}, {t / pt:.2f}, "
              f"{e / pe:.2f}")
    print(f"# realtime (<=300ms window): "
          f"{all(r.realtime for r in rows)}; sustained "
          f"{rows[0].sustained_rate_hz:.2f} Hz; host wall {wall:.1f}s")


if __name__ == "__main__":
    main()
